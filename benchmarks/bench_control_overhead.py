"""§6.1 control-plane overheads, measured:

  * locality-aware placement at 10K clients — paper: < 17 ms;
  * one EWMA hierarchy estimate — paper: ~0.2 ms;
  * warm-executable-cache hit (aggregator reuse) vs a fresh jit compile
    (the JAX "cold start");
  * RoundDriver event dispatch (the typed-event hop every update/
    partial/crash now takes) vs the direct-call path it replaced — the
    gate is that one dispatch stays < 5% of a *warm* shmrt task
    dispatch, i.e. the event seam is control-plane noise.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EWMA, HierarchyPlanner, NodeState, place_updates
from repro.core.reuse import ExecutableCache

# acceptance gate (enforced by benchmarks/run.py): per-event driver
# dispatch overhead < this fraction of warm shmrt task-dispatch latency
DRIVER_DISPATCH_GATE_FRAC = 0.05

# acceptance gates (enforced by benchmarks/run.py): a from-scratch
# 10k-client placement must plan under PLACEMENT_GATE_MS (trending to
# the paper's 17 ms budget), and a steady-state replan — plan cache hit,
# incremental PlacementState — under INCREMENTAL_GATE_MS
PLACEMENT_GATE_MS = 50.0
INCREMENTAL_GATE_MS = 5.0


def _measure_warm_dispatch_s() -> float:
    """Warm task-dispatch latency (submit→ACK) of the multi-process
    runtime: one cold task to fork+park a worker, then a warm re-task."""
    from repro.runtime.shmrt import ShmRuntime

    n = 1 << 12
    u = np.ones(n, np.float32)
    with ShmRuntime() as rt:
        for rid in (1, 2):  # task 2 re-tasks the parked (warm) worker
            rt.submit_task("mid@bench", goal=1, n_elems=n, round_id=rid)
            rt.dispatch("mid@bench", rt.store.put(u), 1.0, round_id=rid)
            p = rt.collect(1)[0]
            rt.store.destroy(p.key)
        return float(rt.stats["warm_latency_s"])


def _measure_driver_dispatch_s(n_events: int = 20000) -> float:
    """Per-event cost of one RoundDriver dispatch hop (guards + handler
    fan-out), measured over a registered handler like the trainer's."""
    from repro.runtime.driver import RoundDriver
    from repro.runtime.events import UpdateArrived

    drv = RoundDriver()
    seen = []
    drv.on(UpdateArrived, lambda ev: seen.append(ev.weight))
    drv.begin_round(1)
    ev = UpdateArrived(round_id=1, client_id="c", node="n0",
                       agg_id="mid@n0", key="k" * 16, weight=1.0)
    t0 = time.perf_counter()
    for _ in range(n_events):
        drv.dispatch(ev)
    dt = time.perf_counter() - t0
    assert len(seen) == n_events
    return dt / n_events


def _bench_incremental_replan(n_nodes: int = 500,
                              n_clients: int = 10_000) -> Dict:
    """Steady-state ``Coordinator.plan_round`` wall with the plan cache
    warm: same cohort size every round, trivial sampler (selection cost
    is not the planner's), ``finish_round`` between rounds as the serve
    layer's rolling loop does.  Gated on the median of 20 rounds."""
    from repro.core import ClientInfo, Coordinator, RoundConfig, Selector

    nodes = {f"n{i}": NodeState(node=f"n{i}", max_capacity=25.0)
             for i in range(n_nodes)}
    clients = [ClientInfo(client_id=f"c{i}") for i in range(n_clients)]
    co = Coordinator(Selector(clients, seed=0), nodes,
                     planner=HierarchyPlanner(fan_in=25))
    cfg = RoundConfig(aggregation_goal=n_clients, over_provision=1.0,
                      fan_in=25)

    def sampler(rid, pool):
        return pool

    t0 = time.perf_counter()
    co.plan_round(cfg, sampler=sampler)
    cold = time.perf_counter() - t0
    co.finish_round()
    times = []
    for _ in range(20):
        t0 = time.perf_counter()
        co.plan_round(cfg, sampler=sampler)
        times.append(time.perf_counter() - t0)
        co.finish_round()
    med = sorted(times)[len(times) // 2]
    return {
        "bench": "control_overhead",
        "case": "placement_10k_incremental",
        "us_per_call": med * 1e6,
        "derived": f"ms={med*1e3:.3f};gate_ms={INCREMENTAL_GATE_MS:g};"
                   f"cold_ms={cold*1e3:.2f};"
                   f"hits={co.plan_cache_stats['hits']};"
                   f"misses={co.plan_cache_stats['misses']}",
    }


def _bench_deep_fold(n_nodes: int = 100, per_node: int = 2,
                     n_elems: int = 4096, fanout: int = 4) -> Dict:
    """Drive one 100-mid round twice through the runtime — the flat
    two-level plan vs a fanout-capped deep tree — on integer-valued
    f32 updates (exact under any fold grouping, so bit-equality is
    meaningful) and check the deltas match bit for bit.  The deep
    plan's cross-node partial traffic must stay within the same
    partials-only bound the flat plan is gated by."""
    from repro.core.placement import (
        build_fold_plan, partial_traffic_bound, plan_cross_node_transfers,
    )
    from repro.runtime.driver import InProcRuntime, RoundDriver

    assignment = {f"n{i:03d}": list(range(i * per_node, (i + 1) * per_node))
                  for i in range(n_nodes)}

    def run_plan(plan):
        rng = np.random.default_rng(11)
        ups = []
        for i in range(n_nodes):
            for j in range(per_node):
                flat = rng.integers(-32, 32, n_elems).astype(np.float32)
                ups.append((f"n{i:03d}", f"c{i}.{j}", flat, 1.0))
        rt = InProcRuntime()
        drv = RoundDriver(rt)
        t0 = time.perf_counter()
        out = drv.run_round(round_id=0, assignment=assignment, updates=ups,
                            goal=n_nodes * per_node, n_elems=n_elems,
                            fold_plan=plan)
        dt = time.perf_counter() - t0
        rt.close()
        return out, dt

    flat_plan = build_fold_plan(assignment, topology="worker")
    deep_plan = build_fold_plan(assignment, topology="worker",
                                fanout=fanout)
    flat_out, flat_s = run_plan(flat_plan)
    deep_out, deep_s = run_plan(deep_plan)
    bitexact = int(flat_out.delta is not None and deep_out.delta is not None
                   and np.array_equal(flat_out.delta, deep_out.delta))
    model_bytes = n_elems * 4
    partial_b = plan_cross_node_transfers(deep_plan) * model_bytes
    bound_b = partial_traffic_bound(n_nodes, model_bytes)
    return {
        "bench": "control_overhead",
        "case": "deep_fold_100node",
        "us_per_call": deep_s * 1e6,
        "derived": f"bitexact={bitexact};"
                   f"partial_mb={partial_b/1e6:.3f};"
                   f"bound_mb={bound_b/1e6:.3f};"
                   f"depth={deep_plan.depth};fanout={fanout};"
                   f"inners={len(deep_plan.inners)};"
                   f"flat_ms={flat_s*1e3:.1f};deep_ms={deep_s*1e3:.1f}",
    }


def run(fast: bool = True) -> List[Dict]:
    rows = []

    # placement @ 10K clients over 500 nodes
    nodes = {
        f"n{i}": NodeState(node=f"n{i}", max_capacity=25.0) for i in range(500)
    }
    t0 = time.perf_counter()
    p = place_updates(10_000, nodes, policy="bestfit")
    dt = time.perf_counter() - t0
    rows.append({
        "bench": "control_overhead",
        "case": "placement_10k_clients",
        "us_per_call": dt * 1e6,
        "derived": f"ms={dt*1e3:.2f};gate_ms={PLACEMENT_GATE_MS:g};"
                   f"paper_budget_ms=17;nodes_used={p.num_nodes_used}",
    })

    # steady-state delta replan: the coordinator's persistent
    # PlacementState + plan cache — round N+1 with an unchanged cohort
    # shape restamps round N's plan instead of replanning the pool
    rows.append(_bench_incremental_replan())

    # deep fold tree: 100 mids folded through log-depth fanout-capped
    # stages, bit-identical to the flat two-level root fold
    rows.append(_bench_deep_fold())

    # EWMA estimate
    e = EWMA(0.7)
    t0 = time.perf_counter()
    n = 1000
    for i in range(n):
        e.update(float(i % 37))
    dt = (time.perf_counter() - t0) / n
    rows.append({
        "bench": "control_overhead",
        "case": "ewma_estimate",
        "us_per_call": dt * 1e6,
        "derived": f"ms={dt*1e3:.4f};paper_budget_ms=0.2",
    })

    # hierarchy plan for 100 nodes
    planner = HierarchyPlanner()
    t0 = time.perf_counter()
    planner.plan({f"n{i}": float(i % 30) for i in range(100)})
    dt = time.perf_counter() - t0
    rows.append({
        "bench": "control_overhead",
        "case": "hierarchy_plan_100_nodes",
        "us_per_call": dt * 1e6,
        "derived": f"ms={dt*1e3:.3f}",
    })

    # cold start (jit compile) vs warm executable reuse — LIFL C8
    def build(**sig):
        n = sig["n"]
        return jax.jit(lambda a, u, w: a + w * u).lower(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        ).compile()

    cache = ExecutableCache(build)
    t0 = time.perf_counter()
    cache.get(n=1 << 20)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    cache.get(n=1 << 20)
    warm = time.perf_counter() - t0
    rows.append({
        "bench": "control_overhead",
        "case": "executable_cold_vs_warm",
        "us_per_call": cold * 1e6,
        "derived": f"cold_ms={cold*1e3:.1f};warm_us={warm*1e6:.1f};"
                   f"speedup={cold/max(warm,1e-9):.0f}x",
    })

    # RoundDriver event dispatch vs the PR-2 direct-call path: the seam
    # must cost a negligible slice of even a *warm* task dispatch
    per_event = _measure_driver_dispatch_s()
    if os.path.isdir("/dev/shm"):
        warm_disp = _measure_warm_dispatch_s()
        frac = per_event / warm_disp if warm_disp > 0 else float("nan")
        derived = (f"events_per_s={1.0 / per_event:.0f};"
                   f"warm_dispatch_us={warm_disp * 1e6:.1f};"
                   f"overhead_frac={frac:.5f};"
                   f"gate_frac={DRIVER_DISPATCH_GATE_FRAC}")
    else:
        derived = (f"events_per_s={1.0 / per_event:.0f};"
                   f"warm_dispatch_us=nan;overhead_frac=nan;"
                   f"gate_frac={DRIVER_DISPATCH_GATE_FRAC} (no /dev/shm)")
    rows.append({
        "bench": "control_overhead",
        "case": "driver_dispatch",
        "us_per_call": per_event * 1e6,
        "derived": derived,
    })
    return rows
