"""§6.1 control-plane overheads, measured:

  * locality-aware placement at 10K clients — paper: < 17 ms;
  * one EWMA hierarchy estimate — paper: ~0.2 ms;
  * warm-executable-cache hit (aggregator reuse) vs a fresh jit compile
    (the JAX "cold start");
  * RoundDriver event dispatch (the typed-event hop every update/
    partial/crash now takes) vs the direct-call path it replaced — the
    gate is that one dispatch stays < 5% of a *warm* shmrt task
    dispatch, i.e. the event seam is control-plane noise.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EWMA, HierarchyPlanner, NodeState, place_updates
from repro.core.reuse import ExecutableCache

# acceptance gate (enforced by benchmarks/run.py): per-event driver
# dispatch overhead < this fraction of warm shmrt task-dispatch latency
DRIVER_DISPATCH_GATE_FRAC = 0.05


def _measure_warm_dispatch_s() -> float:
    """Warm task-dispatch latency (submit→ACK) of the multi-process
    runtime: one cold task to fork+park a worker, then a warm re-task."""
    from repro.runtime.shmrt import ShmRuntime

    n = 1 << 12
    u = np.ones(n, np.float32)
    with ShmRuntime() as rt:
        for rid in (1, 2):  # task 2 re-tasks the parked (warm) worker
            rt.submit_task("mid@bench", goal=1, n_elems=n, round_id=rid)
            rt.dispatch("mid@bench", rt.store.put(u), 1.0, round_id=rid)
            p = rt.collect(1)[0]
            rt.store.destroy(p.key)
        return float(rt.stats["warm_latency_s"])


def _measure_driver_dispatch_s(n_events: int = 20000) -> float:
    """Per-event cost of one RoundDriver dispatch hop (guards + handler
    fan-out), measured over a registered handler like the trainer's."""
    from repro.runtime.driver import RoundDriver
    from repro.runtime.events import UpdateArrived

    drv = RoundDriver()
    seen = []
    drv.on(UpdateArrived, lambda ev: seen.append(ev.weight))
    drv.begin_round(1)
    ev = UpdateArrived(round_id=1, client_id="c", node="n0",
                       agg_id="mid@n0", key="k" * 16, weight=1.0)
    t0 = time.perf_counter()
    for _ in range(n_events):
        drv.dispatch(ev)
    dt = time.perf_counter() - t0
    assert len(seen) == n_events
    return dt / n_events


def run(fast: bool = True) -> List[Dict]:
    rows = []

    # placement @ 10K clients over 500 nodes
    nodes = {
        f"n{i}": NodeState(node=f"n{i}", max_capacity=25.0) for i in range(500)
    }
    t0 = time.perf_counter()
    p = place_updates(10_000, nodes, policy="bestfit")
    dt = time.perf_counter() - t0
    rows.append({
        "bench": "control_overhead",
        "case": "placement_10k_clients",
        "us_per_call": dt * 1e6,
        "derived": f"ms={dt*1e3:.2f};paper_budget_ms=17;nodes_used={p.num_nodes_used}",
    })

    # EWMA estimate
    e = EWMA(0.7)
    t0 = time.perf_counter()
    n = 1000
    for i in range(n):
        e.update(float(i % 37))
    dt = (time.perf_counter() - t0) / n
    rows.append({
        "bench": "control_overhead",
        "case": "ewma_estimate",
        "us_per_call": dt * 1e6,
        "derived": f"ms={dt*1e3:.4f};paper_budget_ms=0.2",
    })

    # hierarchy plan for 100 nodes
    planner = HierarchyPlanner()
    t0 = time.perf_counter()
    planner.plan({f"n{i}": float(i % 30) for i in range(100)})
    dt = time.perf_counter() - t0
    rows.append({
        "bench": "control_overhead",
        "case": "hierarchy_plan_100_nodes",
        "us_per_call": dt * 1e6,
        "derived": f"ms={dt*1e3:.3f}",
    })

    # cold start (jit compile) vs warm executable reuse — LIFL C8
    def build(**sig):
        n = sig["n"]
        return jax.jit(lambda a, u, w: a + w * u).lower(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        ).compile()

    cache = ExecutableCache(build)
    t0 = time.perf_counter()
    cache.get(n=1 << 20)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    cache.get(n=1 << 20)
    warm = time.perf_counter() - t0
    rows.append({
        "bench": "control_overhead",
        "case": "executable_cold_vs_warm",
        "us_per_call": cold * 1e6,
        "derived": f"cold_ms={cold*1e3:.1f};warm_us={warm*1e6:.1f};"
                   f"speedup={cold/max(warm,1e-9):.0f}x",
    })

    # RoundDriver event dispatch vs the PR-2 direct-call path: the seam
    # must cost a negligible slice of even a *warm* task dispatch
    per_event = _measure_driver_dispatch_s()
    if os.path.isdir("/dev/shm"):
        warm_disp = _measure_warm_dispatch_s()
        frac = per_event / warm_disp if warm_disp > 0 else float("nan")
        derived = (f"events_per_s={1.0 / per_event:.0f};"
                   f"warm_dispatch_us={warm_disp * 1e6:.1f};"
                   f"overhead_frac={frac:.5f};"
                   f"gate_frac={DRIVER_DISPATCH_GATE_FRAC}")
    else:
        derived = (f"events_per_s={1.0 / per_event:.0f};"
                   f"warm_dispatch_us=nan;overhead_frac=nan;"
                   f"gate_frac={DRIVER_DISPATCH_GATE_FRAC} (no /dev/shm)")
    rows.append({
        "bench": "control_overhead",
        "case": "driver_dispatch",
        "us_per_call": per_event * 1e6,
        "derived": derived,
    })
    return rows
