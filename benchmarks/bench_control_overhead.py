"""§6.1 control-plane overheads, measured:

  * locality-aware placement at 10K clients — paper: < 17 ms;
  * one EWMA hierarchy estimate — paper: ~0.2 ms;
  * warm-executable-cache hit (aggregator reuse) vs a fresh jit compile
    (the JAX "cold start").
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EWMA, HierarchyPlanner, NodeState, place_updates
from repro.core.reuse import ExecutableCache


def run(fast: bool = True) -> List[Dict]:
    rows = []

    # placement @ 10K clients over 500 nodes
    nodes = {
        f"n{i}": NodeState(node=f"n{i}", max_capacity=25.0) for i in range(500)
    }
    t0 = time.perf_counter()
    p = place_updates(10_000, nodes, policy="bestfit")
    dt = time.perf_counter() - t0
    rows.append({
        "bench": "control_overhead",
        "case": "placement_10k_clients",
        "us_per_call": dt * 1e6,
        "derived": f"ms={dt*1e3:.2f};paper_budget_ms=17;nodes_used={p.num_nodes_used}",
    })

    # EWMA estimate
    e = EWMA(0.7)
    t0 = time.perf_counter()
    n = 1000
    for i in range(n):
        e.update(float(i % 37))
    dt = (time.perf_counter() - t0) / n
    rows.append({
        "bench": "control_overhead",
        "case": "ewma_estimate",
        "us_per_call": dt * 1e6,
        "derived": f"ms={dt*1e3:.4f};paper_budget_ms=0.2",
    })

    # hierarchy plan for 100 nodes
    planner = HierarchyPlanner()
    t0 = time.perf_counter()
    planner.plan({f"n{i}": float(i % 30) for i in range(100)})
    dt = time.perf_counter() - t0
    rows.append({
        "bench": "control_overhead",
        "case": "hierarchy_plan_100_nodes",
        "us_per_call": dt * 1e6,
        "derived": f"ms={dt*1e3:.3f}",
    })

    # cold start (jit compile) vs warm executable reuse — LIFL C8
    def build(**sig):
        n = sig["n"]
        return jax.jit(lambda a, u, w: a + w * u).lower(
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        ).compile()

    cache = ExecutableCache(build)
    t0 = time.perf_counter()
    cache.get(n=1 << 20)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    cache.get(n=1 << 20)
    warm = time.perf_counter() - t0
    rows.append({
        "bench": "control_overhead",
        "case": "executable_cold_vs_warm",
        "us_per_call": cold * 1e6,
        "derived": f"cold_ms={cold*1e3:.1f};warm_us={warm*1e6:.1f};"
                   f"speedup={cold/max(warm,1e-9):.0f}x",
    })
    return rows
