"""Fig 7(a,b): latency + CPU of a single intra-node model-update
transfer, REAL measurements of the three data planes:

  LIFL — write once into the shared-memory object store, consumer maps a
         zero-copy view (+ the fold touching the data once);
  SF   — serverful gRPC-style: serialize → socketpair → deserialize
         (one copy chain, no broker);
  SL   — serverless: sidecar hop + message broker hop, each a
         serialize/copy/deserialize through a local socket (the Fig 5
         "basic serverless" pipeline: client → sidecar → broker →
         sidecar → aggregator).

Model sizes match the paper: ResNet-18 ≈ 44 MB, ResNet-34 ≈ 83 MB,
ResNet-152 ≈ 232 MB (fp32).

The ``fold_*`` rows report old-vs-new fold throughput (GB/s) through
the engine layer (core/engine.py) side by side on the same zero-copy
shared-memory views: the seed's naive scalar fold vs the blocked
in-place fold that ``Aggregator`` now drives.  They run as a separate
pass after the transfer measurements — the transfer probe's consume
stays the seed's single read pass, because this kernel's tmpfs
page-fault cost is highly sensitive to resident heap state and the
ordering claims (SF ≈ 3× LIFL) must stay comparable across PRs.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Dict, List, Tuple

import numpy as np

from benchmarks.engine_probe import fold_gbps
from repro.core.engine import make_engine
from repro.core.gateway import deserialize_update, serialize_update
from repro.core.objectstore import SharedMemoryObjectStore

SIZES = {
    "resnet18": 44 * 1024 * 1024 // 4,
    "resnet34": 83 * 1024 * 1024 // 4,
    "resnet152": 232 * 1024 * 1024 // 4,
}


def _socket_transfer(payload: bytes) -> bytes:
    """One hop through a local socketpair (kernel networking path)."""
    a, b = socket.socketpair()
    received = bytearray()

    def rx():
        while len(received) < len(payload):
            chunk = b.recv(1 << 20)
            if not chunk:
                break
            received.extend(chunk)

    t = threading.Thread(target=rx)
    t.start()
    view = memoryview(payload)
    sent = 0
    while sent < len(payload):
        sent += a.send(view[sent : sent + (1 << 20)])
    a.shutdown(socket.SHUT_WR)
    t.join()
    a.close()
    b.close()
    return bytes(received)


def _consume(update: np.ndarray) -> float:
    """The aggregator's fold (touch every element once)."""
    return float(update.sum())


def transfer_lifl(update: np.ndarray, store: SharedMemoryObjectStore) -> Tuple[float, float]:
    t0 = time.perf_counter()
    c0 = time.process_time()
    key = store.put(update)               # gateway's one-time write
    view = store.get(key)                 # zero-copy consume
    _consume(view)
    dt = time.perf_counter() - t0
    ct = time.process_time() - c0
    store.delete(key)
    return dt, ct


def transfer_serverful(update: np.ndarray) -> Tuple[float, float]:
    t0 = time.perf_counter()
    c0 = time.process_time()
    payload = serialize_update(update, {"num_samples": 1})
    raw = _socket_transfer(payload)       # direct channel (gRPC analogue)
    out, _ = deserialize_update(raw)
    _consume(out)
    return time.perf_counter() - t0, time.process_time() - c0


def transfer_serverless(update: np.ndarray) -> Tuple[float, float]:
    t0 = time.perf_counter()
    c0 = time.process_time()
    payload = serialize_update(update, {"num_samples": 1})
    hop1 = _socket_transfer(payload)      # -> sidecar
    hop2 = _socket_transfer(hop1)         # sidecar -> broker (queued copy)
    queued = bytes(hop2)                  # broker buffers the message
    hop3 = _socket_transfer(queued)       # broker -> consumer sidecar
    out, _ = deserialize_update(hop3)
    _consume(out)
    return time.perf_counter() - t0, time.process_time() - c0


def run(fast: bool = True) -> List[Dict]:
    rows = []
    rng = np.random.default_rng(0)
    sizes = dict(SIZES)
    if fast:
        sizes = {k: v // 8 for k, v in sizes.items()}  # scale, same ordering
    def _grow_ballast(nbytes: int) -> bytearray:
        # Pin the kernel's tmpfs fault path in its warm regime: holding
        # a live, incrementally-grown heap buffer >= the payload makes
        # shm page faults ~5x faster on this kernel (measured: lifl put
        # 250-400 ms cold vs ~52 ms warm — a per-process lottery without
        # it that randomly flips the Fig-7 ordering claim).  The warm
        # state decays as the serverful/serverless paths churn the heap,
        # so it is re-grown per size.  All systems are then measured in
        # the same warm regime — also the steady state of a long-lived
        # gateway process.
        b = bytearray()
        for _ in range(nbytes // (1 << 20) + 2):
            b.extend(b"\0" * (1 << 20))
        return b

    with SharedMemoryObjectStore(capacity_bytes=1 << 31) as store:
        updates = {}  # kept live through both passes (part of the ballast)
        for name, n in sizes.items():
            update = updates[name] = rng.normal(size=(n,)).astype(np.float32)
            ballast = _grow_ballast(update.nbytes)
            reps = 3 if n < 30_000_000 else 1
            for label, fn in (
                ("lifl", lambda u: transfer_lifl(u, store)),
                ("serverful", transfer_serverful),
                ("serverless", transfer_serverless),
            ):
                lat = cpu = 0.0
                for _ in range(reps):
                    l, c = fn(update)
                    lat += l / reps
                    cpu += c / reps
                rows.append({
                    "bench": "dataplane_fig7",
                    "case": f"{name}/{label}",
                    "us_per_call": lat * 1e6,
                    "derived": f"cpu_s={cpu:.4f};mbytes={n*4/1e6:.0f}",
                })
        # old-vs-new fold throughput on the same zero-copy views — a
        # separate pass AFTER all transfer rows so the big naive-fold
        # temporaries can't perturb the transfer measurements above
        engines = {"fold_naive": make_engine("naive"),
                   "fold_blocked": make_engine("blocked")}
        for name, n in sizes.items():
            key = store.put(updates[name])
            view = store.get(key)
            gb = view.nbytes / 1e9
            folds = {}
            for eng_label, eng in engines.items():
                gbps, dt = fold_gbps(eng, view)
                folds[eng_label] = gbps
                rows.append({
                    "bench": "dataplane_fig7",
                    "case": f"{name}/{eng_label}",
                    "us_per_call": dt * 1e6,
                    "derived": (f"fold_gbps={gbps:.2f};"
                                f"mbytes={n*4/1e6:.0f}"),
                })
            rows.append({
                "bench": "dataplane_fig7",
                "case": f"{name}/fold_speedup",
                "us_per_call": 0.0,
                "derived": (f"blocked_over_naive="
                            f"{folds['fold_blocked']/folds['fold_naive']:.2f}x"),
            })
            store.delete(key)
    # headline ratios (paper: SL ≈ 6× LIFL, SF ≈ 3× LIFL on ResNet-152)
    lifl = next(r for r in rows if r["case"].endswith("resnet152/lifl") or r["case"] == "resnet152/lifl")
    sf = next(r for r in rows if r["case"] == "resnet152/serverful")
    sl = next(r for r in rows if r["case"] == "resnet152/serverless")
    rows.append({
        "bench": "dataplane_fig7",
        "case": "resnet152/speedup",
        "us_per_call": 0.0,
        "derived": (f"sf_over_lifl={sf['us_per_call']/lifl['us_per_call']:.2f}x;"
                    f"sl_over_lifl={sl['us_per_call']/lifl['us_per_call']:.2f}x"),
    })
    return rows
