"""Benchmark harness — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract).  ``--full``
uses paper-scale payloads (232 MB updates); default is a fast mode with
scaled payloads that preserves every ordering/ratio claim.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only name]
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    fast = not args.full

    from benchmarks import (
        bench_agg_kernel,
        bench_control_overhead,
        bench_dataplane,
        bench_hierarchy,
        bench_orchestration,
        bench_queuing,
        bench_tta,
    )

    suites = {
        "dataplane_fig7": bench_dataplane.run,
        "queuing_fig13": bench_queuing.run,
        "hierarchy_fig4": bench_hierarchy.run,
        "orchestration_fig8": bench_orchestration.run,
        "control_overhead": bench_control_overhead.run,
        "agg_kernel": bench_agg_kernel.run,
        "tta_fig9": bench_tta.run,
    }
    if args.only:
        suites = {k: v for k, v in suites.items() if args.only in k}

    print("name,us_per_call,derived")
    for name, fn in suites.items():
        t0 = time.time()
        try:
            rows = fn(fast=fast)
        except Exception as e:  # a failed suite must not hide the others
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            continue
        for r in rows:
            print(f"{r['bench']}/{r['case']},{r['us_per_call']:.1f},"
                  f"{r['derived']}", flush=True)
        print(f"# {name} took {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
