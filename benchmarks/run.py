"""Benchmark harness — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract).  ``--full``
uses paper-scale payloads (232 MB updates); default is a fast mode with
scaled payloads that preserves every ordering/ratio claim.

``--json PATH`` additionally writes the agg-kernel + dataplane rows
(the perf-trajectory benchmarks: fold GB/s old vs new) as a JSON list,
so future PRs have a baseline to regress against (see BENCH_agg.json).

  PYTHONPATH=src python -m benchmarks.run [--full] [--only name]
                                          [--json BENCH_agg.json]
"""
import argparse
import json
import os
import sys
import time

# suites whose rows land in the --json perf-trajectory file
JSON_SUITES = ("agg_kernel", "dataplane_fig7", "shmrt", "control_overhead",
               "net", "obs", "serve", "soak")

# PR-1 acceptance floor: blocked fold ≥ 2× naive.  A regression here
# silently rots every throughput claim downstream, so the harness fails
# loudly instead of recording the bad rows.
ENGINE_FOLD_FLOOR = 2.0

# Every gate check stamps its verdict into the row (``gates:
# {name: pass|fail}``) and RETURNS failure messages instead of exiting,
# so a failing run still writes the JSON rows — with the verdicts — and
# exits FATAL afterwards (main collects the messages).


def _stamp(r, gate: str, ok: bool) -> bool:
    r.setdefault("gates", {})[gate] = "pass" if ok else "fail"
    return ok


def _check_engine_fold_floor(rows) -> list:
    """Parse engine_fold_* speedups out of the agg_kernel rows and fail
    the run if the blocked/naive ratio fell below the PR-1 floor."""
    import re

    fails = []
    for r in rows:
        if r["bench"] != "agg_kernel" or "speedup_blocked" not in r["derived"]:
            continue
        m = re.search(r"speedup_blocked=([\d.]+)x", r["derived"])
        if m and not _stamp(r, "engine_fold_floor",
                            float(m.group(1)) >= ENGINE_FOLD_FLOOR):
            fails.append(
                f"FATAL: engine_fold regression — blocked/naive = "
                f"{m.group(1)}x < {ENGINE_FOLD_FLOOR}x floor "
                f"(row {r['case']!r}; see ROADMAP.md perf trajectory)")
    return fails


def _check_driver_dispatch_gate(rows) -> list:
    """PR-3 acceptance gate: one RoundDriver event dispatch must stay
    under 5% of a warm shmrt task dispatch (the event seam is free
    relative to the cheapest real control-plane action it mediates)."""
    import re

    fails = []
    for r in rows:
        if r["case"] != "driver_dispatch":
            continue
        m = re.search(r"overhead_frac=([\d.]+)", r["derived"])
        g = re.search(r"gate_frac=([\d.]+)", r["derived"])
        if m and g and not _stamp(r, "driver_dispatch",
                                  float(m.group(1)) < float(g.group(1))):
            fails.append(
                f"FATAL: driver dispatch overhead regression — "
                f"{float(m.group(1)):.4f} ≥ {g.group(1)} of warm shmrt "
                f"dispatch (row {r['case']!r}; see ROADMAP.md)")
    return fails


def _check_placement_gate(rows) -> list:
    """PR-10 acceptance gates: a from-scratch 10k-client placement must
    plan under its gate (trending toward the paper's 17 ms budget), a
    steady-state delta replan (plan cache + incremental PlacementState)
    under its own much tighter gate, and the deep fold tree must stay
    bit-identical to the flat plan with partials-only traffic."""
    import re

    fails = []
    for r in rows:
        if r["bench"] != "control_overhead":
            continue
        if r["case"] in ("placement_10k_clients",
                         "placement_10k_incremental"):
            m = re.search(r"\bms=([\d.]+);gate_ms=([\d.]+)", r["derived"])
            if m and not _stamp(r, "placement_budget",
                                float(m.group(1)) <= float(m.group(2))):
                fails.append(
                    f"FATAL: control-plane planning regression — "
                    f"{m.group(1)} ms > {m.group(2)} ms gate "
                    f"(row {r['case']!r}; see ROADMAP.md)")
        if r["case"] == "deep_fold_100node":
            b = re.search(r"bitexact=(\d)", r["derived"])
            if b and not _stamp(r, "deep_fold_bitexact", b.group(1) == "1"):
                fails.append(
                    "FATAL: deep fold tree is not bit-identical to the "
                    f"two-level plan (row {r['case']!r})")
            m = re.search(r"partial_mb=([\d.]+);bound_mb=([\d.]+)",
                          r["derived"])
            if m and not _stamp(r, "deep_fold_traffic",
                                float(m.group(1)) <= float(m.group(2))):
                fails.append(
                    f"FATAL: deep fold cross-node traffic "
                    f"{m.group(1)} MB/round > partials-only bound "
                    f"{m.group(2)} MB (row {r['case']!r})")
    return fails


def _check_net_traffic_gate(rows) -> list:
    """PR-4/PR-5 acceptance gates: cross-node aggregation traffic per
    round must stay partials-only — ≤ nodes × model_size × 1.1 (this
    bound now also covers daemon→daemon shipping) — and a node-top
    round must return ≤ 1 × model × 1.1 to the controller: more means
    partials are coming home instead of folding on the root node."""
    import re

    fails = []
    for r in rows:
        if r["bench"] != "net":
            continue
        m = re.search(r"partial_mb=([\d.]+);bound_mb=([\d.]+)", r["derived"])
        if m and not _stamp(r, "net_partials_only",
                            float(m.group(1)) <= float(m.group(2))):
            fails.append(
                f"FATAL: cross-node traffic regression — partial payloads "
                f"{m.group(1)} MB/round > partials-only bound "
                f"{m.group(2)} MB (row {r['case']!r}; see ROADMAP.md)")
        g = re.search(r"return_mb=([\d.]+);return_bound_mb=([\d.]+)",
                      r["derived"])
        if g and not _stamp(r, "net_return_traffic",
                            float(g.group(1)) <= float(g.group(2))):
            fails.append(
                f"FATAL: node-top return-traffic regression — "
                f"{g.group(1)} MB/round came back to the controller > "
                f"1 × model bound {g.group(2)} MB (row {r['case']!r}; "
                f"see ROADMAP.md)")
        b = re.search(r"bitexact=(\d)", r["derived"])
        if b and not _stamp(r, "net_bitexact", b.group(1) == "1"):
            fails.append(
                f"FATAL: cross-node round is not bit-identical to the "
                f"single-node tree (row {r['case']!r})")
    return fails


def _check_obs_overhead_gate(rows) -> list:
    """Tracing must be control-plane noise: a fully-traced warm shmproc
    round ≤ 2% over the untraced round (the obs layer's event-edge-only
    contract, paper §4.3)."""
    import re

    fails = []
    for r in rows:
        if r["bench"] != "obs" or "obs_overhead_frac" not in r["derived"]:
            continue
        m = re.search(r"obs_overhead_frac=([\d.]+)", r["derived"])
        g = re.search(r"gate_frac=([\d.]+)", r["derived"])
        if m and g and not _stamp(r, "obs_overhead",
                                  float(m.group(1)) < float(g.group(1))):
            fails.append(
                f"FATAL: tracing overhead regression — traced round is "
                f"{float(m.group(1)):.4f} over untraced ≥ {g.group(1)} "
                f"gate (row {r['case']!r}; see ROADMAP.md)")
    return fails


def _check_serve_gate(rows) -> list:
    """PR-8 acceptance gates: the continuous service must stay on the
    library's arithmetic — every rolling round bit-identical to its
    cohort replayed sequentially (``bitexact=1``) — and the rolling
    seam must actually overlap round windows (``pipeline_overlap > 0``;
    0 means rounds ran strictly sequentially and the second in-flight
    round never opened)."""
    import re

    fails = []
    for r in rows:
        if r["bench"] != "serve" or r["case"] != "rolling":
            continue
        b = re.search(r"bitexact=(\d)", r["derived"])
        if b and not _stamp(r, "serve_bitexact", b.group(1) == "1"):
            fails.append(
                "FATAL: rolling rounds drifted from the sequential "
                f"run_round path (row {r['case']!r}; see ROADMAP.md)")
        m = re.search(r"pipeline_overlap=([\d.]+)", r["derived"])
        if m and not _stamp(r, "serve_overlap", float(m.group(1)) > 0.0):
            fails.append(
                "FATAL: pipeline_overlap=0 — round N+1 never opened "
                f"during round N's fold (row {r['case']!r})")
    return fails


def _check_net_leak_gate(rows) -> list:
    """PR-8 hygiene gate: the recovery row's /dev/shm leak check —
    after SIGKILL + re-adoption + reap, zero ``lifl*`` segments may
    outlive the bench (``leaked_segs=0``)."""
    import re

    fails = []
    for r in rows:
        if r["bench"] != "net" or "leaked_segs" not in r["derived"]:
            continue
        m = re.search(r"leaked_segs=(\d+)", r["derived"])
        if m and not _stamp(r, "net_shm_leak", m.group(1) == "0"):
            fails.append(
                f"FATAL: /dev/shm leak — {m.group(1)} lifl segment(s) "
                f"survived daemon SIGKILL + reap (row {r['case']!r})")
    return fails


def _check_soak_gate(rows) -> list:
    """PR-9 acceptance gates: the rolling soak must hold the library's
    arithmetic over minutes of overlap (``soak_bitexact=1``) and the
    live scrape loop must stay invisible — total scrape wall under 2%
    of the soak's wall clock (``scrape_overhead_frac < 0.02``)."""
    import re

    fails = []
    for r in rows:
        if r["bench"] != "soak" or r["case"] != "fleet":
            continue
        b = re.search(r"soak_bitexact=(\d)", r["derived"])
        if b and not _stamp(r, "soak_bitexact", b.group(1) == "1"):
            fails.append(
                "FATAL: soak rounds drifted from the sequential "
                f"run_round path (row {r['case']!r}; see ROADMAP.md)")
        m = re.search(r"scrape_overhead_frac=([\d.]+)", r["derived"])
        if m and not _stamp(r, "soak_scrape_overhead",
                            float(m.group(1)) < 0.02):
            fails.append(
                f"FATAL: live-scrape overhead regression — "
                f"{m.group(1)} of soak wall ≥ 0.02 gate "
                f"(row {r['case']!r}; see ROADMAP.md)")
    return fails


def _print_gate_table(rows) -> None:
    """One verdict line per stamped gate, after all suites ran — the
    at-a-glance answer to 'which acceptance bars did this run clear'."""
    stamped = [(r["bench"], r["case"], g, v)
               for r in rows for g, v in r.get("gates", {}).items()]
    if not stamped:
        return
    print("# gate verdicts:", file=sys.stderr)
    w = max(len(f"{b}/{c}") for b, c, _g, _v in stamped)
    for b, c, g, v in stamped:
        print(f"#   {f'{b}/{c}':<{w}}  {g:<22} {v.upper()}",
              file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--fast", action="store_true",
                    help="CI profile: trims the long-soak/net iteration "
                         "counts (suites taking minutes drop to seconds) "
                         "while still stamping and printing every gate "
                         "verdict; do NOT regenerate BENCH_agg.json in "
                         "this mode")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write agg-kernel + dataplane rows to PATH as JSON")
    args = ap.parse_args()
    if args.full and args.fast:
        ap.error("--full and --fast are mutually exclusive")
    fast = not args.full
    if args.json:  # fail on an unwritable path now, not after the run —
        # without creating an empty file a no-row run would leave behind
        target = args.json if os.path.exists(args.json) else (
            os.path.dirname(os.path.abspath(args.json)))
        if not os.access(target, os.W_OK):
            ap.error(f"--json path not writable: {args.json}")

    from benchmarks import (
        bench_agg_kernel,
        bench_control_overhead,
        bench_dataplane,
        bench_hierarchy,
        bench_net,
        bench_obs,
        bench_orchestration,
        bench_queuing,
        bench_serve,
        bench_shmrt,
        bench_soak,
        bench_tta,
    )

    suites = {
        "dataplane_fig7": bench_dataplane.run,
        "queuing_fig13": bench_queuing.run,
        "hierarchy_fig4": bench_hierarchy.run,
        "orchestration_fig8": bench_orchestration.run,
        "control_overhead": bench_control_overhead.run,
        "agg_kernel": bench_agg_kernel.run,
        "shmrt": bench_shmrt.run,
        "net": bench_net.run,
        "obs": bench_obs.run,
        "serve": bench_serve.run,
        "soak": bench_soak.run,
        "tta_fig9": bench_tta.run,
    }
    if args.only:
        suites = {k: v for k, v in suites.items() if args.only in k}

    gate_checks = {
        "agg_kernel": _check_engine_fold_floor,
        "control_overhead": lambda rows: (_check_driver_dispatch_gate(rows)
                                          + _check_placement_gate(rows)),
        "net": lambda rows: (_check_net_traffic_gate(rows)
                             + _check_net_leak_gate(rows)),
        "obs": _check_obs_overhead_gate,
        "serve": _check_serve_gate,
        "soak": _check_soak_gate,
    }
    all_rows: list = []
    json_rows = []
    fatal: list = []
    print("name,us_per_call,derived")
    import inspect

    for name, fn in suites.items():
        t0 = time.time()
        kwargs = {"fast": fast}
        if args.fast and "profile" in inspect.signature(fn).parameters:
            kwargs["profile"] = "ci"   # suites that support extra trimming
        try:
            rows = fn(**kwargs)
        except Exception as e:  # a failed suite must not hide the others
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            continue
        check = gate_checks.get(name)
        if check is not None:
            fatal.extend(check(rows))
        for r in rows:
            print(f"{r['bench']}/{r['case']},{r['us_per_call']:.1f},"
                  f"{r['derived']}", flush=True)
        all_rows.extend(rows)
        if name in JSON_SUITES:
            json_rows.extend(rows)
        print(f"# {name} took {time.time()-t0:.1f}s", file=sys.stderr)

    _print_gate_table(all_rows)

    if args.json:
        if json_rows:
            # every row carries its gate verdicts (possibly empty) so
            # the baseline records what was checked, not just measured
            for r in json_rows:
                r.setdefault("gates", {})
            with open(args.json, "w") as f:
                json.dump({"mode": ("full" if args.full
                                    else "ci" if args.fast else "fast"),
                           "rows": json_rows}, f, indent=2)
            print(f"# wrote {len(json_rows)} rows to {args.json}",
                  file=sys.stderr)
        else:
            # never clobber an existing perf baseline with an empty run
            # (e.g. --only filtered out both JSON suites)
            print(f"# no {'/'.join(JSON_SUITES)} rows produced; "
                  f"left {args.json} untouched", file=sys.stderr)

    if fatal:
        # verdicts are stamped and the JSON is on disk — NOW fail loudly
        sys.exit("\n".join(fatal))


if __name__ == "__main__":
    main()
