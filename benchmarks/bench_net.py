"""Cross-node transport end-to-end (the netrt runtime).

One "round" = the full 2-node hierarchy over loopback sockets: two
``netd`` daemons (each owning its own shm — or in-proc, when /dev/shm
is absent — runtime) aggregate their subtrees, the controller-side
``RoundDriver`` folds the two sealed partials.  Compared against the
same round on the single-node runtimes:

  * ``inproc``        — PR-3 single-process tree (the byte-identical
    reference every multi-node claim is judged against);
  * ``net 2-node``    — cold (daemon fork + connect + first round) and
    warm (parked daemons re-tasked) cross-node rounds.

Derived columns carry the acceptance-gate numbers:

  * ``bitexact``      — the cross-node delta equals the in-proc tree
    bit for bit (raw f32 partials, deterministic top-fold order);
  * ``partial_mb``    — cross-node aggregation traffic per round
    (``object``-frame bytes: the fetched Σc·u payloads; plus
    daemon→daemon ``ship_mb`` for node-top rounds), gated by
    ``run.py`` against ``bound_mb = nodes × model_size × 1.1`` —
    partials only, no per-client fan-in to the top;
  * ``return_mb``     — node-top rounds only: what actually returns to
    the controller — ONE folded Σc·u, gated fatally against
    ``return_bound_mb = 1 × model_size × 1.1`` (the daemon→daemon
    shipping win: controller-top returns nodes × model instead);
  * ``wire_mb``       — total wire bytes/round, both directions (the
    update fan-out to the nodes rides this, not the partial bound);
  * ``disp_us``       — mean remote dispatch latency (one ``deliver``
    frame incl. the serialize-once payload), ``rtt_us`` — frame RTT.
"""
from __future__ import annotations

import os
import subprocess
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.bench_shmrt import G, _mk_updates

N_NODES = 2
SLACK = 1.1


def _net_round(drv, rt, nodes: List[str], ups, ws, N: int, round_id: int,
               topology: str = "controller", kill=None
               ) -> Tuple[np.ndarray, float, float]:
    """One driven cross-node round; returns (delta, wall_s, disp_s).
    ``kill=(idx, fn)`` calls ``fn`` right after update ``idx`` is
    delivered — the recovery row's mid-round daemon restart."""
    from repro.core.placement import build_fold_plan

    W = len(ups)
    assignment = {nodes[w % N_NODES]: [] for w in range(N_NODES)}
    flat_ups, flat_ws, flat_nodes = [], [], []
    for w in range(W):
        node = nodes[w % N_NODES]
        for u, c in zip(ups[w], ws[w]):
            assignment[node].append(len(flat_ups))
            flat_ups.append(u)
            flat_ws.append(c)
            flat_nodes.append(node)
    fold_plan = build_fold_plan(assignment, top_node=nodes[0],
                                topology=topology)

    disp = [0.0, 0]

    def updates():
        for i, (u, c) in enumerate(zip(flat_ups, flat_ws)):
            yield flat_nodes[i], f"c{i}", u, c
            if kill is not None and i == kill[0]:
                kill[1]()

    # instrument deliver to get per-dispatch latency without new code
    orig = rt.deliver

    def timed_deliver(*a, **k):
        t0 = time.perf_counter()
        orig(*a, **k)
        disp[0] += time.perf_counter() - t0
        disp[1] += 1

    rt.deliver = timed_deliver
    t0 = time.perf_counter()
    try:
        out = drv.run_round(round_id=round_id, assignment=assignment,
                            updates=updates(), goal=len(flat_ups), n_elems=N,
                            fold_plan=fold_plan)
    finally:
        rt.deliver = orig
    wall = time.perf_counter() - t0
    return out.delta, wall, disp[0] / max(disp[1], 1)


def _shm_lifl_count() -> int:
    """Live ``lifl*`` segments in /dev/shm — the leak-check probe."""
    try:
        return sum(1 for n in os.listdir("/dev/shm")
                   if n.startswith("lifl"))
    except OSError:
        return 0


def run(fast: bool = True, profile: str = "full") -> List[Dict]:
    """``profile="ci"`` (run.py --fast) trims the warm-round iteration
    counts so the suite answers its gates in CI-scale time; the full
    counts stay the default for BENCH_agg.json regeneration."""
    from repro.core.placement import partial_traffic_bound
    from repro.runtime.driver import InProcRuntime, RoundDriver
    from repro.runtime.netrt import (RemoteRuntime, reap_local_daemon,
                                     spawn_local_daemon)

    node_runtime = "shmproc" if os.path.isdir("/dev/shm") else "inproc"
    shm0 = _shm_lifl_count()               # pre-existing segments
    N = (1 << 19) if fast else (11 << 20)   # 2 MB / 44 MB fp32 updates
    W = 4                                   # update groups (2 per node)
    model_mb = 4 * N / 1e6
    bound_mb = partial_traffic_bound(N_NODES, 4 * N, slack=SLACK) / 1e6

    ups, ws = _mk_updates(W, N)
    # the byte-identical reference: the SAME driven round (same
    # assignment, same delivery order, same engine arithmetic) on the
    # single-node in-proc runtime
    in_rt = InProcRuntime()
    in_drv = RoundDriver(in_rt)
    ref, dt_in, _ = _net_round(in_drv, in_rt, [f"bn{i}" for i in
                                               range(N_NODES)],
                               ups, ws, N, round_id=1)
    in_rt.close()
    rows: List[Dict] = [{
        "bench": "net",
        "case": "inproc_ref",
        "us_per_call": dt_in * 1e6,
        "derived": f"nodes=1;mbytes={4 * N >> 20};updates={W * G}",
    }]

    procs, addrs = [], []
    rt: Optional[RemoteRuntime] = None
    try:
        t_cold0 = time.perf_counter()
        for i in range(N_NODES):
            p, a = spawn_local_daemon(f"bn{i}", runtime=node_runtime,
                                      stdout=subprocess.DEVNULL)
            procs.append(p)
            addrs.append(a)
        rt = RemoteRuntime(addrs)
        drv = RoundDriver(rt)
        nodes = list(rt.node_info())
        rtt_us = rt.ping() * 1e6

        d_cold, wall_cold, disp_cold = _net_round(
            drv, rt, nodes, ups, ws, N, round_id=1)
        cold_total = time.perf_counter() - t_cold0

        deltas, walls, disps = [], [], []
        wire_marks = [rt.wire_stats()]
        n_warm = 1 if profile == "ci" else 3
        for r in range(n_warm):
            d, wall, disp = _net_round(drv, rt, nodes, ups, ws, N,
                                       round_id=2 + r)
            deltas.append(d)
            walls.append(wall)
            disps.append(disp)
            wire_marks.append(rt.wire_stats())

        # --- node-top topology: the root fold runs ON a worker node,
        # partials ship daemon→daemon, only the final Σc·u returns ---
        rt.quiesce()                       # settle daemon ship counters
        ship0 = rt.stats.get("ship_tx_bytes", 0)
        nt_deltas, nt_walls = [], []
        nt_marks = [rt.wire_stats()]
        for r in range(n_warm):
            d, wall, _ = _net_round(drv, rt, nodes, ups, ws, N,
                                    round_id=2 + n_warm + r,
                                    topology="node")
            nt_deltas.append(d)
            nt_walls.append(wall)
            nt_marks.append(rt.wire_stats())
        rt.quiesce()                       # flush the last round's ships
        ship_mb = (rt.stats.get("ship_tx_bytes", 0) - ship0) / n_warm / 1e6

        # --- recovery: SIGKILL the non-root daemon mid-round, respawn
        # it on the same port under its old name.  The round must still
        # land bit-exact (staged keys re-dispatch to the survivor) and
        # the restarted daemon is re-adopted — epoch bump — in time to
        # serve the following round.  bitexact gated FATAL by run.py. ---
        def _restart_bn1():
            # SIGKILL the whole group (daemon + its forked shm
            # workers), but do NOT sweep its segments here — that is
            # the re-adoption sweep's job (epoch bump in _adopt), which
            # this round exercises
            import signal as _signal
            try:
                os.killpg(procs[1].pid, _signal.SIGKILL)
            except (ProcessLookupError, PermissionError):
                procs[1].kill()
            procs[1].wait(timeout=10)
            p2, _ = spawn_local_daemon(nodes[1], runtime=node_runtime,
                                       listen=addrs[1],
                                       stdout=subprocess.DEVNULL)
            procs[1] = p2

        t_rec0 = time.perf_counter()
        d_rec, wall_rec, _ = _net_round(
            drv, rt, nodes, ups, ws, N, round_id=2 + 2 * n_warm,
            kill=(W * G // 2, _restart_bn1))
        # the crash round re-dispatches the dead subtree into the
        # survivor's accumulator: same sum, different fold order — so
        # numerically equivalent, not bit-identical
        rec_close = int(np.allclose(d_rec, ref, rtol=1e-5, atol=1e-6))
        # bounded wait for re-adoption, then one clean post-restart
        # round: THAT one must be bit-exact again — any leaked residency
        # or partial bookkeeping from the dead epoch would break it
        ra_deadline = time.perf_counter() + 15.0
        while (not rt.try_readopt(force=True)
               and time.perf_counter() < ra_deadline):
            time.sleep(0.1)
        readopt_s = time.perf_counter() - t_rec0
        d_post, wall_post, _ = _net_round(
            drv, rt, nodes, ups, ws, N, round_id=3 + 2 * n_warm)
        bit_rec = int(np.array_equal(d_post, ref)) & rec_close
        readopted = sum(1 for n in rt._nodes.values() if n.alive)
        swept_readopt = rt._local.get("swept_segments", 0)
    finally:
        if rt is not None:
            try:
                rt.shutdown_nodes()
            except Exception:
                pass
            rt.close()
        # killpg + /dev/shm sweep per daemon: a SIGKILLed netd's
        # segments must not outlive the bench (the leak this row gates)
        for p in procs:
            reap_local_daemon(p)
    leaked_segs = max(0, _shm_lifl_count() - shm0)

    def _tot(mark, field):
        return sum(v[field] for v in mark.values())

    def _partials(mark):
        return sum(v["rx_by_kind"].get("object", 0) for v in mark.values())

    # steady-state per-round wire cost, averaged over the warm rounds
    wire_mb = (_tot(wire_marks[-1], "tx_bytes")
               + _tot(wire_marks[-1], "rx_bytes")
               - _tot(wire_marks[0], "tx_bytes")
               - _tot(wire_marks[0], "rx_bytes")) / n_warm / 1e6
    partial_mb = (_partials(wire_marks[-1])
                  - _partials(wire_marks[0])) / n_warm / 1e6

    bit_cold = int(np.array_equal(d_cold, ref))
    bit_warm = int(all(np.array_equal(d, ref) for d in deltas))
    rows.append({
        "bench": "net",
        "case": f"net_{N_NODES}node_cold",
        "us_per_call": wall_cold * 1e6,
        "derived": (f"nodes={N_NODES};bitexact={bit_cold};"
                    f"node_rt={node_runtime};"
                    f"spawn_connect_s={cold_total - wall_cold:.2f};"
                    f"disp_us={disp_cold * 1e6:.0f}"),
    })
    rows.append({
        "bench": "net",
        "case": f"net_{N_NODES}node_warm",
        "us_per_call": float(np.mean(walls)) * 1e6,
        "derived": (f"nodes={N_NODES};bitexact={bit_warm};"
                    f"partial_mb={partial_mb:.2f};bound_mb={bound_mb:.2f};"
                    f"wire_mb={wire_mb:.2f};model_mb={model_mb:.2f};"
                    f"disp_us={np.mean(disps) * 1e6:.0f};"
                    f"rtt_us={rtt_us:.0f};"
                    f"inproc_over_net={dt_in / np.mean(walls):.2f}x"),
    })

    # node-top row: return traffic (controller-fetched objects) must be
    # ~1 × model/round — the whole point of rooting on a node — while
    # inter-node shipping (daemon→daemon + return) stays under the
    # partials-only bound.  Both FATAL-gated by run.py.
    return_mb = (_partials(nt_marks[-1]) - _partials(nt_marks[0])) \
        / n_warm / 1e6
    nt_wire_mb = (_tot(nt_marks[-1], "tx_bytes")
                  + _tot(nt_marks[-1], "rx_bytes")
                  - _tot(nt_marks[0], "tx_bytes")
                  - _tot(nt_marks[0], "rx_bytes")) / n_warm / 1e6
    bit_nt = int(all(np.array_equal(d, ref) for d in nt_deltas))
    rows.append({
        "bench": "net",
        "case": f"net_{N_NODES}node_nodetop_warm",
        "us_per_call": float(np.mean(nt_walls)) * 1e6,
        "derived": (f"nodes={N_NODES};bitexact={bit_nt};"
                    f"return_mb={return_mb:.2f};"
                    f"return_bound_mb={model_mb * SLACK / 1:.2f};"
                    f"partial_mb={return_mb + ship_mb:.2f};"
                    f"bound_mb={bound_mb:.2f};"
                    f"ship_mb={ship_mb:.2f};wire_mb={nt_wire_mb:.2f};"
                    f"model_mb={model_mb:.2f};"
                    f"ctrltop_over_nodetop="
                    f"{np.mean(walls) / np.mean(nt_walls):.2f}x"),
    })

    # recovery row: the survivability cost — one mid-round SIGKILL +
    # same-name restart vs a clean warm round, and how long until the
    # fleet is whole again (re-adoption latency incl. python startup).
    rows.append({
        "bench": "net",
        "case": f"net_{N_NODES}node_recovery",
        "us_per_call": wall_rec * 1e6,
        "derived": (f"nodes={N_NODES};bitexact={bit_rec};"
                    f"rec_close={rec_close};"
                    f"alive_after={readopted};"
                    f"readopt_s={readopt_s:.2f};"
                    f"leaked_segs={leaked_segs};"
                    f"swept_readopt={swept_readopt};"
                    f"post_restart_round_us={wall_post * 1e6:.0f};"
                    f"recovery_over_warm="
                    f"{wall_rec / np.mean(walls):.2f}x"),
    })
    return rows
