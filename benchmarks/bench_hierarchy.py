"""Fig 4 / Fig 7(c): does hierarchy help, per data plane?

NH (one aggregator, no hierarchy) vs WH (1 top + 4 leaves, same node)
for 8 trainers × ResNet-152, over the serverful kernel-networking data
plane vs LIFL's shared-memory plane.  Reproduces the paper's
observation: WH ≈ NH on the slow data plane (57 vs 59.8 s —
network contention eats the parallelism) while LIFL's plane lets the
hierarchy pay off (44.9 s/round, §6.1).

Round time = training (fixed ~42 s for the FEMNIST ResNet-152 clients,
Fig 4) + transfer/aggregation span from the simulator's cost model.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import AggregatorPool, SimConfig, simulate_round
from repro.core.simulation import DataPlaneCosts

TRAIN_S = 42.0
N_TRAINERS = 8


def run(fast: bool = True) -> List[Dict]:
    rows = []
    for dataplane in ("serverful", "shm"):
        for hierarchy, label in ((False, "NH"), (True, "WH")):
            cfg = SimConfig(
                n_nodes=1, mc_per_node=20, placement_policy="bestfit",
                hierarchy=hierarchy, reuse=True, eager=hierarchy,
                fan_in=2, dataplane=dataplane, costs=DataPlaneCosts(),
            )
            pool = AggregatorPool(cold_start_s=cfg.costs.t_cold_start)
            simulate_round(N_TRAINERS, cfg, pool=pool, arrival_span_s=3.0)
            res = simulate_round(N_TRAINERS, cfg, pool=pool, arrival_span_s=3.0)
            round_s = TRAIN_S + res.act_s
            rows.append({
                "bench": "hierarchy_fig4",
                "case": f"{dataplane}/{label}",
                "us_per_call": round_s * 1e6,
                "derived": f"round_s={round_s:.1f};agg_s={res.act_s:.1f}",
            })
    return rows
