"""Fig 13 / App-F: message-queuing overheads for one client→aggregator
update across the four Fig-5 pipelines — measured memory buffered along
the pipeline, CPU time, and end-to-end delay.

  SF-mono  — update lands directly in the aggregator's in-memory queue;
  SF-micro — stateless microservice aggregator behind a broker;
  SL-B     — basic serverless: sidecar + broker + sidecar;
  LIFL     — gateway deserializes once into shared memory; aggregator
             maps it in place (queue holds a 16-byte key).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.bench_dataplane import _consume, _socket_transfer
from repro.core.gateway import deserialize_update, serialize_update
from repro.core.objectstore import SharedMemoryObjectStore

SIZES = {"M1_resnet18": 44 << 20, "M2_resnet34": 83 << 20, "M3_resnet152": 232 << 20}


def _pipeline(update, kind: str, store) -> Dict[str, float]:
    nbytes = update.nbytes
    t0, c0 = time.perf_counter(), time.process_time()
    mem = 0
    if kind == "sf_mono":
        q = update.copy()                  # in-memory queue inside the app
        mem += q.nbytes
        _consume(q)
    elif kind == "sf_micro":
        payload = serialize_update(update, {})
        mem += len(payload)                # broker buffer
        raw = _socket_transfer(payload)    # broker -> aggregator
        out, _ = deserialize_update(raw)
        mem += out.nbytes
        _consume(out)
    elif kind == "sl_basic":
        payload = serialize_update(update, {})
        hop1 = _socket_transfer(payload)   # -> sidecar
        mem += len(hop1)                   # sidecar buffer
        hop2 = _socket_transfer(hop1)      # -> broker
        mem += len(hop2)                   # broker buffer
        hop3 = _socket_transfer(hop2)      # -> consumer sidecar
        mem += len(hop3)
        out, _ = deserialize_update(hop3)
        mem += out.nbytes
        _consume(out)
    elif kind == "lifl":
        payload = serialize_update(update, {})
        out, _ = deserialize_update(payload)  # gateway one-time processing
        key = store.put(out)               # in-place queue (shared memory)
        mem += out.nbytes                  # the only buffered copy
        view = store.get(key)
        _consume(view)
        store.delete(key)
    return {
        "latency_s": time.perf_counter() - t0,
        "cpu_s": time.process_time() - c0,
        "mem_bytes": float(mem),
    }


def run(fast: bool = True) -> List[Dict]:
    rows = []
    rng = np.random.default_rng(1)
    with SharedMemoryObjectStore(capacity_bytes=1 << 31) as store:
        for name, nbytes in SIZES.items():
            n = (nbytes // 4) // (8 if fast else 1)
            update = rng.normal(size=(n,)).astype(np.float32)
            for kind in ("sf_mono", "sf_micro", "sl_basic", "lifl"):
                m = _pipeline(update, kind, store)
                rows.append({
                    "bench": "queuing_fig13",
                    "case": f"{name}/{kind}",
                    "us_per_call": m["latency_s"] * 1e6,
                    "derived": (f"cpu_s={m['cpu_s']:.4f};"
                                f"mem_mb={m['mem_bytes']/1e6:.1f}"),
                })
    return rows
