"""Long-haul rolling soak: SLO rows from live mid-round scrapes.

The serving claim the short benches can't test: an *always-on*
2-job service over a real 2-daemon fleet, rounds rolling for minutes,
with the FleetMonitor scraping every daemon's ``stats`` frame on a
jittered period the whole time (the paper's agent → metrics-server
loop, §4.3).  Rows:

* ``soak/slo_<job>`` — per-job p50/p99 TTA (from the streaming
  histograms the live scrapes read), shed fraction, rounds/min, and
  SLO breach count.
* ``soak/fleet`` — the two FATAL gates: ``soak_bitexact=1`` (every
  round the soak closed replays bit-identically through the
  sequential ``run_round`` path — minutes of rolling, zero drift) and
  ``scrape_overhead_frac < 0.02`` (live observability must cost < 2%
  of the soak's wall clock); plus scrape/stale/mid-round counts.

Fast mode soaks ~20 s; ``--full`` ~120 s.
"""
from __future__ import annotations

import threading
import time
import zlib
from typing import Dict, List

import numpy as np

from repro.core import ClientInfo, NodeState, RoundConfig
from repro.runtime.driver import InProcRuntime, RoundDriver
from repro.runtime.events import SLOBreached
from repro.runtime.netrt import (
    RemoteRuntime, reap_local_daemon, spawn_local_daemon,
)
from repro.serve import (
    AdmissionPolicy, AggregationService, DeadlinePolicy, MinCohortIdleGap,
    SLOTarget,
)

N_ELEMS = 4096
JOBS = ("alpha", "beta")


class _Model:
    def loss(self, params, batch):  # external-update-only jobs
        raise NotImplementedError("soak bench never trains locally")


def _flat_for(cid: str) -> np.ndarray:
    rng = np.random.default_rng(zlib.crc32(cid.encode()))
    return rng.standard_normal(N_ELEMS).astype(np.float32)


class _CloseAny:
    def __init__(self, *pols):
        self.pols = pols

    def should_close(self, **kw):
        return any(p.should_close(**kw) for p in self.pols)


def run(fast: bool = True, profile: str = "full") -> List[Dict]:
    """``profile="ci"`` (run.py --fast) shortens the soak window so the
    gate suite runs in CI-scale time; full counts stay the default for
    BENCH_agg.json regeneration."""
    import jax.numpy as jnp

    dur_s = 6.0 if profile == "ci" else (20.0 if fast else 120.0)
    goal = 4
    batch = 4              # rounds per job per run_rounds() batch

    daemons = [spawn_local_daemon(f"node{i}", runtime="inproc")
               for i in range(2)]
    rt = RemoteRuntime([a for _, a in daemons])
    nodes = {n: NodeState(node=n, max_capacity=cap)
             for n, cap in rt.node_info().items()}
    svc = AggregationService(
        nodes, runtime=rt,
        admission=AdmissionPolicy(max_queue=64, job_quota=32,
                                  retry_base_s=0.005, retry_cap_s=0.05))
    params = {"w": jnp.zeros((N_ELEMS,), jnp.float32)}
    for job, weight in zip(JOBS, (2.0, 1.0)):
        svc.add_job(job, _Model(), params,
                    [ClientInfo(client_id=f"{job}-r{i}", num_samples=10)
                     for i in range(2 * goal)],
                    weight=weight,
                    round_cfg=RoundConfig(aggregation_goal=goal),
                    # generous targets: a breach in a healthy soak is a
                    # signal, not noise (the count lands in the row)
                    slo=SLOTarget(p99_tta_s=30.0, max_shed_frac=0.95))
    breaches: List[SLOBreached] = []
    svc.driver.on(SLOBreached, breaches.append)
    # period 0.25 s ≈ the paper agent's cadence; jittered by the
    # monitor so two services never sync-scrape one daemon
    mon = svc.start_monitor(period_s=0.25)

    stop = threading.Event()

    def pusher(job: str) -> None:
        k = 0
        while not stop.is_set():
            cid = f"{job}-u{k}"
            v = svc.submit(job, cid, _flat_for(cid),
                           1.0 + k % 3, submission_id=cid)
            if v["admitted"]:
                k += 1
                time.sleep(0.004)   # paced: rounds stay open long
            else:                   # enough for scrapes to land inside
                time.sleep(v["retry_after_s"])

    threads = [threading.Thread(target=pusher, args=(j,), daemon=True)
               for j in JOBS]
    policy = _CloseAny(
        MinCohortIdleGap(min_cohort=max(1, goal // 2), idle_gap_s=0.02),
        DeadlinePolicy(deadline_s=30.0))

    recs: List[Dict] = []
    t0 = time.perf_counter()
    try:
        for th in threads:
            th.start()
        while time.perf_counter() - t0 < dur_s:
            recs.extend(svc.run_rounds({j: batch for j in JOBS},
                                       policy=policy))
    finally:
        stop.set()
        for th in threads:
            th.join(timeout=10)
    wall = time.perf_counter() - t0

    mc = mon.counters()
    scrape_overhead_frac = mc["scrape_wall_s"] / max(wall, 1e-9)
    per_job_tta = {j: svc.metrics.hist("tta", j) for j in JOBS}
    shed = {j: svc.gateway.shed_frac(j) for j in JOBS}
    health = svc.health()
    stale_now = sum(1 for f in health["fleet"].values() if f.get("stale"))
    svc.close()

    # --- the determinism seam: a soak's worth of rolling rounds, each
    # replayed sequentially — minutes of overlap, zero drift
    bitexact = 1
    for rec in recs:
        if not rec["cohort"]:
            if rec["outcome"].delta is not None:
                bitexact = 0
            continue
        rt2 = InProcRuntime()
        out = RoundDriver(rt2).run_round(
            round_id=rec["ticket"], assignment=rec["assignment"],
            updates=[(node, cid, _flat_for(cid), w)
                     for node, cid, w in rec["cohort"]],
            goal=len(rec["cohort"]), n_elems=N_ELEMS,
            top_node=rec["top_node"])
        rt2.close()
        if not np.array_equal(np.asarray(out.delta),
                              np.asarray(rec["outcome"].delta)):
            bitexact = 0

    for proc, _ in daemons:
        reap_local_daemon(proc)

    rows: List[Dict] = []
    for job in JOBS:
        h = per_job_tta[job]
        q = h.quantiles() if h is not None else {
            "p50": 0.0, "p90": 0.0, "p99": 0.0, "count": 0, "mean": 0.0}
        n_rounds = sum(1 for r in recs if r["job"] == job)
        n_breach = sum(1 for b in breaches if b.job == job)
        rows.append({
            "bench": "soak",
            "case": f"slo_{job}",
            "us_per_call": q["p99"] * 1e6,
            "derived": (f"p50_tta_ms={q['p50'] * 1e3:.1f};"
                        f"p99_tta_ms={q['p99'] * 1e3:.1f};"
                        f"shed_frac={shed[job]:.3f};"
                        f"rounds={n_rounds};"
                        f"rounds_per_min={n_rounds / wall * 60.0:.1f};"
                        f"slo_breaches={n_breach}"),
        })
    rows.append({
        "bench": "soak",
        "case": "fleet",
        "us_per_call": mc["scrape_wall_s"] / max(1, mc["scrapes"]) * 1e6,
        "derived": (f"soak_bitexact={bitexact};"
                    f"scrape_overhead_frac={scrape_overhead_frac:.5f};"
                    f"scrapes={mc['scrapes']};"
                    f"mid_round_scrapes={mc['mid_round_scrapes']};"
                    f"stale_events={mc['stale_events']};"
                    f"stale_now={stale_now};"
                    f"nodes=2;wall_s={wall:.1f};"
                    f"rounds={len(recs)}"),
    })
    return rows


if __name__ == "__main__":
    for r in run(fast=True):
        print(f"{r['bench']}/{r['case']},{r['us_per_call']:.1f},"
              f"{r['derived']}")
