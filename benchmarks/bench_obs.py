"""Tracing overhead: what a fully-traced warm shmproc round pays.

The obs layer's contract is the paper's (§4.3): samples fire only on
event edges, so a fully-traced round must cost a negligible slice of
the round it observes.  The FATAL-gated ``obs_overhead_frac`` is the
directly-accounted tracer work per traced round — wall spent inside
every Tracer hook (begin/end/point/drain, self-timed) plus the
end-of-round trace assembly (``_finish_trace``: span drain, worker-span
conversion, RoundTrace build) — over the round wall.  Any regression
that makes tracing expensive (a hook that serializes, an O(updates)
span path) lands in that numerator.

An A/B comparison (traced vs untraced rounds, strictly alternated) is
run as well and reported in the derived column — but only as context:
warm shmproc rounds are scheduler-noisy (paired same-config round
deltas of ±10 ms on a ~60 ms round are routine under doorbell wakeups
and CPU migration), so a wall-clock A/B cannot resolve a 2% gate; the
accounted fraction is exact and well-conditioned where the A/B is
noise at this scale.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

W = 4        # mid aggregators
G = 4        # updates per mid
WARMUP = 3   # alternated warm-up pairs (forks, first-touch, jit paths)
REPS = 7     # (untraced, traced) pairs
GATE_FRAC = 0.02


def _drive_round(drv, rid: int, ups, ws, N: int) -> float:
    assignment = {f"n{w}": [w * G + i for i in range(G)] for w in range(W)}

    def updates():
        for w in range(W):
            for i in range(G):
                j = w * G + i
                yield f"n{w}", f"c{j}", ups[j], ws[j]

    t0 = time.perf_counter()
    out = drv.run_round(round_id=rid, assignment=assignment,
                        updates=updates(), goal=W * G, n_elems=N)
    dt = time.perf_counter() - t0
    assert out.count == W * G and out.crashes == 0
    return dt


def _make_metered_tracer():
    """A Tracer that self-accounts the wall spent inside its own hooks
    (two extra clock reads per call — the accounting slightly INFLATES
    the measured cost, keeping the gate an upper bound)."""
    from repro.obs.trace import Tracer

    class _Metered(Tracer):
        def __init__(self):
            super().__init__(enabled=True)
            self.self_s = 0.0

        def _timed(self, fn, *a, **kw):
            t0 = time.perf_counter()
            try:
                return fn(*a, **kw)
            finally:
                self.self_s += time.perf_counter() - t0

        def begin(self, *a, **kw):
            return self._timed(super().begin, *a, **kw)

        def end(self, *a, **kw):
            return self._timed(super().end, *a, **kw)

        def point(self, *a, **kw):
            return self._timed(super().point, *a, **kw)

        def add(self, *a, **kw):
            return self._timed(super().add, *a, **kw)

        def drain(self):
            return self._timed(super().drain)

        def reset(self):
            return self._timed(super().reset)

    return _Metered()


def run(fast: bool = True) -> List[Dict]:
    import os

    if not os.path.isdir("/dev/shm"):
        return [{"bench": "obs", "case": "skipped", "us_per_call": 0.0,
                 "derived": "no /dev/shm (POSIX shared memory required)"}]
    from repro.obs.trace import NULL_TRACER
    from repro.runtime.driver import RoundDriver, ShmProcRuntime

    N = (1 << 20) if fast else (11 << 20)  # 4 MB / 44 MB fp32 updates
    rng = np.random.default_rng(0)
    ups = [rng.normal(size=(N,)).astype(np.float32) for _ in range(W * G)]
    ws = [float(1 + i % 5) for i in range(W * G)]

    traced_tr = _make_metered_tracer()
    rt = ShmProcRuntime()
    drv = RoundDriver(rt, tracer=traced_tr)
    # time the end-of-round trace assembly too: it is part of what a
    # traced round pays that an untraced one does not
    finish_acct = {"s": 0.0}
    orig_finish = drv._finish_trace

    def timed_finish(*a, **kw):
        t0 = time.perf_counter()
        try:
            return orig_finish(*a, **kw)
        finally:
            finish_acct["s"] += time.perf_counter() - t0

    drv._finish_trace = timed_finish

    try:
        rid = 0
        for _ in range(WARMUP):  # forks + first-touch, both sides
            for tr in (NULL_TRACER, traced_tr):
                drv.tracer = tr
                _drive_round(drv, rid, ups, ws, N)
                rid += 1
        traced, untraced, fracs = [], [], []
        n_spans = 0
        for _ in range(REPS):  # strict alternation: drift hits both
            drv.tracer = NULL_TRACER
            untraced.append(_drive_round(drv, rid, ups, ws, N))
            rid += 1
            drv.tracer = traced_tr
            s0 = traced_tr.self_s + finish_acct["s"]
            wall = _drive_round(drv, rid, ups, ws, N)
            accounted = (traced_tr.self_s + finish_acct["s"]) - s0
            traced.append(wall)
            fracs.append(accounted / wall)
            rid += 1
            n_spans = len(drv.last_trace.spans)
        cov = drv.last_trace.breakdown()["coverage"]
    finally:
        rt.close()

    frac = float(np.median(fracs))
    med_t = float(np.median(traced))
    med_u = float(np.median(untraced))
    ab = med_t / med_u - 1.0 if med_u > 0 else float("nan")
    return [{
        "bench": "obs",
        "case": "traced_vs_untraced_warm",
        "us_per_call": med_t * 1e6,
        "derived": (f"obs_overhead_frac={frac:.4f};"
                    f"gate_frac={GATE_FRAC};"
                    f"ab_delta_frac={ab:+.4f};"
                    f"med_traced_ms={med_t * 1e3:.2f};"
                    f"med_untraced_ms={med_u * 1e3:.2f};"
                    f"spans={n_spans};coverage={cov:.3f};"
                    f"workers={W};updates={W * G}"),
    }]
