"""Continuous-aggregation serving: ingest latency, admission shed,
rolling-round overlap, and the bit-exactness seam.

Two rows:

* ``serve/ingest`` — sustained ``submit`` pressure from pusher threads
  against a live 2-job rolling service: p50/p99 per-call gateway
  latency, sustained admitted updates/s, shed fraction (admission
  pushing back is *by design* — the row records how often).
* ``serve/rolling`` — the determinism contract under load: every round
  the service closed is replayed through the sequential library
  ``run_round`` path on a fresh runtime, and the deltas must be
  bit-identical (``bitexact=1`` — FATAL gate in run.py); the rolling
  seam must actually overlap round windows (``pipeline_overlap > 0``,
  the second FATAL gate).  Rolling reorders time, never the fold.
"""
from __future__ import annotations

import threading
import time
import zlib
from typing import Dict, List

import numpy as np

from repro.core import ClientInfo, NodeState, RoundConfig
from repro.runtime.driver import InProcRuntime, RoundDriver
from repro.serve import (
    AdmissionPolicy, AggregationService, DeadlinePolicy, MinCohortIdleGap,
)

N_ELEMS = 4096


class _Model:
    def loss(self, params, batch):  # external-update-only jobs
        raise NotImplementedError("serve bench never trains locally")


def _flat_for(cid: str) -> np.ndarray:
    rng = np.random.default_rng(zlib.crc32(cid.encode()))
    return rng.standard_normal(N_ELEMS).astype(np.float32)


class _CloseAny:
    def __init__(self, *pols):
        self.pols = pols

    def should_close(self, **kw):
        return any(p.should_close(**kw) for p in self.pols)


def _mk_service(goal: int) -> AggregationService:
    import jax.numpy as jnp

    nodes = {f"node{i}": NodeState(node=f"node{i}", max_capacity=20.0)
             for i in range(2)}
    svc = AggregationService(
        nodes, runtime="inproc",
        admission=AdmissionPolicy(max_queue=64, job_quota=32,
                                  retry_base_s=0.005, retry_cap_s=0.05))
    params = {"w": jnp.zeros((N_ELEMS,), jnp.float32)}
    for job, weight in (("alpha", 2.0), ("beta", 1.0)):
        svc.add_job(job, _Model(), params,
                    [ClientInfo(client_id=f"{job}-r{i}", num_samples=10)
                     for i in range(2 * goal)],
                    weight=weight,
                    round_cfg=RoundConfig(aggregation_goal=goal))
    return svc


def run(fast: bool = True) -> List[Dict]:
    goal = 4 if fast else 8
    per_job = 6 if fast else 12
    svc = _mk_service(goal)

    lat_lock = threading.Lock()
    lats: List[float] = []
    counts = {"admitted": 0, "tries": 0}
    stop = threading.Event()

    def pusher(job: str) -> None:
        k = 0
        while not stop.is_set():
            cid = f"{job}-u{k}"
            t0 = time.perf_counter()
            v = svc.submit(job, cid, _flat_for(cid),
                           1.0 + k % 3, submission_id=cid)
            dt = time.perf_counter() - t0
            with lat_lock:
                lats.append(dt)
                counts["tries"] += 1
                counts["admitted"] += int(v["admitted"])
            if v["admitted"]:
                k += 1
            else:
                time.sleep(v["retry_after_s"])

    threads = [threading.Thread(target=pusher, args=(j,), daemon=True)
               for j in ("alpha", "beta")]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    try:
        recs = svc.run_rounds(
            {"alpha": per_job, "beta": per_job},
            policy=_CloseAny(
                MinCohortIdleGap(min_cohort=max(1, goal // 2),
                                 idle_gap_s=0.02),
                DeadlinePolicy(deadline_s=30.0)))
    finally:
        stop.set()
        for th in threads:
            th.join(timeout=10)
    wall = time.perf_counter() - t0
    overlap = svc.pipeline_overlap()
    svc.close()

    # --- the determinism seam: replay each closed cohort sequentially
    bitexact = 1
    for rec in recs:
        if not rec["cohort"]:
            if rec["outcome"].delta is not None:
                bitexact = 0
            continue
        rt = InProcRuntime()
        out = RoundDriver(rt).run_round(
            round_id=rec["ticket"], assignment=rec["assignment"],
            updates=[(node, cid, _flat_for(cid), w)
                     for node, cid, w in rec["cohort"]],
            goal=len(rec["cohort"]), n_elems=N_ELEMS,
            top_node=rec["top_node"])
        rt.close()
        if not np.array_equal(np.asarray(out.delta),
                              np.asarray(rec["outcome"].delta)):
            bitexact = 0

    ls = np.sort(np.asarray(lats)) * 1e6
    p50 = float(np.percentile(ls, 50)) if len(ls) else 0.0
    p99 = float(np.percentile(ls, 99)) if len(ls) else 0.0
    shed_frac = 1.0 - counts["admitted"] / max(1, counts["tries"])
    folded = sum(len(r["cohort"]) for r in recs)

    return [
        {
            "bench": "serve",
            "case": "ingest",
            "us_per_call": p50,
            "derived": (f"p50_us={p50:.1f};p99_us={p99:.1f};"
                        f"admitted_per_s={counts['admitted'] / wall:.0f};"
                        f"shed_frac={shed_frac:.3f};"
                        f"submits={counts['tries']}"),
        },
        {
            "bench": "serve",
            "case": "rolling",
            "us_per_call": wall / max(1, len(recs)) * 1e6,
            "derived": (f"bitexact={bitexact};"
                        f"pipeline_overlap={overlap:.3f};"
                        f"rounds={len(recs)};folded={folded};"
                        f"jobs=2;goal={goal}"),
        },
    ]
