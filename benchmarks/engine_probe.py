"""Shared fold-throughput probe for the aggregation engines.

One measurement loop used by bench_agg_kernel, bench_dataplane and
bench_tta so the calibrated speedups fed into ``DataPlaneCosts`` and the
rows recorded in BENCH_agg.json come from the same procedure (warm the
scratch, then average ``reps`` timed folds).

``fold GB/s`` = bytes of update consumed per second — the
apples-to-apples number across engines (the naive engine moves ~7×
that in DRAM traffic; the blocked engine ~3×; that asymmetry is the
point).
"""
from __future__ import annotations

import time
from typing import Sequence, Tuple

import numpy as np

from repro.core.engine import AggregationEngine, make_engine


def fold_gbps(engine, update: np.ndarray, *, reps: int = 3,
              weight: float = 1.7) -> Tuple[float, float]:
    """(GB/s of update consumed, seconds per fold) for one engine."""
    eng = engine if isinstance(engine, AggregationEngine) else make_engine(engine)
    acc = eng.begin(update.size)
    # rebind every fold: the jnp/pallas engines donate the accumulator,
    # so the old handle is dead after each call
    acc = eng.fold(acc, update, weight)    # warm scratch/accumulator
    eng.sync(acc)                          # async engines: drain dispatch
    t0 = time.perf_counter()
    for _ in range(reps):
        acc = eng.fold(acc, update, weight)
    eng.sync(acc)
    dt = (time.perf_counter() - t0) / reps
    eng.recycle(acc)
    return update.nbytes / 1e9 / dt, dt


def fold_many_gbps(engine, updates: Sequence[np.ndarray],
                   weights: Sequence[float], *, reps: int = 3
                   ) -> Tuple[float, float]:
    """(per-update GB/s, seconds per K-way burst) for a batched fold."""
    eng = engine if isinstance(engine, AggregationEngine) else make_engine(engine)
    acc = eng.begin(updates[0].size)
    acc = eng.fold_many(acc, updates, weights)   # warm (donating engines
    eng.sync(acc)                                # invalidate old handles)
    t0 = time.perf_counter()
    for _ in range(reps):
        acc = eng.fold_many(acc, updates, weights)
    eng.sync(acc)
    dt = (time.perf_counter() - t0) / reps
    eng.recycle(acc)
    return sum(u.nbytes for u in updates) / 1e9 / dt, dt
