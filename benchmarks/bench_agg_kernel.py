"""Aggregation-kernel throughput (the §4.1 hot loop): K-way weighted
reduce + eager accumulate over flat update vectors; CPU jnp twin
measured for wall time, Pallas path validated in interpret mode; the
derived column reports achieved GB/s and the v5e roofline expectation
(819 GB/s HBM, memory-bound: (K+1)·4·N bytes per reduce)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import HBM_BW
from repro.kernels.fedavg import eager_accumulate, fedavg_reduce


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def run(fast: bool = True) -> List[Dict]:
    rows = []
    rng = np.random.default_rng(0)
    N = (11 << 20) if fast else (58 << 20)  # ~44 MB fp32 (ResNet-18) or 232 MB
    for K in (2, 4, 8):
        U = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
        W = jnp.asarray(np.ones((K,), np.float32))
        dt = _time(lambda u, w: fedavg_reduce(u, w, impl="jnp"), U, W)
        moved = (K + 1) * 4 * N
        rows.append({
            "bench": "agg_kernel",
            "case": f"reduce_K{K}",
            "us_per_call": dt * 1e6,
            "derived": (f"cpu_gbps={moved/dt/1e9:.2f};"
                        f"v5e_roofline_us={moved/HBM_BW*1e6:.0f}"),
        })
    acc = jnp.zeros((N,), jnp.float32)
    u = jnp.asarray(rng.normal(size=(N,)).astype(np.float32))
    dt = _time(lambda a, uu: eager_accumulate(a.copy(), uu, 1.0, impl="jnp"), acc, u)
    rows.append({
        "bench": "agg_kernel",
        "case": "eager_accumulate",
        "us_per_call": dt * 1e6,
        "derived": (f"cpu_gbps={3*4*N/dt/1e9:.2f};"
                    f"v5e_roofline_us={3*4*N/HBM_BW*1e6:.0f}"),
    })
    return rows
