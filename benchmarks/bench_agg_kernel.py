"""Aggregation-kernel + engine throughput (the §4.1 hot loop).

Two layers measured side by side:

  * kernel layer — K-way weighted reduce + eager/batched accumulate over
    flat update vectors (CPU jnp twin for wall time, Pallas validated in
    interpret mode by tests); the derived column reports achieved GB/s
    and the v5e roofline expectation (819 GB/s HBM, memory-bound:
    (K+1)·4·N bytes per reduce);
  * engine layer (core/engine.py) — the old naive per-update fold
    (full-size astype·w temporary, three passes + an allocation) vs the
    blocked in-place fold vs the K-way batched burst fold, on the
    ResNet-18-sized (44 MB) case.  ``fold GB/s`` = bytes of update
    consumed per second (4·N·K / wall), the apples-to-apples number the
    acceptance gate compares (blocked/batched must be ≥ 2× naive).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.engine_probe import fold_gbps, fold_many_gbps
from repro.analysis.roofline import HBM_BW
from repro.kernels.fedavg import eager_accumulate, fedavg_accumulate_k, fedavg_reduce


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def _engine_rows(N: int) -> List[Dict]:
    """Old-vs-new fold throughput through the engine layer (44 MB case)."""
    rows = []
    rng = np.random.default_rng(1)
    K = 8
    updates = [rng.normal(size=(N,)).astype(np.float32) for _ in range(K)]
    for u in updates:
        u.flags.writeable = False      # same contract as store.get() views

    results = {}
    for name in ("naive", "blocked"):
        results[name], dt = fold_gbps(name, updates[0], reps=4)
        rows.append({
            "bench": "agg_kernel",
            "case": f"engine_fold_{name}",
            "us_per_call": dt * 1e6,
            "derived": f"fold_gbps={results[name]:.2f};n_mb={4*N/1e6:.0f}",
        })

    # K-way batched burst drain: one read of the accumulator for K folds
    ws = [1.0 + i for i in range(K)]
    results["batched"], dt = fold_many_gbps("blocked", updates, ws, reps=3)
    rows.append({
        "bench": "agg_kernel",
        "case": f"engine_fold_batched_K{K}",
        "us_per_call": dt * 1e6,
        "derived": (f"fold_gbps={results['batched']:.2f};n_mb={4*N/1e6:.0f};"
                    f"speedup_blocked={results['blocked']/results['naive']:.2f}x;"
                    f"speedup_batched={results['batched']/results['naive']:.2f}x"),
    })
    return rows


def run(fast: bool = True) -> List[Dict]:
    rows = []
    rng = np.random.default_rng(0)
    N = (11 << 20) if fast else (58 << 20)  # ~44 MB fp32 (ResNet-18) or 232 MB
    for K in (2, 4, 8):
        U = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
        W = jnp.asarray(np.ones((K,), np.float32))
        dt = _time(lambda u, w: fedavg_reduce(u, w, impl="jnp"), U, W)
        moved = (K + 1) * 4 * N
        rows.append({
            "bench": "agg_kernel",
            "case": f"reduce_K{K}",
            "us_per_call": dt * 1e6,
            "derived": (f"cpu_gbps={moved/dt/1e9:.2f};"
                        f"v5e_roofline_us={moved/HBM_BW*1e6:.0f}"),
        })
    acc = jnp.zeros((N,), jnp.float32)
    u = jnp.asarray(rng.normal(size=(N,)).astype(np.float32))
    dt = _time(lambda a, uu: eager_accumulate(a.copy(), uu, 1.0, impl="jnp"), acc, u)
    rows.append({
        "bench": "agg_kernel",
        "case": "eager_accumulate",
        "us_per_call": dt * 1e6,
        "derived": (f"cpu_gbps={3*4*N/dt/1e9:.2f};"
                    f"v5e_roofline_us={3*4*N/HBM_BW*1e6:.0f}"),
    })
    K = 8
    UK = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    WK = jnp.asarray(np.ones((K,), np.float32))
    dt = _time(lambda a, uu, ww: fedavg_accumulate_k(a.copy(), uu, ww, impl="jnp"),
               acc, UK, WK)
    moved = (K + 2) * 4 * N  # K update reads + acc read + acc write
    rows.append({
        "bench": "agg_kernel",
        "case": f"accumulate_K{K}",
        "us_per_call": dt * 1e6,
        "derived": (f"cpu_gbps={moved/dt/1e9:.2f};"
                    f"v5e_roofline_us={moved/HBM_BW*1e6:.0f}"),
    })

    # engine layer: the 44 MB ResNet-18 case regardless of --full (the
    # acceptance gate's fixed reference point)
    rows.extend(_engine_rows(11 << 20))
    return rows
