"""Fig 8: LIFL's orchestration ablation — ACT, CPU, #aggregators and
#nodes vs the number of concurrently-arriving model updates
(20/60/100), stepping through the paper's additions:

  SL-H      shared-memory data plane + Least-Connection (WorstFit)
            spreading + lazy timing + no reuse (cold starts);
  +(1)      locality-aware BestFit placement;
  +(1,2,3)  + hierarchy planning + warm-aggregator reuse;
  +(1..4)   + eager aggregation.

Testbed constants mirror §6.1: 5 nodes, MC_i = 20, ResNet-152 updates,
inter-node transfer 4.2 s.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import AggregatorPool, SimConfig, simulate_round
from repro.core.simulation import DataPlaneCosts

STEPS = {
    "SL-H": dict(placement_policy="worstfit", hierarchy=True, reuse=False,
                 eager=False),
    "+1_placement": dict(placement_policy="bestfit", hierarchy=True,
                         reuse=False, eager=False),
    "+123_reuse": dict(placement_policy="bestfit", hierarchy=True,
                       reuse=True, eager=False),
    "+1234_eager": dict(placement_policy="bestfit", hierarchy=True,
                        reuse=True, eager=True),
}


def run(fast: bool = True) -> List[Dict]:
    rows = []
    arrival_span = 6.0  # client updates spread over ~6 s (Fig 1 timing)
    for n_updates in (20, 60, 100):
        for label, kw in STEPS.items():
            cfg = SimConfig(n_nodes=5, mc_per_node=20, dataplane="shm",
                            costs=DataPlaneCosts(), **kw)
            pool = AggregatorPool(cold_start_s=cfg.costs.t_cold_start)
            if kw["reuse"]:
                # warm pool from a previous round (steady state)
                warm = simulate_round(n_updates, cfg, pool=pool,
                                      arrival_span_s=arrival_span)
            res = simulate_round(
                n_updates, cfg,
                pool=pool if kw["reuse"] else
                AggregatorPool(cold_start_s=cfg.costs.t_cold_start),
                arrival_span_s=arrival_span,
            )
            rows.append({
                "bench": "orchestration_fig8",
                "case": f"n{n_updates}/{label}",
                "us_per_call": res.act_s * 1e6,
                "derived": (f"act_s={res.act_s:.2f};cpu_s={res.cpu_s:.1f};"
                            f"aggs={res.aggregators_created};"
                            f"nodes={res.nodes_used};"
                            f"inter_node={res.inter_node_transfers};"
                            f"cold={res.cold_starts}"),
            })
    # paper-claim checks packed into one derived row
    def act(n, label):
        r = next(x for x in rows if x["case"] == f"n{n}/{label}")
        return float(r["derived"].split("act_s=")[1].split(";")[0])

    rows.append({
        "bench": "orchestration_fig8",
        "case": "claims",
        "us_per_call": 0.0,
        "derived": (
            f"placement_speedup_n20={act(20,'SL-H')/act(20,'+1_placement'):.2f}x;"
            f"reuse_speedup_n60={act(60,'+1_placement')/act(60,'+123_reuse'):.2f}x;"
            f"eager_speedup_n60={act(60,'+123_reuse')/act(60,'+1234_eager'):.2f}x"
        ),
    })
    return rows
