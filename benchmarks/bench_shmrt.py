"""Multi-process hierarchical aggregation end-to-end (the shmrt runtime).

One "round" = the full single-node hierarchy: W middle aggregators with
G updates each, then the parent (top aggregator) folds the W partial
sums.  Three variants measured:

  * ``inproc``   — the PR-1 single-process tree (FedAvgState + blocked
    engine over the in-proc store): the baseline every multi-process
    claim is judged against, and the byte-identical reference (same
    grouping, same engine arithmetic).
  * ``shmproc cold`` — a fresh runtime: every worker pays a fork +
    READY handshake (serverless cold start).
  * ``shmproc warm`` — the same runtime re-tasked: workers are parked
    processes, dispatch is one 64-byte TASK record through the ring
    (§5.3 reuse across real process boundaries).

Derived columns carry the acceptance-gate numbers: ``bitexact`` (the
multi-process delta equals the in-proc tree's bit for bit — the parent
folded the children's partials zero-copy out of the store),
``disp_cold_us``/``disp_warm_us`` (submit→ACK latency incl. fork for
cold), and ``warm_over_cold``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.aggregation import FedAvgState, fedavg_oracle
from repro.core.engine import make_engine
from repro.core.objectstore import InProcObjectStore

WORKER_COUNTS = (1, 2, 4, 8)
G = 4  # updates per middle aggregator


def _mk_updates(W: int, N: int, seed: int = 0
                ) -> Tuple[List[List[np.ndarray]], List[List[float]]]:
    rng = np.random.default_rng(seed)
    ups = [[rng.normal(size=(N,)).astype(np.float32) for _ in range(G)]
           for _ in range(W)]
    ws = [[float(1 + (w * G + i) % 5) for i in range(G)] for w in range(W)]
    return ups, ws


def _inproc_round(ups, ws, N) -> Tuple[np.ndarray, float]:
    """The single-process tree: W mids fold G updates each (blocked
    engine over the in-proc store), top merges the partial sums."""
    store = InProcObjectStore()
    t0 = time.perf_counter()
    partials = []
    for w_ups, w_ws in zip(ups, ws):
        mid = FedAvgState(engine=make_engine("blocked"))
        keys = [store.put(u) for u in w_ups]
        views = [store.get(k) for k in keys]
        mid.fold_many(views, list(w_ws))
        partials.append(mid)
    top_engine = make_engine("blocked")
    top = FedAvgState(engine=top_engine)
    top._ensure_acc(N)
    for mid in partials:
        top.acc = top_engine.add_partial(top.acc, np.asarray(mid.acc))
        top.weight += mid.weight
        top.count += mid.count
    delta, _ = top.result()
    dt = time.perf_counter() - t0
    store.close()
    return delta, dt


def _shmproc_round(rt, ups, ws, N, round_id: int) -> Tuple[np.ndarray, float]:
    """One multi-process round on an existing runtime."""
    W = len(ups)
    t0 = time.perf_counter()
    for w in range(W):
        rt.submit_task(f"mid@n{w}", goal=G, n_elems=N, round_id=round_id)
    update_keys = []
    for w in range(W):
        for u, c in zip(ups[w], ws[w]):
            k = rt.store.put(u)
            update_keys.append(k)
            rt.dispatch(f"mid@n{w}", k, c, round_id=round_id)
    parts = rt.collect(W)
    parts.sort(key=lambda p: p.agg_id)
    engine = make_engine("blocked")
    top = FedAvgState(engine=engine)
    top._ensure_acc(N)
    for p in parts:
        top.acc = engine.add_partial(top.acc, rt.store.get(p.key))
        top.weight += p.weight
        top.count += p.count
    delta, _ = top.result()
    dt = time.perf_counter() - t0
    for p in parts:
        rt.store.destroy(p.key)
    for k in update_keys:
        rt.store.delete(k)
    return delta, dt


def run(fast: bool = True) -> List[Dict]:
    import os

    if not os.path.isdir("/dev/shm"):
        return [{"bench": "shmrt", "case": "skipped", "us_per_call": 0.0,
                 "derived": "no /dev/shm (POSIX shared memory required)"}]
    from repro.runtime.shmrt import ShmRuntime

    N = (1 << 20) if fast else (11 << 20)  # 4 MB / 44 MB fp32 updates
    rows: List[Dict] = []

    for W in WORKER_COUNTS:
        ups, ws = _mk_updates(W, N)
        ref, dt_in = _inproc_round(ups, ws, N)
        oracle = fedavg_oracle(
            [u for g in ups for u in g], [c for g in ws for c in g])
        assert np.allclose(ref, oracle, rtol=1e-5, atol=1e-5)
        rows.append({
            "bench": "shmrt",
            "case": f"inproc_w{W}",
            "us_per_call": dt_in * 1e6,
            "derived": f"workers=0;mbytes={4 * N >> 20};updates={W * G}",
        })

        with ShmRuntime() as rt:
            d_cold, dt_cold = _shmproc_round(rt, ups, ws, N, round_id=1)
            disp_cold = rt.stats["cold_latency_s"]
            d_warm, dt_warm = _shmproc_round(rt, ups, ws, N, round_id=2)
            disp_warm = rt.stats["warm_latency_s"]
            assert rt.stats["cold_starts"] == W and rt.stats["warm_starts"] == W

        bit_cold = int(np.array_equal(d_cold, ref))
        bit_warm = int(np.array_equal(d_warm, ref))
        ratio = disp_warm / disp_cold if disp_cold > 0 else float("nan")
        rows.append({
            "bench": "shmrt",
            "case": f"shmproc_w{W}_cold",
            "us_per_call": dt_cold * 1e6,
            "derived": (f"workers={W};bitexact={bit_cold};"
                        f"disp_cold_us={disp_cold * 1e6:.0f};"
                        f"mbytes={4 * N >> 20}"),
        })
        rows.append({
            "bench": "shmrt",
            "case": f"shmproc_w{W}_warm",
            "us_per_call": dt_warm * 1e6,
            "derived": (f"workers={W};bitexact={bit_warm};"
                        f"disp_warm_us={disp_warm * 1e6:.0f};"
                        f"warm_over_cold={ratio:.4f};"
                        f"inproc_over_shm={dt_in / dt_warm:.2f}x"),
        })
    return rows
