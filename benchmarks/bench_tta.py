"""Fig 9/10: time-to-accuracy + CPU cost, LIFL vs SF vs SL.

Real FL training (reduced ResNet-18 on synthetic non-IID FEMNIST through
the actual LIFL control plane) provides the accuracy-vs-round curve;
per-round wall-clock and CPU are composed from the measured/calibrated
per-system aggregation costs (simulator, §6.1 constants).  The learning
trajectory is identical across systems — exactly the paper's setup,
where only the aggregation service differs — so time-to-accuracy
differences come purely from ACT and cold-start behavior.

LIFL's simulated fold cost uses the blocked aggregation engine
(core/engine.py); SF/SL keep the naive scalar fold.  The blocked/naive
throughput ratio is *measured live* on this host (fold_calibration row,
old-vs-new GB/s) and fed into ``DataPlaneCosts.agg_engine_speedup``.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import numpy as np

from benchmarks.engine_probe import fold_gbps
from repro.api import Session
from repro.configs.resnet import RESNET18
from repro.core import AggregatorPool, ClientInfo, RoundConfig, SimConfig, simulate_round
from repro.core.simulation import DataPlaneCosts
from repro.data import build_client_datasets, dirichlet_partition, synthetic_femnist
from repro.models import build_resnet
from repro.runtime import ClientRuntime

SYSTEMS = {
    # (dataplane, placement, reuse, eager, agg_engine)
    "lifl": ("shm", "bestfit", True, True, "blocked"),
    "sf": ("serverful", "bestfit", True, False, "naive"),   # always-on serverful
    "sl": ("serverless", "worstfit", False, False, "naive"),  # cold starts + broker
}
TRAIN_S_PER_ROUND = 30.0  # client-side training span (masked by arrivals)


def _measure_fold_gbps(n: int = 4 << 20) -> Tuple[float, float]:
    """Live old-vs-new fold throughput (GB/s of update consumed)."""
    rng = np.random.default_rng(0)
    u = rng.normal(size=(n,)).astype(np.float32)
    u.flags.writeable = False
    return fold_gbps("naive", u)[0], fold_gbps("blocked", u)[0]


def run(fast: bool = True) -> List[Dict]:
    rows: List[Dict] = []
    n_rounds = 8 if fast else 30
    target_acc = 0.45 if fast else 0.6

    # --- real accuracy trajectory (shared across systems) ---------------
    cfg = RESNET18.reduced()
    model = build_resnet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    imgs, labels = synthetic_femnist(800, num_classes=10, seed=0)
    shards = dirichlet_partition(labels, 24, alpha=0.5)
    dsets = build_client_datasets(imgs, labels, shards)
    clients = [
        ClientRuntime(ClientInfo(d.client_id, d.num_samples), d,
                      failure_prob=0.05)
        for d in dsets
    ]
    test = {"images": imgs[:256], "labels": labels[:256]}
    accs = []
    with Session.open(
        model, params, clients,
        round_cfg=RoundConfig(aggregation_goal=10, over_provision=1.4),
    ) as sess:
        for r in range(n_rounds):
            sess.run_round(client_lr=0.08, client_batch_size=32,
                           client_epochs=1)
            accs.append(sess.evaluate(test)["accuracy"])

    # --- per-system round costs ------------------------------------------
    # calibrate the engine speedup from a live fold measurement
    naive_gbps, blocked_gbps = _measure_fold_gbps()
    measured_speedup = max(1.0, blocked_gbps / naive_gbps)
    rows.append({
        "bench": "tta_fig9",
        "case": "fold_calibration",
        "us_per_call": 0.0,
        "derived": (f"fold_gbps_naive={naive_gbps:.2f};"
                    f"fold_gbps_blocked={blocked_gbps:.2f};"
                    f"speedup={measured_speedup:.2f}x"),
    })

    n_updates = 10
    for name, (dp, policy, reuse, eager, engine) in SYSTEMS.items():
        costs = DataPlaneCosts()
        costs.agg_engine_speedup["blocked"] = measured_speedup
        sim_cfg = SimConfig(n_nodes=5, mc_per_node=20, placement_policy=policy,
                            hierarchy=True, reuse=reuse, eager=eager,
                            dataplane=dp, agg_engine=engine, costs=costs)
        pool = AggregatorPool(cold_start_s=sim_cfg.costs.t_cold_start)
        wall = cpu = 0.0
        reached = None
        for r in range(n_rounds):
            p = pool if reuse else AggregatorPool(
                cold_start_s=sim_cfg.costs.t_cold_start)
            res = simulate_round(n_updates, sim_cfg, pool=p, arrival_span_s=8.0)
            round_wall = max(TRAIN_S_PER_ROUND, res.act_s) if eager \
                else TRAIN_S_PER_ROUND + res.act_s
            wall += round_wall
            cpu += res.cpu_s
            if reached is None and accs[r] >= target_acc:
                reached = (wall, cpu, r + 1)
        if reached is None:
            reached = (wall, cpu, n_rounds)
        rows.append({
            "bench": "tta_fig9",
            "case": name,
            "us_per_call": reached[0] * 1e6,
            "derived": (f"tta_s={reached[0]:.0f};cpu_s={reached[1]:.0f};"
                        f"rounds={reached[2]};final_acc={accs[-1]:.3f};"
                        f"target_acc={target_acc}"),
        })

    lifl = next(r for r in rows if r["case"] == "lifl")
    for other in ("sf", "sl"):
        o = next(r for r in rows if r["case"] == other)
        rows.append({
            "bench": "tta_fig9",
            "case": f"speedup_vs_{other}",
            "us_per_call": 0.0,
            "derived": (
                f"tta={o['us_per_call']/lifl['us_per_call']:.2f}x;"
                f"cpu={float(o['derived'].split('cpu_s=')[1].split(';')[0]) / float(lifl['derived'].split('cpu_s=')[1].split(';')[0]):.2f}x"
            ),
        })
    return rows
