"""Minimal, dependency-free stand-in for the `hypothesis` API subset
used by test_properties.py.

The image doesn't ship hypothesis; rather than skipping the property
suite wholesale, this shim re-implements just enough — ``given``,
``settings`` profiles, and the six strategies the tests draw from — as
a deterministic random sampler (fixed per-test seed, ``max_examples``
draws, with a bias toward boundary values).  No shrinking, no database:
a failing example is reported verbatim in the assertion message so it
can be pasted into a regression test.

If real hypothesis is ever installed, test_properties.py prefers it and
this module goes unused.
"""
from __future__ import annotations

import random
from typing import Any, Callable, List


class _Strategy:
    """A draw function + repr, mirroring hypothesis's SearchStrategy."""

    def __init__(self, draw: Callable[[random.Random], Any], label: str):
        self._draw = draw
        self.label = label

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def __repr__(self) -> str:
        return self.label


class strategies:
    """The `hypothesis.strategies` subset test_properties.py uses."""

    @staticmethod
    def floats(min_value: float, max_value: float, *, allow_nan: bool = True,
               width: int = 64) -> _Strategy:
        def draw(rng: random.Random) -> float:
            r = rng.random()
            if r < 0.05:
                return float(min_value)
            if r < 0.10:
                return float(max_value)
            if r < 0.13 and min_value <= 0.0 <= max_value:
                return 0.0
            return rng.uniform(min_value, max_value)

        return _Strategy(draw, f"floats({min_value}, {max_value})")

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        def draw(rng: random.Random) -> int:
            r = rng.random()
            if r < 0.05:
                return int(min_value)
            if r < 0.10:
                return int(max_value)
            return rng.randint(min_value, max_value)

        return _Strategy(draw, f"integers({min_value}, {max_value})")

    @staticmethod
    def lists(elements: _Strategy, *, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng: random.Random) -> List[Any]:
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]

        return _Strategy(draw, f"lists({elements!r})")

    @staticmethod
    def tuples(*parts: _Strategy) -> _Strategy:
        def draw(rng: random.Random):
            return tuple(p.example(rng) for p in parts)

        return _Strategy(draw, f"tuples({', '.join(map(repr, parts))})")

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        pool = list(seq)

        def draw(rng: random.Random):
            return rng.choice(pool)

        return _Strategy(draw, f"sampled_from({pool!r})")

    @staticmethod
    def dictionaries(keys: _Strategy, values: _Strategy, *,
                     min_size: int = 0, max_size: int = 10) -> _Strategy:
        def draw(rng: random.Random):
            n = rng.randint(min_size, max_size)
            out = {}
            for _ in range(n * 3):  # distinct-key retry budget
                if len(out) >= n:
                    break
                out[keys.example(rng)] = values.example(rng)
            while len(out) < min_size:  # keys strategy too small: force
                out[keys.example(rng)] = values.example(rng)
            return out

        return _Strategy(draw, "dictionaries(...)")


st = strategies


class _Profile:
    def __init__(self, max_examples: int = 100, deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline


class settings:
    """Profile registry compatible with hypothesis.settings usage."""

    _profiles = {"default": _Profile()}
    _current = _profiles["default"]

    def __init__(self, **kwargs):
        self._profile = _Profile(**kwargs)

    @classmethod
    def register_profile(cls, name: str, **kwargs) -> None:
        cls._profiles[name] = _Profile(**kwargs)

    @classmethod
    def load_profile(cls, name: str) -> None:
        cls._current = cls._profiles[name]


def given(*strats: _Strategy):
    """Run the test once per generated example (no shrinking)."""

    def deco(fn):
        # NOTE: the wrapper must expose a ZERO-arg signature — pytest
        # inspects it and would otherwise treat the strategy-filled
        # parameters as fixtures (functools.wraps would leak the
        # original signature via __wrapped__).
        def wrapper():
            # deterministic per-test seed: failures reproduce
            rng = random.Random(f"lifl-{fn.__name__}")
            for i in range(settings._current.max_examples):
                example = [s.example(rng) for s in strats]
                try:
                    fn(*example)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} falsified on example #{i}: "
                        f"{example!r}") from e

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
