"""The examples are API documentation — they must actually run.

Each example supports ``--fast`` (fewer rounds, same code paths); the
smoke tests run them as real subprocesses, exactly as a user would,
through the public ``Session`` API.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run_example(name: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", name), "--fast"],
        capture_output=True, text=True, timeout=600, env=env, cwd=ROOT)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    return out.stdout


@pytest.mark.slow
def test_quickstart_runs_via_session_api():
    stdout = _run_example("quickstart.py")
    assert "quickstart OK" in stdout
    # the Session part really drove rounds and saw driver events
    assert "model_version=2" in stdout
    assert "events=" in stdout


@pytest.mark.slow
def test_elastic_scaling_example_runs():
    stdout = _run_example("elastic_scaling.py")
    assert "elastic_scaling OK" in stdout
    assert "node_lost" in stdout and "node_joined" in stdout


@pytest.mark.slow
def test_multinode_example_runs():
    stdout = _run_example("multinode.py")
    assert "Multi-node LIFL" in stdout
    assert "connected nodes: ['node0', 'node1']" in stdout
    assert "partial from mid@" in stdout
    assert "client:" in stdout            # the external push was acked
    assert "done: cross-node rounds" in stdout
