"""Unit tests for the LIFL control plane (placement, hierarchy, reuse,
routing, gateway, object store, sidecar, coordinator)."""
import numpy as np
import pytest

import repro.core as core


# ---------------------------------------------------------------------------
# object store + gateway
# ---------------------------------------------------------------------------

def test_object_store_roundtrip_and_immutability():
    store = core.InProcObjectStore()
    x = np.random.default_rng(0).normal(size=(100,)).astype(np.float32)
    key = store.put(x)
    got = store.get(key)
    np.testing.assert_array_equal(got, x)
    with pytest.raises(ValueError):
        got[0] = 1.0  # immutable (paper §4.1)
    store.delete(key)
    assert not store.contains(key)
    assert store.bytes_in_use == 0


def test_shared_memory_store_zero_copy():
    store = core.SharedMemoryObjectStore(capacity_bytes=1 << 24)
    try:
        x = np.arange(1024, dtype=np.float32)
        key = store.put(x)
        a = store.get(key)
        b = store.get(key)
        np.testing.assert_array_equal(a, x)
        # both views alias the same shared segment (zero-copy)
        assert a.__array_interface__["data"][0] == b.__array_interface__["data"][0]
        assert store.stats["zero_copy_gets"] == 2
    finally:
        store.close()


def test_store_capacity_enforced():
    store = core.InProcObjectStore(capacity_bytes=100)
    with pytest.raises(MemoryError):
        store.put(np.zeros(1000, np.float32))


def test_gateway_serialize_once_and_queue():
    store = core.InProcObjectStore()
    gw = core.Gateway("node0", store)
    seen = []
    gw.subscribe(seen.append)
    u = np.random.default_rng(1).normal(size=(50,)).astype(np.float32)
    payload = core.serialize_update(u, {"num_samples": 3.0})
    env = gw.receive_from_client(payload, round_id=0, sender_id="c0")
    assert gw.queue_length() == 1
    assert seen and seen[0].object_key == env.object_key
    np.testing.assert_allclose(store.get(env.object_key), u)
    assert env.num_samples == 3.0


def test_inter_node_gateway_transfer():
    s0, s1 = core.InProcObjectStore("n0"), core.InProcObjectStore("n1")
    g0, g1 = core.Gateway("n0", s0), core.Gateway("n1", s1)
    g0.connect_peer(g1)
    u = np.ones((32,), np.float32)
    env = g0.put_local(u, 0, "agg", 2.0)
    env2 = g0.send_to_node(env, "n1")
    np.testing.assert_array_equal(s1.get(env2.object_key), u)
    assert g0.stats["tx_updates"] == 1


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------

def _nodes(caps):
    return {
        f"node{i}": core.NodeState(node=f"node{i}", max_capacity=c)
        for i, c in enumerate(caps)
    }


def test_bestfit_concentrates_worstfit_spreads():
    best = core.place_updates(20, _nodes([20] * 5), policy="bestfit")
    worst = core.place_updates(20, _nodes([20] * 5), policy="worstfit")
    assert best.num_nodes_used == 1      # fully packed (paper Fig 8(d))
    assert worst.num_nodes_used == 5     # Least-Connection spreading


def test_placement_respects_capacity():
    p = core.place_updates(100, _nodes([20] * 5), policy="bestfit")
    assert p.num_nodes_used == 5
    assert not p.overflow
    counts = {n: len(v) for n, v in p.assignment.items()}
    assert all(c <= 20 for c in counts.values())
    p2 = core.place_updates(101, _nodes([20] * 5), policy="bestfit")
    assert p2.overflow  # beyond total capacity


def test_residual_capacity_model():
    ns = core.NodeState(node="n", max_capacity=20, arrival_rate=4, exec_time_s=2.0)
    assert ns.queue_estimate == 8.0
    assert ns.residual_capacity == 12.0


def test_measure_max_capacity_inflection():
    # E flat at 1.0 until overload at k=10 where E doubles
    obs = [(2, 1.0), (5, 1.0), (8, 1.1), (10, 2.2), (12, 4.0)]
    mc = core.measure_max_capacity(obs)
    assert mc == pytest.approx(22.0)  # k'·E' at inflection


def test_inter_node_transfers_counts_non_top_nodes():
    p = core.place_updates(60, _nodes([20] * 5), policy="bestfit")
    top = core.choose_top_node(_nodes([20] * 5), p.assignment)
    assert core.inter_node_transfers(p.assignment, top) == p.num_nodes_used - 1


# ---------------------------------------------------------------------------
# hierarchy planner
# ---------------------------------------------------------------------------

def test_ewma_alpha_07():
    e = core.EWMA(alpha=0.7)
    assert e.update(10) == 10
    assert e.update(20) == pytest.approx(0.7 * 10 + 0.3 * 20)


def test_planner_two_level_tree():
    planner = core.HierarchyPlanner(fan_in=2)
    plan = planner.plan({"node0": 8.0, "node1": 3.0}, smooth=False)
    assert plan.per_node["node0"].num_leaves == 4
    assert plan.per_node["node0"].has_middle
    assert plan.per_node["node1"].num_leaves == 2
    assert plan.top_node == "node0"
    assert plan.total_aggregators == 4 + 1 + 2 + 1 + 1


def test_planner_diff_creates_and_terminates():
    planner = core.HierarchyPlanner(fan_in=2)
    planner.plan({"a": 8.0}, smooth=False)
    new = planner.plan({"a": 2.0}, smooth=False)
    # EWMA smoothing off: 8 -> 2 updates means fewer aggregators
    diff = planner.diff(new)
    assert all(v <= 0 for v in diff.values()) or not diff


def test_eager_beats_lazy_in_act_model():
    planner = core.HierarchyPlanner(fan_in=2)
    plan = planner.plan({"n0": 10.0, "n1": 10.0}, smooth=False)
    kw = dict(t_agg=0.5, t_intra=0.7, t_inter=4.2)
    eager = core.aggregation_completion_time(20, plan, eager=True, **kw)
    lazy = core.aggregation_completion_time(20, plan, eager=False, **kw)
    assert eager < lazy


# ---------------------------------------------------------------------------
# reuse pool
# ---------------------------------------------------------------------------

def test_pool_reuse_and_promotion():
    pool = core.AggregatorPool(cold_start_s=2.0)
    inst, delay = pool.acquire("node0", core.Role.LEAF)
    assert delay == 2.0 and pool.stats.cold_starts == 1
    pool.release(inst.agg_id)
    inst2, delay2 = pool.acquire("node0", core.Role.MIDDLE)
    assert inst2.agg_id == inst.agg_id      # same warm runtime
    assert delay2 == 0.0                     # no cold start
    assert inst2.role == core.Role.MIDDLE    # promoted (§5.3)
    assert pool.stats.promoted == 1


def test_pool_no_cross_node_reuse():
    pool = core.AggregatorPool()
    a, _ = pool.acquire("node0", core.Role.LEAF)
    pool.release(a.agg_id)
    b, _ = pool.acquire("node1", core.Role.LEAF)
    assert b.agg_id != a.agg_id


def test_terminate_idle_scales_down():
    pool = core.AggregatorPool()
    ids = [pool.acquire("node0", core.Role.LEAF)[0].agg_id for _ in range(4)]
    for i in ids:
        pool.release(i)
    assert pool.terminate_idle() == 4
    assert pool.count() == 0


def test_executable_cache_hit_on_same_signature():
    builds = []
    cache = core.ExecutableCache(lambda **sig: builds.append(sig) or len(builds))
    cache.get(shape=(10,), fan_in=2)
    cache.get(shape=(10,), fan_in=2)
    cache.get(shape=(20,), fan_in=2)
    assert cache.hits == 1 and cache.misses == 2


# ---------------------------------------------------------------------------
# TAG + routing
# ---------------------------------------------------------------------------

def test_tag_single_rooted_and_groups():
    tag = core.build_two_level_tag({"n0": 2, "n1": 1}, 2, "n0")
    assert tag.validate_single_rooted()
    groups = tag.groups()
    assert "n0" in groups and "n1" in groups
    assert len(tag.leaves()) == 3


def test_routing_intra_vs_inter():
    core.clear_registry()
    stores = {n: core.InProcObjectStore(n) for n in ("n0", "n1")}
    gws = {n: core.Gateway(n, stores[n]) for n in stores}
    gws["n0"].connect_peer(gws["n1"])
    sms = {n: core.SockMap() for n in stores}
    mgrs = {n: core.RoutingManager(n, gws[n], sms[n]) for n in stores}
    for m in mgrs.values():
        core.register_node(m)
    tag = core.build_two_level_tag({"n0": 1, "n1": 1}, 2, "n0")
    for m in mgrs.values():
        m.install_tag(tag)
    sms["n0"].register("mid@n0")
    sms["n0"].register("top@n0")

    u = np.ones((16,), np.float32)
    env = gws["n0"].put_local(u, 0, "leaf0@n0", 1.0)
    assert mgrs["n0"].send("leaf0@n0", env)           # intra-node hop
    assert mgrs["n0"].stats["intra_node_sends"] == 1
    env1 = gws["n1"].put_local(u, 0, "mid@n1", 1.0)
    assert mgrs["n1"].send("mid@n1", env1)            # inter-node hop
    assert mgrs["n1"].stats["inter_node_sends"] == 1
    assert len(sms["n0"].mailbox("top@n0")) == 1


# ---------------------------------------------------------------------------
# sidecar (event-driven)
# ---------------------------------------------------------------------------

def test_sidecar_event_driven_zero_idle():
    mm = core.MetricsMap()
    sc = core.EventSidecar("agg1", mm)
    assert sc.invocations == 0            # no events -> no activity
    sc.on_aggregate(3, 0.5)
    assert sc.invocations == 1
    total, count = mm.peek("agg1", "agg_exec_s")
    assert total == pytest.approx(0.5) and count == 1
    drained = mm.drain()
    assert ("agg1", "agg_exec_s") in drained
    assert mm.peek("agg1", "agg_exec_s") == (0.0, 0)  # map reset


def test_metrics_server_mean():
    mm, ms = core.MetricsMap(), core.MetricsServer()
    sc = core.EventSidecar("a", mm)
    for t in (0.2, 0.4):
        sc.on_aggregate(1, t)
    ms.push(mm.drain())
    assert ms.mean("a", "agg_exec_s") == pytest.approx(0.3)


# ---------------------------------------------------------------------------
# coordinator
# ---------------------------------------------------------------------------

def test_coordinator_round_lifecycle():
    clients = [core.ClientInfo(f"c{i}", num_samples=10) for i in range(30)]
    nodes = _nodes([20] * 3)
    coord = core.Coordinator(core.Selector(clients), nodes)
    cfg = core.RoundConfig(aggregation_goal=10, over_provision=1.2)
    plan = coord.plan_round(cfg)
    assert len(plan.selected) == 12           # over-provisioned
    assert plan.tag.validate_single_rooted()
    v = coord.finish_round()
    assert v == 1
    plan2 = coord.plan_round(cfg)
    assert plan2.reused > 0                    # warm pool reused next round
    # selector diversity: round 2 prefers clients not picked in round 1
    first = {c.client_id for c in plan.selected}
    second = {c.client_id for c in plan2.selected}
    assert first.isdisjoint(second)
