"""Fused FL round semantics on the host mesh: eager==lazy, server
optimizers, compression, metrics."""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_opts
from repro.compat import use_mesh
from repro.configs import ARCHS
from repro.fl.compression import dequantize_tree, quantize_tree
from repro.fl.round import AggregationConfig, accumulate_updates, build_train_step
from repro.fl.server import apply_server_opt, init_server_state
from repro.launch.mesh import make_host_mesh
from repro.models import build_model


def _setup(timing="eager", micro=4, opt="fedavg"):
    cfg = ARCHS["llama3.2-3b"].reduced(dtype="float32")
    mesh = make_host_mesh()
    agg = AggregationConfig(
        hierarchy="flat", timing=timing, num_microbatches=micro, server_opt=opt
    )
    step, model = build_train_step(cfg, mesh, agg, opts=tiny_opts(vocab_axis=None))
    return cfg, mesh, agg, step, model


def _batch(cfg, B=8, S=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, size=(B, S))
    return {
        "tokens": jnp.asarray(toks, jnp.int32),
        "labels": jnp.asarray(np.roll(toks, -1, 1), jnp.int32),
    }


def test_eager_equals_lazy_aggregation():
    """The paper's precondition: eager (cumulative) and lazy (batch)
    produce the same aggregated update."""
    cfg, mesh, _, _, model = _setup()
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    with use_mesh(mesh):
        de, we, _ = accumulate_updates(
            model, params, batch, AggregationConfig(timing="eager", num_microbatches=4)
        )
        dl, wl, _ = accumulate_updates(
            model, params, batch, AggregationConfig(timing="lazy", num_microbatches=4)
        )
    assert float(we) == float(wl)
    for a, b in zip(jax.tree.leaves(de), jax.tree.leaves(dl)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-5, atol=1e-6)


def test_train_step_decreases_loss():
    cfg, mesh, agg, step, model = _setup(micro=2)
    with use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        state = init_server_state("fedavg", params)
        jstep = jax.jit(step)
        losses = []
        for r in range(8):
            params, state, m = jstep(params, state, _batch(cfg, seed=r % 2))
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    assert m["updates_aggregated"] == 2
    assert float(m["update_norm"]) > 0


@pytest.mark.parametrize("opt", ["fedavg", "fedavgm", "fedadam"])
def test_server_optimizers_progress(opt):
    cfg, mesh, agg, step, model = _setup(opt=opt, micro=2)
    lr = {"fedavg": 1.0, "fedavgm": 0.7, "fedadam": 0.01}[opt]
    agg = dataclasses.replace(agg, server_lr=lr)
    step, model = build_train_step(cfg, mesh, agg, opts=tiny_opts(vocab_axis=None))
    with use_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        state = init_server_state(opt, params)
        jstep = jax.jit(step)
        losses = []
        for r in range(6):
            params, state, m = jstep(params, state, _batch(cfg, seed=0))
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (opt, losses)


def test_int8_tree_compression_roundtrip():
    tree = {
        "w": jnp.asarray(np.random.default_rng(0).normal(size=(33, 7)), jnp.float32),
        "b": jnp.asarray(np.random.default_rng(1).normal(size=(5,)), jnp.bfloat16),
    }
    qs, meta, treedef = quantize_tree(tree)
    back = dequantize_tree(qs, meta, treedef)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        a32 = np.asarray(a, np.float32)
        b32 = np.asarray(b, np.float32)
        denom = max(np.abs(a32).max(), 1e-6)
        assert np.abs(a32 - b32).max() / denom < 0.02  # <2% of block max


def test_server_opt_shapes_preserved():
    cfg, mesh, _, _, model = _setup()
    params = model.init(jax.random.PRNGKey(0))
    delta = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32) * 0.01, params)
    for opt in ("fedavg", "fedavgm", "fedadam"):
        st = init_server_state(opt, params)
        newp, st2 = apply_server_opt(opt, params, st, delta, lr=0.5)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(newp)):
            assert a.shape == b.shape and a.dtype == b.dtype
        assert int(st2["step"]) == 1
