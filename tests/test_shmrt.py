"""The multi-process runtime: rings, object-store lifecycle, workers.

Fast tests cover the SPSC ring protocol (wraparound, backpressure,
cross-process transport) and the store's crash-safety mechanics; the
``slow``-marked tests drive real forked aggregator workers end-to-end
(warm reuse, SIGKILL mid-drain + segment reclaim, byte-identical
hierarchy vs the in-proc path).

    python -m pytest -m slow tests/test_shmrt.py    # multi-process smoke
"""
import os
import signal
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.core.aggregation import FedAvgState, fedavg_oracle
from repro.core.engine import make_engine
from repro.core.objectstore import SharedMemoryObjectStore
from repro.runtime.shmrt import Record, RecordKind, ShmRuntime, SpscRing, WorkerCrash

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

pytestmark = pytest.mark.skipif(
    not os.path.isdir("/dev/shm"), reason="needs POSIX /dev/shm")


def _ring_name(tag: str) -> str:
    return f"lifltest-{os.getpid()}-{tag}"


# ---------------------------------------------------------------------------
# SPSC ring protocol
# ---------------------------------------------------------------------------

def test_ring_roundtrip_and_wraparound():
    with SpscRing(_ring_name("wrap"), nslots=4, create=True) as ring:
        # 3 full laps over a 4-slot ring
        for i in range(12):
            rec = Record(kind=RecordKind.UPDATE, key=f"{i:016x}"[:16],
                         num_samples=float(i))
            assert ring.push(rec.pack())
            got = Record.unpack(ring.pop())
            assert got.key == rec.key and got.num_samples == float(i)
        assert ring.pop() is None  # empty


def test_ring_full_backpressure():
    with SpscRing(_ring_name("bp"), nslots=2, create=True) as ring:
        r = Record(kind=RecordKind.UPDATE).pack()
        assert ring.push(r) and ring.push(r)
        assert ring.full()
        assert not ring.push(r)                  # non-blocking: rejected
        assert not ring.push(r, timeout=0.05)    # blocking: times out
        ring.pop()
        assert ring.push(r)                      # space freed -> accepted
        assert len(ring) == 2


def test_ring_fifo_order_preserved():
    with SpscRing(_ring_name("fifo"), nslots=64, create=True) as ring:
        for i in range(50):
            ring.push(Record(kind=RecordKind.UPDATE, a=i).pack())
        got = [Record.unpack(r).a for r in ring.pop_many(64)]
        assert got == list(range(50))


def test_ring_cross_process_producer():
    """A separate (spawned, not forked) process attaches the ring by
    name and produces; the parent consumes."""
    name = _ring_name("xproc")
    with SpscRing(name, nslots=128, create=True) as ring:
        code = f"""
        from repro.runtime.shmrt import Record, RecordKind, SpscRing
        ring = SpscRing({name!r})
        for i in range(100):
            assert ring.push(Record(kind=RecordKind.UPDATE, a=i).pack(),
                             timeout=5.0)
        ring.close()
        """
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        out = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            capture_output=True, text=True, timeout=60, env=env)
        assert out.returncode == 0, out.stderr[-2000:]
        got = [Record.unpack(r).a for r in ring.pop_many(128)]
        assert got == list(range(100))


# ---------------------------------------------------------------------------
# object store: cross-process + crash safety
# ---------------------------------------------------------------------------

def test_store_cross_process_get_and_creator_survives():
    with SharedMemoryObjectStore(prefix=f"lt{os.getpid() & 0xffff:x}") as s:
        a = np.arange(1000, dtype=np.float32)
        k = s.put(a)
        code = f"""
        import numpy as np
        from repro.core.objectstore import SharedMemoryObjectStore
        s = SharedMemoryObjectStore(prefix={s.prefix!r})
        v = s.get({k!r})
        assert not v.flags.writeable
        print(float(v.sum()))
        s.close()
        """
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC
        out = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(code)],
            capture_output=True, text=True, timeout=60, env=env)
        assert out.returncode == 0, out.stderr[-2000:]
        assert abs(float(out.stdout) - float(a.sum())) < 1e-3
        # the attacher's exit must not have unlinked the creator's segment
        assert np.array_equal(s.get(k), a)


def test_store_atexit_reclaims_leaked_segments():
    """A process that creates objects and exits without close() must
    not leak /dev/shm segments (the crashed-test scenario)."""
    prefix = f"lk{os.getpid() & 0xffff:x}"
    code = f"""
    import numpy as np
    from repro.core.objectstore import SharedMemoryObjectStore
    s = SharedMemoryObjectStore(prefix={prefix!r})
    for _ in range(3):
        k = s.put(np.ones(4096, np.float32))
    print(k)  # no close(): the atexit registry must reclaim
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=60, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    leaked = [n for n in os.listdir("/dev/shm") if n.startswith(prefix)]
    assert leaked == []


def test_store_recycles_segments():
    with SharedMemoryObjectStore(prefix=f"rc{os.getpid() & 0xffff:x}") as s:
        a = np.full(2048, 3.0, np.float32)
        k1 = s.put(a)
        name1 = s.segment_name(k1)
        s.delete(k1)
        assert os.path.exists(f"/dev/shm/{name1}")  # parked, not unlinked
        k2 = s.put(a * 2)                            # same size: reused
        assert k2 == k1 and s.stats["recycled"] == 1
        assert np.array_equal(s.get(k2), a * 2)
    assert not os.path.exists(f"/dev/shm/{name1}")   # close() unlinks


# ---------------------------------------------------------------------------
# multi-process runtime (slow: forks real workers)
# ---------------------------------------------------------------------------

def _hier_inproc(ups, ws, N):
    """Reference: same grouping through the in-proc engines."""
    partials = []
    for g_ups, g_ws in zip(ups, ws):
        st = FedAvgState(engine=make_engine("blocked"))
        st.fold_many(list(g_ups), list(g_ws))
        partials.append(st)
    eng = make_engine("blocked")
    top = FedAvgState(engine=eng)
    top._ensure_acc(N)
    for p in partials:
        top.acc = eng.add_partial(top.acc, np.asarray(p.acc))
        top.weight += p.weight
        top.count += p.count
    return top.result()[0]


@pytest.mark.slow
def test_runtime_two_workers_bitexact_and_warm_reuse():
    N = 1 << 14
    rng = np.random.default_rng(0)
    ups = [[rng.normal(size=(N,)).astype(np.float32) for _ in range(3)]
           for _ in range(2)]
    ws = [[1.0, 2.5, 4.0], [3.0, 0.5, 7.0]]
    with ShmRuntime() as rt:
        for rid in (1, 2):  # round 2 re-tasks the same (warm) workers
            for g in range(2):
                rt.submit_task(f"mid@n{g}", goal=3, n_elems=N, round_id=rid)
            keys = []
            for g in range(2):
                for u, c in zip(ups[g], ws[g]):
                    k = rt.store.put(u)
                    keys.append(k)
                    rt.dispatch(f"mid@n{g}", k, c, round_id=rid)
            parts = sorted(rt.collect(2), key=lambda p: p.agg_id)
            assert [p.count for p in parts] == [3, 3]
            eng = make_engine("blocked")
            top = FedAvgState(engine=eng)
            top._ensure_acc(N)
            for p in parts:
                # zero payload copies: fold the shm view directly
                top.acc = eng.add_partial(top.acc, rt.store.get(p.key))
                top.weight += p.weight
                top.count += p.count
            got = top.result()[0]
            ref = _hier_inproc(ups, ws, N)
            assert np.array_equal(got, ref)  # byte-identical to in-proc
            assert np.allclose(
                got, fedavg_oracle([u for g in ups for u in g],
                                   [c for g in ws for c in g]),
                rtol=1e-5, atol=1e-5)
            for p in parts:
                rt.store.destroy(p.key)
            for k in keys:
                rt.store.delete(k)
        assert rt.stats["cold_starts"] == 2      # only round 1 forked
        assert rt.stats["warm_starts"] == 2      # round 2 reused both
        assert len(rt.worker_pids()) == 2
        assert rt.stats["warm_latency_s"] < rt.stats["cold_latency_s"]
    assert [n for n in os.listdir("/dev/shm") if n.startswith(rt.prefix)] == []


@pytest.mark.slow
def test_runtime_drain_closes_short_task():
    N = 1 << 12
    u = np.ones(N, np.float32)
    with ShmRuntime() as rt:
        rt.submit_task("mid@n0", goal=8, n_elems=N)
        rt.dispatch("mid@n0", rt.store.put(u), 2.0)
        rt.dispatch("mid@n0", rt.store.put(u * 3), 1.0)
        time.sleep(0.2)
        rt.drain("mid@n0")  # only 2 of 8 arrived (stragglers)
        p = rt.collect(1)[0]
        assert p.count == 2 and p.weight == 3.0
        np.testing.assert_allclose(
            np.asarray(rt.store.get(p.key)), u * 2.0 * 1 + u * 3.0)
        rt.store.destroy(p.key)


@pytest.mark.slow
def test_runtime_zero_update_drain_reuses_agg_id():
    """A task drained before any update (EMPTY closure) must neither
    leak the worker's accumulator segment nor block re-submitting the
    same tree position next round."""
    N = 1 << 12
    u = np.ones(N, np.float32)
    with ShmRuntime() as rt:
        for _ in range(3):  # repeated empty drains: no segment growth
            rt.submit_task("mid@n0", goal=4, n_elems=N)
            rt.drain("mid@n0")
            rt.quiesce(timeout=10.0)
            assert "mid@n0" not in rt._route
        wsegs = [n for n in os.listdir("/dev/shm")
                 if n.startswith(f"{rt.prefix}-w")]
        assert len(wsegs) <= 1  # the engine's single warm accumulator
        # the position is reusable and aggregates correctly
        rt.submit_task("mid@n0", goal=1, n_elems=N)
        rt.dispatch("mid@n0", rt.store.put(u * 7), 1.0)
        p = rt.collect(1)[0]
        np.testing.assert_allclose(np.asarray(rt.store.get(p.key)), u * 7)
        rt.store.destroy(p.key)


@pytest.mark.slow
def test_runtime_sigkill_mid_drain_reclaims_segments():
    """SIGKILL a worker holding a live shm accumulator: the dispatcher
    must detect the crash, reclaim the worker's segments, and keep
    serving."""
    N = 1 << 14
    u = np.ones(N, np.float32)
    with ShmRuntime() as rt:
        rt.submit_task("mid@n0", goal=8, n_elems=N)
        rt.dispatch("mid@n0", rt.store.put(u), 1.0)
        time.sleep(0.3)  # worker has folded: its accumulator segment exists
        victim = rt._route["mid@n0"]
        wseg_prefix = f"{rt.prefix}-w{victim.idx & 0xff:02x}"
        assert any(n.startswith(wseg_prefix) for n in os.listdir("/dev/shm"))
        os.kill(victim.proc.pid, signal.SIGKILL)
        time.sleep(0.2)
        with pytest.raises(WorkerCrash):
            rt.poll()
        # the dead worker's segments are gone
        assert not any(n.startswith(wseg_prefix)
                       for n in os.listdir("/dev/shm"))
        assert rt.stats["crashes"] == 1
        # the runtime recovers: a fresh worker serves the next task
        rt.submit_task("mid@n0", goal=1, n_elems=N)
        rt.dispatch("mid@n0", rt.store.put(u * 5), 1.0)
        p = rt.collect(1)[0]
        np.testing.assert_allclose(np.asarray(rt.store.get(p.key)), u * 5)
        rt.store.destroy(p.key)
    assert [n for n in os.listdir("/dev/shm") if n.startswith(rt.prefix)] == []


@pytest.mark.slow
def test_driver_redispatch_after_sigkill_reaches_full_goal():
    """SIGKILL a worker mid-round: the crashed subtree's surviving
    (still-sealed) update objects are re-dispatched to a fresh worker —
    the round reaches the FULL goal instead of shrinking it, and the
    runtime closes idempotently afterward."""
    from repro.runtime.driver import RoundDriver, ShmProcRuntime
    from repro.runtime.events import WorkerCrashed

    N = 1 << 14
    rng = np.random.default_rng(3)
    ups = {n: [rng.normal(size=(N,)).astype(np.float32) for _ in range(4)]
           for n in ("n0", "n1")}
    ws = {"n0": [1.0, 2.0, 3.0, 4.0], "n1": [2.0, 2.5, 1.5, 0.5]}
    # n0 plans 5 slots but only gets 4 updates, so its worker holds an
    # open, unpublished task when the SIGKILL lands
    assignment = {"n0": [0, 1, 2, 3, 4], "n1": [5, 6, 7, 8]}
    crashes = []

    rt = ShmProcRuntime()
    drv = RoundDriver(rt)
    drv.on(WorkerCrashed, crashes.append)

    def updates():
        for u, w in zip(ups["n0"], ws["n0"]):
            yield "n0", "c", u, w
        victim = rt._rt._route["mid@n0"]
        os.kill(victim.proc.pid, signal.SIGKILL)
        for u, w in zip(ups["n1"], ws["n1"]):
            yield "n1", "c", u, w

    out = drv.run_round(round_id=1, assignment=assignment,
                        updates=updates(), goal=8, n_elems=N, top_node="n0")
    try:
        assert out.accepted == 8
        assert out.crashes >= 1 and out.redispatched >= 1
        assert len(crashes) >= 1 and crashes[0].agg_id == "mid@n0"
        # every dispatched update made the round: full goal, no shrink
        assert out.count == 8
        oracle = fedavg_oracle(ups["n0"] + ups["n1"], ws["n0"] + ws["n1"])
        np.testing.assert_allclose(out.delta, oracle, rtol=1e-5, atol=1e-5)
    finally:
        rt.close()
        rt.close()  # close-after-crash is idempotent
    assert [n for n in os.listdir("/dev/shm")
            if n.startswith(rt._rt.prefix)] == []


@pytest.mark.slow
def test_trainer_shmproc_matches_inproc():
    """FederatedTrainer(runtime="shmproc") reproduces the in-proc
    round bit for bit over a ≥3-round run (same clients, same seeds,
    same engine math through the one RoundDriver loop)."""
    import jax

    from repro.configs import RESNET18
    from repro.core import ClientInfo, RoundConfig
    from repro.data import (build_client_datasets, dirichlet_partition,
                            synthetic_femnist)
    from repro.models import build_resnet
    from repro.runtime.trainer import ClientRuntime, FederatedTrainer

    def mk(runtime):
        cfg = RESNET18.reduced()
        model = build_resnet(cfg)
        params = model.init(jax.random.PRNGKey(0))
        imgs, labels = synthetic_femnist(200, num_classes=10, seed=0)
        shards = dirichlet_partition(labels, 8, alpha=0.5)
        dsets = build_client_datasets(imgs, labels, shards)
        clients = [ClientRuntime(ClientInfo(d.client_id, d.num_samples), d)
                   for d in dsets]
        return FederatedTrainer(
            model, params, clients,
            round_cfg=RoundConfig(aggregation_goal=4, over_provision=1.5),
            seed=0, runtime=runtime)

    tr_in, tr_sh = mk("inproc"), mk("shmproc")
    try:
        for r in range(3):
            ri = tr_in.run_round(client_lr=0.05, client_batch_size=32)
            rs = tr_sh.run_round(client_lr=0.05, client_batch_size=32)
            assert ri["updates"] == rs["updates"]
            # params bit-identical across runtimes after EVERY round
            for a, b in zip(jax.tree.leaves(tr_in.params),
                            jax.tree.leaves(tr_sh.params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert rs["reused"] > 0  # later rounds reused warm workers
    finally:
        tr_sh.close()
        tr_sh.close()  # double-close: no raise, no leak
        tr_in.close()
