"""Live fleet telemetry (PR 9): histograms, SLO tracking, the monitor.

Five seams this file holds:

  * **Histogram algebra** (property-based) — merge is associative on
    everything observable (buckets, zero count, quantiles; the float
    ``sum`` up to round-off), drain-then-absorb is indistinguishable
    from never draining, and every quantile answer is within the
    advertised relative error of the true order statistic;
  * **metric-map integration** — streaming histograms live in the
    ``MetricsMap`` next to the (sum, count) series, with the same
    non-destructive-snapshot / destructive-drain / prefixed-absorb
    contract ``drain_series`` has;
  * **pressure pricing** — the gateway's ``retry_after_s`` rises with
    the *measured* ingest p99, not just queue depth;
  * **the agent loop** — the FleetMonitor scrapes land mid-round
    (between SPAWN and FOLD), a sustained straggler fires one typed
    ``SLOBreached`` per episode, and a SIGKILLed daemon shows
    ``stale=True`` on the next scrape while the driver's round-edge
    view still believes the node is alive;
  * **surface parity** — ``Session.status()`` mirrors
    ``AggregationService.health()`` key-for-key, and the new gauges
    ride ``Session.metrics()``.
"""
import math
import os
import signal
import threading
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # vendored sampler shim — same API subset
    from _hypothesis_stub import given, settings, strategies as st

from repro.api import Session  # noqa: E402
from repro.core import ClientInfo, MetricsMap, NodeState, RoundConfig  # noqa: E402
from repro.obs import summary_line, to_prometheus  # noqa: E402
from repro.obs.live import FleetMonitor, Histogram, SLOTarget, SLOTracker  # noqa: E402
from repro.runtime.events import SLOBreached  # noqa: E402
from repro.runtime.netrt import (  # noqa: E402
    RemoteRuntime, reap_local_daemon, spawn_local_daemon,
)
from repro.serve import (  # noqa: E402
    AdmissionPolicy, AggregationService, IngressGateway, MinCohortIdleGap,
)

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")

REL = 0.05
vals = st.lists(st.floats(1e-6, 1e4, allow_nan=False),
                min_size=1, max_size=120)


def _fill(values):
    h = Histogram(rel_err=REL)
    for v in values:
        h.observe(v)
    return h


def _same(a: Histogram, b: Histogram) -> None:
    """Observational equality: everything but float-sum round-off."""
    wa, wb = a.to_wire(), b.to_wire()
    sa, sb = wa.pop("sum"), wb.pop("sum")
    assert wa == wb
    assert math.isclose(sa, sb, rel_tol=1e-9, abs_tol=1e-12)
    for q in (0.0, 0.5, 0.9, 0.99, 1.0):
        assert a.quantile(q) == b.quantile(q)


# ---------------------------------------------------------------------------
# histogram algebra (property-based)
# ---------------------------------------------------------------------------

@given(vals, vals, vals)
def test_hist_merge_associative_and_commutative(xs, ys, zs):
    left = _fill(xs).merge(_fill(ys)).merge(_fill(zs))
    right = _fill(xs).merge(_fill(ys).merge(_fill(zs)))
    _same(left, right)
    swapped = _fill(zs).merge(_fill(ys)).merge(_fill(xs))
    _same(left, swapped)
    assert left.count == len(xs) + len(ys) + len(zs)


@given(vals, vals)
def test_hist_drain_then_absorb_equals_never_drained(xs, ys):
    """The agent's destructive retrieval loses nothing: draining after
    the first batch and absorbing the snapshot back gives the same
    histogram as observing both batches straight through."""
    drained = _fill(xs)
    snap = drained.drain()
    assert drained.count == 0 and drained.sum == 0.0
    for v in ys:
        drained.observe(v)
    drained.merge(snap)
    _same(drained, _fill(xs + ys))


@given(vals)
def test_hist_quantile_relative_error_bound(values):
    """quantile(q) is within rel_err of the true order statistic for
    any stream inside the tracked range (the DDSketch guarantee)."""
    h = _fill(values)
    ordered = sorted(values)
    for q in (0.0, 0.25, 0.5, 0.9, 0.99, 1.0):
        truth = ordered[math.floor(q * (len(ordered) - 1))]
        est = h.quantile(q)
        assert abs(est - truth) <= REL * truth + 1e-12, (q, truth, est)


@given(vals)
def test_hist_wire_roundtrip_exact(values):
    h = _fill(values)
    back = Histogram.from_wire(h.to_wire())
    assert back.to_wire() == h.to_wire()
    import json
    assert json.loads(json.dumps(h.to_wire())) == h.to_wire()


def test_hist_zero_bucket_and_edges():
    h = Histogram(rel_err=REL, min_value=1e-8)
    for v in (0.0, -3.0, 1e-9, float("nan")):
        h.observe(v)
    assert h.zero == 4 and h.count == 4
    assert h.quantile(0.5) == 0.0
    assert h.quantile(0.5, default=7.0) == 0.0   # non-empty: no default
    assert Histogram().quantile(0.5, default=7.0) == 7.0
    # out-of-range values clamp into edge buckets, never KeyError
    h.observe(1e12)
    assert h.count == 5 and h.quantile(1.0) > 0.0


def test_hist_merge_shape_mismatch_raises():
    with pytest.raises(ValueError):
        Histogram(rel_err=0.05).merge(Histogram(rel_err=0.01))
    with pytest.raises(ValueError):
        Histogram(n_buckets=288).merge(Histogram(n_buckets=64))


# ---------------------------------------------------------------------------
# metric-map integration
# ---------------------------------------------------------------------------

def test_metricsmap_hist_snapshot_drain_absorb():
    m = MetricsMap()
    for v in (0.010, 0.020, 0.040):
        m.observe("tta", "jobA", v)
    assert m.quantile("tta", "jobA", 0.5) == pytest.approx(0.020, rel=0.06)
    assert m.quantile("tta", "nope", 0.5, default=-1.0) == -1.0
    # snapshot is non-destructive; hist() returns an isolated copy
    snap1 = m.hists_snapshot()
    snap2 = m.hists_snapshot()
    assert snap1 == snap2 and "tta/jobA" in snap1
    m.hist("tta", "jobA").observe(9.9)          # mutating the copy...
    assert m.hists_snapshot() == snap1          # ...changes nothing
    # drain is destructive; absorb with a node prefix rebuilds it
    drained = m.drain_hists()
    assert m.hists_snapshot() == {}
    m2 = MetricsMap()
    m2.absorb_hists(drained, prefix="n0.")
    assert m2.quantile("n0.tta", "jobA", 0.5) == pytest.approx(
        0.020, rel=0.06)
    # absorbing into an existing histogram merges, not replaces
    m2.absorb_hists(drained, prefix="n0.")
    assert m2.hist("n0.tta", "jobA").count == 6


# ---------------------------------------------------------------------------
# pressure pricing
# ---------------------------------------------------------------------------

def test_retry_after_rises_with_measured_ingest_p99():
    pol = AdmissionPolicy(retry_base_s=0.01, retry_cap_s=10.0,
                          ingest_gain=4.0)
    flat = pol.retry_after(5, 10, ingest_p99_s=0.0)
    slow = pol.retry_after(5, 10, ingest_p99_s=0.5)
    slower = pol.retry_after(5, 10, ingest_p99_s=1.0)
    assert flat < slow < slower                  # measured p99 lifts it
    assert pol.retry_after(50, 10, 0.5) > slow   # so does depth pressure
    assert pol.retry_after(10**6, 10, 10.0) == 10.0   # capped
    # same thing end-to-end through the gateway's measured histogram
    gw = IngressGateway(pol)
    gw.register("j", lambda *a, **k: True, lambda: 5)
    before = gw.retry_after_now()
    for _ in range(50):
        gw.ingest_hist.observe(0.5)
    assert gw.retry_after_now() > before


def test_slo_tracker_hysteresis_one_event_per_episode():
    fired = []
    slo = SLOTracker(breach_after=3, emit=fired.append)
    slo.set_target("j", SLOTarget(p99_tta_s=0.1))
    bad = dict(p99_tta_s=0.5, shed_frac=0.0)
    assert slo.observe("j", **bad) is None       # 1st violation
    assert slo.observe("j", **bad) is None       # 2nd
    ev = slo.observe("j", **bad)                 # 3rd: sustained
    assert isinstance(ev, SLOBreached)
    assert ev.metric == "p99_tta_s" and ev.measured == 0.5
    assert slo.observe("j", **bad) is None       # latched: no re-fire
    assert slo.status("j")["breached"] is True
    slo.observe("j", p99_tta_s=0.01, shed_frac=0.0)   # clean: re-arm
    assert slo.status("j")["breached"] is False
    for _ in range(3):
        slo.observe("j", **bad)
    assert len(fired) == 2                       # one per episode
    # the shed axis breaches independently, with its own metric name
    slo.set_target("k", {"max_shed_frac": 0.2})
    for _ in range(3):
        ev = slo.observe("k", p99_tta_s=0.0, shed_frac=0.9)
    assert ev.metric == "shed_frac" and ev.target == 0.2


# ---------------------------------------------------------------------------
# the agent loop (inproc service)
# ---------------------------------------------------------------------------

class _Model:
    def loss(self, params, batch):
        return jnp.sum(params["w"] ** 2), {}


N = 64


def _service(**kw):
    svc = AggregationService(
        admission=AdmissionPolicy(max_queue=64, job_quota=32), **kw)
    svc.add_job("j", _Model(), {"w": jnp.zeros((N,), jnp.float32)},
                [ClientInfo(client_id=f"c{i}", num_samples=10)
                 for i in range(8)],
                round_cfg=RoundConfig(aggregation_goal=4),
                # paced pushers are the injected stragglers: real TTA
                # runs tens of ms against a 1 ms promise
                slo=SLOTarget(p99_tta_s=0.001))
    return svc


def test_monitor_scrapes_mid_round_and_slo_breaches():
    svc = _service()
    breaches = []
    svc.driver.on(SLOBreached, breaches.append)
    mon = svc.start_monitor(period_s=0.01)
    assert svc.start_monitor() is mon            # idempotent
    stop = threading.Event()

    def pusher():
        k = 0
        while not stop.is_set():
            v = svc.submit("j", f"u{k}", np.full(N, 1.0, np.float32),
                           1.0, submission_id=f"u{k}")
            if v["admitted"]:
                k += 1
            time.sleep(0.02)                     # the straggler trickle

    th = threading.Thread(target=pusher, daemon=True)
    th.start()
    try:
        svc.run_rounds({"j": 8}, policy=MinCohortIdleGap(
            min_cohort=4, idle_gap_s=5.0))
    finally:
        stop.set()
        th.join(timeout=5)
    mc = mon.counters()
    # ≥1 scrape landed between SPAWN and FOLD of an open round — the
    # live-drain point the round-edge path can never see
    assert mc["mid_round_scrapes"] >= 1
    mid = [r for r in mon.log if r["mid_round"]]
    assert mid and any(p in ("spawn", "dispatch", "collect", "fold")
                       for r in mid for p in r["phases"])
    # the sustained straggler fired the typed event on the driver bus
    assert breaches and breaches[0].job == "j"
    assert breaches[0].metric == "p99_tta_s"
    assert breaches[0].measured > breaches[0].target
    assert svc.slo.status("j")["breached"] is True
    snap = svc.health()
    assert snap["jobs"]["j"]["tta"]["count"] >= 8
    assert snap["monitor"]["scrapes"] == mc["scrapes"]
    svc.close()
    assert svc.monitor is None                   # close stops the agent


def test_health_export_renders():
    svc = _service()
    svc.submit("j", "u0", np.zeros(N, np.float32), 1.0)
    snap = svc.health()
    prom = to_prometheus(snap)
    assert "lifl_open_rounds" in prom
    assert 'lifl_job_queue_depth{job="j"} 1' in prom
    assert 'lifl_job_tta_seconds{job="j",quantile="p99"}' in prom
    assert "lifl_gateway_retry_after_seconds" in prom
    line = summary_line(snap)
    assert "rounds" in line and "gateway" in line
    svc.close()


def test_session_status_health_key_parity():
    svc = _service()
    svc_keys = set(svc.health())
    svc.close()
    with Session.open(_Model(), {"w": jnp.zeros((N,), jnp.float32)}, [],
                      admission=True) as s:
        assert set(s.status()) == svc_keys
        m = s.metrics()
        for gauge in ("open_rounds", "gateway_queue_depth",
                      "fleet_nodes_alive"):
            assert gauge in m and m[gauge] >= 0
        assert s.status()["fleet_nodes_alive"] == m["fleet_nodes_alive"]


# ---------------------------------------------------------------------------
# the agent loop (real daemons)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_stats_frame_and_sigkill_shows_stale_before_round_edge():
    daemons = [spawn_local_daemon(f"mn{i}", runtime="inproc")
               for i in range(2)]
    procs = [p for p, _ in daemons]
    svc = _service(nodes={f"mn{i}": NodeState(node=f"mn{i}",
                                              max_capacity=20.0)
                          for i in range(2)},
                   runtime=RemoteRuntime([a for _, a in daemons]))
    rt = svc.runtime
    mon = FleetMonitor(svc, period_s=0.05)       # driven by hand
    try:
        mon.scrape_once()
        view = mon.fleet_view()
        assert set(view) == {"mn0", "mn1"}
        for f in view.values():
            assert f["stale"] is False and f["rtt_s"] > 0.0
            h = f["health"]
            for k in ("open_conns", "shm_bytes", "workers",
                      "workers_busy", "workers_parked", "ring_depth"):
                assert k in h, k
        # poll_stats: same frame through the controller's own conns,
        # non-destructive — no series count may shrink between polls
        # (the daemon's own tx counters legitimately grow per reply)
        s1 = rt.poll_stats()
        s2 = rt.poll_stats()
        assert set(s1) == {"mn0", "mn1"}
        for name in s1:
            for key, (_total, n) in s1[name]["series"].items():
                assert s2[name]["series"][key][1] >= n, key
            assert s1[name]["uptime_s"] <= s2[name]["uptime_s"]

        os.kill(procs[1].pid, signal.SIGKILL)
        time.sleep(0.3)
        mon.scrape_once()
        # the heartbeat sees the death NOW; the driver's round-edge
        # view hasn't run a round, so it still believes mn1 is alive
        assert mon.fleet_view()["mn1"]["stale"] is True
        assert mon.fleet_view()["mn0"]["stale"] is False
        assert rt._nodes["mn1"].alive is True
        assert mon.counters()["stale_events"] == 1
        mon.scrape_once()                        # still stale: no re-count
        assert mon.counters()["stale_events"] == 1
    finally:
        mon.stop()
        svc.close()
        for p in procs:
            reap_local_daemon(p)


@pytest.mark.slow
def test_spawn_daemon_log_file_lifecycle():
    proc, _addr = spawn_local_daemon("logx", runtime="inproc")
    path = proc.lifl_log_path
    assert path and os.path.exists(path)
    reap_local_daemon(proc)
    assert not os.path.exists(path)              # clean reap unlinks
    # a caller-supplied stdout opts out of the log file entirely
    import subprocess
    proc2, _addr2 = spawn_local_daemon("logy", runtime="inproc",
                                       stdout=subprocess.DEVNULL)
    assert proc2.lifl_log_path == ""
    reap_local_daemon(proc2)


# ---------------------------------------------------------------------------
# the minutes-long soak (excluded from tier-1; ``-m soak`` opts in)
# ---------------------------------------------------------------------------

@pytest.mark.soak
def test_soak_gates():
    from benchmarks.bench_soak import run as soak_run

    rows = {r["case"]: r["derived"] for r in soak_run(fast=True)}
    fleet = rows["fleet"]
    assert "soak_bitexact=1" in fleet
    frac = float(fleet.split("scrape_overhead_frac=")[1].split(";")[0])
    assert frac < 0.02
    mid = int(fleet.split("mid_round_scrapes=")[1].split(";")[0])
    assert mid >= 1
    for job in ("alpha", "beta"):
        assert f"slo_{job}" in rows
        assert "p99_tta_ms=" in rows[f"slo_{job}"]
