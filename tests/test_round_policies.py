"""Round-level policies: heavy-tailed stragglers under RoundDeadline,
pluggable per-round client sampling, locality-aware multi-node
placement, and the event-fed RC capacity model."""
import time

import numpy as np
import pytest

from repro.core import ClientInfo, Coordinator, NodeState, RoundConfig, Selector
from repro.core.aggregation import fedavg_oracle
from repro.core.placement import (
    cross_node_bytes,
    partial_traffic_bound,
    place_updates,
)
from repro.data import StragglerModel
from repro.runtime.driver import InProcRuntime, RoundDriver
from repro.runtime.events import PartialReady, RoundDeadline, UpdateArrived


# ---------------------------------------------------------------------------
# the straggler model itself
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["lognormal", "pareto"])
def test_straggler_model_is_heavy_tailed_and_deterministic(dist):
    m = StragglerModel(dist=dist, median_s=1.0, sigma=1.2, alpha=1.3)
    s1 = m.sample(4000, np.random.default_rng(3))
    s2 = m.sample(4000, np.random.default_rng(3))
    np.testing.assert_array_equal(s1, s2)        # seeded ⇒ reproducible
    assert np.all(s1 > 0)
    # heavy tail: the p99 client is many times the median one — the
    # regime where deadline-closed partial rounds are the normal case
    ratio = m.tail_ratio(4000, np.random.default_rng(4))
    assert ratio > 5.0
    # and the extreme straggler dwarfs even the p99 (fat, not just wide)
    assert np.max(s1) / np.quantile(s1, 0.5) > ratio


def test_straggler_model_rejects_unknown_dist():
    with pytest.raises(ValueError):
        StragglerModel(dist="uniform").sample(4, np.random.default_rng(0))


# ---------------------------------------------------------------------------
# RoundDeadline under realistic straggler exec times
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dist", ["lognormal", "pareto"])
def test_deadline_closes_with_partials_at_hand_under_stragglers(dist):
    """A heavy-tailed cohort against a wall-clock budget: the driver
    must close the round at the deadline with whatever subtrees have
    folded, and the folded params must equal the oracle over exactly
    the arrived subset."""
    rng = np.random.default_rng(11)
    model = StragglerModel(dist=dist, median_s=1.0, sigma=1.2, alpha=1.3)
    n_clients = 12
    # scale sampled exec times so the cohort straddles a ~0.25 s budget:
    # the fast half lands, the tail does not
    delays = model.sample(n_clients, rng)
    delays = 0.08 * delays / np.median(delays)
    ups = [rng.normal(size=256).astype(np.float32) for _ in range(n_clients)]
    ws = [float(1 + i % 4) for i in range(n_clients)]

    def updates():
        for i in range(n_clients):
            time.sleep(delays[i])        # iteration IS the client exec
            yield ("n0" if i % 2 == 0 else "n1"), f"c{i}", ups[i], ws[i]

    rt = InProcRuntime()
    drv = RoundDriver(rt)
    deadlines, arrived = [], []
    drv.on(RoundDeadline, deadlines.append)
    # UpdateArrived fires per *delivered* update: an update pulled from
    # the cohort right as the budget expires is dropped, not delivered
    drv.on(UpdateArrived, lambda ev: arrived.append(int(ev.client_id[1:])))
    out = drv.run_round(
        round_id=0,
        assignment={"n0": list(range(0, n_clients, 2)),
                    "n1": list(range(1, n_clients, 2))},
        updates=updates(), goal=n_clients, n_elems=256, deadline_s=0.25)
    rt.close()

    assert out.deadline_hit and len(deadlines) == 1
    assert 0 < out.accepted < n_clients          # a partial round
    assert out.count == out.accepted == len(arrived)
    # params match the oracle over exactly the arrived subset
    oracle = fedavg_oracle([ups[i] for i in arrived],
                           [ws[i] for i in arrived])
    np.testing.assert_allclose(out.delta, oracle, rtol=1e-5, atol=1e-6)
    assert out.weight == pytest.approx(sum(ws[i] for i in arrived))


# ---------------------------------------------------------------------------
# per-round client sampling as a pluggable policy
# ---------------------------------------------------------------------------

def _mk_coordinator(n_clients=20, seed=0):
    infos = [ClientInfo(f"c{i}", num_samples=1 + i) for i in range(n_clients)]
    nodes = {f"n{i}": NodeState(node=f"n{i}", max_capacity=20.0)
             for i in range(3)}
    return Coordinator(Selector(infos, seed=seed), nodes)


def _seeded_sampler(seed, k=6):
    rng = np.random.default_rng(seed)

    def sampler(round_id, pool):
        idx = rng.choice(len(pool), size=min(k, len(pool)), replace=False)
        return [pool[i] for i in sorted(idx)]

    return sampler


def test_seeded_sampler_reproduces_cohorts():
    cfg = RoundConfig(aggregation_goal=4)
    picks = []
    for _ in range(2):  # two independent coordinators, same sampler seed
        coord = _mk_coordinator()
        sampler = _seeded_sampler(42)   # one RNG advancing across rounds
        runs = []
        for _ in range(3):
            plan = coord.plan_round(cfg, sampler=sampler)
            runs.append([c.client_id for c in plan.selected])
            coord.finish_round()
        picks.append(runs)
    assert picks[0] == picks[1]                  # bit-reproducible
    assert len(set(map(tuple, picks[0]))) > 1    # and not degenerate
    # a different sampler seed draws a different cohort sequence
    coord = _mk_coordinator()
    other = [c.client_id
             for c in coord.plan_round(cfg,
                                       sampler=_seeded_sampler(7)).selected]
    assert other != picks[0][0]


def test_sampler_updates_selection_bookkeeping():
    coord = _mk_coordinator()
    plan = coord.plan_round(RoundConfig(aggregation_goal=4),
                            sampler=lambda rid, pool: pool[:3])
    assert [c.client_id for c in plan.selected] == ["c0", "c1", "c2"]
    assert all(c.last_selected_round == 0 for c in plan.selected)
    coord.finish_round()
    # without a sampler the built-in diversity selector resumes and
    # deprioritizes the just-sampled clients
    plan2 = coord.plan_round(RoundConfig(aggregation_goal=4,
                                         over_provision=1.0))
    assert not {"c0", "c1", "c2"} & {c.client_id for c in plan2.selected}


def test_trainer_run_round_accepts_sampler():
    """The sampler kwarg rides Session.run_round → FederatedTrainer →
    Coordinator.plan_round; with a constant sampler the cohort is
    pinned, observable through UpdateArrived events."""
    jax = pytest.importorskip("jax")
    from repro.api import Session
    from repro.configs.resnet import RESNET18
    from repro.data import (build_client_datasets, dirichlet_partition,
                            synthetic_femnist)
    from repro.models import build_resnet
    from repro.runtime import ClientRuntime

    cfg = RESNET18.reduced()
    model = build_resnet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    imgs, labels = synthetic_femnist(120, num_classes=10, seed=0)
    shards = dirichlet_partition(labels, 8, alpha=0.5)
    clients = [ClientRuntime(ClientInfo(d.client_id, d.num_samples), d)
               for d in build_client_datasets(imgs, labels, shards)]
    pinned = {clients[1].info.client_id, clients[3].info.client_id,
              clients[5].info.client_id}

    with Session.open(model, params, clients,
                      round_cfg=RoundConfig(aggregation_goal=3,
                                            over_provision=1.0)) as s:
        seen = []
        s.on(UpdateArrived, lambda ev: seen.append(ev.client_id))
        s.run_round(client_lr=0.05,
                    sampler=lambda rid, pool: [c for c in pool
                                               if c.client_id in pinned])
        assert set(seen) == pinned


# ---------------------------------------------------------------------------
# locality-aware multi-node placement + event-fed RC model
# ---------------------------------------------------------------------------

def _nodes(caps):
    return {f"n{i}": NodeState(node=f"n{i}", max_capacity=c)
            for i, c in enumerate(caps)}


def test_locality_policy_minimizes_cross_node_partials():
    # 8 updates fit on one node: locality uses exactly one; worstfit
    # (the SL-H spreading baseline) uses them all
    loc = place_updates(8, _nodes([10.0, 10.0, 10.0]), policy="locality")
    assert loc.num_nodes_used == 1
    spread = place_updates(8, _nodes([10.0, 10.0, 10.0]), policy="worstfit")
    assert spread.num_nodes_used == 3
    model_bytes = 4 * (1 << 20)
    top = loc.nodes_used[0]
    assert cross_node_bytes(loc.assignment, top, model_bytes) == 0
    assert cross_node_bytes(spread.assignment, spread.nodes_used[0],
                            model_bytes) == 2 * model_bytes


def test_locality_policy_spills_to_largest_rc_node():
    # the first open and every spill pick the biggest-RC unused node —
    # a fresh subtree should absorb the most before the next spill —
    # so 12 updates land as n2(9) + n1(3), and n0 never opens
    nodes = _nodes([3.0, 4.0, 9.0])
    p = place_updates(12, nodes, policy="locality")
    assert set(p.nodes_used) == {"n1", "n2"}
    assert len(p.assignment["n2"]) == 9 and len(p.assignment["n1"]) == 3
    assert p.overflow == []


def test_partial_traffic_bound():
    assert partial_traffic_bound(2, 100) == 220
    assert partial_traffic_bound(3, 10, slack=1.0) == 30


def test_partial_ready_events_feed_rc_capacity_model():
    """PartialReady through Coordinator.handle_event updates the
    subtree's node E_{i,t}/k_{i,t} EWMAs — the RC model learns node
    speed from the same events that cross the wire in multi-node
    rounds."""
    coord = _mk_coordinator()
    ns = coord.nodes["n1"]
    e0, k0 = ns.exec_time_s, ns.arrival_rate
    coord.handle_event(PartialReady(round_id=0, agg_id="mid@n1",
                                    key="k", weight=4.0, count=6,
                                    exec_s=3.0))
    assert ns.exec_time_s == pytest.approx(0.5 * e0 + 0.5 * 3.0)
    # the rate is count over the BLENDED exec time, so Q = k·E stays in
    # update units (Little's law) across rounds
    blended = 0.5 * e0 + 0.5 * 3.0
    assert ns.arrival_rate == pytest.approx(0.5 * k0 + 0.5 * (6.0 / blended))
    # unknown node: ignored, no KeyError
    coord.handle_event(PartialReady(round_id=0, agg_id="mid@ghost",
                                    key="k", exec_s=1.0, count=1))
    # the EWMA'd exec time shrinks the node's residual capacity
    assert ns.residual_capacity < ns.max_capacity
