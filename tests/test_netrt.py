"""The multi-node transport: frame protocol, netd daemons, RemoteRuntime
through the unchanged RoundDriver, dead-peer teardown, and serve mode.

Daemon-based tests spawn real OS processes (``python -m
repro.runtime.netrt.netd``) joined to the controller by loopback TCP —
the acceptance scenario is two daemons each running its *own*
shared-memory runtime, producing params bit-identical to the
single-node in-proc tree over 3 rounds."""
import os
import signal
import socket
import subprocess
import sys
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core.aggregation import fedavg_oracle
from repro.runtime.driver import InProcRuntime, RoundDriver
from repro.runtime.events import NodeLost, WorkerCrashed
from repro.runtime.netrt import (
    FrameConn,
    FrameServer,
    PeerDead,
    RemoteRuntime,
    connect,
    push_update,
    spawn_local_daemon,
)
from repro.runtime.netrt.transport import parse_addr

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


# ---------------------------------------------------------------------------
# transport
# ---------------------------------------------------------------------------

def _pair():
    a, b = socket.socketpair()
    return FrameConn(a, peer="a"), FrameConn(b, peer="b")


def test_frame_roundtrip_with_blob():
    a, b = _pair()
    payload = np.arange(1000, dtype=np.float32)
    a.send("deliver", {"agg_id": "mid@n0", "weight": 2.5,
                       "dtype": "float32", "shape": [1000]},
           blob=payload)
    f = b.recv(timeout=2.0)
    assert f.kind == "deliver" and f.meta["weight"] == 2.5
    back = np.frombuffer(f.blob, np.float32)
    np.testing.assert_array_equal(back, payload)
    # counters saw the full frame both ways
    assert a.tx_bytes == b.rx_bytes > payload.nbytes
    assert a.tx_by_kind["deliver"] == b.rx_by_kind["deliver"]
    a.close(), b.close()


def test_frames_survive_partial_reads_and_coalescing():
    """Many frames written back-to-back parse out one by one, whatever
    the segmentation (the incremental parser keeps partial frames)."""
    a, b = _pair()
    for i in range(50):
        a.send("event", {"i": i}, blob=bytes([i]) * i)
    got = [b.recv(timeout=2.0) for _ in range(50)]
    assert [f.meta["i"] for f in got] == list(range(50))
    assert all(len(f.blob) == f.meta["i"] for f in got)
    a.close(), b.close()


def test_recv_timeout_returns_none_then_completes():
    a, b = _pair()
    assert b.recv(timeout=0.05) is None
    a.send("ping", {})
    assert b.recv(timeout=2.0).kind == "ping"
    a.close(), b.close()


def test_dead_peer_raises_peerdead():
    a, b = _pair()
    a.close()
    with pytest.raises(PeerDead):
        while True:
            b.recv(timeout=1.0)
    assert not b.alive


def _next_frame(srv, timeout=5.0):
    """Poll a FrameServer until a real frame arrives (accept and first
    frame usually land in separate poll calls)."""
    deadline = time.perf_counter() + timeout
    while time.perf_counter() < deadline:
        for c, f in srv.poll(0.1):
            if f is not None:
                return c, f
    raise AssertionError("no frame within timeout")


def test_connect_retries_until_server_binds():
    """A controller may start before its daemons: connect keeps
    retrying until the listener appears."""
    held: dict = {}

    def bind_late():
        time.sleep(0.4)
        held["srv"] = FrameServer(f"127.0.0.1:{held['port']}")

    # reserve a port, release it, bind it late from the thread
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    held["port"] = probe.getsockname()[1]
    probe.close()
    t = threading.Thread(target=bind_late)
    t.start()
    conn = connect(f"127.0.0.1:{held['port']}", timeout=5.0)
    t.join()
    conn.send("ping", {})
    _, f = _next_frame(held["srv"])
    assert f.kind == "ping"
    conn.close()
    held["srv"].close()


def test_unix_socket_addr():
    path = tempfile.mktemp(suffix=".nrt.sock")
    srv = FrameServer(f"unix:{path}")
    assert srv.addr == f"unix:{path}" and os.path.exists(path)
    conn = connect(srv.addr, timeout=2.0)
    conn.send("hello", {"role": "client"})
    _, f = _next_frame(srv)
    assert f.kind == "hello"
    conn.close()
    srv.close()
    assert not os.path.exists(path)   # unlinked on close


def test_parse_addr_rejects_garbage():
    with pytest.raises(ValueError):
        parse_addr("no-port-here")


# ---------------------------------------------------------------------------
# netd daemons
# ---------------------------------------------------------------------------

def _spawn_netd(node, runtime="inproc", timeout=30.0):
    return spawn_local_daemon(node, runtime=runtime, timeout=timeout,
                              stdout=subprocess.DEVNULL)


@pytest.fixture
def two_inproc_daemons():
    procs, addrs = [], []
    for name in ("nodeA", "nodeB"):
        p, a = _spawn_netd(name, "inproc")
        procs.append(p)
        addrs.append(a)
    yield procs, addrs
    for p in procs:
        if p.poll() is None:
            p.terminate()
    for p in procs:
        try:
            p.wait(timeout=10)
        except subprocess.TimeoutExpired:
            p.kill()


def _mk_updates(n_updates=6, n_elems=4096, seed=0):
    rng = np.random.default_rng(seed)
    ups = [rng.normal(size=n_elems).astype(np.float32)
           for _ in range(n_updates)]
    ws = [float(1 + i % 3) for i in range(n_updates)]
    return ups, ws


def _drive(drv, nodes, ups, ws, n_elems, round_id, kill_after=None):
    """One driven round: update i → nodes[i % 2]; ``kill_after=(idx,
    fn)`` calls ``fn`` right after update ``idx`` is delivered."""
    assignment = {nodes[0]: [i for i in range(len(ups)) if i % 2 == 0],
                  nodes[1]: [i for i in range(len(ups)) if i % 2 == 1]}

    def updates():
        for i, (u, w) in enumerate(zip(ups, ws)):
            yield nodes[i % 2], f"c{i}", u, w
            if kill_after is not None and i == kill_after[0]:
                kill_after[1]()

    return drv.run_round(round_id=round_id, assignment=assignment,
                         updates=updates(), goal=len(ups), n_elems=n_elems)


@pytest.mark.slow
def test_two_shm_nodes_three_rounds_bitexact_vs_inproc():
    """THE acceptance scenario: two OS processes joined by sockets,
    each running its own shared-memory runtime (forked workers, shm
    rings), 3 hierarchical rounds — params bit-identical to the
    single-node in-proc tree, only sealed partials on the wire."""
    if not os.path.isdir("/dev/shm"):
        pytest.skip("POSIX shared memory required")
    N = 4096
    ups, ws = _mk_updates(6, N)
    procs, addrs = [], []
    try:
        for name in ("nodeA", "nodeB"):
            p, a = _spawn_netd(name, "shmproc")
            procs.append(p)
            addrs.append(a)
        rt = RemoteRuntime(addrs)
        assert list(rt.node_info()) == ["nodeA", "nodeB"]
        drv = RoundDriver(rt)
        net_deltas = []
        for rid in range(3):
            out = _drive(drv, ["nodeA", "nodeB"], ups, ws, N, rid)
            assert out.count == 6 and out.crashes == 0
            net_deltas.append(out.delta)
        # partials-only traffic: per warm round, each node ships ~one
        # model-size object payload (plus tiny frame overhead)
        wire = rt.wire_stats()
        for name in ("nodeA", "nodeB"):
            obj = wire[name]["rx_by_kind"]["object"]
            assert obj <= 3 * (4 * N) * 1.1
        # nothing in-flight leaks at rest
        assert not rt._staged and not rt._partial_home
        rt.shutdown_nodes()
        rt.close()
    finally:
        for p in procs:
            if p.poll() is None:
                p.terminate()
        for p in procs:
            p.wait(timeout=10)

    in_rt = InProcRuntime()
    in_drv = RoundDriver(in_rt)
    for rid in range(3):
        ref = _drive(in_drv, ["nodeA", "nodeB"], ups, ws, N, rid)
        np.testing.assert_array_equal(ref.delta, net_deltas[rid])
    in_rt.close()


@pytest.mark.slow
def test_sigkilled_netd_mid_round_redispatches_to_survivor(
        two_inproc_daemons):
    """Dead-peer teardown (the transport fix): SIGKILL one netd
    mid-round → NodeLost + synthesized WorkerCrashed → the driver
    re-dispatches the subtree's staged keys to the surviving node, the
    round reaches its FULL goal, and no in-flight bookkeeping leaks."""
    procs, addrs = two_inproc_daemons
    N = 2048
    ups, ws = _mk_updates(6, N, seed=1)
    rt = RemoteRuntime(addrs)
    drv = RoundDriver(rt)
    lost, crashed = [], []
    drv.on(NodeLost, lost.append)
    drv.on(WorkerCrashed, crashed.append)

    def kill_b():
        os.kill(procs[1].pid, signal.SIGKILL)
        procs[1].wait()
        time.sleep(0.05)

    out = _drive(drv, ["nodeA", "nodeB"], ups, ws, N, 0,
                 kill_after=(4, kill_b))
    # full goal despite the node loss: the subtree moved to nodeA
    assert out.count == 6 and out.crashes == 1 and out.redispatched == 1
    assert [e.node for e in lost] == ["nodeB"]
    assert [e.agg_id for e in crashed] == ["mid@nodeB"]
    np.testing.assert_allclose(out.delta, fedavg_oracle(ups, ws),
                               rtol=1e-5, atol=1e-6)
    # dead-peer teardown released the node's in-flight round objects
    assert not rt._staged and not rt._partial_home
    assert all(not n.delivered for n in rt._nodes.values())
    assert rt.stats["node_lost"] == 1
    # the next round still runs, on the survivor alone
    out2 = drv.run_round(
        round_id=1, assignment={"nodeA": list(range(6))},
        updates=(("nodeA", f"c{i}", u, w)
                 for i, (u, w) in enumerate(zip(ups, ws))),
        goal=6, n_elems=N)
    assert out2.count == 6 and out2.crashes == 0
    rt.close()


def test_remote_runtime_duplicate_node_name_rejected(two_inproc_daemons):
    procs, addrs = two_inproc_daemons
    p, addr = _spawn_netd("nodeA")   # name collides with the fixture's
    try:
        with pytest.raises(ValueError, match="duplicate node name"):
            RemoteRuntime([addrs[0], addr])
    finally:
        p.terminate()
        p.wait(timeout=10)


def test_daemon_survives_bad_frames(two_inproc_daemons):
    """A malformed request gets an error reply; the daemon stays up."""
    _, addrs = two_inproc_daemons
    conn = connect(addrs[0], timeout=5.0)
    conn.send("hello", {"role": "client"})
    assert conn.recv_expect(("welcome",), 5.0).meta["node"] == "nodeA"
    conn.send("deliver", {"agg_id": "mid@nodeA", "key": "nope",
                          "weight": 1.0, "round_id": 0})   # no blob, unknown
    err = conn.recv_expect(("error",), 5.0)
    assert "nope" in err.meta["msg"]
    assert conn.ping() < 5.0                 # still alive
    conn.close()


# ---------------------------------------------------------------------------
# Session-level multi-node + serve mode
# ---------------------------------------------------------------------------

def _mk_session_fixtures():
    jax = pytest.importorskip("jax")
    from repro.configs.resnet import RESNET18
    from repro.core import ClientInfo
    from repro.data import (build_client_datasets, dirichlet_partition,
                            synthetic_femnist)
    from repro.models import build_resnet
    from repro.runtime import ClientRuntime

    model = build_resnet(RESNET18.reduced())
    params = model.init(jax.random.PRNGKey(0))
    imgs, labels = synthetic_femnist(120, num_classes=10, seed=0)
    shards = dirichlet_partition(labels, 8, alpha=0.5)
    clients = lambda: [  # noqa: E731 - fresh fleet per session
        ClientRuntime(ClientInfo(d.client_id, d.num_samples), d)
        for d in build_client_datasets(imgs, labels, shards)]
    return model, params, clients


@pytest.mark.slow
def test_session_multinode_params_match_inproc(two_inproc_daemons):
    """Session.open(nodes=[addr, addr]) drives the same rounds as a
    single-node inproc session with identically named/sized NodeStates:
    params bit-identical (same cohorts, same plan, same arithmetic)."""
    import jax
    from repro.api import Session
    from repro.core import NodeState, RoundConfig

    _, addrs = two_inproc_daemons
    model, params, clients = _mk_session_fixtures()
    rc = RoundConfig(aggregation_goal=4, over_provision=1.5,
                     placement_policy="locality")

    with Session.open(model, params, clients(), nodes=list(addrs),
                      round_cfg=rc) as s:
        assert set(s.nodes) == {"nodeA", "nodeB"}
        assert s.metrics()["runtime"] == "net"
        for _ in range(2):
            rec = s.run_round(client_lr=0.05)
            assert rec["updates"] == 4.0
        net_params = s.params
        side = s.metrics()["sidecar"]
        assert side.get("net/tx_bytes", 0) > 0    # updates to the nodes
        assert side.get("net/rx_bytes", 0) > 0    # fetched partials

    with Session.open(
            model, params, clients(),
            nodes={"nodeA": NodeState(node="nodeA", max_capacity=20.0),
                   "nodeB": NodeState(node="nodeB", max_capacity=20.0)},
            round_cfg=rc) as s2:
        for _ in range(2):
            s2.run_round(client_lr=0.05)
        ref_params = s2.params

    for a, b in zip(jax.tree.leaves(net_params),
                    jax.tree.leaves(ref_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_session_serve_accepts_external_client_process():
    """Serve mode: an external OS process pushes a submit_update frame
    over the wire; it takes a cohort slot in the next round."""
    import jax
    from repro.api import Session
    from repro.core import RoundConfig
    from repro.runtime.events import UpdateArrived

    model, params, clients = _mk_session_fixtures()
    n = sum(int(np.prod(np.shape(l))) for l in jax.tree.leaves(params))
    with Session.open(model, params, clients(),
                      round_cfg=RoundConfig(aggregation_goal=3,
                                            over_provision=1.0)) as s:
        addr = s.serve("127.0.0.1:0")
        assert s.serve_addr == addr
        assert s.serve(addr) == addr          # idempotent
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        code = (
            "import numpy as np\n"
            "from repro.runtime.netrt import push_update\n"
            f"ack = push_update({addr!r}, 'edge-7', "
            f"np.full({n}, 0.25, np.float32), weight=3.0)\n"
            "assert ack['queued'] == 1, ack\n")
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=120)
        assert r.returncode == 0, r.stderr
        seen = []
        s.on(UpdateArrived, lambda ev: seen.append(ev.client_id))
        s.run_round(client_lr=0.05)
        assert "edge-7" in seen               # it took a cohort slot
    assert s.serve_addr is None               # close stopped the server


def test_serve_rejects_wrong_size_update():
    """A bad external update is refused with an error frame; the serve
    loop keeps running."""
    model, params, clients = _mk_session_fixtures()
    from repro.api import Session

    with Session.open(model, params, clients()) as s:
        addr = s.serve("127.0.0.1:0")
        with pytest.raises(ValueError, match="rejected"):
            push_update(addr, "edge-bad", np.zeros(3, np.float32))
        # still serving after the rejection
        conn = connect(addr, timeout=5.0)
        assert conn.ping() < 5.0
        conn.close()
        # a size-matching but non-1-D payload is flattened on ingest,
        # never queued with a shape the fold loop would trip over
        import jax
        n = sum(int(np.prod(np.shape(l))) for l in jax.tree.leaves(params))
        push_update(addr, "edge-2d", np.zeros((1, n), np.float32))
        assert s.trainer._external[-1][1].ndim == 1
        s.trainer.submit_update("direct-2d", np.zeros((1, n), np.float32))
        assert s.trainer._external[-1][1].ndim == 1


def test_daemon_parks_runtime_when_controller_disconnects(
        two_inproc_daemons):
    """A controller that dies mid-round must not strand its open
    aggregators on the daemon: when the last controller disconnects the
    daemon quiesces, so a reconnecting controller can spawn the same
    agg_ids again."""
    _, addrs = two_inproc_daemons

    def controller():
        conn = connect(addrs[0], timeout=5.0)
        conn.send("hello", {"role": "controller"})
        conn.recv_expect(("welcome",), 5.0)
        conn.send("spawn", {"agg_id": "mid@nodeA", "goal": 2,
                            "n_elems": 64, "round_id": 0})
        return conn

    c1 = controller()
    c1.close()          # dies mid-round, task still open on the daemon
    time.sleep(0.3)     # let the daemon notice and park
    c2 = controller()   # same agg_id spawns cleanly again
    # an error reply for the spawn would arrive before the pong
    stash = []
    c2.ping(timeout=5.0, stash=stash)
    assert not [f for f in stash if f.kind == "error"]
    c2.close()


def test_daemon_error_reply_synthesizes_worker_crash(two_inproc_daemons):
    """A daemon-side spawn/deliver failure (the daemon survives, replies
    with an error frame) must not hang the round: the controller
    synthesizes a WorkerCrashed so the driver's re-dispatch — and its
    give-up cap — take over."""
    _, addrs = two_inproc_daemons
    rt = RemoteRuntime(addrs)
    rt.spawn_aggregator("mid@nodeA", goal=2, n_elems=64, round_id=3)
    # second spawn for the same open agg_id: the daemon refuses it
    rt.spawn_aggregator("mid@nodeA", goal=2, n_elems=64, round_id=3)
    deadline = time.perf_counter() + 10.0
    evs = []
    while not evs and time.perf_counter() < deadline:
        evs = [e for e in rt.poll_events(0.2)
               if isinstance(e, WorkerCrashed)]
    assert evs and evs[0].agg_id == "mid@nodeA" and evs[0].round_id == 3
    assert rt.stats["refused"] >= 1
    rt.close()


def test_quiesce_keeps_node_lost_drops_round_scoped(two_inproc_daemons):
    """The inter-round barrier must not eat cluster-state events: a
    NodeLost queued by a peer death survives quiesce (the coordinator
    still has to drop the node), while a stale WorkerCrashed — whose
    agg_id will be reused next round — does not."""
    procs, addrs = two_inproc_daemons
    rt = RemoteRuntime(addrs)
    rt.spawn_aggregator("mid@nodeB", goal=2, n_elems=64, round_id=0)
    os.kill(procs[1].pid, signal.SIGKILL)
    procs[1].wait()
    # a failed send tears the peer down and queues NodeLost + a
    # synthetic WorkerCrashed without anyone polling.  The FIRST send
    # after the kill may still land in the kernel buffer (no RST seen
    # yet), so retry until the teardown has fired — deterministic
    # within a couple of iterations.
    deadline = time.perf_counter() + 10.0
    while not rt._pending and time.perf_counter() < deadline:
        rt.drain("mid@nodeB")
        time.sleep(0.05)
    assert any(isinstance(e, NodeLost) for e in rt._pending)
    rt.quiesce()
    evs = rt.poll_events(0.0)
    assert [e.node for e in evs if isinstance(e, NodeLost)] == ["nodeB"]
    assert not [e for e in evs if isinstance(e, WorkerCrashed)]
    rt.close()


def test_session_open_rejects_runtime_with_node_addresses():
    from repro.api import Session

    with pytest.raises(ValueError, match="netd --runtime"):
        Session.open(object(), {}, [], runtime="shmproc",
                     nodes=["127.0.0.1:1"])


def test_session_multinode_close_before_first_round_closes_fleet(
        two_inproc_daemons):
    """Session.open(nodes=[...]) connects immediately, so close()
    before the first run_round must still reach the fleet — otherwise
    every daemon keeps a stale controller registered forever."""
    from repro.api import Session

    model, params, clients = _mk_session_fixtures()
    _, addrs = two_inproc_daemons
    s = Session.open(model, params, clients(), nodes=list(addrs))
    rt = s.trainer._runtime
    assert rt is not None                     # eager attach
    s.close()
    assert all(not n.alive for n in rt._nodes.values())


def test_node_death_between_publish_and_fetch_aborts_retriable(
        two_inproc_daemons):
    """The fail-stop window: a node dies after publishing its partial
    but before the top fold fetches it.  get_partial must run the full
    dead-peer teardown and raise; the driver's exception path closes
    the round retriable instead of hanging or leaking bookkeeping."""
    procs, addrs = two_inproc_daemons
    N = 512
    ups, ws = _mk_updates(4, N, seed=2)
    rt = RemoteRuntime(addrs)
    drv = RoundDriver(rt)

    real_get = rt.get_partial

    def dying_get(key):
        if rt._partial_home.get(key) == "nodeB" and procs[1].poll() is None:
            os.kill(procs[1].pid, signal.SIGKILL)
            procs[1].wait()
            time.sleep(0.05)
        return real_get(key)

    rt.get_partial = dying_get
    with pytest.raises(KeyError, match="lost with its node|unreachable"):
        _drive(drv, ["nodeA", "nodeB"], ups, ws, N, 0)
    rt.get_partial = real_get
    assert not rt._nodes["nodeB"].alive        # teardown ran
    assert not rt._staged                      # round objects released
    # the driver stays usable: retry on the survivor under the SAME rid
    out = drv.run_round(
        round_id=0, assignment={"nodeA": list(range(4))},
        updates=(("nodeA", f"c{i}", u, w)
                 for i, (u, w) in enumerate(zip(ups, ws))),
        goal=4, n_elems=N)
    assert out.count == 4
    np.testing.assert_allclose(out.delta, fedavg_oracle(ups, ws),
                               rtol=1e-5, atol=1e-6)
    rt.close()


# ---------------------------------------------------------------------------
# wire compression (FrameConn(compress=...))
# ---------------------------------------------------------------------------

def test_compressed_frame_roundtrip_and_counters():
    a, b = _pair()
    a.compress = 6
    payload = np.tile(np.arange(256, dtype=np.float32), 64)  # compressible
    a.send("deliver", {"agg_id": "mid@n0", "weight": 1.0}, blob=payload)
    f = b.recv(timeout=2.0)
    np.testing.assert_array_equal(np.frombuffer(f.blob, np.float32), payload)
    assert "_z" not in f.meta            # the marker never leaks upward
    # the wire carried far fewer bytes than the raw frame
    assert a.tx_by_kind["deliver"] < a.tx_raw_by_kind["deliver"] / 2
    assert b.rx_raw_by_kind["deliver"] == a.tx_raw_by_kind["deliver"]
    assert b.rx_by_kind["deliver"] == a.tx_by_kind["deliver"]
    a.close(), b.close()


def test_compression_falls_back_to_raw():
    a, b = _pair()
    a.compress = 6
    # incompressible blob: sent raw (no size win), decoded unchanged
    rnd = np.random.default_rng(0).integers(0, 256, 4096) \
        .astype(np.uint8).tobytes()
    a.send("x", {}, blob=rnd)
    assert b.recv(timeout=2.0).blob == rnd
    assert a.tx_by_kind["x"] == a.tx_raw_by_kind["x"]
    # tiny blobs below the threshold are never compressed
    a.send("y", {}, blob=b"abc")
    assert b.recv(timeout=2.0).blob == b"abc"
    a.close(), b.close()


def test_compressed_remote_round_bitexact(two_inproc_daemons):
    """End-to-end with compress on: the daemons decode the compressed
    update blobs, the round's delta is bit-identical to the
    uncompressed in-proc reference, and the update traffic measurably
    shrank (float32 model weights compress)."""
    _, addrs = two_inproc_daemons
    N = 4096
    # compressible updates (real weights compress less than this, but
    # the transport must win when the payload allows it)
    ups = [np.tile(np.float32(i + 1), N) for i in range(4)]
    ws = [1.0, 2.0, 1.0, 3.0]

    in_rt = InProcRuntime()
    ref = _drive(RoundDriver(in_rt), ["nodeA", "nodeB"], ups, ws, N, 0)
    in_rt.close()

    rt = RemoteRuntime(addrs, compress=6)
    out = _drive(RoundDriver(rt), ["nodeA", "nodeB"], ups, ws, N, 0)
    np.testing.assert_array_equal(out.delta, ref.delta)
    wire = rt.wire_stats()
    rt.close()
    total_tx = sum(v["tx_bytes"] for v in wire.values())
    # 4 updates × 16 KiB raw: with compression the deliver path must
    # ship far less than the raw payload bytes
    assert total_tx < 4 * 4 * N / 2
