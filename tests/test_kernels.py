"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes (assignment requirement (c))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import fedavg_oracle
from repro.kernels.fedavg import (
    eager_accumulate,
    fedavg_accumulate_k,
    fedavg_reduce,
    fedavg_reduce_tree,
)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.quantize import QBLOCK, dequantize, quantize

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# fedavg
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("K,N", [(2, 64), (4, 1000), (8, 8192 + 17), (3, 64 * 128 * 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_reduce_pallas_vs_ref(K, N, dtype):
    U = jnp.asarray(RNG.normal(size=(K, N)), dtype)
    W = jnp.asarray(RNG.uniform(0.5, 4.0, size=(K,)), jnp.float32)
    got = fedavg_reduce(U, W, impl="pallas_interpret")
    ref = fedavg_reduce(U, W, impl="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    oracle = fedavg_oracle(
        [np.asarray(u, np.float32) for u in U], [float(w) for w in W]
    )
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), oracle, rtol=tol, atol=tol)


@pytest.mark.parametrize("N", [64, 999, 64 * 128 + 1])
def test_eager_accumulate_pallas_vs_ref(N):
    acc = jnp.asarray(RNG.normal(size=(N,)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(N,)), jnp.float32)
    got = eager_accumulate(acc.copy(), u, 1.75, impl="pallas_interpret")
    ref = acc + 1.75 * u
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("K,N", [(2, 64), (5, 999), (8, 64 * 128 + 1)])
def test_fedavg_accumulate_k_pallas_vs_ref(K, N):
    """K-way burst fold (aliased accumulator, single grid sweep)."""
    acc = jnp.asarray(RNG.normal(size=(N,)), jnp.float32)
    U = jnp.asarray(RNG.normal(size=(K, N)), jnp.float32)
    W = jnp.asarray(RNG.uniform(0.5, 4.0, size=(K,)), jnp.float32)
    got = fedavg_accumulate_k(acc.copy(), U, W, impl="pallas_interpret")
    ref = acc + jnp.sum(U * W[:, None], axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # a burst then one single fold == K+1 sequential folds
    seq = acc
    for k in range(K):
        seq = eager_accumulate(seq, U[k], W[k], impl="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(seq),
                               rtol=1e-5, atol=1e-5)


def test_fedavg_reduce_tree_matches_oracle():
    trees = [
        {"a": jnp.asarray(RNG.normal(size=(7, 3)), jnp.float32),
         "b": [jnp.asarray(RNG.normal(size=(11,)), jnp.float32)]}
        for _ in range(5)
    ]
    ws = [1.0, 2.0, 0.5, 3.0, 1.5]
    got = fedavg_reduce_tree(trees, ws, impl="jnp")
    for path in ("a",):
        oracle = fedavg_oracle([np.asarray(t["a"]) for t in trees], ws)
        np.testing.assert_allclose(np.asarray(got["a"]), oracle, rtol=1e-5)


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("N", [QBLOCK, QBLOCK * 3 + 5, 100, 70000])
def test_quantize_pallas_vs_ref_and_error_bound(N):
    x = jnp.asarray(RNG.normal(size=(N,)) * 3, jnp.float32)
    qp, sp = quantize(x, impl="pallas_interpret")
    qr, sr = quantize(x, impl="jnp")
    np.testing.assert_array_equal(np.asarray(qp), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), rtol=1e-6)
    back = dequantize(qp, sp, N, impl="pallas_interpret")
    # error bound: |x - deq| <= scale/2 per block
    err = np.abs(np.asarray(back) - np.asarray(x))
    scales = np.repeat(np.asarray(sp), QBLOCK)[:N]
    assert np.all(err <= scales / 2 + 1e-7)


def test_quantize_zero_block():
    x = jnp.zeros((QBLOCK * 2,), jnp.float32)
    q, s = quantize(x, impl="pallas_interpret")
    back = dequantize(q, s, x.shape[0], impl="pallas_interpret")
    np.testing.assert_array_equal(np.asarray(back), 0.0)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,K,G,D,window", [
    (1, 128, 1, 1, 32, -1),
    (2, 256, 2, 3, 64, -1),
    (1, 256, 4, 1, 64, 64),
    (2, 192, 2, 2, 32, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_pallas_vs_naive(B, S, K, G, D, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, S, K, G, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, S, K, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, S, K, D)), dtype)
    scale = D ** -0.5
    out = flash_attention(
        q, k, v, window=window, causal=True, scale=scale,
        impl="pallas_interpret", bq=64, bk=64,
    )
    ref = attention_ref(
        q.astype(jnp.float32).reshape(B, S, K * G, D).transpose(0, 2, 1, 3),
        k.astype(jnp.float32).transpose(0, 2, 1, 3),
        v.astype(jnp.float32).transpose(0, 2, 1, 3),
        scale=scale, window=window, causal=True,
    ).transpose(0, 2, 1, 3).reshape(B, S, K, G, D)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_vjp_matches_naive_grads():
    from repro.models.flash import flash_self_attention
    from repro.models.attention import _attend_naive

    B, S, K, G, D = 2, 64, 2, 2, 16
    q = jnp.asarray(RNG.normal(size=(B, S, K, G, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, K, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, K, D)), jnp.float32)
    pos = jnp.arange(S)
    for window in (-1, 8):
        gn = jax.grad(
            lambda q, k, v: jnp.sum(
                _attend_naive(q, k, v, pos, pos, window, True, 0.25) ** 2
            ), argnums=(0, 1, 2),
        )(q, k, v)
        gf = jax.grad(
            lambda q, k, v: jnp.sum(
                flash_self_attention(q, k, v, window, True, 0.25, 16) ** 2
            ), argnums=(0, 1, 2),
        )(q, k, v)
        for a, b in zip(gn, gf):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)
