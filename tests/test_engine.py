"""Aggregation-engine correctness: every backend (naive / blocked / jnp /
pallas_interpret) against ``fedavg_oracle``, through both the raw
``FedAvgState`` fold API and the full ``Aggregator`` pipeline (eager vs
lazy timing, bursty arrival orders, K-way batched drain), plus the
warm-pool buffer-reuse contract (§5.3 at the fold level)."""
import numpy as np
import pytest

from repro.core import (
    Aggregator,
    AggregatorPool,
    FedAvgState,
    InProcObjectStore,
    Role,
    fedavg_oracle,
    make_engine,
)
from repro.core.engine import BlockedNumpyEngine
from repro.core.gateway import UpdateEnvelope
from repro.core.sidecar import EventSidecar, MetricsMap

ENGINES = ["naive", "blocked", "jnp", "pallas_interpret"]
RNG = np.random.default_rng(7)


def _updates(k=6, n=1000, dtype=np.float32):
    us = [RNG.normal(size=(n,)).astype(dtype) for _ in range(k)]
    ws = [float(w) for w in RNG.uniform(0.5, 8.0, size=k)]
    return us, ws


def _feed(agg, store, us, ws):
    for u, w in zip(us, ws):
        key = store.put(u)
        agg.recv(UpdateEnvelope(key, 0, "c", w, enqueue_ts=0.0))


# ---------------------------------------------------------------------------
# FedAvgState-level: fold / fold_many / merge per backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_sequential_fold_matches_oracle(engine):
    us, ws = _updates()
    st = FedAvgState(engine=make_engine(engine))
    for u, w in zip(us, ws):
        st.fold(u, w)
    got, weight = st.result()
    np.testing.assert_allclose(got, fedavg_oracle(us, ws), rtol=1e-5, atol=1e-5)
    assert weight == pytest.approx(sum(ws))
    assert st.count == len(us)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("n", [64, 999, 64 * 1024 + 17])  # block remainders
def test_batched_fold_matches_oracle(engine, n):
    us, ws = _updates(k=5, n=n)
    st = FedAvgState(engine=make_engine(engine))
    st.fold_many(us, ws)
    got, _ = st.result()
    np.testing.assert_allclose(got, fedavg_oracle(us, ws), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("engine", ENGINES)
def test_merge_partials_matches_oracle(engine):
    us, ws = _updates(k=7)
    a = FedAvgState(engine=make_engine(engine))
    b = FedAvgState(engine=make_engine(engine))
    for u, w in zip(us[:3], ws[:3]):
        a.fold(u, w)
    b.fold_many(us[3:], ws[3:])
    a.merge(b)
    got, _ = a.result()
    np.testing.assert_allclose(got, fedavg_oracle(us, ws), rtol=1e-5, atol=1e-5)


def test_blocked_reads_view_without_copy_or_alloc():
    """The blocked fold consumes read-only store views in place and does
    zero per-fold allocation after warm-up."""
    eng = BlockedNumpyEngine()
    us, ws = _updates(k=4, n=50_000)
    for u in us:
        u.flags.writeable = False            # store.get() contract
    acc = eng.begin(us[0].size)
    eng.fold(acc, us[0], ws[0])
    allocs = eng.buffer_allocs
    eng.fold_many(acc, us[1:], ws[1:])
    assert eng.buffer_allocs == allocs       # no new buffers post warm-up


# ---------------------------------------------------------------------------
# Aggregator-level: eager vs lazy, bursty arrivals, batched drain
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("eager", [True, False])
def test_aggregator_end_to_end_matches_oracle(engine, eager):
    us, ws = _updates(k=9)
    store = InProcObjectStore()
    agg = Aggregator("a", store, goal=len(us), eager=eager,
                     engine=engine, batch_k=4)
    _feed(agg, store, us, ws)
    if not eager:
        agg.flush()
    assert agg.done
    got, weight = agg.result
    np.testing.assert_allclose(got, fedavg_oracle(us, ws), rtol=1e-5, atol=1e-5)
    assert weight == pytest.approx(sum(ws))


@pytest.mark.parametrize("engine", ENGINES)
def test_bursty_arrival_order_invariance(engine):
    """Permuted + bursty arrivals (lazy queue drained in K-way batches)
    agree with in-order eager arrival bit-for-bit within tolerance."""
    us, ws = _updates(k=11)
    perm = RNG.permutation(len(us))
    results = []
    for order, eager, batch_k in (
        (range(len(us)), True, 1),       # in-order, fold-on-arrival
        (perm, False, 8),                # permuted burst, batched drain
        (perm[::-1], False, 3),          # reversed burst, ragged batches
    ):
        store = InProcObjectStore()
        agg = Aggregator("a", store, goal=len(us), eager=eager,
                         engine=engine, batch_k=batch_k)
        _feed(agg, store, [us[i] for i in order], [ws[i] for i in order])
        if not eager:
            agg.flush()
        assert agg.done
        results.append(agg.result[0])
    oracle = fedavg_oracle(us, ws)
    for got in results:
        np.testing.assert_allclose(got, oracle, rtol=1e-5, atol=1e-5)


def test_drain_batches_reported_to_sidecar():
    """Lazy drain folds in K-way batches — the sidecar sees fewer, larger
    aggregate events; the updates total is conserved."""
    us, ws = _updates(k=10)
    store = InProcObjectStore()
    mm = MetricsMap()
    agg = Aggregator("a", store, goal=len(us), eager=False, engine="blocked",
                     batch_k=4, sidecar=EventSidecar("a", mm))
    _feed(agg, store, us, ws)
    agg.flush()
    total, events = mm.peek("a", "agg_updates")
    assert total == len(us)
    assert events == 3                       # 4 + 4 + 2

    # satellite: InProcObjectStore.meta() feeds real rx_bytes now
    rx, _ = mm.peek("a", "rx_bytes")
    assert rx == sum(u.nbytes for u in us)


def test_goal_overshoot_leaves_extra_updates_queued():
    us, ws = _updates(k=6)
    store = InProcObjectStore()
    agg = Aggregator("a", store, goal=4, eager=False, engine="blocked",
                     batch_k=8)
    _feed(agg, store, us, ws)
    agg.flush()
    assert agg.done and agg.state.count == 4  # batch clamped to the goal
    assert len(agg.fifo) == 2                 # stragglers left queued
    np.testing.assert_allclose(
        agg.result[0], fedavg_oracle(us[:4], ws[:4]), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# warm pool keeps engine buffers across release/acquire (§5.3)
# ---------------------------------------------------------------------------

def test_pool_reuse_keeps_warm_engine_buffers():
    pool = AggregatorPool(cold_start_s=1.0, engine="blocked")
    inst, _ = pool.acquire("node0", Role.LEAF)
    assert inst.engine is None               # lazy: sims never pay
    eng = pool.engine_for(inst)
    assert isinstance(eng, BlockedNumpyEngine)

    us, ws = _updates(k=3, n=20_000)
    acc = eng.begin(us[0].size)
    eng.fold_many(acc, us, ws)
    allocs = eng.buffer_allocs
    pool.release(inst.agg_id)

    inst2, delay = pool.acquire("node0", Role.MIDDLE)
    assert inst2.engine is eng and delay == 0.0   # same warm runtime
    acc2 = eng.begin(us[0].size)                  # buffer reused, re-zeroed
    assert eng.buffer_allocs == allocs
    eng.fold(acc2, us[0], ws[0])
    np.testing.assert_allclose(
        acc2, np.float32(ws[0]) * us[0], rtol=1e-5, atol=1e-5)


def test_blocked_begin_while_busy_is_safe():
    """A second begin() while the warm accumulator is handed out must
    not corrupt or untrack it; after recycle the warm buffer is reused
    with no fresh allocation."""
    eng = BlockedNumpyEngine()
    a = eng.begin(64)
    eng.fold(a, np.ones(64, np.float32), 2.0)
    b = eng.begin(64)                   # one-off: warm buffer is busy
    assert b is not a
    np.testing.assert_allclose(a, 2.0)  # first handle untouched
    allocs = eng.buffer_allocs
    eng.recycle(b)                      # not the warm buffer: no-op
    c = eng.begin(64)
    assert c is not a and eng.buffer_allocs == allocs + 1
    eng.recycle(a)
    d = eng.begin(64)                   # warm buffer back in rotation
    assert d is a and eng.buffer_allocs == allocs + 1


def test_jax_engine_recycle_reuses_device_buffer():
    """recycle() + begin() rewinds the donated device buffer to zeros
    instead of allocating — buffer_allocs stays flat across rounds."""
    from repro.core.engine import JaxEngine

    eng = JaxEngine(impl="jnp")
    us, ws = _updates(k=3, n=512)
    acc = eng.begin(512)
    for u, w in zip(us, ws):
        acc = eng.fold(acc, u, w)
    allocs = eng.buffer_allocs
    eng.recycle(acc)
    acc2 = eng.begin(512)                     # warm: donated zeroing
    assert eng.buffer_allocs == allocs
    np.testing.assert_allclose(np.asarray(acc2), 0.0)
    acc2 = eng.fold(acc2, us[0], ws[0])
    np.testing.assert_allclose(np.asarray(acc2), np.float32(ws[0]) * us[0],
                               rtol=1e-6, atol=1e-6)


def test_simulation_engine_speedup_strict():
    from repro.core.simulation import DataPlaneCosts

    c = DataPlaneCosts()
    assert c.t_agg_for("naive") == c.t_agg
    assert c.t_agg_for("blocked") < c.t_agg
    assert c.t_agg_for("auto") < c.t_agg      # resolves like make_engine
    with pytest.raises(ValueError):
        c.t_agg_for("warpdrive")


def test_object_store_meta():
    store = InProcObjectStore()
    x = RNG.normal(size=(17, 3)).astype(np.float32)
    key = store.put(x)
    m = store.meta(key)
    assert m.nbytes == x.nbytes and m.shape == (17, 3)
    assert m.dtype == "float32" and m.sealed


# ---------------------------------------------------------------------------
# dtype-preserving folds: reduced-precision wire updates, f32 accumulation
# ---------------------------------------------------------------------------

_WIRE_DTYPES = ["float16", "bfloat16"]


def _wire_updates(dtype_name, k=6, n=1000):
    """f32 ground-truth updates + their wire-dtype (rounded) twins."""
    dt = np.dtype(dtype_name) if dtype_name != "bfloat16" else np.dtype(
        pytest.importorskip("ml_dtypes").bfloat16)
    us32, ws = _updates(k=k, n=n)
    wire = [u.astype(dt) for u in us32]
    return us32, wire, ws, dt


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("wire_dtype", _WIRE_DTYPES)
def test_reduced_dtype_fold_accumulates_in_f32(engine, wire_dtype):
    """bf16/f16 wire updates fold without materializing f32 copies of
    the inputs; the running sum is f32, so the only error vs the f32
    oracle is the *per-update* wire rounding — exact against an oracle
    fed the same rounded values, loosely bounded against the f32 one."""
    us32, wire, ws, _ = _wire_updates(wire_dtype)
    st = FedAvgState(engine=make_engine(engine))
    for u, w in zip(wire, ws):
        st.fold(u, w)
    got, _ = st.result()
    assert np.asarray(st.acc).dtype == np.float32  # accumulate-in-f32
    # tight: same rounded inputs, f32 accumulation on both sides
    rounded_oracle = fedavg_oracle([u.astype(np.float32) for u in wire], ws)
    np.testing.assert_allclose(got, rounded_oracle, rtol=1e-5, atol=1e-5)
    # loose: wire precision loss is bounded (bf16 ≈ 8 mantissa bits)
    np.testing.assert_allclose(got, fedavg_oracle(us32, ws),
                               rtol=0.05, atol=0.05)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("wire_dtype", _WIRE_DTYPES)
def test_reduced_dtype_burst_fold_matches_rounded_oracle(engine, wire_dtype):
    """The K-way burst path (fedavg_accumulate_k for jax engines,
    blocked scratch staging for numpy) handles reduced wire dtypes."""
    _, wire, ws, _ = _wire_updates(wire_dtype, k=5, n=64 * 1024 + 17)
    st = FedAvgState(engine=make_engine(engine))
    st.fold_many(wire, ws)
    got, _ = st.result()
    rounded_oracle = fedavg_oracle([u.astype(np.float32) for u in wire], ws)
    np.testing.assert_allclose(got, rounded_oracle, rtol=1e-5, atol=1e-5)


def test_jax_engine_slab_preserves_wire_dtype():
    """A homogeneous bf16 burst must stage through a bf16 slab (half
    the host-side staging bytes), not silently upcast to f32."""
    jnp = pytest.importorskip("jax.numpy")  # noqa: F841
    ml = pytest.importorskip("ml_dtypes")
    from repro.core.engine import JaxEngine

    eng = JaxEngine(impl="jnp")
    _, wire, ws, dt = _wire_updates("bfloat16", k=4, n=512)
    acc = eng.begin(512)
    acc = eng.fold_many(acc, wire, ws)
    assert eng._slabs[np.dtype(ml.bfloat16).str].dtype == dt
    # mixed-dtype bursts fall back to the f32 slab
    mixed = [wire[0], wire[1].astype(np.float32), wire[2], wire[3]]
    acc = eng.fold_many(acc, mixed, ws)
    assert np.dtype(np.float32).str in eng._slabs


def test_accumulate_k_ref_path_bf16_wire():
    """fedavg_accumulate_k's jnp ref path: (K,N) bf16 slab folded into
    the aliased f32 accumulator matches the f32 oracle to wire
    tolerance."""
    jnp = pytest.importorskip("jax.numpy")
    pytest.importorskip("ml_dtypes")
    from repro.kernels.fedavg import fedavg_accumulate_k

    us32, wire, ws, _ = _wire_updates("bfloat16", k=4, n=4096)
    acc = jnp.zeros((4096,), jnp.float32)
    out = fedavg_accumulate_k(
        acc, jnp.asarray(np.stack(wire)),
        jnp.asarray(np.asarray(ws, np.float32)), impl="jnp")
    assert out.dtype == jnp.float32
    expect = sum(np.float32(w) * u.astype(np.float32)
                 for u, w in zip(wire, ws))
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# block-size autotune (EngineConfig(block="auto"))
# ---------------------------------------------------------------------------

def test_autotune_block_picks_candidate_and_caches():
    from repro.core import engine as engine_mod
    from repro.core.engine import (EngineConfig, autotune_block_elems,
                                   make_engine)

    # tiny probe: the result must come from the candidate set and be
    # cached for the rest of the process (keyed by the probe arguments:
    # a caller constraining the candidates gets its own answer, never a
    # tile outside its requested set)
    engine_mod._AUTOTUNE_CACHE.clear()
    try:
        blk = autotune_block_elems(candidates=(8 * 1024, 32 * 1024),
                                   n_elems=1 << 17, repeats=1)
        assert blk in (8 * 1024, 32 * 1024)
        # same arguments: answered from the cache, no re-probe
        assert len(engine_mod._AUTOTUNE_CACHE) == 1
        assert autotune_block_elems(candidates=(8 * 1024, 32 * 1024),
                                    n_elems=1 << 17, repeats=1) == blk
        assert len(engine_mod._AUTOTUNE_CACHE) == 1
        # different candidate set: a fresh probe honoring it
        assert autotune_block_elems(candidates=(123,), n_elems=1 << 14,
                                    repeats=1) == 123
        eng = make_engine(EngineConfig(name="blocked", block="auto"))
        assert eng.name == "blocked"
        assert eng.block_elems in engine_mod._AUTOTUNE_CANDIDATES
        eng2 = make_engine("blocked", block_elems="auto")
        assert eng2.block_elems == eng.block_elems  # default-key cache
    finally:
        engine_mod._AUTOTUNE_CACHE.clear()


def test_engine_config_explicit_block_and_autotuned_bits_match():
    from repro.core.engine import EngineConfig, make_engine

    rng = np.random.default_rng(3)
    ups = [rng.normal(size=5000).astype(np.float32) for _ in range(4)]
    ws = [1.0, 2.0, 0.5, 3.0]

    def run(engine):
        acc = engine.begin(5000)
        acc = engine.fold_many(acc, ups, ws)
        return np.asarray(acc)

    base = run(make_engine("blocked"))
    cfgd = run(make_engine(EngineConfig(name="blocked", block=16 * 1024)))
    # tile size changes the blocking, never the bits (per-element fold
    # order within a block is element-independent)
    np.testing.assert_array_equal(base, cfgd)
