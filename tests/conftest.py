"""Test fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
the single real CPU device; multi-device tests spawn subprocesses."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


def tiny_opts(**over):
    from repro.models.transformer import ModelOptions

    base = dict(
        attn_impl="naive", moe_impl="dense", ssm_chunk=8, loss_chunk=16,
        block_kv=8, remat=False,
    )
    base.update(over)
    return ModelOptions(**base)
