"""FoldPlan: plan-driven aggregation topology.

The explicit fold tree (core/placement.py) interpreted by RoundDriver:
controller-top (the legacy fold, bit for bit), worker-top (the top
aggregator is itself a runtime aggregator — a parked worker process
under shmproc), and node-top (the root lives on a worker node, partials
ship daemon→daemon, only the final folded Σc·u returns).  The
acceptance claims: all three topologies are bit-identical across
multi-round runs, node-top return traffic is ~1 × model, and a
SIGKILLed root node re-roots the round on a survivor.
"""
import os
import signal
import subprocess
import time

import numpy as np
import pytest

from repro.core.aggregation import fedavg_oracle
from repro.core.placement import (
    FoldPlan,
    FoldSite,
    NodeState,
    build_fold_plan,
    choose_top_node,
)
from repro.runtime.driver import InProcRuntime, RoundDriver
from repro.runtime.events import NodeLost, PartialShipped, TopFolded


# ---------------------------------------------------------------------------
# plan construction + wire round-trip
# ---------------------------------------------------------------------------

ASSIGNMENT = {"nodeA": [0, 2, 4], "nodeB": [1, 3, 5]}


def test_build_fold_plan_structure():
    plan = build_fold_plan(ASSIGNMENT, top_node="nodeA", topology="node")
    assert plan.root == "top@nodeA"
    assert plan.topology == "node"
    root = plan.site(plan.root)
    assert root.node == "nodeA" and root.goal == 2
    assert root.children == ("mid@nodeA", "mid@nodeB")
    mids = {s.agg_id: s for s in plan.mids}
    assert mids["mid@nodeA"].goal == 3 and mids["mid@nodeA"].tier == "worker"
    assert mids["mid@nodeB"].goal == 3


def test_build_fold_plan_empty_and_bad_topology():
    assert build_fold_plan({}) == FoldPlan()
    assert build_fold_plan({"n": []}) == FoldPlan()
    with pytest.raises(ValueError, match="topology"):
        build_fold_plan(ASSIGNMENT, topology="cloud")


def test_build_fold_plan_root_defaults_to_busiest():
    plan = build_fold_plan({"a": [0], "b": [1, 2, 3]}, topology="worker")
    assert plan.site(plan.root).node == "b"
    # a top_node outside the assignment falls back to the busiest too
    plan2 = build_fold_plan({"a": [0], "b": [1, 2]}, top_node="ghost")
    assert plan2.site(plan2.root).node == "b"


def test_fold_plan_wire_roundtrip():
    plan = build_fold_plan(ASSIGNMENT, top_node="nodeB", topology="worker")
    raw = plan.to_wire()
    assert isinstance(raw, bytes)
    back = FoldPlan.from_wire(raw)
    assert back == plan
    assert FoldPlan.from_wire(raw.decode()) == plan  # str transport too
    with pytest.raises(ValueError, match="FoldPlan"):
        FoldPlan.from_wire(b'{"plan":"NotAPlan"}')


def test_choose_top_node_rc_tiebreak():
    nodes = {
        "a": NodeState(node="a", max_capacity=10.0),
        "b": NodeState(node="b", max_capacity=30.0),
    }
    # equal update counts: the larger residual capacity wins
    assert choose_top_node(nodes, {"a": [0], "b": [1]}) == "b"
    # update count still dominates RC
    assert choose_top_node(nodes, {"a": [0, 2], "b": [1]}) == "a"


# ---------------------------------------------------------------------------
# driven rounds per topology
# ---------------------------------------------------------------------------

def _mk_updates(n_updates=6, n_elems=4096, seed=0):
    rng = np.random.default_rng(seed)
    ups = [rng.normal(size=n_elems).astype(np.float32)
           for _ in range(n_updates)]
    ws = [float(1 + i % 3) for i in range(n_updates)]
    return ups, ws


def _drive(drv, ups, ws, n_elems, round_id, plan):
    def updates():
        for i, (u, w) in enumerate(zip(ups, ws)):
            yield ("nodeA" if i % 2 == 0 else "nodeB"), f"c{i}", u, w

    return drv.run_round(round_id=round_id, assignment=ASSIGNMENT,
                         updates=updates(), goal=len(ups), n_elems=n_elems,
                         fold_plan=plan)


def _inproc_refs(ups, ws, n_elems, rounds):
    plan = build_fold_plan(ASSIGNMENT, top_node="nodeA",
                           topology="controller")
    rt = InProcRuntime()
    drv = RoundDriver(rt)
    refs = [_drive(drv, ups, ws, n_elems, r, plan) for r in range(rounds)]
    rt.close()
    return refs


def test_worker_top_inproc_bitexact_vs_controller_top():
    """The plan's root as a runtime aggregator (worker tier) folds the
    exact same bits as the controller-side fold."""
    N = 4096
    ups, ws = _mk_updates(6, N)
    refs = _inproc_refs(ups, ws, N, 2)

    rt = InProcRuntime()
    drv = RoundDriver(rt)
    events = []
    drv.on(TopFolded, events.append)
    plan = build_fold_plan(ASSIGNMENT, top_node="nodeA", topology="worker")
    for r in range(2):
        out = _drive(drv, ups, ws, N, r, plan)
        assert out.fold_tier == "worker" and out.root_node == "nodeA"
        assert out.count == 6 and out.weight == refs[r].weight
        np.testing.assert_array_equal(out.delta, refs[r].delta)
    rt.close()
    assert [e.tier for e in events] == ["worker", "worker"]
    # the controller fold also announces itself
    assert refs[0].fold_tier == "controller"


@pytest.mark.slow
def test_worker_top_shmproc_bitexact_vs_controller_top():
    """shmrt middle-tier option: the top aggregator is a parked worker
    process, not the dispatcher — and still bit-identical."""
    if not os.path.isdir("/dev/shm"):
        pytest.skip("POSIX shared memory required")
    from repro.runtime.driver import ShmProcRuntime

    N = 4096
    ups, ws = _mk_updates(6, N)
    refs = _inproc_refs(ups, ws, N, 2)

    rt = ShmProcRuntime()
    try:
        drv = RoundDriver(rt)
        plan = build_fold_plan(ASSIGNMENT, top_node="nodeA",
                               topology="worker")
        for r in range(2):
            out = _drive(drv, ups, ws, N, r, plan)
            assert out.fold_tier == "worker"
            assert out.count == 6
            np.testing.assert_array_equal(out.delta, refs[r].delta)
            # the top fold ran in a worker process, not this one
            assert out.workers >= 1
    finally:
        rt.close()


# ---------------------------------------------------------------------------
# node-top over real daemons
# ---------------------------------------------------------------------------

def _spawn_fleet(runtime="inproc"):
    from repro.runtime.netrt import spawn_local_daemon

    procs, addrs = [], []
    for name in ("nodeA", "nodeB"):
        p, a = spawn_local_daemon(name, runtime=runtime,
                                  stdout=subprocess.DEVNULL)
        procs.append(p)
        addrs.append(a)
    return procs, addrs


def _kill_fleet(procs):
    for p in procs:
        if p.poll() is None:
            p.terminate()
    for p in procs:
        try:
            p.wait(timeout=10)
        except subprocess.TimeoutExpired:
            p.kill()


@pytest.mark.slow
def test_node_top_two_daemons_bitexact_and_return_traffic():
    """THE node-top acceptance scenario: the root fold runs on nodeA's
    daemon, nodeB ships its sealed partial daemon→daemon, the
    controller fetches only the final folded Σc·u — return traffic
    ≤ 1 × model × 1.1 per round — and params are bit-identical to the
    single-node inproc tree over 3 rounds."""
    from repro.runtime.netrt import RemoteRuntime

    N = 4096
    ups, ws = _mk_updates(6, N)
    refs = _inproc_refs(ups, ws, N, 3)
    plan = build_fold_plan(ASSIGNMENT, top_node="nodeA", topology="node")

    procs, addrs = _spawn_fleet()
    try:
        rt = RemoteRuntime(addrs)
        drv = RoundDriver(rt)
        shipped, folded = [], []
        drv.on(PartialShipped, shipped.append)
        drv.on(TopFolded, folded.append)
        for r in range(3):
            out = _drive(drv, ups, ws, N, r, plan)
            assert out.fold_tier == "node" and out.root_node == "nodeA"
            assert out.count == 6 and out.crashes == 0
            np.testing.assert_array_equal(out.delta, refs[r].delta)
        # return traffic: one model-size object per ROUND total (from
        # the root only), not one per node
        wire = rt.wire_stats()
        model_bytes = 4 * N
        assert wire["nodeA"]["rx_by_kind"]["object"] <= \
            3 * model_bytes * 1.1
        assert wire["nodeB"]["rx_by_kind"].get("object", 0) == 0
        # nodeB's partial went daemon→daemon, once per round.
        # PartialShipped is pushed async by nodeB and can still be in
        # flight when run_round returns — drain (bounded) before
        # asserting the exact count
        deadline = time.time() + 5.0
        while len(shipped) < 3 and time.time() < deadline:
            for ev in rt.poll_events(0.05):
                drv.dispatch(ev)
        assert [(e.src, e.dst) for e in shipped] == \
            [("nodeB", "nodeA")] * 3
        assert all(e.nbytes == model_bytes for e in shipped)
        assert [(e.node, e.tier) for e in folded] == [("nodeA", "node")] * 3
        # nothing in-flight leaks at rest
        assert not rt._staged and not rt._partial_home
        rt.shutdown_nodes()
        rt.close()
    finally:
        _kill_fleet(procs)


@pytest.mark.slow
def test_sigkilled_root_node_reroots_on_survivor():
    """Acceptance: SIGKILL the ROOT node as the fold phase begins — the
    driver re-roots the round on the survivor (which re-collects the
    dead node's subtree from staged keys) and still reaches the full
    goal."""
    from repro.runtime.netrt import RemoteRuntime

    N = 2048
    ups, ws = _mk_updates(6, N, seed=1)
    plan = build_fold_plan(ASSIGNMENT, top_node="nodeA", topology="node")

    procs, addrs = _spawn_fleet()
    try:
        rt = RemoteRuntime(addrs)
        drv = RoundDriver(rt)
        lost, folded = [], []
        drv.on(NodeLost, lost.append)
        drv.on(TopFolded, folded.append)

        orig = rt.deliver_partial

        def killing_deliver(agg_id, key, weight, count, round_id=0, seq=0):
            # the first root-fold input: take the root down right now
            if procs[0].poll() is None:
                os.kill(procs[0].pid, signal.SIGKILL)
                procs[0].wait()
                time.sleep(0.05)
            return orig(agg_id, key, weight, count, round_id=round_id,
                        seq=seq)

        rt.deliver_partial = killing_deliver
        out = _drive(drv, ups, ws, N, 0, plan)
        rt.deliver_partial = orig

        assert out.count == 6                       # FULL goal
        assert out.fold_tier == "node"
        assert out.root_node == "nodeB"             # re-rooted
        assert out.crashes >= 1 and out.redispatched >= 1
        assert [e.node for e in lost] == ["nodeA"]
        assert folded and folded[-1].node == "nodeB"
        np.testing.assert_allclose(out.delta, fedavg_oracle(ups, ws),
                                   rtol=1e-5, atol=1e-6)
        # dead-peer teardown + end-of-round sweep left nothing behind
        assert not rt._staged and not rt._partial_home
        rt.close()
    finally:
        _kill_fleet(procs)


@pytest.mark.slow
def test_session_node_top_matches_controller_top_params():
    """Session-level: the same rounds under topology='node' (2 daemons)
    and topology='controller' produce bit-identical params — the
    topology changes where bytes move, never what they say."""
    jax = pytest.importorskip("jax")
    from repro.api import Session
    from repro.configs.resnet import RESNET18
    from repro.core import ClientInfo, RoundConfig
    from repro.data import (build_client_datasets, dirichlet_partition,
                            synthetic_femnist)
    from repro.models import build_resnet
    from repro.runtime import ClientRuntime

    model = build_resnet(RESNET18.reduced())
    params = model.init(jax.random.PRNGKey(0))
    imgs, labels = synthetic_femnist(120, num_classes=10, seed=0)
    shards = dirichlet_partition(labels, 8, alpha=0.5)

    def clients():
        return [ClientRuntime(ClientInfo(d.client_id, d.num_samples), d)
                for d in build_client_datasets(imgs, labels, shards)]

    def rc(topology):
        return RoundConfig(aggregation_goal=4, over_provision=1.5,
                           placement_policy="locality", topology=topology)

    procs, addrs = _spawn_fleet()
    try:
        with Session.open(model, params, clients(), nodes=list(addrs),
                          round_cfg=rc("node")) as s:
            roots = []
            s.on(TopFolded, lambda ev: roots.append((ev.node, ev.tier)))
            for _ in range(2):
                s.run_round(client_lr=0.05)
            node_params = s.params
            assert all(t == "node" for _, t in roots) and len(roots) == 2
            side = s.metrics()["sidecar"]
            assert side.get("net/rx_bytes", 0) > 0
    finally:
        _kill_fleet(procs)

    from repro.core import NodeState as NS
    with Session.open(
            model, params, clients(),
            nodes={"nodeA": NS(node="nodeA", max_capacity=20.0),
                   "nodeB": NS(node="nodeB", max_capacity=20.0)},
            round_cfg=rc("controller")) as s2:
        for _ in range(2):
            s2.run_round(client_lr=0.05)
        ref_params = s2.params

    for a, b in zip(jax.tree.leaves(node_params),
                    jax.tree.leaves(ref_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
