"""HLO cost parser + roofline model unit tests."""
import numpy as np
import pytest

from repro.analysis.hlo_cost import (
    _logical_lines,
    _operand_names,
    _opcode,
    _result_type,
    _shape_dims,
    _type_bytes,
    parse_hlo_cost,
)
from repro.analysis.roofline import Roofline, model_flops
from repro.configs import get_arch, get_shape


def test_split_rhs_with_index_comments():
    rhs = ("(s32[], f32[8,4]{1,0}, /*index=2*/f32[2]{0}) while(%t), "
           "condition=%c.1, body=%b.2, backend_config={\"known_trip_count\":{\"n\":\"7\"}}")
    assert _opcode(rhs) == "while"
    assert _type_bytes(_result_type(rhs)) == 4 + 8 * 4 * 4 + 2 * 4
    assert _operand_names(rhs) == ["t"]


def test_type_bytes_dtypes():
    assert _type_bytes("bf16[2,3]{1,0}") == 12
    assert _type_bytes("s8[10]") == 10
    assert _type_bytes("(f32[2], pred[3])") == 11
    assert _type_bytes("token[]") == 0


_MINI_HLO = """
HloModule test

%body.1 (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %d = f32[4,4]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,4]{1,0} all-reduce(%d), replica_groups={{0,1},{2,3}}, to_apply=%add.9
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4,4]{1,0}) tuple(%i2, %ar)
}

%add.9 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

%cond.2 (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[4,4]) -> f32[4,4] {
  %x = f32[4,4]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4,4]{1,0}) tuple(%z, %x)
  %w = (s32[], f32[4,4]{1,0}) while(%t0), condition=%cond.2, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[4,4]{1,0} get-tuple-element(%w), index=1
}
"""


def test_parse_hlo_trip_count_scaling():
    hc = parse_hlo_cost(_MINI_HLO, pod_size=2)
    # 5 iterations × one 4x4x4 dot = 5 · 2·4·4·4 = 640 flops
    assert hc.flops == pytest.approx(640.0)
    # all-reduce result 64B × 5 trips
    assert hc.coll_by_kind["all-reduce"] == pytest.approx(320.0)
    # groups {0,1},{2,3} with pod_size=2 -> intra-pod (ici)
    assert hc.coll_dcn == 0.0
    hc2 = parse_hlo_cost(_MINI_HLO, pod_size=1)
    assert hc2.coll_dcn == pytest.approx(320.0)  # every group spans pods


def test_roofline_terms_and_dominance():
    r = Roofline(flops=197e12, hbm_bytes=819e9 * 2, coll_bytes=50e9 * 0.5,
                 dcn_bytes=0, chips=256, model_flops_=197e12 * 256 * 0.5)
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.collective_s == pytest.approx(0.5)
    assert r.dominant == "memory"
    assert r.roofline_fraction == pytest.approx(0.25)


def test_model_flops_conventions():
    llama = get_arch("llama3.2-3b")
    t = get_shape("train_4k")
    assert model_flops(llama, t) == pytest.approx(
        6.0 * llama.active_param_count() * t.global_batch * t.seq_len)
    kimi = get_arch("kimi-k2-1t-a32b")
    # MoE uses ACTIVE params
    assert model_flops(kimi, t) < 6.0 * kimi.param_count() * t.global_batch * t.seq_len / 10
    d = get_shape("decode_32k")
    assert model_flops(llama, d) == pytest.approx(
        2.0 * llama.active_param_count() * d.global_batch)
