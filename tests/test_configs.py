"""Config registry + analytical param counts vs real pytrees."""
import jax
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, get_shape, grid, shape_applicable
from repro.models import build_model


def test_all_archs_present():
    assert set(ARCHS) == {
        "seamless-m4t-large-v2", "h2o-danube-3-4b", "gemma3-4b", "gemma3-12b",
        "llama3.2-3b", "hymba-1.5b", "internvl2-26b", "kimi-k2-1t-a32b",
        "deepseek-v2-lite-16b", "falcon-mamba-7b",
    }
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}


def test_grid_is_40_cells():
    cells = grid()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    # 5 pure-full-attention archs skip long_500k
    assert len(runnable) == 35


@pytest.mark.parametrize("name,lo,hi", [
    ("llama3.2-3b", 3.0e9, 3.5e9),
    ("gemma3-4b", 3.5e9, 4.4e9),
    ("gemma3-12b", 11.0e9, 12.5e9),
    ("h2o-danube-3-4b", 3.6e9, 4.3e9),
    ("falcon-mamba-7b", 6.8e9, 7.8e9),
    ("hymba-1.5b", 1.3e9, 1.8e9),
    ("deepseek-v2-lite-16b", 14.5e9, 16.5e9),
    ("kimi-k2-1t-a32b", 0.95e12, 1.1e12),
    ("internvl2-26b", 18.5e9, 21.0e9),  # LM backbone of the 26B VLM
    ("seamless-m4t-large-v2", 1.5e9, 2.1e9),
])
def test_param_counts_match_advertised_size(name, lo, hi):
    n = get_arch(name).param_count()
    assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_kimi_active_params_near_32b():
    cfg = get_arch("kimi-k2-1t-a32b")
    assert 30e9 <= cfg.active_param_count() <= 38e9


def test_long_500k_applicability():
    assert shape_applicable(get_arch("falcon-mamba-7b"), get_shape("long_500k"))[0]
    assert shape_applicable(get_arch("hymba-1.5b"), get_shape("long_500k"))[0]
    assert shape_applicable(get_arch("gemma3-4b"), get_shape("long_500k"))[0]
    assert not shape_applicable(get_arch("llama3.2-3b"), get_shape("long_500k"))[0]
    assert not shape_applicable(get_arch("kimi-k2-1t-a32b"), get_shape("long_500k"))[0]


def test_analytic_count_matches_real_tree():
    """The analytic formula must track the actual init'd pytree."""
    for name in ("llama3.2-3b", "deepseek-v2-lite-16b", "falcon-mamba-7b",
                 "hymba-1.5b", "seamless-m4t-large-v2"):
        cfg = get_arch(name).reduced()
        model = build_model(cfg)
        aparams = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        real = sum(
            int(__import__("numpy").prod(l.shape))
            for l in jax.tree.leaves(aparams)
        )
        analytic = cfg.param_count()
        assert abs(real - analytic) / real < 0.06, (
            f"{name}: real {real} vs analytic {analytic}"
        )


def test_layer_windows_gemma_pattern():
    cfg = get_arch("gemma3-4b")
    w = cfg.layer_windows()
    assert len(w) == 34
    assert w[:6] == (1024,) * 5 + (-1,)
    assert sum(1 for x in w if x == -1) == 5  # globals at 5,11,17,23,29
