"""The continuous aggregation service under fire.

Four properties of ``repro.serve`` this file holds:

  * **admission** — the gateway's bounded ingress: over-budget
    submissions get ``busy`` + a growing ``retry_after_s`` hint, never
    a silent drop, and a shed update that retries lands in a *later*
    round exactly once (idempotency keys from the survivability PR);
  * **rolling bit-exactness** — a 2-job, 2-node soak (≥ 6 rounds per
    job, concurrent pusher threads) where every closed round's delta is
    bit-identical to the same cohort run sequentially through the
    library ``run_round`` path, and the round windows measurably
    overlap (``pipeline_overlap > 0``);
  * **fair-share isolation** — per-job cohorts never mix, per-job
    round traces stay per-job;
  * **under fire** — external pushers (threads + a subprocess) against
    a rolling netrt fleet with a ``FaultPlan`` daemon SIGKILL mid-soak:
    every closed round still equals the FedAvg oracle over exactly its
    admitted cohort (allclose — a crash re-dispatch reorders the fold),
    and the SIGKILLed daemon's /dev/shm segments are swept on
    re-adoption / ``reap_local_daemon``.
"""
import os
import signal
import subprocess
import sys
import threading
import time
import zlib

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core import ClientInfo, NodeState, RoundConfig  # noqa: E402
from repro.core.aggregation import fedavg_oracle  # noqa: E402
from repro.runtime.driver import InProcRuntime, RoundDriver  # noqa: E402
from repro.serve import (  # noqa: E402
    AdmissionPolicy,
    AggregationService,
    DeadlinePolicy,
    GoalPolicy,
    IngressGateway,
    MinCohortIdleGap,
)

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")
N_ELEMS = 16


class _Model:
    """External-update-only jobs: loss exists, training never runs."""

    def loss(self, params, batch):
        return jnp.sum(params["w"] ** 2), {}


def _params():
    return {"w": jnp.zeros((N_ELEMS,), jnp.float32)}


def _flat_for(cid: str) -> np.ndarray:
    """Deterministic per-client update — the oracle regenerates it
    from the cohort record alone."""
    rng = np.random.default_rng(zlib.crc32(cid.encode()))
    return rng.standard_normal(N_ELEMS).astype(np.float32)


def _weight_for(cid: str) -> float:
    return float(1 + zlib.crc32(cid.encode()) % 4)


def _mk_service(jobs=("alpha", "beta"), *, runtime="inproc", goal=4,
                weights=None, admission=None, n_nodes=2):
    nodes = {f"node{i}": NodeState(node=f"node{i}", max_capacity=20.0)
             for i in range(n_nodes)}
    svc = AggregationService(nodes, runtime=runtime, admission=admission)
    for j in jobs:
        clients = [ClientInfo(client_id=f"{j}-r{i}", num_samples=10)
                   for i in range(2 * goal)]
        svc.add_job(j, _Model(), _params(), clients,
                    weight=(weights or {}).get(j, 1.0),
                    round_cfg=RoundConfig(aggregation_goal=goal))
    return svc


def _oracle_delta(rec):
    """Replay a closed round's recorded cohort through the sequential
    library path (fresh runtime, controller fold plan) — the rolling /
    fair-share machinery must not have changed a single bit."""
    cohort = rec["cohort"]
    if not cohort:
        return None
    rt = InProcRuntime()
    drv = RoundDriver(rt)
    out = drv.run_round(
        round_id=rec["ticket"],
        assignment=rec["assignment"],
        updates=[(node, cid, _flat_for(cid), w)
                 for node, cid, w in cohort],
        goal=len(cohort), n_elems=N_ELEMS,
        top_node=rec["top_node"])
    rt.close()
    return out.delta


class _CloseAny:
    """Close when any wrapped policy says so (test safety valve)."""

    def __init__(self, *pols):
        self.pols = pols

    def should_close(self, **kw):
        return any(p.should_close(**kw) for p in self.pols)


# ---------------------------------------------------------------------------
# gateway + policies (units)
# ---------------------------------------------------------------------------

def test_admission_retry_hint_grows_with_pressure():
    pol = AdmissionPolicy(max_queue=10, retry_base_s=0.1, retry_cap_s=2.0)
    h0 = pol.retry_after(10, 10)          # just over budget
    h1 = pol.retry_after(30, 10)          # deeply backed up
    assert 0.1 <= h0 < h1 <= 2.0
    assert pol.retry_after(10_000, 10) == 2.0


def test_gateway_quota_busy_and_duplicates():
    q = []
    shed_events = []
    gw = IngressGateway(AdmissionPolicy(max_queue=2),
                        emit=shed_events.append)
    seen = set()

    def submit(cid, flat, w, submission_id=None, round_id=None):
        if (cid, submission_id) in seen:
            return False
        seen.add((cid, submission_id))
        q.append(cid)
        return True

    gw.register("j", submit, lambda: len(q))
    flat = np.zeros(4, np.float32)
    v1 = gw.admit("j", "c1", flat, submission_id="s1")
    v2 = gw.admit("j", "c2", flat, submission_id="s2")
    assert v1["admitted"] and v2["admitted"]
    v3 = gw.admit("j", "c3", flat, submission_id="s3")
    assert v3["busy"] and v3["retry_after_s"] > 0
    assert not v3["admitted"]
    assert len(shed_events) == 1 and shed_events[0].client_id == "c3"
    # a retried duplicate of an ADMITTED submission is not backpressure
    q.pop()
    vd = gw.admit("j", "c1", flat, submission_id="s1")
    assert vd["duplicate"] and not vd["busy"]
    assert gw.counters == {"admitted": 2, "shed": 1, "duplicates": 1}
    with pytest.raises(KeyError):
        gw.admit("nope", "c", flat)


def test_close_policies():
    assert not GoalPolicy().should_close(n=999, opened_s=999, idle_s=999)
    dp = DeadlinePolicy(deadline_s=1.0)
    assert not dp.should_close(n=0, opened_s=0.5, idle_s=0.5)
    assert dp.should_close(n=0, opened_s=1.5, idle_s=0.0)
    mc = MinCohortIdleGap(min_cohort=3, idle_gap_s=0.1)
    assert not mc.should_close(n=2, opened_s=9.0, idle_s=9.0)   # too few
    assert not mc.should_close(n=3, opened_s=9.0, idle_s=0.01)  # not idle
    assert mc.should_close(n=3, opened_s=0.2, idle_s=0.2)


# ---------------------------------------------------------------------------
# the acceptance soak: 2 jobs, 2 nodes, rolling, bit-exact
# ---------------------------------------------------------------------------

def test_rolling_two_job_soak_bitexact_vs_sequential_oracle():
    svc = _mk_service(("alpha", "beta"), goal=4,
                      weights={"alpha": 2.0, "beta": 1.0})
    stop = threading.Event()
    pushed = {"alpha": [], "beta": []}

    def pusher(job):
        k = 0
        while not stop.is_set():
            cid = f"{job}-u{k}"
            v = svc.submit(job, cid, _flat_for(cid), _weight_for(cid),
                           submission_id=cid)
            if v["admitted"]:
                pushed[job].append(cid)
                k += 1
            time.sleep(0.002)

    threads = [threading.Thread(target=pusher, args=(j,), daemon=True)
               for j in ("alpha", "beta")]
    for t in threads:
        t.start()
    try:
        recs = svc.run_rounds(
            {"alpha": 6, "beta": 6},
            policy=_CloseAny(MinCohortIdleGap(min_cohort=2,
                                              idle_gap_s=0.02),
                             DeadlinePolicy(deadline_s=30.0)))
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=5)

    by_job = {"alpha": [], "beta": []}
    for r in recs:
        by_job[r["job"]].append(r)
    assert len(by_job["alpha"]) == 6 and len(by_job["beta"]) == 6

    # rolling reordered time, not the arithmetic: every closed round is
    # bit-identical to its cohort run sequentially through run_round
    nonempty = 0
    for rec in recs:
        want = _oracle_delta(rec)
        got = rec["outcome"].delta
        if want is None:
            assert got is None
            continue
        nonempty += 1
        assert np.array_equal(np.asarray(got), np.asarray(want)), \
            f"round {rec['ticket']} ({rec['job']}) drifted from oracle"
    assert nonempty >= 10

    # per-job isolation: cohorts never mix, job-local round numbering
    # is dense, and every admitted update landed at most once
    for job, rows in by_job.items():
        cids = [cid for r in rows for _n, cid, _w in r["cohort"]]
        assert all(cid.startswith(job) for cid in cids)
        assert len(cids) == len(set(cids)), "an update double-folded"
        assert sorted(r["round"] for r in rows) == list(range(6))
        assert set(cids) <= set(pushed[job])
        tr = svc.trainer(job)
        assert tr.trace() is not None
        assert tr.trace().meta["job"] == job

    # the rolling seam did overlap round windows
    assert svc.pipeline_overlap() > 0.0
    svc.close()


def test_fair_share_splits_fleet_by_weight():
    svc = _mk_service(("big", "small"), goal=4,
                      weights={"big": 3.0, "small": 1.0})
    assert svc.coordinator.job_share("big") == pytest.approx(0.75)
    assert svc.coordinator.job_share("small") == pytest.approx(0.25)
    svc.close()


# ---------------------------------------------------------------------------
# shed → retried → lands later exactly once
# ---------------------------------------------------------------------------

def test_shed_update_lands_in_later_round_exactly_once():
    svc = _mk_service(("solo",), goal=2,
                      admission=AdmissionPolicy(max_queue=2,
                                                retry_base_s=0.01))
    n_updates = 10
    landed_acks = {}
    sheds = {"n": 0}

    def pusher():
        for k in range(n_updates):
            cid = f"solo-u{k}"
            while True:
                v = svc.submit("solo", cid, _flat_for(cid),
                               _weight_for(cid), submission_id=f"s{k}")
                if v["busy"]:
                    sheds["n"] += 1
                    time.sleep(v["retry_after_s"])
                    continue
                landed_acks.setdefault(cid, 0)
                landed_acks[cid] += 1
                break
            # an immediate duplicate retry (lost-ack simulation) must
            # dedupe, not double-queue
            dv = svc.submit("solo", cid, _flat_for(cid),
                            _weight_for(cid), submission_id=f"s{k}")
            assert dv["duplicate"] or dv["busy"]
            if dv["busy"]:          # the probe itself was shed
                sheds["n"] += 1

    th = threading.Thread(target=pusher, daemon=True)
    th.start()
    try:
        recs = svc.run_rounds(
            {"solo": 5},
            policy=_CloseAny(MinCohortIdleGap(min_cohort=1,
                                              idle_gap_s=0.02),
                             DeadlinePolicy(deadline_s=30.0)))
    finally:
        th.join(timeout=30)
    assert not th.is_alive()

    cids = [cid for r in recs for _n, cid, _w in r["cohort"]]
    assert len(cids) == len(set(cids)), "a shed retry double-folded"
    assert sheds["n"] > 0, "queue bound never engaged — weak test"
    # everything admitted before the last round closed must have landed
    # exactly once; nothing landed that was never admitted
    assert set(cids) <= set(landed_acks)
    assert all(n == 1 for n in landed_acks.values())
    gw = svc.ingress_metrics()
    assert gw["shed"] == sheds["n"]
    assert gw["admitted"] == n_updates
    svc.close()


# ---------------------------------------------------------------------------
# under fire: netrt fleet, FaultPlan daemon kill, threads + subprocess
# ---------------------------------------------------------------------------

_PUSH_SCRIPT = """
import sys
import numpy as np
import zlib
from repro.runtime.netrt import push_update

addr, job, n = sys.argv[1], sys.argv[2], int(sys.argv[3])
for k in range(n):
    cid = f"{job}-p{k}"
    rng = np.random.default_rng(zlib.crc32(cid.encode()))
    flat = rng.standard_normal(16).astype(np.float32)
    w = float(1 + zlib.crc32(cid.encode()) % 4)
    push_update(addr, cid, flat, w, job=job, submission_id=cid,
                timeout=30.0, retries=8, busy_retries=1000)
print("pushed", n)
"""


@pytest.mark.chaos
def test_serve_under_fire_netrt_daemon_kill():
    from repro.runtime.netrt import (FaultPlan, RemoteRuntime,
                                     reap_local_daemon,
                                     spawn_local_daemon)

    procs, addrs = [], []
    svc = None
    pushproc = None
    try:
        p0, a0 = spawn_local_daemon("uf0", runtime="inproc",
                                    stdout=subprocess.DEVNULL)
        procs.append(p0)
        addrs.append(a0)
        # uf1 SIGKILLs itself mid-soak — the deterministic crash
        p1, a1 = spawn_local_daemon(
            "uf1", runtime="inproc", stdout=subprocess.DEVNULL,
            fault_spec=FaultPlan(kill_after=12))
        procs.append(p1)
        addrs.append(a1)

        rt = RemoteRuntime(addrs)
        nodes = {n: NodeState(node=n, max_capacity=cap)
                 for n, cap in rt.node_info().items()}
        # per-job quota: one job's backlog must not starve the other's
        # ingress out of the shared global budget
        svc = AggregationService(
            nodes, runtime=rt,
            admission=AdmissionPolicy(max_queue=32, job_quota=16,
                                      retry_base_s=0.01,
                                      retry_cap_s=0.1))
        for j in ("wired", "local"):
            svc.add_job(j, _Model(), _params(),
                        [ClientInfo(client_id=f"{j}-r{i}", num_samples=10)
                         for i in range(8)],
                        round_cfg=RoundConfig(aggregation_goal=4))
        addr = svc.serve("127.0.0.1:0")

        # subprocess pusher over the wire + an in-process thread pusher
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        pushproc = subprocess.Popen(
            [sys.executable, "-c", _PUSH_SCRIPT, addr, "wired", "40"],
            env=env, stdout=subprocess.DEVNULL)
        stop = threading.Event()

        def local_pusher():
            k = 0
            while not stop.is_set():
                cid = f"local-u{k}"
                v = svc.submit("local", cid, _flat_for(cid),
                               _weight_for(cid), submission_id=cid)
                if v["admitted"]:
                    k += 1
                    time.sleep(0.002)
                else:
                    time.sleep(v["retry_after_s"])

        th = threading.Thread(target=local_pusher, daemon=True)
        th.start()
        try:
            recs = svc.run_rounds(
                {"wired": 4, "local": 4},
                policy=_CloseAny(MinCohortIdleGap(min_cohort=2,
                                                  idle_gap_s=0.05),
                                 DeadlinePolicy(deadline_s=20.0)))
        finally:
            stop.set()
            th.join(timeout=5)

        assert len(recs) == 8
        # the daemon died mid-soak: crash-round re-dispatch reorders
        # the fold, so the contract is the FedAvg ORACLE over exactly
        # the admitted cohort (allclose), for every single round
        for rec in recs:
            got = rec["outcome"].delta
            if not rec["cohort"]:
                assert got is None
                continue
            ups = [_flat_for(cid) for _n, cid, _w in rec["cohort"]]
            ws = [w for _n, _c, w in rec["cohort"]]
            want = fedavg_oracle(ups, ws)
            assert got is not None
            assert np.allclose(np.asarray(got), want,
                               rtol=1e-5, atol=1e-6), \
                f"round {rec['ticket']} lost/duplicated updates"
        # exactly-once across the whole soak, per job
        for job in ("wired", "local"):
            cids = [cid for r in recs if r["job"] == job
                    for _n, cid, _w in r["cohort"]]
            assert len(cids) == len(set(cids))
        assert procs[1].poll() is not None, "FaultPlan kill never fired"
    finally:
        if pushproc is not None:
            pushproc.kill()
            pushproc.wait(timeout=10)
        if svc is not None:
            svc.close()
        for p in procs:
            reap_local_daemon(p)


# ---------------------------------------------------------------------------
# /dev/shm hygiene: SIGKILL leaks are swept on re-adoption and reap
# ---------------------------------------------------------------------------

def _lifl_segments(prefix):
    try:
        return [n for n in os.listdir("/dev/shm")
                if n == prefix or n.startswith(prefix + "-")]
    except OSError:
        return []


@pytest.mark.skipif(not os.path.isdir("/dev/shm"),
                    reason="needs POSIX /dev/shm")
@pytest.mark.chaos
def test_sigkilled_daemon_segments_swept_on_readoption_and_reap():
    from repro.runtime.netrt import (RemoteRuntime, reap_local_daemon,
                                     spawn_local_daemon)

    proc, addr = spawn_local_daemon("swp0", runtime="shmproc",
                                    stdout=subprocess.DEVNULL)
    prefix = proc.lifl_store_prefix
    assert prefix, "shmproc daemon must advertise its store prefix"
    rt = None
    proc2 = None
    try:
        rt = RemoteRuntime([addr])
        assert rt._nodes["swp0"].store_prefix == prefix
        drv = RoundDriver(rt)
        ups = [_flat_for(f"s{i}") for i in range(4)]
        out = drv.run_round(
            round_id=1, assignment={"swp0": [0, 1, 2, 3]},
            updates=[("swp0", f"s{i}", u, 1.0)
                     for i, u in enumerate(ups)],
            goal=4, n_elems=N_ELEMS)
        assert np.allclose(out.delta, fedavg_oracle(ups, [1.0] * 4))

        # SIGKILL the whole group: atexit never runs, segments leak
        os.killpg(proc.pid, signal.SIGKILL)
        proc.wait(timeout=10)
        leaked = _lifl_segments(prefix)
        assert leaked, "expected orphaned segments after SIGKILL"

        # same name, same address: re-adoption sees the epoch bump and
        # sweeps the dead epoch's namespace
        proc2, _ = spawn_local_daemon("swp0", runtime="shmproc",
                                      listen=addr,
                                      stdout=subprocess.DEVNULL)
        deadline = time.perf_counter() + 20.0
        while time.perf_counter() < deadline:
            rt.poll_events(0.0)
            if rt.try_readopt(force=True) or rt._nodes["swp0"].alive:
                if rt._nodes["swp0"].store_prefix != prefix:
                    break
            time.sleep(0.1)
        assert rt._nodes["swp0"].alive
        assert rt._nodes["swp0"].store_prefix != prefix
        assert not _lifl_segments(prefix), \
            "re-adoption left dead-epoch segments behind"
        assert rt._local.get("swept_segments", 0) >= len(leaked)
    finally:
        if rt is not None:
            rt.close()
        reap_local_daemon(proc)
        if proc2 is not None:
            prefix2 = getattr(proc2, "lifl_store_prefix", "")
            reap_local_daemon(proc2)
            assert not _lifl_segments(prefix2), \
                "reap_local_daemon left segments behind"
