"""Integration: end-to-end FL training, checkpoint/restart, failure
injection, elastic scaling, data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_opts
from repro.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import ARCHS
from repro.configs.resnet import RESNET18
from repro.core import ClientInfo, NodeState, RoundConfig
from repro.data import (
    CohortTokenLoader,
    build_client_datasets,
    dirichlet_partition,
    synthetic_femnist,
)
from repro.fl.round import AggregationConfig
from repro.launch.mesh import make_host_mesh
from repro.models import build_model, build_resnet
from repro.runtime import (
    ArrivalTrace,
    ClientRuntime,
    ElasticController,
    FederatedTrainer,
    FusedFLTrainer,
)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_dirichlet_partition_covers_all_samples():
    labels = np.random.default_rng(0).integers(0, 10, size=500)
    shards = dirichlet_partition(labels, 20, alpha=0.3)
    all_idx = np.concatenate([s.indices for s in shards])
    assert sorted(all_idx.tolist()) == list(range(500))


def test_dirichlet_is_non_iid():
    labels = np.random.default_rng(0).integers(0, 10, size=2000)
    shards = dirichlet_partition(labels, 10, alpha=0.1)
    # at alpha=0.1 the per-client label histograms should be skewed
    skews = []
    for s in shards:
        if s.num_samples < 10:
            continue
        hist = np.bincount(labels[s.indices], minlength=10) / s.num_samples
        skews.append(hist.max())
    assert np.mean(skews) > 0.4


def test_cohort_token_loader_layout():
    loader = CohortTokenLoader(vocab_size=97, seq_len=16, n_cohorts=4)
    b = loader.round_batch(16, round_id=0)
    assert b["tokens"].shape == (16, 16)
    assert b["labels"].shape == (16, 16)
    assert (b["labels"][:, -1] == -1).all()


def test_token_task_is_learnable_structure():
    loader = CohortTokenLoader(vocab_size=31, seq_len=32, n_cohorts=1)
    b = loader.round_batch(8, 0)
    toks, labels = b["tokens"], b["labels"]
    pred = (5 * toks + 17) % 31
    agree = (pred[:, :-1] == labels[:, :-1]).mean()
    assert agree > 0.85  # 5% noise


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    params = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
              "b": [jnp.ones((4,), jnp.bfloat16)]}
    save_checkpoint(tmp_path, 3, params)
    got, step = restore_checkpoint(tmp_path, params)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(params["a"]))
    assert got["b"][0].dtype == jnp.bfloat16


def test_async_checkpointer_ordered(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    for s in (1, 2, 3):
        ck.submit(s, {"w": jnp.full((8,), float(s))})
    ck.wait()
    assert latest_step(tmp_path) == 3
    got, _ = restore_checkpoint(tmp_path, {"w": jnp.zeros((8,))})
    np.testing.assert_array_equal(np.asarray(got["w"]), 3.0)


def test_fused_trainer_checkpoint_restart(tmp_path):
    cfg = ARCHS["llama3.2-3b"].reduced(dtype="float32")
    mesh = make_host_mesh()
    agg = AggregationConfig(hierarchy="flat", num_microbatches=2)
    loader = CohortTokenLoader(cfg.vocab_size, 16, 2)

    tr = FusedFLTrainer(cfg, mesh, agg, opts=tiny_opts(vocab_axis=None),
                        checkpoint_dir=str(tmp_path), checkpoint_every=2)
    tr.init(seed=0)
    for r in range(4):
        tr.train_round(loader.round_batch(8, r))
    tr.ckpt.wait()
    params_after_4 = jax.tree.map(np.asarray, tr.params)

    # crash + restart: a fresh trainer restores the round-4 checkpoint
    tr2 = FusedFLTrainer(cfg, mesh, agg, opts=tiny_opts(vocab_axis=None),
                         checkpoint_dir=str(tmp_path))
    tr2.init(seed=99)  # different init, must be overwritten by restore
    assert tr2.maybe_restore()
    assert tr2.round_id == 4
    for a, b in zip(jax.tree.leaves(tr2.params), jax.tree.leaves(params_after_4)):
        np.testing.assert_array_equal(np.asarray(a), b)


# ---------------------------------------------------------------------------
# failure injection + straggler handling
# ---------------------------------------------------------------------------

def _mk_fl_trainer(failure_prob, seed=0, goal=6):
    cfg = RESNET18.reduced()
    model = build_resnet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    imgs, labels = synthetic_femnist(400, num_classes=10, seed=0)
    shards = dirichlet_partition(labels, 12, alpha=0.5)
    dsets = build_client_datasets(imgs, labels, shards)
    clients = [
        ClientRuntime(ClientInfo(d.client_id, d.num_samples), d,
                      failure_prob=failure_prob)
        for d in dsets
    ]
    return FederatedTrainer(
        model, params, clients,
        round_cfg=RoundConfig(aggregation_goal=goal, over_provision=1.8),
        seed=seed,
    ), imgs, labels


def test_round_completes_despite_client_failures():
    tr, imgs, labels = _mk_fl_trainer(failure_prob=0.3)
    rec = tr.run_round(client_lr=0.05, client_batch_size=32)
    assert rec["updates"] >= 1  # over-provisioning absorbed failures
    # training still progresses
    pre = tr.evaluate({"images": imgs[:128], "labels": labels[:128]})
    for _ in range(3):
        tr.run_round(client_lr=0.05, client_batch_size=32)
    post = tr.evaluate({"images": imgs[:128], "labels": labels[:128]})
    assert post["loss"] < pre["loss"]


def test_aggregator_reuse_across_rounds():
    tr, *_ = _mk_fl_trainer(failure_prob=0.0)
    r1 = tr.run_round(client_lr=0.01, client_batch_size=32)
    r2 = tr.run_round(client_lr=0.01, client_batch_size=32)
    assert r2["reused"] > 0
    assert r2["cold_starts"] <= r1["cold_starts"]


# ---------------------------------------------------------------------------
# elastic scaling
# ---------------------------------------------------------------------------

def test_elastic_controller_scales_and_survives_node_loss():
    nodes = {f"n{i}": NodeState(node=f"n{i}", max_capacity=20) for i in range(4)}
    ec = ElasticController(nodes)
    low = ec.step(0, expected_updates=8)
    high = ec.step(1, expected_updates=64)
    assert high["aggregators_planned"] > low["aggregators_planned"]
    ec.lose_node("n0", 2)
    after = ec.step(2, expected_updates=64)
    assert after["nodes"] == 3
    kinds = [e.kind for e in ec.events]
    assert "node_lost" in kinds and "scale_up" in kinds


def test_arrival_trace_varies():
    tr = ArrivalTrace(base_rate=10, variability=0.5)
    rates = [tr.rate(r) for r in range(40)]
    assert max(rates) > 1.5 * min(rates)
