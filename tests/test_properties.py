"""Property-based tests (hypothesis) on system invariants.

Runs under real hypothesis when installed; otherwise falls back to the
vendored sampler shim (tests/_hypothesis_stub.py) so the invariants are
exercised in every environment instead of skipping wholesale."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # no optional dep in the image: use the shim
    from _hypothesis_stub import given, settings, strategies as st

import repro.core as core
from repro.core.aggregation import FedAvgState, fedavg_oracle

settings.register_profile("ci", max_examples=40, deadline=None)
settings.load_profile("ci")


# ---------------------------------------------------------------------------
# FedAvg invariants
# ---------------------------------------------------------------------------

updates_strategy = st.lists(
    st.tuples(
        st.lists(st.floats(-100, 100, allow_nan=False), min_size=4, max_size=4),
        st.floats(0.1, 50.0),
    ),
    min_size=1, max_size=8,
)


@given(updates_strategy)
def test_fedavg_permutation_invariance(items):
    us = [np.asarray(u, np.float32) for u, _ in items]
    ws = [w for _, w in items]
    a = fedavg_oracle(us, ws)
    perm = np.random.default_rng(0).permutation(len(us))
    b = fedavg_oracle([us[i] for i in perm], [ws[i] for i in perm])
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)


@given(updates_strategy)
def test_fedavg_result_in_convex_hull(items):
    us = [np.asarray(u, np.float32) for u, _ in items]
    ws = [w for _, w in items]
    out = fedavg_oracle(us, ws)
    lo = np.min(np.stack(us), axis=0)
    hi = np.max(np.stack(us), axis=0)
    assert np.all(out >= lo - 1e-3) and np.all(out <= hi + 1e-3)


@given(updates_strategy)
def test_eager_fold_equals_lazy_batch(items):
    """Cumulative averaging (eager) == batch averaging (lazy) exactly
    (the precondition for the paper's eager aggregation, §2.1)."""
    us = [np.asarray(u, np.float32) for u, _ in items]
    ws = [w for _, w in items]
    eager = FedAvgState()
    for u, w in zip(us, ws):
        eager.fold(u, w)
    got, _ = eager.result()
    np.testing.assert_allclose(got, fedavg_oracle(us, ws), rtol=1e-4, atol=1e-4)


@given(updates_strategy, st.integers(1, 6))
def test_hierarchical_merge_associativity(items, split):
    """Tree aggregation (partials merged) == flat aggregation for any
    partition of updates into leaf groups — the invariant that makes the
    aggregation hierarchy shape-free."""
    us = [np.asarray(u, np.float32) for u, _ in items]
    ws = [w for _, w in items]
    k = min(split, len(us))
    groups = np.array_split(np.arange(len(us)), k)
    root = FedAvgState()
    for g in groups:
        part = FedAvgState()
        for i in g:
            part.fold(us[i], ws[i])
        root.merge(part)
    got, _ = root.result()
    np.testing.assert_allclose(got, fedavg_oracle(us, ws), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# placement invariants
# ---------------------------------------------------------------------------

@given(
    st.integers(0, 150),
    st.lists(st.floats(1.0, 40.0), min_size=1, max_size=8),
    st.sampled_from(["bestfit", "worstfit", "firstfit"]),
)
def test_placement_never_exceeds_capacity(n_updates, caps, policy):
    nodes = {
        f"n{i}": core.NodeState(node=f"n{i}", max_capacity=c)
        for i, c in enumerate(caps)
    }
    p = core.place_updates(n_updates, nodes, policy=policy)
    for node, idxs in p.assignment.items():
        assert len(idxs) <= nodes[node].max_capacity + 1e-9
    placed = sum(len(v) for v in p.assignment.values())
    assert placed + len(p.overflow) == n_updates
    # no duplicates
    seen = [i for v in p.assignment.values() for i in v] + list(p.overflow)
    assert sorted(seen) == list(range(n_updates))


@given(
    st.integers(1, 100),
    st.integers(2, 8),
    st.floats(5.0, 40.0),
)
def test_bestfit_uses_no_more_nodes_than_worstfit(n_updates, n_nodes, cap):
    """Holds for HOMOGENEOUS capacities (the paper's testbed, §6.1).
    Hypothesis refuted the heterogeneous version (caps [5, 11], 6
    updates: BestFit fills the small bin first and spills, WorstFit fits
    everything in the big bin) — BestFit is a locality heuristic, not a
    bin-count optimum; recorded in EXPERIMENTS.md §Perf lessons."""
    mk = lambda: {
        f"n{i}": core.NodeState(node=f"n{i}", max_capacity=cap)
        for i in range(n_nodes)
    }
    best = core.place_updates(n_updates, mk(), policy="bestfit")
    worst = core.place_updates(n_updates, mk(), policy="worstfit")
    if not best.overflow and not worst.overflow:
        assert best.num_nodes_used <= worst.num_nodes_used


# ---------------------------------------------------------------------------
# hierarchy invariants
# ---------------------------------------------------------------------------

@given(
    st.dictionaries(
        st.sampled_from([f"n{i}" for i in range(6)]),
        st.floats(0.0, 50.0),
        min_size=1, max_size=6,
    ),
    st.integers(1, 5),
)
def test_hierarchy_covers_all_updates(queues, fan_in):
    planner = core.HierarchyPlanner(fan_in=fan_in)
    plan = planner.plan(queues, smooth=False)
    for node, q in queues.items():
        leaves = plan.per_node[node].num_leaves
        assert leaves * fan_in >= q - 1e-9     # capacity covers queue
        if q >= 1e-6:  # denormal q underflows ceil(q/fan) — not real load
            assert leaves >= 1
        assert leaves <= np.ceil(q / fan_in) + 1e-9  # no over-allocation


@given(st.lists(st.floats(0.0, 100.0), min_size=1, max_size=30))
def test_ewma_bounded_by_observations(obs):
    e = core.EWMA(0.7)
    for o in obs:
        v = e.update(o)
        assert min(obs) - 1e-6 <= v <= max(obs) + 1e-6


# ---------------------------------------------------------------------------
# quantization invariants
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32),
                min_size=1, max_size=600))
def test_quantize_roundtrip_error_bound(vals):
    import jax.numpy as jnp
    from repro.kernels.quantize import QBLOCK, dequantize, quantize

    x = jnp.asarray(np.asarray(vals, np.float32))
    q, s = quantize(x, impl="jnp")
    back = dequantize(q, s, len(vals), impl="jnp")
    scales = np.repeat(np.asarray(s), QBLOCK)[: len(vals)]
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert np.all(err <= scales / 2 * 1.001 + 1e-6)
