"""Fleet survivability: netd re-adoption, idempotent client retries,
and the deterministic fault-injection harness.

Three layers under test:

  * the shared :class:`Backoff` schedule (``connect``, peer redials,
    ``push_update``) — deterministic under seed, cap and deadline
    respected;
  * idempotent ingress — ``(client_id, submission_id)`` dedupe at the
    trainer, stale-round refusal, requeue of cohort-skipped externals:
    a retried submission can never double-fold;
  * re-adoption + :class:`FaultPlan` — a daemon SIGKILLed mid-round
    and restarted under its old name rejoins the fleet (epoch bump,
    ``NodeRejoined``), and seeded fault soaks (drops / resets / a
    daemon restart) land every round on the FedAvg oracle over exactly
    the updates that arrived.

On bit-exactness: a round where a node dies re-dispatches its staged
updates into a surviving subtree — same sum, different fold order — so
crash rounds assert ``allclose`` (as the PR-4 crash tests do) plus
bit-exact *determinism* (same seed → same bytes); fault-free rounds,
drop-only rounds, and every post-recovery clean round assert
bit-for-bit equality against the in-proc reference.
"""
import os
import signal
import socket
import subprocess
import threading
import time

import numpy as np
import pytest

from repro.core.aggregation import fedavg_oracle
from repro.runtime.driver import InProcRuntime, RoundDriver
from repro.runtime.events import NodeLost, NodeRejoined
from repro.runtime.netrt import (
    Backoff,
    FaultPlan,
    FrameConn,
    PeerDead,
    RemoteRuntime,
    connect,
    push_update,
    spawn_local_daemon,
)

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")


# ---------------------------------------------------------------------------
# Backoff: the one retry schedule
# ---------------------------------------------------------------------------

def test_backoff_deterministic_under_seed():
    import itertools
    mk = lambda: Backoff(base=0.01, factor=2.0, cap=0.5, jitter=0.25,
                         seed=7, deadline_s=1e9)
    # a deadline that large never truncates the early schedule
    sched1 = list(itertools.islice(iter(mk()), 200))
    sched2 = list(itertools.islice(iter(mk()), 200))
    assert sched1 == sched2
    assert Backoff(seed=7, deadline_s=30.0).next_delay() is not None


def test_backoff_grows_to_cap_with_bounded_jitter():
    bo = Backoff(base=0.01, factor=2.0, cap=0.4, jitter=0.25, seed=3)
    delays = [bo.next_delay() for _ in range(12)]
    for k, d in enumerate(delays):
        raw = min(0.4, 0.01 * (2.0 ** k))
        assert raw * 0.75 <= d <= raw * 1.25
    # tail is pinned at the cap (± jitter)
    assert all(0.4 * 0.75 <= d <= 0.4 * 1.25 for d in delays[-3:])


def test_backoff_deadline_budget_exhausts():
    bo = Backoff(base=0.005, cap=0.01, jitter=0.0, deadline_s=0.05, seed=0)
    total = 0.0
    for d in bo:
        total += d
        time.sleep(d)
    # the schedule ended because the budget did, and never overran it
    assert bo.next_delay() is None and not bo.sleep()
    assert total <= 0.05 + 0.02


def test_backoff_zero_deadline_is_single_attempt():
    # deadline_s=0 arms an already-expired budget: the first sleep()
    # returns False — how try_readopt makes connect() dial exactly once
    bo = Backoff(deadline_s=0.0)
    assert bo.next_delay() is None
    assert not bo.sleep()


def test_backoff_rejects_bad_policy():
    for kw in ({"base": 0.0}, {"factor": 0.5}, {"jitter": 1.0},
               {"jitter": -0.1}):
        with pytest.raises(ValueError):
            Backoff(**kw)


def test_connect_gives_up_within_deadline():
    # nothing listens here; the retry loop must respect the budget
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    t0 = time.perf_counter()
    with pytest.raises(PeerDead):
        connect(f"127.0.0.1:{port}", timeout=0.3)
    assert time.perf_counter() - t0 < 5.0


# ---------------------------------------------------------------------------
# FaultPlan: seeded schedules
# ---------------------------------------------------------------------------

_FRAME_SEQ = (["deliver"] * 20 + ["event"] * 10 + ["spawn"] * 5
              + ["partial"] * 10) * 4


def test_faultplan_deterministic_under_seed():
    mk = lambda: FaultPlan(seed=11, drop=0.2, reset=0.1, delay=0.1)
    p1, p2 = mk(), mk()
    acts1 = [p1.on_send(k) for k in _FRAME_SEQ]
    acts2 = [p2.on_send(k) for k in _FRAME_SEQ]
    assert acts1 == acts2
    assert p1.injected == p2.injected and p1.total_injected > 0


def test_faultplan_scopes_and_budget():
    # drops only touch drop_kinds; the budget stops all injection
    p = FaultPlan(seed=5, drop=0.9, drop_kinds=("deliver",), max_faults=3)
    acts = [p.on_send(k)[0] for k in ["spawn", "event", "quiesce"] * 10]
    assert all(a == "pass" for a in acts)        # out of scope: untouched
    acts = [p.on_send("deliver")[0] for _ in range(50)]
    assert acts.count("drop") == 3               # budget spent...
    last = len(acts) - 1 - acts[::-1].index("drop")
    assert all(a == "pass" for a in acts[last + 1:])   # ...then inert
    assert p.total_injected == 3


def test_faultplan_json_roundtrip():
    p = FaultPlan(seed=9, drop=0.25, reset=0.5, delay_s=0.01,
                  drop_kinds=("deliver",), max_faults=7, kill_after=40)
    q = FaultPlan.from_json(p.to_json())
    assert (q.seed, q.drop, q.reset, q.delay_s) == (9, 0.25, 0.5, 0.01)
    assert q.drop_kinds == ("deliver",)
    assert q.max_faults == 7 and q.kill_after == 40
    # same seed, same stream
    assert [q.on_send(k) for k in _FRAME_SEQ[:40]] == \
           [p.on_send(k) for k in _FRAME_SEQ[:40]]


def test_frameconn_fault_hooks():
    sa, sb = socket.socketpair()
    plan = FaultPlan(seed=0, drop=1.0, drop_kinds=("deliver",))
    a = FrameConn(sa, peer="a", faults=plan)
    b = FrameConn(sb, peer="b")
    a.send("deliver", {"i": 1})          # dropped: never hits the wire
    a.send("spawn", {"i": 2})            # out of drop scope: arrives
    f = b.recv(timeout=2.0)
    assert f.kind == "spawn" and plan.injected == {"drop": 1}
    # reset: the injected failure closes the conn like a real one
    a.faults = FaultPlan(seed=0, reset=1.0)
    with pytest.raises(PeerDead):
        a.send("spawn", {"i": 3})
    assert not a.alive
    b.close()


# ---------------------------------------------------------------------------
# driver: skipped externals are reported, not dropped
# ---------------------------------------------------------------------------

def test_driver_reports_skipped_updates():
    rt = InProcRuntime()
    drv = RoundDriver(rt)
    u0, u1 = np.ones(8, np.float32), np.full(8, 2.0, np.float32)

    def ups():
        yield "n0", "c0", u0, 1.0
        yield "n0", "c1", u1, 1.0      # node full (planned goal 1)

    out = drv.run_round(round_id=0, assignment={"n0": [0]},
                        updates=ups(), goal=2, n_elems=8)
    assert out.accepted == 1
    assert len(out.skipped) == 1
    node, cid, flat, w = out.skipped[0]
    assert cid == "c1" and flat is u1    # the very object, requeueable
    rt.close()


# ---------------------------------------------------------------------------
# idempotent ingress (trainer / Session level)
# ---------------------------------------------------------------------------

jax = pytest.importorskip("jax")

from repro.api import Session                              # noqa: E402
from repro.configs.resnet import RESNET18                  # noqa: E402
from repro.core import ClientInfo, RoundConfig             # noqa: E402
from repro.data import (build_client_datasets,             # noqa: E402
                        dirichlet_partition, synthetic_femnist)
from repro.models import build_resnet                      # noqa: E402
from repro.runtime import ClientRuntime, FederatedTrainer  # noqa: E402


def _mk_clients(n_samples=120, n_clients=8):
    cfg = RESNET18.reduced()
    model = build_resnet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    imgs, labels = synthetic_femnist(n_samples, num_classes=10, seed=0)
    shards = dirichlet_partition(labels, n_clients, alpha=0.5)
    clients = [
        ClientRuntime(ClientInfo(d.client_id, d.num_samples), d)
        for d in build_client_datasets(imgs, labels, shards)
    ]
    return model, params, clients


def _mk_trainer(seed=0, **kw):
    model, params, clients = _mk_clients()
    return FederatedTrainer(
        model, params, clients,
        round_cfg=RoundConfig(aggregation_goal=4, over_provision=1.5),
        seed=seed, **kw)


def _nparams(tr):
    return int(sum(int(np.prod(np.shape(l)))
                   for l in jax.tree.leaves(tr.params)))


def _assert_params_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_duplicate_submission_id_folds_once():
    """The same submission retried twice folds exactly once: params are
    bit-equal to the single-submission run, and the dupe is counted."""
    tr, ref = _mk_trainer(seed=0), _mk_trainer(seed=0)
    n = _nparams(tr)
    up = np.full(n, 0.25, np.float32)

    assert ref.submit_update("edge-1", up, weight=2.0,
                             submission_id="s-1") is True
    assert tr.submit_update("edge-1", up, weight=2.0,
                            submission_id="s-1") is True
    assert tr.submit_update("edge-1", up.copy(), weight=2.0,
                            submission_id="s-1") is False   # the retry
    assert tr.ingress["duplicates"] == 1 and tr.ingress["queued"] == 1
    assert len(tr._external) == 1

    tr.run_round(client_lr=0.05)
    ref.run_round(client_lr=0.05)
    _assert_params_equal(tr.params, ref.params)


def test_retry_after_goal_reached_is_still_deduped():
    """A retry that loses the race with round completion (the original
    already folded, GoalReached fired, the round is over) must be
    dropped by the dedupe record, not fold into the next round."""
    tr, ref = _mk_trainer(seed=0), _mk_trainer(seed=0)
    n = _nparams(tr)
    up = np.full(n, 0.125, np.float32)
    for t in (tr, ref):
        assert t.submit_update("edge-9", up, weight=1.5,
                               submission_id="s-42") is True
        t.run_round(client_lr=0.05)
    # the late retry: same (client_id, submission_id), next round open
    assert tr.submit_update("edge-9", up.copy(), weight=1.5,
                            submission_id="s-42") is False
    assert tr.ingress["duplicates"] == 1 and not tr._external
    tr.run_round(client_lr=0.05)
    ref.run_round(client_lr=0.05)
    _assert_params_equal(tr.params, ref.params)


def test_stale_round_id_is_refused_and_counted():
    tr = _mk_trainer()
    n = _nparams(tr)
    tr.run_round(client_lr=0.05)                 # round 0 is history
    with pytest.raises(ValueError, match="stale round_id"):
        tr.submit_update("edge-2", np.zeros(n, np.float32),
                         submission_id="s-2", round_id=0)
    assert tr.ingress["stale_round"] == 1 and not tr._external
    # pinning the CURRENT round is fine
    assert tr.submit_update("edge-2", np.zeros(n, np.float32),
                            submission_id="s-3", round_id=1) is True


def test_skipped_external_requeues_for_next_round():
    """An external update the driver pulled but could not place (the
    round's wall-clock budget expired first) rides the next cohort
    instead of vanishing — the PR-6 fix for the silent drop."""
    tr = _mk_trainer()
    n = _nparams(tr)
    assert tr.submit_update("edge-5", np.full(n, 0.5, np.float32),
                            weight=3.0, submission_id="s-5") is True
    tr.run_round(client_lr=0.05, deadline_s=1e-9)
    assert tr.ingress["requeued"] == 1
    assert len(tr._external) == 1                # buffered, not lost
    arrived = []
    from repro.runtime.events import UpdateArrived
    tr.driver.on(UpdateArrived, lambda ev: arrived.append(ev.client_id))
    tr.run_round(client_lr=0.05)
    assert "edge-5" in arrived                   # folded this time


def test_session_metrics_expose_ingress_counters():
    model, params, clients = _mk_clients()
    with Session.open(
            model, params, clients,
            round_cfg=RoundConfig(aggregation_goal=4,
                                  over_provision=1.5)) as sess:
        n = int(sum(int(np.prod(np.shape(l)))
                    for l in jax.tree.leaves(params)))
        up = np.full(n, 0.25, np.float32)
        assert sess.submit_update("e1", up, submission_id="a") is True
        assert sess.submit_update("e1", up, submission_id="a") is False
        ing = sess.metrics()["ingress"]
        assert ing["queued"] == 1 and ing["duplicates"] == 1


@pytest.mark.slow
def test_push_update_wire_retry_is_idempotent():
    """The wire client retries on the shared Backoff with a stable
    submission_id: re-sending the same submission gets duplicate=True
    and the round's params are bit-equal to the single-send run."""
    model, params, clients = _mk_clients()
    # the reference session needs its OWN client objects: ClientRuntime
    # is stateful (training advances its batch/rng state), so sharing
    # one list would let sess's round perturb ref's
    _, _, ref_clients = _mk_clients()
    n = int(sum(int(np.prod(np.shape(l)))
                for l in jax.tree.leaves(params)))
    up = np.full(n, 0.25, np.float32)
    cfg = RoundConfig(aggregation_goal=4, over_provision=1.5)
    with Session.open(model, params, clients, round_cfg=cfg) as sess, \
            Session.open(model, params, ref_clients,
                         round_cfg=cfg) as ref:
        addr = sess.serve("127.0.0.1:0")
        ack1 = push_update(addr, "edge-7", up, weight=2.0,
                           submission_id="wire-1", round_id=0)
        assert ack1["duplicate"] is False
        # the retry: same submission_id, e.g. after a lost ack
        ack2 = push_update(addr, "edge-7", up, weight=2.0,
                           submission_id="wire-1", round_id=0)
        assert ack2["duplicate"] is True
        # an explicit refusal is not retried: stale round errors out
        sess.run_round(client_lr=0.05)
        with pytest.raises(ValueError, match="stale"):
            push_update(addr, "edge-7", up, submission_id="wire-2",
                        round_id=0)
        ref.submit_update("edge-7", up, weight=2.0)
        ref.run_round(client_lr=0.05)
        _assert_params_equal(sess.params, ref.params)
        ing = sess.metrics()["ingress"]
        assert ing["duplicates"] == 1 and ing["stale_round"] == 1


# ---------------------------------------------------------------------------
# re-adoption: SIGKILL + same-name restart
# ---------------------------------------------------------------------------

def _mk_updates(n_updates=6, n_elems=4096, seed=0, pow2=False):
    rng = np.random.default_rng(seed)
    ups = [rng.normal(size=n_elems).astype(np.float32)
           for _ in range(n_updates)]
    ws = ([2.0 ** i for i in range(n_updates)] if pow2
          else [float(1 + i % 3) for i in range(n_updates)])
    return ups, ws


def _spawn(name, listen="127.0.0.1:0", fault_spec=None):
    return spawn_local_daemon(name, runtime="inproc", listen=listen,
                              stdout=subprocess.DEVNULL,
                              fault_spec=fault_spec)


def _kill_fleet(procs):
    for p in procs:
        if p.poll() is None:
            p.terminate()
    for p in procs:
        try:
            p.wait(timeout=10)
        except subprocess.TimeoutExpired:
            p.kill()


def _assert_no_leaks(rt):
    assert not rt._staged and not rt._partial_home
    assert all(not n.delivered for n in rt._nodes.values())


def _inproc_ref(ups, ws, n_elems, nodes=("rjA", "rjB"), round_id=0):
    """The bit-exactness reference: the same driven round in-proc."""
    rt = InProcRuntime()
    drv = RoundDriver(rt)
    assignment = {nodes[0]: [i for i in range(len(ups)) if i % 2 == 0],
                  nodes[1]: [i for i in range(len(ups)) if i % 2 == 1]}
    out = drv.run_round(
        round_id=round_id, assignment=assignment,
        updates=((nodes[i % 2], f"c{i}", u, w)
                 for i, (u, w) in enumerate(zip(ups, ws))),
        goal=len(ups), n_elems=n_elems)
    rt.close()
    return out.delta


def _spin_readopt(rt, name, old_epoch, budget_s, required=True):
    """Probe until ``name`` is re-adopted at a NEW epoch (bounded)."""
    deadline = time.perf_counter() + budget_s
    while time.perf_counter() < deadline:
        node = rt._nodes[name]
        if node.alive and node.epoch != old_epoch:
            return True
        rt.try_readopt(force=True)
        time.sleep(0.05)
    if required:
        raise AssertionError(f"{name} was never re-adopted")
    return False


def _readopt_round(kill_name, procs, addrs):
    """One mid-round SIGKILL + same-port restart of ``kill_name``; the
    round must finish on the oracle and the daemon must be re-adopted
    under its old name with a bumped epoch."""
    N = 4096
    ups, ws = _mk_updates(6, N)
    names = ["rjA", "rjB"]
    kill_idx = names.index(kill_name)
    rt = RemoteRuntime(addrs, readopt_timeout=2.0)
    try:
        assert list(rt.node_info()) == names
        drv = RoundDriver(rt)
        lost, rejoined = [], []
        drv.on(NodeLost, lost.append)
        drv.on(NodeRejoined, rejoined.append)
        old_epoch = rt._nodes[kill_name].epoch

        def kill_and_restart():
            os.kill(procs[kill_idx].pid, signal.SIGKILL)
            procs[kill_idx].wait(timeout=10)
            p2, _ = _spawn(kill_name, listen=addrs[kill_idx])
            procs[kill_idx] = p2

        assignment = {names[0]: [0, 2, 4], names[1]: [1, 3, 5]}

        def updates():
            for i, (u, w) in enumerate(zip(ups, ws)):
                yield names[i % 2], f"c{i}", u, w
                if i == 2:
                    kill_and_restart()
                if i == 3:
                    # by now the failed delivery has marked the node
                    # dead: re-adopt it MID-ROUND so the tail of the
                    # cohort flows through the restarted daemon (no
                    # assert — late discovery just adopts post-round)
                    _spin_readopt(rt, kill_name, old_epoch, 15.0,
                                  required=False)

        out = drv.run_round(round_id=0, assignment=assignment,
                            updates=updates(), goal=6, n_elems=N)
        # oracle-exact over ALL six updates: the dead subtree's staged
        # keys re-dispatched, nothing was lost with the daemon
        assert out.count == 6
        np.testing.assert_allclose(out.delta, fedavg_oracle(ups, ws),
                                   rtol=1e-5, atol=1e-6)
        assert [e.node for e in lost] == [kill_name]
        _spin_readopt(rt, kill_name, old_epoch, 30.0)
        assert rt.stats["readopted"] == 1 and rt.stats["epoch_bumps"] == 1
        assert rt._nodes[kill_name].alive
        _assert_no_leaks(rt)

        # the next round runs on the re-adopted fleet and is bit-exact
        out2 = drv.run_round(
            round_id=1, assignment=assignment,
            updates=((names[i % 2], f"c{i}", u, w)
                     for i, (u, w) in enumerate(zip(ups, ws))),
            goal=6, n_elems=N)
        np.testing.assert_array_equal(
            out2.delta, _inproc_ref(ups, ws, N, nodes=names, round_id=1))
        assert out2.crashes == 0
        # the NodeRejoined event reached the driver's handlers (during
        # whichever round's poll absorbed it)
        assert [e.node for e in rejoined] == [kill_name]
        assert rejoined[0].old_epoch == old_epoch
        assert rejoined[0].epoch != old_epoch        # a NEW process
        _assert_no_leaks(rt)
    finally:
        rt.close()


@pytest.mark.slow
def test_nonroot_daemon_restart_readopted_mid_round():
    procs, addrs = [], []
    try:
        for name in ("rjA", "rjB"):
            p, a = _spawn(name)
            procs.append(p)
            addrs.append(a)
        _readopt_round("rjB", procs, addrs)      # rjB: not the top node
    finally:
        _kill_fleet(procs)


@pytest.mark.slow
def test_root_daemon_restart_readopted_mid_round():
    procs, addrs = [], []
    try:
        for name in ("rjA", "rjB"):
            p, a = _spawn(name)
            procs.append(p)
            addrs.append(a)
        _readopt_round("rjA", procs, addrs)      # rjA: the top node
    finally:
        _kill_fleet(procs)


@pytest.mark.slow
def test_same_epoch_reconnect_after_controller_restart():
    """A controller that closes and reopens against a parked daemon
    re-adopts it at the SAME epoch (the daemon never died): no epoch
    bump, and staged state re-ships because the daemon swept on our
    disconnect."""
    procs, addrs = [], []
    try:
        p, a = _spawn("rjS")
        procs.append(p)
        addrs.append(a)
        rt1 = RemoteRuntime([a])
        ep1 = rt1._nodes["rjS"].epoch
        rt1.close()                              # daemon parks + sweeps
        rt2 = RemoteRuntime([a])
        assert rt2._nodes["rjS"].epoch == ep1    # same process answered
        N = 1024
        ups, ws = _mk_updates(2, N)
        drv = RoundDriver(rt2)
        out = drv.run_round(
            round_id=0, assignment={"rjS": [0, 1]},
            updates=(("rjS", f"c{i}", u, w)
                     for i, (u, w) in enumerate(zip(ups, ws))),
            goal=2, n_elems=N)
        np.testing.assert_allclose(out.delta, fedavg_oracle(ups, ws),
                                   rtol=1e-5, atol=1e-6)
        rt2.close()
    finally:
        _kill_fleet(procs)


# ---------------------------------------------------------------------------
# the fault soak: seeded chaos, oracle-exact rounds
# ---------------------------------------------------------------------------

def _decode_arrived(weight_sum, ws):
    """Power-of-2 weights make the folded subset exactly decodable:
    the float64 sum of distinct powers of two is lossless, so the
    round's Σc names exactly which updates folded."""
    arrived, rem = [], float(weight_sum)
    for i in reversed(range(len(ws))):
        if rem >= ws[i] - 1e-9:
            arrived.append(i)
            rem -= ws[i]
    assert abs(rem) < 1e-9, f"undecodable weight sum {weight_sum}"
    return sorted(arrived)


def _soak_round(rt, names, ups, ws, N, round_id, deadline_s=20.0):
    drv = RoundDriver(rt)
    assignment = {names[0]: [i for i in range(len(ups)) if i % 2 == 0],
                  names[1]: [i for i in range(len(ups)) if i % 2 == 1]}
    out = drv.run_round(
        round_id=round_id, assignment=assignment,
        updates=((names[i % 2], f"c{i}", u, w)
                 for i, (u, w) in enumerate(zip(ups, ws))),
        goal=len(ups), n_elems=N, deadline_s=deadline_s)
    return out


def _subset_ref(ups, ws, arrived, N, names, round_id):
    """In-proc reference over exactly the arrived subset, preserving
    each update's node assignment and relative order."""
    rt = InProcRuntime()
    drv = RoundDriver(rt)
    assignment = {names[0]: [i for i in arrived if i % 2 == 0],
                  names[1]: [i for i in arrived if i % 2 == 1]}
    assignment = {k: v for k, v in assignment.items() if v}
    out = drv.run_round(
        round_id=round_id, assignment=assignment,
        updates=((names[i % 2], f"c{i}", ups[i], ws[i]) for i in arrived),
        goal=len(arrived), n_elems=N)
    rt.close()
    return out.delta


@pytest.mark.slow
@pytest.mark.chaos
@pytest.mark.parametrize("seed", [101, 202, 303])
def test_fault_soak_rounds_match_arrived_oracle(seed):
    """Three seeded fault schedules — pure drops, drops + resets, and
    drops + a mid-round daemon SIGKILL/restart — each drive a round
    that must land on the FedAvg oracle over exactly the updates that
    arrived, and a clean follow-up round that is bit-exact again."""
    N = 1024
    n_updates = 10
    ups, ws = _mk_updates(n_updates, N, seed=seed, pow2=True)
    names = ["skA", "skB"]

    drop_only = seed == 101
    with_kill = seed == 303
    plan = FaultPlan(seed=seed,
                     drop=0.25,
                     reset=0.0 if (drop_only or with_kill) else 0.2,
                     drop_kinds=("deliver",),
                     # resets scoped to frames whose failure the round
                     # machinery recovers from (dead-peer teardown +
                     # re-dispatch), not the construction handshake
                     reset_kinds=("deliver", "drain"),
                     max_faults=4)
    daemon_spec = FaultPlan(kill_after=5) if with_kill else None

    procs, addrs = [], []
    try:
        for i, name in enumerate(names):
            p, a = _spawn(name,
                          fault_spec=daemon_spec if i == 1 else None)
            procs.append(p)
            addrs.append(a)
        if with_kill:
            # respawn skB on its old port the moment it dies; the
            # controller re-adopts it via poll_events' readopt pass
            def respawner():
                procs[1].wait()
                p2, _ = _spawn(names[1], listen=addrs[1])
                procs[1] = p2
            threading.Thread(target=respawner, daemon=True).start()

        rt = RemoteRuntime(addrs, fault_plan=plan, readopt_timeout=2.0)
        try:
            out = _soak_round(rt, names, ups, ws, N, round_id=0)
            arrived = _decode_arrived(out.weight, ws)
            assert out.count == len(arrived)
            if plan.injected.get("drop"):
                # dropped delivers are truly lost (the daemon never saw
                # them) — unlike a dead node's staged keys, which
                # re-dispatch recovers
                assert len(arrived) < n_updates
            # the FedAvg oracle over exactly the arrived updates
            sub_u = [ups[i] for i in arrived]
            sub_w = [ws[i] for i in arrived]
            np.testing.assert_allclose(
                out.delta, fedavg_oracle(sub_u, sub_w),
                rtol=1e-5, atol=1e-6)
            if drop_only:
                # no node ever died → per-node fold order is exactly
                # the arrived sub-sequence: bit-for-bit reproducible
                np.testing.assert_array_equal(
                    out.delta,
                    _subset_ref(ups, ws, arrived, N, names, round_id=0))

            # recovery: wait out the fleet (kill seed: re-adoption),
            # then a clean round must be bit-exact vs the in-proc tree
            if with_kill:
                deadline = time.perf_counter() + 30.0
                while not all(n.alive for n in rt._nodes.values()):
                    rt.try_readopt(force=True)
                    if time.perf_counter() > deadline:
                        raise AssertionError("fleet never whole again")
                    time.sleep(0.05)
            assert plan.max_faults is not None
            plan.injected["drop"] = plan.max_faults   # spend the budget
            out2 = _soak_round(rt, names, ups, ws, N, round_id=1)
            assert out2.count == n_updates
            np.testing.assert_array_equal(
                out2.delta,
                _inproc_ref(ups, ws, N, nodes=names, round_id=1))
            _assert_no_leaks(rt)
        finally:
            rt.close()
    finally:
        _kill_fleet(procs)


@pytest.mark.slow
@pytest.mark.chaos
def test_fault_soak_same_seed_is_bit_identical():
    """Determinism contract: the same controller-side fault seed over
    the same frame sequence injects the same faults — two runs of a
    drop-only soak produce byte-identical deltas and identical
    injection counts."""
    N = 1024
    ups, ws = _mk_updates(8, N, seed=7, pow2=True)
    names = ["dtA", "dtB"]
    deltas, counts = [], []
    for _ in range(2):
        plan = FaultPlan(seed=17, drop=0.3, drop_kinds=("deliver",),
                         max_faults=3)
        procs, addrs = [], []
        try:
            for name in names:
                p, a = _spawn(name)
                procs.append(p)
                addrs.append(a)
            rt = RemoteRuntime(addrs, fault_plan=plan)
            try:
                out = _soak_round(rt, names, ups, ws, N, round_id=0)
                deltas.append(out.delta.copy())
                counts.append(dict(plan.injected))
            finally:
                rt.close()
        finally:
            _kill_fleet(procs)
    assert counts[0] == counts[1]
    np.testing.assert_array_equal(deltas[0], deltas[1])
