"""The obs layer (paper §4.3, the LIFL agent): event-edge spans, the
per-round TTA breakdown, daemon telemetry drained over the wire, the
tolerant JSONL trace log, and the telemetry→capacity-model feedback.

Contracts covered:
  * every SPAN_KINDS entry survives the wire codec (same seam contract
    as events.EVENT_TYPES);
  * a disabled Tracer is inert (begin → -1, end(-1)/point no-ops);
  * ``breakdown()`` attributes ≥ 95% of round wall on the inproc,
    shmproc, and 2-node paths — the acceptance floor;
  * the JSONL trace file survives a FaultPlan daemon kill mid-round
    and ``read_traces`` skips the truncated/corrupt lines a kill
    leaves behind;
  * ``TopFolded.exec_s`` / ``PartialShipped.wire_s`` feed the RC
    capacity model and actually move the root-fold placement.
"""
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from repro.core.sidecar import MetricsMap, series_flatten
from repro.obs.trace import (
    NULL_TRACER,
    SPAN_KINDS,
    RoundTrace,
    Span,
    Tracer,
    read_traces,
    span_from_wire,
    span_to_wire,
    write_trace,
)
from repro.runtime.driver import InProcRuntime, RoundDriver

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# wire codec
# ---------------------------------------------------------------------------

def test_every_span_kind_roundtrips_on_the_wire():
    for i, kind in enumerate(SPAN_KINDS):
        s = Span(kind=kind, owner=f"mid@n{i}", node=f"n{i}", round_id=i,
                 t0=1.5 + i, dur_s=0.25 * (i + 1), id=i, parent=i - 1,
                 worker=i % 3 - 1, n=float(i * 10))
        assert span_from_wire(span_to_wire(s)) == s
        # str form decodes too (JSONL readers hand lines around as str)
        assert span_from_wire(span_to_wire(s).decode()) == s


def test_span_wire_rejects_unknown_kinds():
    with pytest.raises(TypeError, match="not a wire-registered"):
        span_to_wire(Span(kind="made-up"))
    with pytest.raises(ValueError, match="unknown span kind"):
        span_from_wire(b'{"span":"made-up","owner":""}')


# ---------------------------------------------------------------------------
# Tracer semantics
# ---------------------------------------------------------------------------

def test_tracer_begin_end_point_drain():
    tr = Tracer(enabled=True)
    tok = tr.begin("round", owner="driver", round_id=7)
    span = tr.end(tok, n=6.0)
    assert span.kind == "round" and span.round_id == 7 and span.n == 6.0
    assert span.dur_s >= 0.0
    tr.point("fold.top", 0.125, owner="top@n0", worker=2)
    got = tr.drain()
    assert [s.kind for s in got] == ["round", "fold.top"]
    assert tr.drain() == []                 # drain took everything
    # reset drops abandoned begins (exception paths)
    tr.begin("spawn")
    tr.reset()
    assert tr.drain() == [] and not tr._open


def test_disabled_tracer_is_inert():
    tr = Tracer(enabled=False)
    assert tr.begin("round") == -1
    assert tr.end(-1) is None               # callers never branch
    assert tr.point("fold.top", 0.1) is None
    with tr.span("dispatch") as tok:
        assert tok == -1
    tr.add(Span(kind="round"))
    assert tr.drain() == []
    assert NULL_TRACER.enabled is False


def test_end_with_stale_token_is_a_noop():
    tr = Tracer(enabled=True)
    tok = tr.begin("collect")
    assert tr.end(tok) is not None
    assert tr.end(tok) is None              # double-end


# ---------------------------------------------------------------------------
# MetricsMap: the agent's map drain
# ---------------------------------------------------------------------------

def test_metrics_map_drain_series_is_destructive():
    m = MetricsMap()
    m.update("netd", "ship_s", 0.25)
    m.update("netd", "ship_s", 0.75)
    m.update("mid@n0", "agg_exec_s", 0.5)
    series = m.drain_series()
    assert series["netd/ship_s"] == [1.0, 2]
    assert series["mid@n0/agg_exec_s"] == [0.5, 1]
    assert m.drain_series() == {}           # the drain reset the map
    # absorb_series merges a remote drain without inflating counts,
    # namespacing owners the way the controller files each daemon's map
    m.absorb_series(series, prefix="nodeB.")
    assert m.peek("nodeB.netd", "ship_s") == (1.0, 2)
    assert series_flatten(m.snapshot())["nodeB.mid@n0/agg_exec_s"] == [0.5, 1]


# ---------------------------------------------------------------------------
# breakdown coverage: inproc / shmproc / 2-node
# ---------------------------------------------------------------------------

def _drive_one(drv, nodes, ups, ws, n_elems, rid=0, fold_plan=None):
    assignment = {n: [i for i in range(len(ups)) if i % len(nodes) == j]
                  for j, n in enumerate(nodes)}
    updates = ((nodes[i % len(nodes)], f"c{i}", u, w)
               for i, (u, w) in enumerate(zip(ups, ws)))
    return drv.run_round(round_id=rid, assignment=assignment,
                         updates=updates, goal=len(ups), n_elems=n_elems,
                         fold_plan=fold_plan)


def _mk_updates(n_updates, n_elems, seed=0):
    rng = np.random.default_rng(seed)
    return ([rng.normal(size=n_elems).astype(np.float32)
             for _ in range(n_updates)],
            [float(1 + i % 3) for i in range(n_updates)])


def _assert_accounts(trace, floor=0.95):
    b = trace.breakdown()
    assert b["coverage"] >= floor, trace.summary()
    # the tiers are a partition: they sum to the wall by construction
    parts = (b["client_train_s"] + b["wire_s"] + b["mid_fold_s"]
             + b["top_fold_s"] + b["control_s"] + b["unaccounted_s"])
    assert parts == pytest.approx(b["wall_s"], rel=1e-6)
    return b


def test_breakdown_accounts_inproc_round():
    # big enough that the fixed inter-phase bookkeeping (~0.1 ms) stays
    # well under the 5% residual floor even on a loaded machine
    N = 1 << 20
    ups, ws = _mk_updates(6, N)
    rt = InProcRuntime()
    drv = RoundDriver(rt)                   # tracing on by default
    out = _drive_one(drv, ["n0", "n1"], ups, ws, N)
    assert out.count == 6
    trace = drv.last_trace
    assert trace is not None and trace.round_id == 0
    b = _assert_accounts(trace)
    assert b["wall_s"] == pytest.approx(trace.wall_s)
    # phase spans all fired exactly once
    for kind in ("round", "spawn", "dispatch", "collect", "fold"):
        assert len(trace.spans_of(kind)) == 1, kind
    # per-subtree latency points carry the subtree's update count
    subs = trace.spans_of("subtree")
    assert sorted(s.owner for s in subs) == ["mid@n0", "mid@n1"]
    assert sum(s.n for s in subs) == 6
    rt.close()


@pytest.mark.slow
def test_breakdown_accounts_shmproc_round_with_worker_spans():
    if not os.path.isdir("/dev/shm"):
        pytest.skip("POSIX shared memory required")
    from repro.runtime.driver import ShmProcRuntime

    N = 1 << 18
    ups, ws = _mk_updates(8, N)
    rt = ShmProcRuntime()
    drv = RoundDriver(rt)
    try:
        _drive_one(drv, ["n0", "n1"], ups, ws, N, rid=0)   # warm the pool
        out = _drive_one(drv, ["n0", "n1"], ups, ws, N, rid=1)
        assert out.count == 8 and out.crashes == 0
        trace = drv.last_trace
        _assert_accounts(trace)
        # worker spans were reconstructed from the shm ring records
        tasks = trace.spans_of("worker.task")
        assert tasks and all(s.worker >= 0 for s in tasks)
        assert all(s.node == rt.name for s in tasks)
        # ring-wait (TELEM) never exceeds its task's wall
        waits = {s.worker: s.dur_s for s in trace.spans_of("worker.wait")}
        for t in tasks:
            if t.worker in waits:
                assert waits[t.worker] <= t.dur_s + 0.01
    finally:
        rt.close()


@pytest.mark.slow
def test_two_node_node_top_round_drains_daemon_telemetry():
    """THE acceptance scenario: a 2-node node-top round accounts ≥ 95%
    of its wall, with each daemon's MetricsMap drained over the wire —
    including the fold-phase samples (partial ship, top-fold serve)
    that land after the quiesce edge."""
    from repro.core.placement import build_fold_plan
    from repro.runtime.netrt import RemoteRuntime, spawn_local_daemon

    N = 1 << 15
    ups, ws = _mk_updates(6, N, seed=3)
    procs, addrs = [], []
    try:
        for name in ("nodeA", "nodeB"):
            p, a = spawn_local_daemon(name, runtime="inproc",
                                      stdout=subprocess.DEVNULL)
            procs.append(p)
            addrs.append(a)
        rt = RemoteRuntime(addrs)
        drv = RoundDriver(rt)
        assignment_nodes = ["nodeA", "nodeB"]
        plan = build_fold_plan(
            {n: [i for i in range(6) if i % 2 == j]
             for j, n in enumerate(assignment_nodes)},
            topology="node")
        out = _drive_one(drv, assignment_nodes, ups, ws, N, rid=0,
                         fold_plan=plan)
        assert out.count == 6 and out.fold_tier == "node"
        trace = drv.last_trace
        _assert_accounts(trace)
        # per-daemon maps came over the wire, keyed by node name
        assert set(trace.telemetry) == {"nodeA", "nodeB"}
        # mid-tier fold exec was measured daemon-side on both nodes
        for node in ("nodeA", "nodeB"):
            s, c = 0.0, 0
            for key, sc in trace.telemetry[node].items():
                if key.endswith("/agg_exec_s"):
                    s += sc[0]
                    c += sc[1]
            assert c > 0 and s >= 0.0, node
        # exactly one sealed partial shipped daemon→daemon, and the
        # ship sample was pulled into THIS round's trace (not the next)
        ship_s, ship_n = trace.telemetry_series("netd/ship_s")
        assert ship_n == 1 and ship_s > 0.0
        _, served = trace.telemetry_series("netd/fetch_serve_s")
        assert served == 1                  # controller fetched the root fold
        # frame-conn sidecar series rode along (wire/tx_* per daemon)
        assert any(k.startswith("wire/tx_")
                   for k in trace.telemetry[out.root_node])
        rt.shutdown_nodes()
        rt.close()
    finally:
        for p in procs:
            if p.poll() is None:
                p.terminate()
        for p in procs:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                p.kill()


# ---------------------------------------------------------------------------
# JSONL trace log: tolerant reader, fault survival
# ---------------------------------------------------------------------------

def test_read_traces_skips_truncated_and_corrupt_lines(tmp_path):
    path = str(tmp_path / "traces.jsonl")
    for rid in range(2):
        write_trace(path, RoundTrace(
            round_id=rid, wall_s=0.5,
            spans=[Span(kind="round", owner="driver", round_id=rid,
                        dur_s=0.5)],
            telemetry={"nodeA": {"netd/ship_s": [0.01, 1]}}))
    with open(path, "a") as f:
        f.write('{"round_id": 2, "wall_s": 0.1, "spa')   # killed mid-write
    got = read_traces(path)
    assert [t.round_id for t in got] == [0, 1]
    assert got[1].telemetry["nodeA"]["netd/ship_s"] == [0.01, 1]
    assert got[0].spans[0].kind == "round"
    # corrupt middle lines are skipped, later good lines still load
    with open(path, "a") as f:
        f.write("\nnot json at all\n")
        f.write('{"schema": "drift"}\n')
    write_trace(path, RoundTrace(round_id=3, wall_s=0.2))
    assert [t.round_id for t in read_traces(path)] == [0, 1, 3]
    assert read_traces(str(tmp_path / "never-written.jsonl")) == []


@pytest.mark.slow
def test_trace_jsonl_survives_fault_plan_daemon_kill():
    """A FaultPlan(kill_after=N) daemon SIGKILLs itself mid-round; the
    driver re-dispatches to the survivor and every round's trace still
    lands in the JSONL file, parseable by the tolerant reader."""
    from repro.runtime.netrt import FaultPlan, RemoteRuntime, \
        spawn_local_daemon

    N = 2048
    ups, ws = _mk_updates(6, N, seed=4)
    path = tempfile.mktemp(suffix=".traces.jsonl")
    procs = []
    try:
        pa, aa = spawn_local_daemon("nodeA", runtime="inproc",
                                    stdout=subprocess.DEVNULL)
        procs.append(pa)
        # frame 4 on nodeB is the second deliver: the daemon dies
        # MID-DISPATCH, before publishing its partial, so the driver's
        # redispatch path (not the retriable publish/fetch abort) runs
        pb, ab = spawn_local_daemon("nodeB", runtime="inproc",
                                    stdout=subprocess.DEVNULL,
                                    fault_spec=FaultPlan(kill_after=4))
        procs.append(pb)
        rt = RemoteRuntime([aa, ab])
        drv = RoundDriver(rt, trace_sink=lambda t: write_trace(path, t))
        for rid in range(3):
            nodes = ["nodeA", "nodeB"] if rid == 0 else ["nodeA"]
            out = _drive_one(drv, nodes, ups, ws, N, rid=rid)
            assert out.count == 6           # goal reached despite the kill
        assert rt.stats["node_lost"] == 1   # the fault plan fired
        rt.close()
    finally:
        for p in procs:
            if p.poll() is None:
                p.terminate()
        for p in procs:
            try:
                p.wait(timeout=10)
            except subprocess.TimeoutExpired:
                p.kill()
    try:
        got = read_traces(path)
        assert [t.round_id for t in got] == [0, 1, 2]
        # the kill round recorded its crash in the trace meta
        assert got[0].meta["crashes"] >= 1
        assert all(t.meta["completed"] for t in got)
    finally:
        os.unlink(path)


# ---------------------------------------------------------------------------
# Session surface: metrics series + trace accessor
# ---------------------------------------------------------------------------

def _mk_session_fixtures():
    jax = pytest.importorskip("jax")
    from repro.configs.resnet import RESNET18
    from repro.core import ClientInfo
    from repro.data import (build_client_datasets, dirichlet_partition,
                            synthetic_femnist)
    from repro.models import build_resnet
    from repro.runtime import ClientRuntime

    model = build_resnet(RESNET18.reduced())
    params = model.init(jax.random.PRNGKey(0))
    imgs, labels = synthetic_femnist(120, num_classes=10, seed=0)
    shards = dirichlet_partition(labels, 8, alpha=0.5)
    clients = [ClientRuntime(ClientInfo(d.client_id, d.num_samples), d)
               for d in build_client_datasets(imgs, labels, shards)]
    return model, params, clients


@pytest.mark.slow
def test_session_metrics_series_and_trace(tmp_path):
    from repro.api import Session
    from repro.core import RoundConfig

    model, params, clients = _mk_session_fixtures()
    trace_path = str(tmp_path / "session.jsonl")
    with Session.open(model, params, clients,
                      round_cfg=RoundConfig(aggregation_goal=4),
                      trace_path=trace_path) as s:
        s.run_round(client_lr=0.05)
        s.run_round(client_lr=0.05)
        m = s.metrics()
        # the legacy flat-sum view and the full series view cover the
        # same keys; sum/count/mean are mutually consistent
        assert set(m["sidecar"]) == set(m["sidecar_series"])
        assert m["sidecar_series"], "sidecar saw no events"
        for key, stats in m["sidecar_series"].items():
            assert m["sidecar"][key] == stats["sum"]
            if stats["count"]:
                assert stats["mean"] == pytest.approx(
                    stats["sum"] / stats["count"])
        exec_series = [v for k, v in m["sidecar_series"].items()
                       if k.endswith("/agg_exec_s")]
        assert exec_series and all(v["count"] >= 1 for v in exec_series)
        # trace accessor: latest round by default, by id explicitly
        t1 = s.trace()
        assert t1.round_id == 1 and s.trace(1) is t1
        assert s.trace(0).round_id == 0
        assert s.trace(99) is None
        _assert_accounts(t1)
        assert "coverage" in t1.breakdown()
    # the JSONL sink got every round, independent of the in-memory cache
    assert [t.round_id for t in read_traces(trace_path)] == [0, 1]


# ---------------------------------------------------------------------------
# telemetry → capacity model feedback (satellite: placement shifts)
# ---------------------------------------------------------------------------

def _coordinator(nodes):
    from repro.core.coordinator import Coordinator, Selector

    return Coordinator(Selector([]), nodes)


def test_topfolded_exec_feeds_root_node_ewma_and_shifts_placement():
    from repro.core.placement import NodeState, choose_top_node
    from repro.runtime.events import TopFolded

    nodes = {"nA": NodeState(node="nA", max_capacity=20.0),
             "nB": NodeState(node="nB", max_capacity=20.0)}
    co = _coordinator(nodes)
    # tie on assignment share → deterministic RC/name tie-break picks nB
    tie = {"nA": [0, 1], "nB": [2, 3]}
    assert choose_top_node(nodes, tie) == "nB"
    # an expensive measured root fold ON nB (node tier) prices load
    # into its EWMA — the next root choice shifts to nA
    for _ in range(4):
        co.handle_event(TopFolded(round_id=0, agg_id="top@nB", node="nB",
                                  tier="node", count=16, weight=16.0,
                                  exec_s=4.0))
    assert nodes["nB"].exec_time_s > 1.0    # EWMA moved off the default
    assert nodes["nB"].residual_capacity < nodes["nA"].residual_capacity
    assert choose_top_node(nodes, tie) == "nA"


def test_controller_tier_topfolded_does_not_price_the_node():
    """A controller-tier fold burns controller CPU — it must not touch
    the EWMA of the node it is nominally named for."""
    from repro.core.placement import NodeState
    from repro.runtime.events import TopFolded

    nodes = {"nA": NodeState(node="nA", max_capacity=20.0)}
    co = _coordinator(nodes)
    co.handle_event(TopFolded(round_id=0, agg_id="top@nA", node="nA",
                              tier="controller", count=16, weight=16.0,
                              exec_s=9.0))
    assert nodes["nA"].exec_time_s == 1.0   # untouched default


def test_partialshipped_wire_ewma_prices_uplink_into_rc():
    from repro.core.placement import NodeState, choose_top_node
    from repro.runtime.events import PartialShipped

    nodes = {"nA": NodeState(node="nA", max_capacity=20.0),
             "nB": NodeState(node="nB", max_capacity=20.0)}
    co = _coordinator(nodes)
    rc0 = nodes["nB"].residual_capacity
    for _ in range(3):
        co.handle_event(PartialShipped(round_id=0, key="p0", src="nB",
                                       dst="nA", nbytes=1 << 20,
                                       wire_s=2.0))
    assert nodes["nB"].wire_time_s > 0.0
    assert nodes["nB"].residual_capacity < rc0
    # the tie-break now avoids the node with the loaded uplink
    assert choose_top_node(nodes, {"nA": [0, 1], "nB": [2, 3]}) == "nA"


# ---------------------------------------------------------------------------
# benchmark harness: gate verdicts ride the JSON rows
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_benchmarks_json_rows_carry_gate_verdicts(tmp_path):
    """`run.py --json` smoke: the output parses and every row carries a
    ``gates`` mapping with pass/fail verdicts (the obs suite's FATAL
    overhead gate among them)."""
    import json

    out_path = str(tmp_path / "bench.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "obs",
         "--json", out_path],
        capture_output=True, text=True, timeout=600, env=env, cwd=ROOT)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-2000:])
    with open(out_path) as f:
        doc = json.load(f)
    rows = doc["rows"]
    assert rows and all("gates" in row for row in rows)
    obs = [row for row in rows if row["bench"] == "obs"]
    assert obs and obs[0]["gates"].get("obs_overhead") == "pass"
    assert all(v in ("pass", "fail")
               for row in rows for v in row["gates"].values())
