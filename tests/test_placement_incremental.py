"""O(round) control plane (PR 10): incremental placement, plan
caching, deep fold trees.

Four seams this file holds:

  * **index ↔ reference parity** — the sorted-residual packing index
    (`PlacementState`) and the hoisted FirstFit loop are bit-identical
    to the original per-update-full-sort loop (``method="reference"``,
    kept verbatim as the oracle), ties / custom weights / fair-share
    values / overflow included, and the persistent index stays exact
    across node churn and EWMA drift;
  * **plan cache** — an unchanged cohort shape reuses the previous
    round's `FoldPlan` object (restamp identity), while cohort-size
    change, node churn through `handle_event`, and super-threshold
    EWMA drift each force a fresh plan — and a multi-round churn
    sequence driven through the public `Session` surface produces
    bit-identical params with the cache on and off;
  * **deep fold trees** — `build_fold_plan(fanout=K)` emits log-depth
    trees whose inner stages are co-located with their heaviest child
    (cross-node partial traffic stays within the two-level bound),
    survive the wire, and fold bit-identically to the flat plan on
    integer-valued updates under every root tier — with a crashed
    inner stage bailing out to the flat fold;
  * **pool index** — `AggregatorPool.acquire` through the per-node
    idle heap keeps the historical first-created-wins reuse order.
"""
import copy

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.api import Session  # noqa: E402
from repro.core import (  # noqa: E402
    ClientInfo, Coordinator, NodeState, PlacementState, RoundConfig,
    Selector, choose_fanout, place_updates,
)
from repro.core.coordinator import PLAN_DRIFT_REL  # noqa: E402
from repro.core.placement import (  # noqa: E402
    FoldPlan, build_fold_plan, partial_traffic_bound,
    plan_cross_node_transfers,
)
from repro.core.reuse import AggregatorPool, Role  # noqa: E402
from repro.runtime.driver import InProcRuntime, RoundDriver  # noqa: E402
from repro.runtime.events import (  # noqa: E402
    NodeJoined, NodeLost, NodeRejoined, WorkerCrashed,
)
from repro.runtime.trainer import ClientRuntime  # noqa: E402


def _fleet(caps, **kw):
    return {f"n{i}": NodeState(node=f"n{i}", max_capacity=float(c), **kw)
            for i, c in enumerate(caps)}


def _same_placement(a, b):
    assert a.assignment == b.assignment
    assert a.nodes_used == b.nodes_used
    assert a.overflow == b.overflow


# ---------------------------------------------------------------------------
# index ↔ reference parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy",
                         ["bestfit", "worstfit", "locality", "firstfit"])
def test_indexed_placement_matches_reference_fuzz(policy):
    """The O(U log N) index replays the O(U·N log N) loop bit for bit:
    random fleets with residual ties (equal capacities), fractional
    EWMA load, custom weights, fair-share caps, and overflow."""
    for seed in range(12):
        rng = np.random.default_rng(seed)
        n_nodes = int(rng.integers(3, 14))
        # half the fleets are all-equal capacity: every placement is a
        # tie broken purely by fleet insertion order
        if seed % 2:
            caps = [10.0] * n_nodes
        else:
            caps = rng.choice([5.0, 10.0, 10.0, 25.0], n_nodes).tolist()
        nodes = _fleet(caps)
        for ns in nodes.values():
            ns.arrival_rate = float(rng.choice([0.0, 0.3, 1.1]))
            ns.exec_time_s = float(rng.choice([0.5, 1.0]))
            ns.wire_time_s = float(rng.choice([0.0, 0.2]))
        n_up = int(rng.integers(1, 60))
        weights = (None if seed % 3 == 0
                   else rng.choice([1.0, 1.0, 2.0, 3.5], n_up).tolist())
        share = [1.0, 0.5, 0.37][seed % 3]
        ref = place_updates(n_up, copy.deepcopy(nodes), policy=policy,
                            weights=weights, share=share,
                            method="reference")
        got = place_updates(n_up, nodes, policy=policy,
                            weights=weights, share=share)
        _same_placement(got, ref)


def test_firstfit_hoisted_loop_matches_reference():
    """Satellite regression: the FirstFit path no longer rebuilds
    ``set(assignment)`` / re-sorts per update — assignments must stay
    bit-identical, overflow included."""
    nodes = _fleet([3.0, 2.0, 4.0])
    nodes["n1"].arrival_rate = 0.5     # fractional residual
    ref = place_updates(12, copy.deepcopy(nodes), policy="firstfit",
                        weights=[1.0, 2.0] * 6, method="reference")
    got = place_updates(12, nodes, policy="firstfit",
                        weights=[1.0, 2.0] * 6)
    _same_placement(got, ref)
    assert got.overflow                # capacity 9 < weight 18: spills


def test_placement_state_persists_across_churn():
    """One persistent index, repaired by deltas (remove/add/drift),
    packs every round exactly like a from-scratch reference run on a
    deep-copied fleet."""
    nodes = _fleet([8.0, 8.0, 12.0, 6.0])
    state = PlacementState(nodes)
    policies = ["bestfit", "worstfit", "locality",
                "bestfit", "locality", "worstfit"]
    rng = np.random.default_rng(7)
    for step, policy in enumerate(policies):
        if step == 2:                  # NodeLost
            del nodes["n1"]
            state.remove("n1")
        if step == 3:                  # NodeJoined (fresh name)
            ns = NodeState(node="n9", max_capacity=10.0)
            nodes["n9"] = ns
            state.add(ns)
        if step == 4:                  # EWMA drift behind sync's back
            nodes["n2"].arrival_rate = 1.7
            nodes["n0"].wire_time_s = 0.4
        if step == 5:                  # NodeRejoined under the old name
            ns = NodeState(node="n1", max_capacity=8.0)
            nodes["n1"] = ns
            state.add(ns)
        n_up = int(rng.integers(5, 40))
        weights = rng.choice([1.0, 1.0, 2.0], n_up).tolist()
        ref = place_updates(n_up, copy.deepcopy(nodes), policy=policy,
                            weights=weights, method="reference")
        got = place_updates(n_up, nodes, policy=policy, weights=weights,
                            state=state)
        _same_placement(got, ref)
        for ns in nodes.values():      # finish_round lifts the charge
            ns.assigned = 0.0


def test_placement_share_rebuild():
    """A share change re-keys every entry: the index must rebuild and
    still match the reference at the new share."""
    nodes = _fleet([10.0, 10.0, 10.0])
    state = PlacementState(nodes)
    for share in (1.0, 0.5, 1.0):
        ref = place_updates(9, copy.deepcopy(nodes), share=share,
                            method="reference")
        got = place_updates(9, nodes, share=share, state=state)
        _same_placement(got, ref)
        for ns in nodes.values():
            ns.assigned = 0.0


# ---------------------------------------------------------------------------
# plan cache (coordinator level)
# ---------------------------------------------------------------------------

def _coord(n_nodes=4, cap=20.0, n_clients=40):
    nodes = _fleet([cap] * n_nodes)
    sel = Selector([ClientInfo(client_id=f"c{i}")
                    for i in range(n_clients)], seed=0)
    return Coordinator(sel, nodes)


def _sampler(k):
    def sample(rid, pool):
        return pool[:k]
    return sample


def test_plan_cache_hit_restamps_same_object():
    co = _coord()
    cfg = RoundConfig(aggregation_goal=16, over_provision=1.0)
    p1 = co.plan_round(cfg, sampler=_sampler(16))
    co.finish_round()
    p2 = co.plan_round(cfg, sampler=_sampler(16))
    co.finish_round()
    # untagged plans restamp to themselves: the identity is the proof
    # the cache (not a replan) produced round 2
    assert p2.fold_plan is p1.fold_plan
    assert p2.placement.assignment == p1.placement.assignment
    assert p2.tag is p1.tag
    assert co.plan_cache_stats == {"hits": 1, "misses": 1,
                                   "invalidations": 0}


def test_plan_cache_misses_on_cohort_size_change():
    co = _coord()
    cfg = RoundConfig(aggregation_goal=16, over_provision=1.0)
    co.plan_round(cfg, sampler=_sampler(16))
    co.finish_round()
    p2 = co.plan_round(cfg, sampler=_sampler(12))
    co.finish_round()
    assert sum(len(v) for v in p2.placement.assignment.values()) == 12
    assert co.plan_cache_stats["hits"] == 0
    assert co.plan_cache_stats["misses"] == 2
    assert co.plan_cache_stats["invalidations"] == 1  # slot replaced


@pytest.mark.parametrize("event", [
    NodeLost(node="n1"),
    NodeJoined(node="nX", capacity=20.0),
])
def test_plan_cache_invalidated_by_node_churn(event):
    co = _coord()
    cfg = RoundConfig(aggregation_goal=16, over_provision=1.0)
    co.plan_round(cfg, sampler=_sampler(16))
    co.finish_round()
    co.handle_event(event)
    assert co.plan_cache_stats["invalidations"] == 1
    p2 = co.plan_round(cfg, sampler=_sampler(16))
    co.finish_round()
    assert co.plan_cache_stats["hits"] == 0
    if isinstance(event, NodeLost):
        assert "n1" not in p2.placement.assignment
    ref = place_updates(16, copy.deepcopy(co.nodes), method="reference")
    assert p2.placement.assignment == ref.assignment


def test_plan_cache_invalidated_by_rejoin_after_loss():
    co = _coord()
    cfg = RoundConfig(aggregation_goal=16, over_provision=1.0)
    co.plan_round(cfg, sampler=_sampler(16))
    co.finish_round()
    co.handle_event(NodeLost(node="n2"))
    co.plan_round(cfg, sampler=_sampler(16))
    co.finish_round()
    co.handle_event(NodeRejoined(node="n2", epoch=2, capacity=20.0))
    assert "n2" in co.nodes
    p3 = co.plan_round(cfg, sampler=_sampler(16))
    co.finish_round()
    assert co.plan_cache_stats["invalidations"] >= 2
    ref = place_updates(16, copy.deepcopy(co.nodes), method="reference")
    assert p3.placement.assignment == ref.assignment


def test_plan_cache_drift_threshold():
    """Sub-threshold EWMA drift keeps the cached plan; a node drifting
    past PLAN_DRIFT_REL of its capacity forces a replan."""
    co = _coord(cap=20.0)        # bucket width = 0.05 * 20 = 1.0
    cfg = RoundConfig(aggregation_goal=16, over_provision=1.0)
    co.plan_round(cfg, sampler=_sampler(16))
    co.finish_round()
    co.nodes["n0"].arrival_rate = 0.4 * PLAN_DRIFT_REL * 20.0 / 1.0
    co.plan_round(cfg, sampler=_sampler(16))
    co.finish_round()
    assert co.plan_cache_stats["hits"] == 1      # noise: plan survives
    co.nodes["n0"].arrival_rate = 2.5 * PLAN_DRIFT_REL * 20.0 / 1.0
    p3 = co.plan_round(cfg, sampler=_sampler(16))
    co.finish_round()
    assert co.plan_cache_stats["hits"] == 1      # drift: replanned
    assert co.plan_cache_stats["misses"] == 2
    ref = place_updates(16, copy.deepcopy(co.nodes), method="reference")
    assert p3.placement.assignment == ref.assignment


def test_plan_cache_off_is_bit_identical_with_on():
    """The cache is a pure memo: over a churn + drift sequence the
    cached coordinator and a cache-off twin produce identical plans
    AND identical post-round capacity state."""
    cfg_on = RoundConfig(aggregation_goal=12, over_provision=1.0)
    cfg_off = RoundConfig(aggregation_goal=12, over_provision=1.0,
                          plan_cache=False)
    a, b = _coord(), _coord()
    for step in range(5):
        for co in (a, b):
            if step == 2:
                co.handle_event(NodeLost(node="n3"))
            if step == 3:
                co.nodes["n0"].arrival_rate = 2.0
        pa = a.plan_round(cfg_on, sampler=_sampler(12))
        pb = b.plan_round(cfg_off, sampler=_sampler(12))
        assert pa.placement.assignment == pb.placement.assignment
        assert pa.top_node == pb.top_node
        assert pa.fold_plan == pb.fold_plan
        assert {n: ns.assigned for n, ns in a.nodes.items()} \
            == {n: ns.assigned for n, ns in b.nodes.items()}
        a.finish_round()
        b.finish_round()
    assert a.plan_cache_stats["hits"] >= 2
    assert b.plan_cache_stats["hits"] == 0


# ---------------------------------------------------------------------------
# plan cache (Session level, through the public surface)
# ---------------------------------------------------------------------------

class _Model:
    def loss(self, params, batch):   # external-update-only session
        raise NotImplementedError


N = 64


def _ext(cid):
    rng = np.random.default_rng(abs(hash(cid)) % (1 << 31))
    return rng.standard_normal(N).astype(np.float32)


def _session(plan_cache):
    clients = [ClientRuntime(ClientInfo(client_id=f"c{i}"), None)
               for i in range(8)]
    # roomy nodes: the drift bucket (PLAN_DRIFT_REL × MC = 2.0) then
    # rides out the EWMA cold-start transient the first folds feed
    # back, so the cache stabilizes right after round 0
    nodes = _fleet([40.0] * 4)
    return Session.open(
        _Model(), {"w": jnp.zeros((N,), jnp.float32)}, clients,
        nodes=nodes, seed=0,
        round_cfg=RoundConfig(aggregation_goal=8, over_provision=1.0,
                              plan_cache=plan_cache))


def test_session_churn_sequence_bitexact_with_and_without_cache():
    """Multi-round churn through the public Session surface: per-round
    params are bitwise equal between a plan-cached session and a
    cache-off twin, and the cached one actually hit."""
    churn = {1: NodeLost(node="n1"),
             3: NodeJoined(node="n8", capacity=40.0),
             4: NodeRejoined(node="n1", epoch=2, capacity=40.0)}
    with _session(True) as sa, _session(False) as sb:
        for r in range(8):
            ev = churn.get(r)
            for s in (sa, sb):
                if ev is not None:
                    s.emit(ev)
                for i in range(8):
                    s.submit_update(f"r{r}u{i}", _ext(f"r{r}u{i}"),
                                    weight=1.0 + i % 3)
                s.run_round()
            wa = np.asarray(sa.trainer.params["w"])
            wb = np.asarray(sb.trainer.params["w"])
            assert np.array_equal(wa, wb), f"round {r} diverged"
        ma, mb = sa.metrics()["planner"], sb.metrics()["planner"]
        assert ma["hits"] >= 2 and ma["invalidations"] >= 2
        assert mb["hits"] == 0
        assert "planner" in sa.status()


# ---------------------------------------------------------------------------
# deep fold trees
# ---------------------------------------------------------------------------

def _assignment(n_nodes, per_node=1):
    return {f"n{i:02d}": list(range(i * per_node, (i + 1) * per_node))
            for i in range(n_nodes)}


def test_deep_plan_shape_and_heaviest_child_placement():
    asg = _assignment(9)
    asg["n03"] = [100, 101, 102]       # the heavy subtree
    plan = build_fold_plan(asg, topology="worker", fanout=2)
    assert len(plan.mids) == 9
    assert plan.depth == 4             # 9 → 5 → 3 → 2 → root
    # trailing singletons hoist instead of wrapping: 4 + 2 + 1 stages
    assert len(plan.inners) == 7
    sites = {s.agg_id: s for s in plan.sites}
    for s in plan.inners + (plan.site(plan.root),):
        assert 2 <= len(s.children) <= 2
        # co-located with its heaviest child (subtree count, name tie)
        child_nodes = {sites[c].node for c in s.children}
        assert s.node in child_nodes
    # n03's weight pulls its whole spine of inner folds onto n03
    parent = {c: s for s in plan.sites for c in s.children}
    spine = "mid@n03"
    while spine in parent:
        assert parent[spine].node == "n03"
        spine = parent[spine].agg_id


def test_deep_plan_fanout_noop_and_validation():
    asg = _assignment(6)
    flat = build_fold_plan(asg, topology="worker")
    assert build_fold_plan(asg, topology="worker", fanout=8) == flat
    assert flat.depth == 1 and not flat.inners
    with pytest.raises(ValueError):
        build_fold_plan(asg, fanout=1)


def test_deep_plan_traffic_within_two_level_bound():
    model_bytes = 4096 * 4
    for fanout in (2, 3, 8):
        plan = build_fold_plan(_assignment(40), topology="worker",
                               fanout=fanout)
        crossings = plan_cross_node_transfers(plan)
        # every inner/root is co-located with ≥1 child, so the deep
        # tree ships at most leaves−1 partials — within the same bound
        # the flat plan is gated by
        assert crossings <= len(plan.mids) - 1
        assert crossings * model_bytes \
            < partial_traffic_bound(40, model_bytes)


def test_deep_plan_wire_roundtrip_and_restamp():
    plan = build_fold_plan(_assignment(9), topology="worker", fanout=3,
                           job="j", round_tag=1)
    assert FoldPlan.from_wire(plan.to_wire()) == plan
    re = plan.restamp(2)
    assert re != plan and len(re.sites) == len(plan.sites)
    assert all("#2@" in s.agg_id for s in re.sites)
    assert {s.node for s in re.sites} == {s.node for s in plan.sites}
    untagged = build_fold_plan(_assignment(9), topology="worker", fanout=3)
    assert untagged.restamp(None) is untagged


def _run(plan, n_nodes=12, per_node=2, n_elems=32):
    rng = np.random.default_rng(5)
    ups = [(f"n{i:02d}", f"c{i}.{j}",
            rng.integers(-16, 16, n_elems).astype(np.float32), 1.0)
           for i in range(n_nodes) for j in range(per_node)]
    rt = InProcRuntime()
    out = RoundDriver(rt).run_round(
        round_id=0, assignment=_assignment(n_nodes, per_node),
        updates=ups, goal=n_nodes * per_node, n_elems=n_elems,
        fold_plan=plan)
    rt.close()
    return out


def test_deep_fold_bitexact_across_tiers():
    """Integer-valued f32 updates fold to the same bits through the
    flat two-level plan and a fanout-3 deep tree, under both the
    controller and worker root tiers."""
    asg = _assignment(12, 2)
    flat = _run(build_fold_plan(asg, topology="controller"))
    outs = {}
    for tier in ("controller", "worker"):
        out = outs[tier] = _run(build_fold_plan(asg, topology=tier,
                                                fanout=3))
        assert out.count == 24 and out.fold_tier == tier
        assert np.array_equal(out.delta, flat.delta)
    # the inner stages actually ran: their exec stamps are recorded
    deep_plan = build_fold_plan(asg, topology="worker", fanout=3)
    assert any(s.agg_id in outs["worker"].exec_s
               for s in deep_plan.inners)


def test_deep_fold_crashed_inner_falls_back_to_flat():
    """A crashed inner stage must not cost the round: the driver bails
    to the battle-tested flat fold over the still-live leaf partials
    and the delta is unchanged."""
    class CrashInner(InProcRuntime):
        def __init__(self):
            super().__init__()
            self.crashed = False

        def spawn_aggregator(self, agg_id, **kw):
            super().spawn_aggregator(agg_id, **kw)
            if agg_id.startswith("fold") and not self.crashed:
                self.crashed = True
                self._open.pop(agg_id)
                self._events.append(WorkerCrashed(
                    round_id=kw.get("round_id", 0), agg_id=agg_id))

        def deliver_partial(self, agg_id, *a, **kw):
            if agg_id.startswith("fold") and agg_id not in self._open:
                return                 # deliveries to the corpse vanish
            super().deliver_partial(agg_id, *a, **kw)

    asg = _assignment(12, 2)
    flat = _run(build_fold_plan(asg, topology="controller"))
    rng = np.random.default_rng(5)
    ups = [(f"n{i:02d}", f"c{i}.{j}",
            rng.integers(-16, 16, 32).astype(np.float32), 1.0)
           for i in range(12) for j in range(2)]
    rt = CrashInner()
    out = RoundDriver(rt).run_round(
        round_id=0, assignment=asg, updates=ups, goal=24, n_elems=32,
        fold_plan=build_fold_plan(asg, topology="controller", fanout=3))
    rt.close()
    assert rt.crashed
    assert out.fold_tier == "controller" and out.count == 24
    assert np.array_equal(out.delta, flat.delta)


def test_choose_fanout_policy():
    assert choose_fanout(4) is None            # already a sane fan-in
    ex = _fleet([10.0] * 4)                    # wire EWMAs at 0
    assert choose_fanout(25, ex) == 5          # √M baseline
    wire = _fleet([10.0] * 4, wire_time_s=1.0)
    assert choose_fanout(25, wire) == 10       # shipping dear: widen
    hot = _fleet([10.0] * 4, wire_time_s=50.0)
    assert choose_fanout(100, hot) == 16       # clamped to the cap
    assert choose_fanout(5, hot) == 5          # never above site count


# ---------------------------------------------------------------------------
# pool idle index
# ---------------------------------------------------------------------------

def test_pool_idle_heap_keeps_first_created_wins_order():
    pool = AggregatorPool()
    a, _ = pool.acquire("n0", Role.LEAF)
    b, _ = pool.acquire("n0", Role.LEAF)
    other, _ = pool.acquire("n1", Role.LEAF)
    pool.release(b.agg_id)
    pool.release(a.agg_id)
    pool.release(a.agg_id)             # re-release: must not double-index
    pool.release(other.agg_id)
    got, delay = pool.acquire("n0", Role.MIDDLE)
    assert got is a and delay == 0.0   # oldest creation wins, promoted
    assert got.role == Role.MIDDLE
    got2, _ = pool.acquire("n0", Role.LEAF)
    assert got2 is b
    pool.terminate(other.agg_id)       # stale heap entry: lazy-deleted
    fresh, delay = pool.acquire("n1", Role.LEAF)
    assert fresh is not other and delay == pool.cold_start_s
    assert pool.stats.reused == 2 and pool.stats.promoted == 1
