"""Per-architecture smoke tests: REDUCED same-family configs, one
forward/train step on CPU, asserting output shapes + no NaNs (assignment
requirement (f))."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_opts
from repro.configs import ARCHS
from repro.models import build_model

ARCH_NAMES = sorted(ARCHS)


def _batch(cfg, B=2, S=24):
    b = {
        "tokens": jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, size=(B, S)), jnp.int32),
        "labels": jnp.asarray(np.random.default_rng(1).integers(
            0, cfg.vocab_size, size=(B, S)), jnp.int32),
    }
    if cfg.frontend:
        b["frontend"] = jnp.asarray(
            np.random.default_rng(2).normal(
                0, 0.02, size=(B, cfg.frontend_tokens, cfg.d_model)
            ), jnp.float32)
    return b


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_forward_and_train_step(name):
    cfg = ARCHS[name].reduced(dtype="float32")
    model = build_model(cfg, tiny_opts())
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    loss, aux = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"
    assert float(loss) > 0

    # one SGD train step must change params and keep them finite
    grads = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
    newp = jax.tree.map(lambda p, g: p - 0.1 * g, params, grads)
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(newp))
    )
    assert moved, f"{name}: gradients are identically zero"
    for leaf in jax.tree.leaves(newp):
        assert bool(jnp.all(jnp.isfinite(leaf))), f"{name}: non-finite params"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_reduced_decode_matches_forward(name):
    cfg = ARCHS[name].reduced(dtype="float32")
    model = build_model(
        cfg, tiny_opts(prefill_cache_capacity=40)
    )
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 17
    toks = jnp.asarray(np.random.default_rng(3).integers(
        0, cfg.vocab_size, size=(B, S)), jnp.int32)
    full = {"tokens": toks}
    pre = {"tokens": toks[:, : S - 1]}
    if cfg.frontend:
        fe = jnp.asarray(np.random.default_rng(4).normal(
            0, 0.02, size=(B, cfg.frontend_tokens, cfg.d_model)), jnp.float32)
        full["frontend"] = fe
        pre["frontend"] = fe
    lf, _ = jax.jit(model.prefill)(params, full)
    lp, caches = jax.jit(model.prefill)(params, pre)
    pos = S - 1 + (cfg.frontend_tokens if (cfg.frontend and not cfg.encoder_layers) else 0)
    ld, _ = jax.jit(model.decode_step)(
        params, toks[:, S - 1 : S], caches, jnp.int32(pos)
    )
    np.testing.assert_allclose(
        np.asarray(lf), np.asarray(ld), rtol=2e-3, atol=2e-3,
        err_msg=f"{name}: decode_step != full forward",
    )


def test_chunked_attention_matches_naive_in_model():
    cfg = ARCHS["gemma3-4b"].reduced(dtype="float32")
    batch = _batch(cfg)
    params = build_model(cfg, tiny_opts()).init(jax.random.PRNGKey(0))
    l_naive, _ = build_model(cfg, tiny_opts(attn_impl="naive")).loss(params, batch)
    l_chunk, _ = build_model(cfg, tiny_opts(attn_impl="chunked")).loss(params, batch)
    np.testing.assert_allclose(float(l_naive), float(l_chunk), rtol=1e-5)


def test_moe_dense_loss_changes_with_router():
    """Router actually routes: permuting router weights changes loss."""
    cfg = ARCHS["deepseek-v2-lite-16b"].reduced(dtype="float32")
    model = build_model(cfg, tiny_opts())
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l1, aux1 = model.loss(params, batch)
    assert float(aux1["moe_aux"]) > 0
