"""The event protocol + RoundDriver: serialization, ordering guards,
the deprecation shim, and the Session facade lifecycle.

Everything here is fast and single-process (the multi-process driver
scenarios — crash re-dispatch, bit-identity — live in test_shmrt.py).
"""
import time
import warnings

import jax
import numpy as np
import pytest

from repro.api import Session
from repro.configs.resnet import RESNET18
from repro.core import ClientInfo, NodeState, RoundConfig
from repro.data import (build_client_datasets, dirichlet_partition,
                        synthetic_femnist)
from repro.models import build_resnet
from repro.runtime import ClientRuntime, FederatedTrainer
from repro.runtime.driver import InProcRuntime, RoundDriver
from repro.runtime.events import (
    EVENT_TYPES,
    GoalReached,
    NodeJoined,
    NodeLost,
    NodeRejoined,
    PartialReady,
    PartialShipped,
    RoundDeadline,
    RoundEvent,
    RoundOpened,
    ScaleDecision,
    SLOBreached,
    UpdateShed,
    TopFolded,
    UpdateArrived,
    WorkerCrashed,
    from_wire,
    to_wire,
)

# ---------------------------------------------------------------------------
# wire protocol
# ---------------------------------------------------------------------------

# one non-default instance per registered event type: the round-trip
# must preserve every field of every type
_SAMPLES = [
    UpdateArrived(round_id=3, client_id="c7", node="n1", agg_id="mid@n1",
                  key="deadbeef" * 2, weight=12.5),
    PartialReady(round_id=4, agg_id="mid@n0", key="ab" * 8, weight=7.0,
                 count=3, exec_s=0.125, worker=2),
    PartialShipped(round_id=4, agg_id="top@n1", key="cd" * 8, src="n0",
                   dst="n1", nbytes=4096, wire_s=0.004),
    TopFolded(round_id=4, agg_id="top@n1", node="n1", tier="node",
              count=8, weight=21.0, exec_s=0.0625),
    GoalReached(round_id=5, goal=8, accepted=8),
    WorkerCrashed(round_id=6, agg_id="mid@n2", worker=1, exitcode=-9),
    NodeJoined(round_id=None, node="n9", capacity=25.0),
    NodeLost(round_id=7, node="n3"),
    NodeRejoined(round_id=None, node="n3", epoch=1723190400123456789,
                 old_epoch=1723190300987654321, capacity=16.0),
    RoundDeadline(round_id=8, deadline_s=30.0),
    RoundOpened(round_id=10, job="mnist", goal=16),
    UpdateShed(round_id=11, job="mnist", client_id="c3",
               retry_after_s=0.25, queued=32),
    ScaleDecision(round_id=9, aggregators_planned=12, nodes=4, levels=2,
                  direction="up"),
    SLOBreached(round_id=None, job="mnist", metric="p99_tta_s",
                measured=2.5, target=1.0, window=3),
]


def test_every_event_type_has_a_sample():
    assert {type(s).__name__ for s in _SAMPLES} == set(EVENT_TYPES)


@pytest.mark.parametrize("ev", _SAMPLES, ids=lambda e: type(e).__name__)
def test_wire_roundtrip(ev):
    raw = to_wire(ev)
    back = from_wire(raw)
    assert type(back) is type(ev)
    assert back == ev
    # str input works too (a JSON-carrying transport)
    assert from_wire(raw.decode()) == ev


def test_events_are_frozen():
    ev = GoalReached(round_id=1, goal=4, accepted=4)
    with pytest.raises(Exception):
        ev.goal = 5


def test_from_wire_rejects_unknown_type():
    with pytest.raises(ValueError):
        from_wire(b'{"event":"NotAnEvent","round_id":1}')


def test_to_wire_rejects_unregistered_class():
    with pytest.raises(TypeError):
        to_wire(RoundEvent(round_id=1))  # the base class is not on the wire


# ---------------------------------------------------------------------------
# driver ordering guards
# ---------------------------------------------------------------------------

def test_dispatch_reaches_typed_and_catchall_handlers():
    drv = RoundDriver()
    typed, every = [], []
    drv.on(UpdateArrived, typed.append)
    drv.on(RoundEvent, every.append)
    assert drv.dispatch(UpdateArrived(round_id=0, client_id="c"))
    assert drv.dispatch(GoalReached(round_id=0, goal=1, accepted=1))
    assert len(typed) == 1 and len(every) == 2


def test_deadline_after_goal_is_ignored():
    drv = RoundDriver()
    seen = []
    drv.on(RoundDeadline, seen.append)
    drv.begin_round(5)
    assert drv.dispatch(GoalReached(round_id=5, goal=4, accepted=4))
    # the goal was met: a late deadline for the same round is moot
    assert not drv.dispatch(RoundDeadline(round_id=5, deadline_s=1.0))
    assert seen == []
    assert drv.stats["deadline_ignored"] == 1


def test_deadline_before_goal_fires():
    drv = RoundDriver()
    seen = []
    drv.on(RoundDeadline, seen.append)
    drv.begin_round(2)
    assert drv.dispatch(RoundDeadline(round_id=2, deadline_s=1.0))
    assert len(seen) == 1


def test_stale_round_events_dropped():
    drv = RoundDriver()
    seen = []
    drv.on(RoundEvent, seen.append)
    drv.begin_round(1)
    drv.end_round(1)
    # round 1 is finished: its leftovers must not reach handlers
    assert not drv.dispatch(PartialReady(round_id=1, agg_id="mid@n0"))
    assert not drv.dispatch(RoundDeadline(round_id=0, deadline_s=1.0))
    assert seen == [] and drv.stats["stale_dropped"] == 2
    # round-agnostic events (round_id=None) always pass
    assert drv.dispatch(NodeLost(node="n1"))
    assert len(seen) == 1


def test_late_partial_shipped_is_not_stale_dropped():
    """PartialShipped is pushed async by a remote daemon and routinely
    loses the race with its own round's close-out; it is telemetry, so
    the stale-round guard must let it through to handlers."""
    drv = RoundDriver()
    seen = []
    drv.on(PartialShipped, seen.append)
    drv.begin_round(1)
    drv.end_round(1)
    assert drv.dispatch(PartialShipped(
        round_id=1, src="nodeB", dst="nodeA", key="k", nbytes=16))
    assert len(seen) == 1 and drv.stats["stale_dropped"] == 0


def test_driver_refuses_nested_rounds():
    drv = RoundDriver()
    drv.begin_round(1)
    with pytest.raises(RuntimeError):
        drv.begin_round(2)


def test_driver_survives_failing_update_source():
    """A client raising mid-round (iteration IS the training) must not
    brick the driver: the round closes, resources release, and the next
    round runs clean."""
    rt = InProcRuntime()
    drv = RoundDriver(rt)

    def boom():
        yield "n0", "c0", np.ones(8, np.float32), 1.0
        raise RuntimeError("client died mid-training")

    with pytest.raises(RuntimeError, match="client died"):
        drv.run_round(round_id=0, assignment={"n0": [0, 1]}, updates=boom(),
                      goal=2, n_elems=8)

    def ok():
        yield "n0", "c0", np.full(8, 2.0, np.float32), 1.0

    out = drv.run_round(round_id=1, assignment={"n0": [0]}, updates=ok(),
                        goal=1, n_elems=8)
    assert out.count == 1
    np.testing.assert_allclose(out.delta, np.full(8, 2.0, np.float32))
    rt.close()


def test_failed_round_is_retriable_under_same_round_id():
    """An aborted round must not advance the stale-round horizon: the
    coordinator never finished it, so the retry reuses the round_id and
    its events must still reach handlers."""
    rt = InProcRuntime()
    drv = RoundDriver(rt)

    def boom():
        yield "n0", "c0", np.ones(8, np.float32), 1.0
        raise RuntimeError("flaky client")

    with pytest.raises(RuntimeError):
        drv.run_round(round_id=5, assignment={"n0": [0, 1]}, updates=boom(),
                      goal=2, n_elems=8)
    seen = []
    drv.on(GoalReached, seen.append)
    out = drv.run_round(
        round_id=5, assignment={"n0": [0]},
        updates=iter([("n0", "c0", np.ones(8, np.float32), 1.0)]),
        goal=1, n_elems=8)
    assert out.count == 1
    assert len(seen) == 1  # retry events were NOT stale-dropped
    rt.close()


def test_no_store_leak_when_handler_raises_after_publish():
    """A mid that published (eagerly, inside deliver) before a handler
    raised must not strand its partial object in the store."""
    rt = InProcRuntime()
    drv = RoundDriver(rt)

    def die(ev):
        raise RuntimeError("handler boom")

    drv.on(UpdateArrived, die)  # fires AFTER the goal-1 mid published
    with pytest.raises(RuntimeError, match="handler boom"):
        drv.run_round(
            round_id=0, assignment={"n0": [0]},
            updates=iter([("n0", "c0", np.ones(8, np.float32), 1.0)]),
            goal=1, n_elems=8)
    assert rt.store._objs == {}  # update AND unabsorbed partial reclaimed
    rt.close()


def test_crash_before_any_dispatch_keeps_subtree_alive():
    """A subtree whose worker dies before receiving any update is
    respawned, so later updates for its node still have a live route
    and the round reaches the full goal."""
    from repro.runtime.events import WorkerCrashed as WC

    class CrashOnce(InProcRuntime):
        def __init__(self):
            super().__init__()
            self.crashed = False

        def poll_events(self, timeout=0.0):
            evs = super().poll_events(timeout)
            if not self.crashed:
                self.crashed = True
                self._open.pop("mid@n1", None)  # the "worker" died
                evs.append(WC(round_id=0, agg_id="mid@n1", worker=0))
            return evs

    rt = CrashOnce()
    drv = RoundDriver(rt)

    def ups():
        yield "n0", "c0", np.full(8, 1.0, np.float32), 1.0  # triggers crash
        yield "n1", "c1", np.full(8, 3.0, np.float32), 1.0
        yield "n1", "c2", np.full(8, 5.0, np.float32), 1.0

    out = drv.run_round(round_id=0, assignment={"n0": [0], "n1": [1, 2]},
                        updates=ups(), goal=3, n_elems=8)
    assert out.crashes == 1
    assert out.count == 3  # the n1 subtree survived its early crash
    np.testing.assert_allclose(out.delta, np.full(8, 3.0, np.float32))
    rt.close()


def test_legacy_kwarg_conflicting_with_canonical_raises():
    tr = _mk_trainer()
    with pytest.raises(TypeError, match="both"):
        tr.run_round(client_lr=0.1, lr=0.2)


def test_deadline_bounds_the_dispatch_pump():
    """The wall-clock budget applies to the cohort pump too (client
    training IS the pump), not just the collect phase."""
    rt = InProcRuntime()
    drv = RoundDriver(rt)
    deadlines = []
    drv.on(RoundDeadline, deadlines.append)

    def slow():
        yield "n0", "c0", np.ones(8, np.float32), 1.0
        time.sleep(0.3)  # a slow client blows the 0.1 s budget
        yield "n0", "c1", np.ones(8, np.float32), 1.0
        yield "n0", "c2", np.ones(8, np.float32), 1.0

    out = drv.run_round(round_id=0, assignment={"n0": [0, 1, 2]},
                        updates=slow(), goal=3, n_elems=8, deadline_s=0.1)
    assert out.deadline_hit
    assert len(deadlines) == 1      # fired exactly once
    assert out.accepted == 1        # pump stopped at the budget
    assert out.count == 1           # round closed with what had arrived
    rt.close()


def test_redispatch_cap_gives_up_poisoned_subtree():
    """A subtree that crashes deterministically on every respawn is
    given up after redispatch_limit attempts — the round closes with
    the healthy subtrees instead of hanging."""
    from repro.runtime.events import WorkerCrashed as WC

    class Poisoned(InProcRuntime):
        def drain(self, agg_id):
            if agg_id == "mid@n1":
                if self._open.pop(agg_id, None) is not None:
                    self._events.append(
                        WC(round_id=0, agg_id="mid@n1", worker=0))
            else:
                super().drain(agg_id)

    rt = Poisoned()
    drv = RoundDriver(rt)

    def ups():
        yield "n0", "c0", np.full(8, 2.0, np.float32), 1.0
        yield "n1", "c1", np.ones(8, np.float32), 1.0

    out = drv.run_round(round_id=0, assignment={"n0": [1], "n1": [0, 2]},
                        updates=ups(), goal=2, n_elems=8)
    assert out.redispatched == drv.redispatch_limit
    assert out.crashes == drv.redispatch_limit + 1
    assert out.count == 1           # the healthy subtree still folded
    np.testing.assert_allclose(out.delta, np.full(8, 2.0, np.float32))
    rt.close()


def test_subscribing_handlers_does_not_boot_runtime():
    """Session.on/emit must not construct the runtime as a side effect
    (a shmproc session would fork a dispatcher just to add a handler)."""
    model, params, clients = _mk_clients()
    with Session.open(model, params, clients,
                      round_cfg=RoundConfig(aggregation_goal=4)) as sess:
        sess.on(UpdateArrived, lambda ev: None)
        sess.emit(NodeJoined(node="nx", capacity=5.0))
        assert sess.trainer._runtime is None   # event bus only
        sess.run_round(client_lr=0.05)
        assert sess.trainer._runtime is not None


def test_deadline_closes_round_even_after_goal():
    """A counted subtree that never publishes must not hang run_round
    when a deadline budget is set: the budget always closes the round;
    the guard only suppresses the RoundDeadline *event* once the goal
    was met."""
    class Withholding(InProcRuntime):
        def drain(self, agg_id):
            if agg_id == "mid@n1":
                self._open.pop(agg_id, None)   # swallow: never publishes
            else:
                super().drain(agg_id)

    rt = Withholding()
    drv = RoundDriver(rt)
    deadlines = []
    drv.on(RoundDeadline, deadlines.append)

    def ups():
        yield "n0", "c0", np.ones(8, np.float32), 1.0
        yield "n1", "c1", np.ones(8, np.float32), 1.0

    out = drv.run_round(round_id=0, assignment={"n0": [0], "n1": [1, 2]},
                        updates=ups(), goal=2, n_elems=8, deadline_s=0.3)
    assert out.deadline_hit
    assert out.count == 1           # closed with the partial at hand
    assert deadlines == []          # goal met first: event suppressed...
    assert drv.stats["deadline_ignored"] == 1  # ...exactly once
    rt.close()


# ---------------------------------------------------------------------------
# trainer/Session end-to-end (inproc runtime)
# ---------------------------------------------------------------------------

def _mk_clients(n_samples=200, n_clients=8, failure_prob=0.0):
    cfg = RESNET18.reduced()
    model = build_resnet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    imgs, labels = synthetic_femnist(n_samples, num_classes=10, seed=0)
    shards = dirichlet_partition(labels, n_clients, alpha=0.5)
    clients = [
        ClientRuntime(ClientInfo(d.client_id, d.num_samples), d,
                      failure_prob=failure_prob)
        for d in build_client_datasets(imgs, labels, shards)
    ]
    return model, params, clients


def _mk_trainer(seed=0, **kw):
    model, params, clients = _mk_clients()
    return FederatedTrainer(
        model, params, clients,
        round_cfg=RoundConfig(aggregation_goal=4, over_provision=1.5),
        seed=seed, **kw)


def test_run_round_legacy_kwargs_shim():
    """PR-2 era run_round(lr=, batch_size=, epochs=) still works, warns
    DeprecationWarning, and produces the exact same params."""
    tr_old, tr_new = _mk_trainer(seed=0), _mk_trainer(seed=0)
    with pytest.warns(DeprecationWarning):
        rec_old = tr_old.run_round(lr=0.05, batch_size=32, epochs=1)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # the canonical spelling is clean
        rec_new = tr_new.run_round(client_lr=0.05, client_batch_size=32,
                                   client_epochs=1)
    assert rec_old["updates"] == rec_new["updates"]
    for a, b in zip(jax.tree.leaves(tr_old.params),
                    jax.tree.leaves(tr_new.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_round_unknown_kwarg_raises():
    tr = _mk_trainer()
    with pytest.raises(TypeError):
        tr.run_round(learning_rate=0.1)


def test_session_round_events_and_metrics():
    model, params, clients = _mk_clients()
    arrived, goals = [], []
    with Session.open(
        model, params, clients,
        round_cfg=RoundConfig(aggregation_goal=4, over_provision=1.5),
    ) as sess:
        sess.on(UpdateArrived, arrived.append)
        sess.on(GoalReached, goals.append)
        rec = sess.run_round(client_lr=0.05, client_batch_size=32)
        assert rec["updates"] == 4.0
        assert len(arrived) == 4 and len(goals) == 1
        assert goals[0].accepted == 4
        m = sess.metrics()
        assert m["model_version"] == 1 and len(m["rounds"]) == 1
        assert m["driver"]["events_dispatched"] >= 5
        assert any(k.startswith("top/") for k in m["sidecar"])
    assert sess.closed


def test_session_submit_update_rides_a_cohort_slot():
    model, params, clients = _mk_clients()
    n = sum(int(np.prod(np.shape(l))) for l in jax.tree.leaves(params))
    with Session.open(
        model, params, clients,
        round_cfg=RoundConfig(aggregation_goal=4, over_provision=1.5),
        server_opt="fedavg",
    ) as sess:
        ext = []
        sess.on(UpdateArrived, lambda ev: ext.append(ev.client_id))
        sess.submit_update("edge-1", np.full(n, 0.25, np.float32), weight=2.0)
        rec = sess.run_round(client_lr=0.05)
        assert rec["updates"] == 4.0
        assert "edge-1" in ext  # the external update took a slot
    with pytest.raises(ValueError):
        Session.open(model, params, clients).submit_update(
            "bad", np.zeros(3, np.float32))


def test_session_close_is_idempotent():
    model, params, clients = _mk_clients()
    sess = Session.open(model, params, clients,
                        round_cfg=RoundConfig(aggregation_goal=4))
    sess.run_round(client_lr=0.05)
    sess.close()
    sess.close()          # double close: no raise
    with sess:            # re-entering a closed session is harmless...
        pass
    assert sess.closed
    with pytest.raises(RuntimeError):
        sess.run_round()  # ...but driving rounds on it is an error
    # evaluate stays usable after close (params are still held)
    imgs, labels = synthetic_femnist(64, num_classes=10, seed=1)
    assert "loss" in sess.evaluate({"images": imgs, "labels": labels})


def test_node_churn_events_reshape_next_plan():
    """NodeLost/NodeJoined via Session.emit are coordinator hooks: the
    next round plans around the changed node set."""
    model, params, clients = _mk_clients()
    with Session.open(
        model, params, clients,
        nodes={f"node{i}": NodeState(node=f"node{i}", max_capacity=3.0)
               for i in range(3)},
        round_cfg=RoundConfig(aggregation_goal=6, over_provision=1.2),
    ) as sess:
        sess.run_round(client_lr=0.05)
        assert set(sess.nodes) == {"node0", "node1", "node2"}
        sess.emit(NodeLost(node="node2"))
        sess.emit(NodeJoined(node="node9", capacity=5.0))
        assert "node2" not in sess.nodes and "node9" in sess.nodes
        rec = sess.run_round(client_lr=0.05)
        assert rec["updates"] > 0
        plan = sess.trainer.coordinator.history[-1]
        assert "node2" not in plan.placement.assignment


def test_lazy_timing_still_aggregates():
    """RoundConfig(eager=False) queues then folds at drain — the PR-1
    regression (lazy rounds silently skipping aggregation) stays dead
    through the driver path."""
    model, params, clients = _mk_clients()
    tr = FederatedTrainer(
        model, params, clients,
        round_cfg=RoundConfig(aggregation_goal=4, over_provision=1.5,
                              eager=False),
        seed=0)
    before = [np.asarray(l).copy() for l in jax.tree.leaves(tr.params)]
    rec = tr.run_round(client_lr=0.05)
    assert rec["updates"] == 4.0
    moved = any(not np.array_equal(np.asarray(a), b)
                for a, b in zip(jax.tree.leaves(tr.params), before))
    assert moved


def test_eager_and_lazy_rounds_match_bitwise():
    """Recv∥Agg overlap is a timing choice, not a numeric one."""
    tr_e = _mk_trainer(seed=0)
    model, params, clients = _mk_clients()
    tr_l = FederatedTrainer(
        model, params, clients,
        round_cfg=RoundConfig(aggregation_goal=4, over_provision=1.5,
                              eager=False),
        seed=0)
    tr_e.run_round(client_lr=0.05)
    tr_l.run_round(client_lr=0.05)
    for a, b in zip(jax.tree.leaves(tr_e.params), jax.tree.leaves(tr_l.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
