"""Multi-device semantics, via subprocesses (XLA_FLAGS must be set
before jax import, so these tests don't share the test process)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_forced(code: str, ndev: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.slow
def test_moe_ep_matches_dense_dispatch():
    """Expert-parallel shard_map dispatch == dense oracle (no drops at
    high capacity factor)."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.compat import use_mesh
        from repro.launch.mesh import make_debug_mesh
        from repro.configs import ARCHS
        from repro.models import moe as moe_mod

        mesh = make_debug_mesh((2,2), ('data','model'))
        cfg = ARCHS['deepseek-v2-lite-16b'].reduced(dtype='float32')
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
        key = jax.random.PRNGKey(0)
        params = moe_mod.init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        with use_mesh(mesh):
            yd, _ = moe_mod.moe_block(cfg, params, x, impl='dense')
            ye, _ = moe_mod.moe_block(cfg, params, x, impl='ep',
                                       dp_axes=('data',), model_axis='model')
        err = float(jnp.max(jnp.abs(yd - ye)))
        rel = err / float(jnp.max(jnp.abs(yd)))
        print('REL', rel)
        assert rel < 2e-4, rel
        print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_vocab_matches_dense():
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import use_mesh
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_debug_mesh
        from repro.models.sharded_vocab import (
            chunked_lm_loss_sharded, decode_logits, embed_lookup)

        mesh = make_debug_mesh((2,2), ('data','model'))
        V, D, B, S = 512, 16, 4, 16
        key = jax.random.PRNGKey(0)
        table = jax.random.normal(key, (V, D), jnp.float32) * 0.05
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, V - 7)
        hid = jax.random.normal(jax.random.PRNGKey(2), (B, S, D))
        labels = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, V - 7)
        with use_mesh(mesh):
            # production paths are always jitted (eager shard_map with
            # partial-manual axes rejects unmentioned auto axes)
            e_sh = jax.jit(lambda t, k: embed_lookup(t, k, 'model'))(table, toks)
            e_dn = jnp.take(table, toks, axis=0)
            assert float(jnp.max(jnp.abs(e_sh - e_dn))) < 1e-5

            ce_sh = jax.jit(lambda h, t, y: chunked_lm_loss_sharded(
                h, t, y, vocab=V-7, tied=True, model_axis='model', chunk=8))
            ce_dn = jax.jit(lambda h, t, y: chunked_lm_loss_sharded(
                h, t, y, vocab=V-7, tied=True, model_axis=None, chunk=8))
            l_sh = ce_sh(hid, table, labels)
            l_dn = ce_dn(hid, table, labels)
            assert abs(float(l_sh) - float(l_dn)) < 1e-4, (float(l_sh), float(l_dn))

            g_sh = jax.jit(jax.grad(lambda t: chunked_lm_loss_sharded(
                hid, t, labels, vocab=V-7, tied=True, model_axis='model',
                chunk=8)))(table)
            g_dn = jax.jit(jax.grad(lambda t: chunked_lm_loss_sharded(
                hid, t, labels, vocab=V-7, tied=True, model_axis=None,
                chunk=8)))(table)
            assert float(jnp.max(jnp.abs(g_sh - g_dn))) < 1e-5

            d_sh = jax.jit(lambda h, t: decode_logits(
                h, t, vocab=V-7, tied=True, model_axis='model'))(hid[:, :1], table)
            d_dn = decode_logits(hid[:, :1], table, vocab=V-7, tied=True,
                                  model_axis=None)
            assert float(jnp.max(jnp.abs(d_sh - d_dn))) < 1e-4
        print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_hierarchical_equals_flat_aggregation_numerics():
    """LIFL hierarchical (manual-pod) round == flat GSPMD round: the
    schedule changes, the math must not."""
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.compat import use_mesh
        from functools import partial
        from repro.configs import ARCHS, ShapeConfig
        from repro.fl.round import (AggregationConfig, build_train_step,
            input_specs, train_shardings, abstract_params)
        from repro.fl.server import init_server_state
        from repro.launch.mesh import make_debug_mesh, dp_axes as mdp
        from repro.sharding import batch_specs, divisibility_fix, to_named

        mesh = make_debug_mesh((2,2,2), ('pod','data','model'))
        cfg = ARCHS['llama3.2-3b'].reduced(dtype='float32')
        dp = mdp(mesh)
        rng = np.random.default_rng(0)
        B, S = 8, 16
        toks = rng.integers(0, cfg.vocab_size, size=(B, S))
        batch = {'tokens': jnp.asarray(toks, jnp.int32),
                 'labels': jnp.asarray(np.roll(toks, -1, 1), jnp.int32)}
        results = {}
        with use_mesh(mesh):
            for hier in ('flat', 'hierarchical'):
                agg = AggregationConfig(hierarchy=hier, num_microbatches=2)
                step, model = build_train_step(cfg, mesh, agg)
                params = model.init(jax.random.PRNGKey(0))
                state = init_server_state('fedavg', params)
                p2, s2, m = jax.jit(step)(params, state, batch)
                results[hier] = (jax.tree.map(np.asarray, p2), float(m['loss']))
        pf, lf = results['flat']
        ph, lh = results['hierarchical']
        assert abs(lf - lh) < 1e-4, (lf, lh)
        errs = [float(np.max(np.abs(a - b)))
                for a, b in zip(jax.tree.leaves(pf), jax.tree.leaves(ph))]
        assert max(errs) < 5e-5, max(errs)
        print('OK flat==hier, loss', lf)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_int8_pod_compression_small_error():
    out = run_forced("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.compat import use_mesh
        from repro.configs import ARCHS
        from repro.fl.round import AggregationConfig, build_train_step
        from repro.fl.server import init_server_state
        from repro.launch.mesh import make_debug_mesh

        mesh = make_debug_mesh((2,2,2), ('pod','data','model'))
        cfg = ARCHS['llama3.2-3b'].reduced(dtype='float32')
        rng = np.random.default_rng(0)
        toks = rng.integers(0, cfg.vocab_size, size=(8, 16))
        batch = {'tokens': jnp.asarray(toks, jnp.int32),
                 'labels': jnp.asarray(np.roll(toks, -1, 1), jnp.int32)}
        outs = {}
        with use_mesh(mesh):
            for comp in ('none', 'int8'):
                agg = AggregationConfig(hierarchy='hierarchical',
                                         compress=comp, num_microbatches=2)
                step, model = build_train_step(cfg, mesh, agg)
                params = model.init(jax.random.PRNGKey(0))
                state = init_server_state('fedavg', params)
                p2, _, m = jax.jit(step)(params, state, batch)
                outs[comp] = jax.tree.map(np.asarray, p2)
        rel = max(
            float(np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9))
            for a, b in zip(jax.tree.leaves(outs['none']),
                            jax.tree.leaves(outs['int8'])))
        print('rel', rel)
        assert rel < 0.05, rel
        print('OK')
    """)
    assert "OK" in out


@pytest.mark.slow
def test_mini_dryrun_cell():
    """A miniature of the production dry-run path: lower + compile +
    memory/cost/collective extraction on a (2,2,2) mesh."""
    out = run_forced("""
        import jax
        from functools import partial
        from repro.compat import use_mesh
        from repro.analysis.hlo_cost import parse_hlo_cost
        from repro.configs import ARCHS, ShapeConfig
        from repro.fl.round import (AggregationConfig, abstract_params,
            build_train_step, input_specs, train_shardings)
        from repro.fl.server import init_server_state
        from repro.launch.mesh import make_debug_mesh, dp_axes as mdp
        from repro.sharding import batch_specs, divisibility_fix, to_named

        mesh = make_debug_mesh((2,2,2), ('pod','data','model'))
        cfg = ARCHS['gemma3-4b'].reduced()
        shape = ShapeConfig('t', 64, 8, 'train')
        agg = AggregationConfig(num_microbatches=2)
        dp = mdp(mesh)
        with use_mesh(mesh):
            step, model = build_train_step(cfg, mesh, agg)
            ap = abstract_params(model)
            ps, ss = train_shardings(model, mesh, agg)
            ast = jax.eval_shape(partial(init_server_state, 'fedavg'), ap)
            ab = input_specs(cfg, shape)
            bs = divisibility_fix(batch_specs(ab, dp), ab, mesh)
            fn = jax.jit(step,
                in_shardings=(to_named(ps, mesh), to_named(ss, mesh),
                              to_named(bs, mesh)),
                out_shardings=(to_named(ps, mesh), to_named(ss, mesh), None),
                donate_argnums=(0, 1))
            compiled = fn.lower(ap, ast, ab).compile()
        ma = compiled.memory_analysis()
        assert ma.temp_size_in_bytes > 0
        hc = parse_hlo_cost(compiled.as_text(), pod_size=4)
        assert hc.flops > 0 and hc.bytes_ > 0
        assert hc.coll_total > 0 and hc.coll_dcn > 0  # pod hop crosses DCN
        print('OK', hc.flops)
    """)
    assert "OK" in out
