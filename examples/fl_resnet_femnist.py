"""Paper-faithful workload: ResNet-18 (reduced) on non-IID synthetic
FEMNIST via the full LIFL control plane — the Fig 9(a) setup at laptop
scale, comparing the LIFL configuration against the SL-H-style baseline
(WorstFit spreading, lazy, no reuse) on the SAME learning trajectory.

  PYTHONPATH=src python examples/fl_resnet_femnist.py [--rounds 8]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.configs.resnet import RESNET18
from repro.core import (
    AggregatorPool,
    ClientInfo,
    NodeState,
    RoundConfig,
    SimConfig,
    simulate_round,
)
from repro.core.simulation import DataPlaneCosts
from repro.data import build_client_datasets, dirichlet_partition, synthetic_femnist
from repro.api import Session
from repro.models import build_resnet
from repro.runtime import ClientRuntime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--clients", type=int, default=24)
    ap.add_argument("--goal", type=int, default=10)
    args = ap.parse_args()

    cfg = RESNET18.reduced()
    model = build_resnet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    imgs, labels = synthetic_femnist(1000, num_classes=10, seed=0)
    shards = dirichlet_partition(labels, args.clients, alpha=0.3)
    clients = [
        ClientRuntime(ClientInfo(d.client_id, d.num_samples), d,
                      failure_prob=0.05)
        for d in build_client_datasets(imgs, labels, shards)
    ]
    test = {"images": imgs[:256], "labels": labels[:256]}

    lifl_cfg = SimConfig(n_nodes=5, mc_per_node=20, placement_policy="bestfit",
                         reuse=True, eager=True, dataplane="shm",
                         costs=DataPlaneCosts())
    slh_cfg = SimConfig(n_nodes=5, mc_per_node=20, placement_policy="worstfit",
                        reuse=False, eager=False, dataplane="shm",
                        costs=DataPlaneCosts())
    lifl_pool = AggregatorPool(cold_start_s=2.0)
    wall = {"lifl": 0.0, "sl_h": 0.0}
    print(f"{'round':>5} {'acc':>6} {'loss':>7} {'lifl_t':>8} {'slh_t':>8}")
    with Session.open(
        model, params, clients,
        round_cfg=RoundConfig(aggregation_goal=args.goal, over_provision=1.4,
                              placement_policy="bestfit"),
    ) as sess:
        for r in range(args.rounds):
            sess.run_round(client_lr=0.08, client_batch_size=32)
            ev = sess.evaluate(test)
            lifl = simulate_round(args.goal, lifl_cfg, pool=lifl_pool,
                                  arrival_span_s=8.0)
            slh = simulate_round(args.goal, slh_cfg,
                                 pool=AggregatorPool(cold_start_s=2.0),
                                 arrival_span_s=8.0)
            wall["lifl"] += max(30.0, lifl.act_s)   # eager overlaps training
            wall["sl_h"] += 30.0 + slh.act_s        # lazy adds up
            print(f"{r:5d} {ev['accuracy']:6.3f} {ev['loss']:7.4f} "
                  f"{wall['lifl']:8.1f} {wall['sl_h']:8.1f}")
    print(f"\nsame accuracy, simulated wall-clock: "
          f"LIFL {wall['lifl']:.0f}s vs SL-H {wall['sl_h']:.0f}s "
          f"({wall['sl_h']/wall['lifl']:.2f}x)")
    print("fl_resnet_femnist OK")


if __name__ == "__main__":
    main()
