"""Continuous aggregation: two jobs sharing one 2-node fleet.

LIFL's serving story, end to end.  An :class:`AggregationService` owns
a single fleet (two netd daemons over loopback TCP), a single rolling
:class:`RoundDriver` (two rounds in flight), and a shared coordinator
whose weighted fair-share splits node capacity 2:1 between the jobs.
Clients push updates whenever they finish — a thread per job here,
plus one real separate OS process over the wire — and the ingress
gateway decides, per submission, admit / busy-with-retry-hint /
duplicate.  The service opens, fills, and closes rounds continuously;
round N+1 spawns while round N's top fold is still in flight.

  PYTHONPATH=src python examples/serve_gateway.py [--fast]
"""
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import ClientInfo, NodeState, RoundConfig
from repro.obs import summary_line, to_prometheus
from repro.runtime.netrt import RemoteRuntime, spawn_local_daemon
from repro.serve import (
    AdmissionPolicy, AggregationService, DeadlinePolicy, MinCohortIdleGap,
    SLOTarget,
)

SRC = str(Path(__file__).parent.parent / "src")
N = 1024


class Model:
    """Jobs here are pure aggregation consumers — updates arrive from
    the outside, the service never runs local training."""

    def loss(self, params, batch):
        return jnp.sum(params["w"] ** 2), {}


class _CloseAny:
    def __init__(self, *pols):
        self.pols = pols

    def should_close(self, **kw):
        return any(p.should_close(**kw) for p in self.pols)


def main(fast: bool = False):
    rounds = 3 if fast else 6
    print("=== Continuous aggregation: 2 jobs, 2 netd nodes, rolling ===")
    # default spawn = per-daemon log file (proc.lifl_log_path), so an
    # orphaned daemon can never hang this process's pipes
    daemons = [spawn_local_daemon(f"node{i}", runtime="inproc")
               for i in range(2)]
    rt = RemoteRuntime([a for _, a in daemons])
    nodes = {n: NodeState(node=n, max_capacity=cap)
             for n, cap in rt.node_info().items()}
    svc = AggregationService(
        nodes, runtime=rt,
        admission=AdmissionPolicy(max_queue=64, job_quota=32,
                                  retry_base_s=0.01))
    try:
        params = {"w": jnp.zeros((N,), jnp.float32)}
        for job, weight in (("mnist", 2.0), ("speech", 1.0)):
            svc.add_job(
                job, Model(), params,
                [ClientInfo(client_id=f"{job}-c{i}", num_samples=10)
                 for i in range(8)],
                weight=weight,
                round_cfg=RoundConfig(aggregation_goal=4),
                slo=SLOTarget(p99_tta_s=30.0, max_shed_frac=0.9))
        for job in svc.jobs:
            print(f"job {job!r}: "
                  f"fair-share={svc.coordinator.job_share(job):.2f}")

        # the live-telemetry loop: scrape both daemons' stats frames on
        # a jittered period, mid-round included, feeding the SLO tracker
        svc.start_monitor(period_s=0.25)

        addr = svc.serve("127.0.0.1:0")
        print(f"serving on {addr} (jobs route by frame meta)")

        # one pusher thread per job: push until told to stop, honour
        # busy verdicts by sleeping the server's retry hint
        stop = threading.Event()
        rng = np.random.default_rng(0)
        flats = {}

        def pusher(job):
            k = 0
            while not stop.is_set():
                cid = f"{job}-u{k}"
                flat = flats.setdefault(
                    cid, rng.standard_normal(N).astype(np.float32))
                v = svc.submit(job, cid, flat, 1.0 + k % 3,
                               submission_id=cid)
                if v["admitted"]:
                    k += 1
                    time.sleep(0.002)
                else:
                    time.sleep(v["retry_after_s"])

        threads = [threading.Thread(target=pusher, args=(j,), daemon=True)
                   for j in ("mnist", "speech")]
        for t in threads:
            t.start()

        # ... and one genuinely external pusher process over the wire
        code = (
            "import numpy as np\n"
            "from repro.runtime.netrt import push_update\n"
            "for k in range(8):\n"
            f"    push_update({addr!r}, f'edge-{{k}}', "
            "np.ones(%d, np.float32), job='mnist', "
            "submission_id=f'edge-{k}')\n"
            "print('edge client: 8 updates pushed')\n" % N)
        env = dict(os.environ)
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        edge = subprocess.Popen([sys.executable, "-c", code], env=env)

        t0 = time.perf_counter()
        recs = svc.run_rounds(
            {"mnist": rounds, "speech": rounds},
            policy=_CloseAny(MinCohortIdleGap(min_cohort=2,
                                              idle_gap_s=0.05),
                             DeadlinePolicy(deadline_s=20.0)))
        wall = time.perf_counter() - t0
        stop.set()
        for t in threads:
            t.join(timeout=5)
        edge.wait(timeout=30)

        for rec in recs:
            print(f"  ticket {rec['ticket']}: job={rec['job']} "
                  f"round={rec['round']} cohort={len(rec['cohort'])} "
                  f"wall={rec['t_close'] - rec['t_open']:.2f}s")
        m = svc.ingress_metrics()
        print(f"{2 * rounds} rounds in {wall:.2f}s  "
              f"pipeline_overlap={svc.pipeline_overlap():.2f}")
        print(f"ingress: admitted={m['admitted']} shed={m['shed']} "
              f"duplicates={m['duplicates']} queued_now={m['queued_now']}")
        # one fleet snapshot, rendered both ways
        snap = svc.health()
        print("health:", summary_line(snap))
        prom = to_prometheus(snap)
        print(f"prometheus export: {len(prom.splitlines())} samples, e.g.")
        for line in prom.splitlines():
            if "tta_seconds" in line or "_node_up" in line:
                print("  " + line)
        mon = snap["monitor"]
        print(f"monitor: {mon['scrapes']} scrapes "
              f"({mon['mid_round_scrapes']} mid-round), "
              f"{mon['stale_events']} stale events")
        assert svc.pipeline_overlap() > 0, "rounds never overlapped"
        assert mon["scrapes"] > 0, "monitor never scraped the fleet"
    finally:
        svc.close()
        from repro.runtime.netrt import reap_local_daemon
        for proc, _ in daemons:
            reap_local_daemon(proc)
    print("done: two jobs, one fleet, rounds rolling — no silent drops.")


if __name__ == "__main__":
    main(fast="--fast" in sys.argv)
