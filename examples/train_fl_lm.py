"""End-to-end driver: federated training of a ~100M-parameter LM for a
few hundred rounds with the full stack — federated data pipeline, fused
LIFL rounds (eager hierarchical FedAvg), in-graph sidecar metrics,
async checkpointing, checkpoint/restart.

  PYTHONPATH=src python examples/train_fl_lm.py [--rounds 200] [--resume]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import dataclasses

import jax

from repro.configs import ARCHS
from repro.data import CohortTokenLoader
from repro.fl.round import AggregationConfig
from repro.launch.mesh import make_host_mesh
from repro.models import ModelOptions
from repro.runtime import FusedFLTrainer


def build_100m_config():
    """A ~100M-param llama-family config (12L, d=768, 12H/4KV, ff=2048)."""
    base = ARCHS["llama3.2-3b"]
    return dataclasses.replace(
        base,
        name="llama-fl-100m",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=4,
        head_dim=64,
        d_ff=2048,
        vocab_size=32000,
        dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--cohorts", type=int, default=4)
    ap.add_argument("--ckpt", default="results/fl_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--server-opt", default="fedadam")
    args = ap.parse_args()

    cfg = build_100m_config()
    print(f"model: {cfg.name} params={cfg.param_count()/1e6:.1f}M")
    mesh = make_host_mesh()
    agg = AggregationConfig(
        hierarchy="flat",              # single host 'pod'
        timing="eager",
        num_microbatches=args.cohorts,
        server_opt=args.server_opt,
        server_lr=3e-3 if args.server_opt == "fedadam" else 0.7,
    )
    opts = ModelOptions(attn_impl="chunked", moe_impl="dense",
                        loss_chunk=128, block_kv=128, remat=True)
    trainer = FusedFLTrainer(cfg, mesh, agg, opts=opts,
                             checkpoint_dir=args.ckpt, checkpoint_every=50)
    if args.resume and trainer.maybe_restore():
        print(f"resumed from round {trainer.round_id}")
    else:
        trainer.init(seed=0)

    loader = CohortTokenLoader(cfg.vocab_size, args.seq, args.cohorts)
    t0 = time.time()
    for r in range(trainer.round_id, args.rounds):
        rec = trainer.train_round(loader.round_batch(args.batch, r))
        if r % 10 == 0 or r == args.rounds - 1:
            tok_s = args.batch * args.seq * (r + 1 - trainer.round_id + 1) / max(
                time.time() - t0, 1e-9)
            print(f"round {r:4d} loss={rec['loss']:.4f} "
                  f"|Δ|={rec['update_norm']:.3f} ({tok_s:,.0f} tok/s)",
                  flush=True)
    if trainer.ckpt:
        trainer.ckpt.wait()
    print("final loss:", trainer.history[-1]["loss"])


if __name__ == "__main__":
    main()
