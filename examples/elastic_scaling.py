"""Elastic scaling through the event protocol (Fig 10(a,b) shape).

A varying client arrival rate drives the EWMA hierarchy planner; the
elastic controller and the coordinator are ordinary event handlers on
the Session's round driver: ``NodeLost``/``NodeJoined`` injected with
``Session.emit`` reshape the *next* round's plan (the warm pool absorbs
re-plans without cold starts), and every re-plan is published as a
typed ``ScaleDecision`` event.

  PYTHONPATH=src python examples/elastic_scaling.py [--fast]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax

from repro.api import Session
from repro.configs.resnet import RESNET18
from repro.core import ClientInfo, NodeState, RoundConfig
from repro.data import build_client_datasets, dirichlet_partition, synthetic_femnist
from repro.models import build_resnet
from repro.runtime import (
    ArrivalTrace,
    ClientRuntime,
    ElasticController,
    NodeJoined,
    NodeLost,
    ScaleDecision,
)


def main(rounds: int = 8):
    cfg = RESNET18.reduced()
    model = build_resnet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    imgs, labels = synthetic_femnist(300, num_classes=10, seed=0)
    shards = dirichlet_partition(labels, 10, alpha=0.5)
    clients = [ClientRuntime(ClientInfo(d.client_id, d.num_samples), d)
               for d in build_client_datasets(imgs, labels, shards)]
    nodes = {f"n{i}": NodeState(node=f"n{i}", max_capacity=20) for i in range(5)}

    ec = ElasticController(nodes)
    trace = ArrivalTrace(base_rate=40, variability=0.6, period_rounds=6)
    decisions = []

    with Session.open(
        model, params, clients, nodes=nodes,
        round_cfg=RoundConfig(aggregation_goal=4, over_provision=1.5),
    ) as sess:
        # the controller reacts to churn; anyone can watch the decisions
        sess.on(NodeLost, ec.handle)
        sess.on(NodeJoined, ec.handle)
        sess.on(ScaleDecision, decisions.append)

        print(f"{'round':>5} {'arrivals':>9} {'aggs':>5} {'nodes':>6} "
              f"{'updates':>8} {'reused':>7}")
        for r in range(rounds):
            if r == rounds // 2:
                sess.emit(NodeLost(node="n1"))            # pod failure
            if r == rounds - 2:
                sess.emit(NodeJoined(node="n5", capacity=20))  # replacement
            rate = trace.rate(r)
            sess.emit(ec.decide(r, expected_updates=rate))
            rec = sess.run_round(client_lr=0.05, client_batch_size=32)
            d = decisions[-1]
            print(f"{r:5d} {rate:9.1f} {d.aggregators_planned:5d} "
                  f"{rec['nodes_used']:6.0f} {rec['updates']:8.0f} "
                  f"{rec['reused']:7.0f}")

        print("\ncontroller events:")
        for e in ec.events[:12]:
            print(f"  round {e.round_id}: {e.kind} {e.detail}")
        print(f"scale decisions: "
              f"{[f'{d.round_id}:{d.direction}' for d in decisions]}")
    print("elastic_scaling OK")


if __name__ == "__main__":
    main(rounds=4 if "--fast" in sys.argv[1:] else 8)
