"""Elastic scaling demo (Fig 10(a,b) shape): a varying client arrival
rate drives the EWMA hierarchy planner; aggregator count tracks load
(load-proportional resources), nodes can die mid-run, and the warm pool
absorbs re-plans without cold starts.

  PYTHONPATH=src python examples/elastic_scaling.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.core import NodeState
from repro.runtime import ArrivalTrace, ElasticController


def main():
    nodes = {f"n{i}": NodeState(node=f"n{i}", max_capacity=20) for i in range(5)}
    ec = ElasticController(nodes)
    trace = ArrivalTrace(base_rate=40, variability=0.6, period_rounds=12)
    print(f"{'round':>5} {'arrivals':>9} {'aggs':>5} {'nodes':>6} {'levels':>7}")
    for r in range(30):
        if r == 12:
            ec.lose_node("n1", r)       # pod failure mid-run
        if r == 20:
            ec.join_node("n5", 20, r)   # replacement joins
        rate = trace.rate(r)
        st = ec.step(r, expected_updates=rate)
        print(f"{r:5d} {rate:9.1f} {st['aggregators_planned']:5d} "
              f"{st['nodes']:6d} {st['levels']:7d}")
    print("\nevents:")
    for e in ec.events[:12]:
        print(f"  round {e.round_id}: {e.kind} {e.detail}")
    print("elastic_scaling OK")


if __name__ == "__main__":
    main()
