"""Quickstart: one LIFL FL round, end to end, on CPU in ~a minute.

Shows the whole pipeline at toy scale through the public API:
  Session.open → clients → selector → BestFit placement → EWMA
  hierarchy plan → warm engines → RoundDriver event loop → eager
  hierarchical FedAvg → server update (plus an externally-submitted
  update riding a cohort slot),
then the same semantics as a single fused XLA step (the form the
512-chip dry-run lowers).

  PYTHONPATH=src python examples/quickstart.py [--fast]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Session
from repro.configs import ARCHS
from repro.core import ClientInfo, NodeState, RoundConfig
from repro.data import CohortTokenLoader, build_client_datasets, dirichlet_partition, synthetic_femnist
from repro.fl.round import AggregationConfig
from repro.launch.mesh import make_host_mesh
from repro.models import build_resnet, build_model, ModelOptions
from repro.configs.resnet import RESNET18
from repro.runtime import ClientRuntime, FusedFLTrainer, UpdateArrived


def part1_paper_faithful(rounds: int = 4):
    print("=== Part 1: paper-faithful LIFL round (ResNet-18-reduced, FEMNIST) ===")
    cfg = RESNET18.reduced()
    model = build_resnet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    imgs, labels = synthetic_femnist(400, num_classes=10, seed=0)
    shards = dirichlet_partition(labels, 12, alpha=0.5)
    clients = [
        ClientRuntime(ClientInfo(d.client_id, d.num_samples), d, failure_prob=0.1)
        for d in build_client_datasets(imgs, labels, shards)
    ]
    test = {"images": imgs[:128], "labels": labels[:128]}
    arrivals = []
    with Session.open(
        model, params, clients,
        nodes={f"node{i}": NodeState(node=f"node{i}", max_capacity=20)
               for i in range(3)},
        round_cfg=RoundConfig(aggregation_goal=6, over_provision=1.5),
    ) as sess:
        sess.on(UpdateArrived, lambda ev: arrivals.append(ev.client_id))
        print("  before:", sess.evaluate(test))
        for r in range(rounds):
            if r == 1:
                # an externally-computed update rides a cohort slot
                # (a params-shaped pytree delta; flat vectors work too)
                sess.submit_update(
                    "edge-client",
                    jax.tree.map(np.zeros_like, sess.params), weight=1.0)
            rec = sess.run_round(client_lr=0.05, client_batch_size=32)
            print(f"  round {r}: updates={rec['updates']:.0f} "
                  f"nodes={rec['nodes_used']:.0f} inter_node={rec['inter_node']:.0f} "
                  f"cold={rec['cold_starts']:.0f} reused={rec['reused']:.0f}")
        print("  after :", sess.evaluate(test))
        m = sess.metrics()
        print(f"  metrics: model_version={m['model_version']} "
              f"events={m['driver']['events_dispatched']} "
              f"arrivals_seen={len(arrivals)}")


def part2_fused_round(rounds: int = 6):
    print("=== Part 2: fused FL round as one XLA program (tiny llama) ===")
    cfg = ARCHS["llama3.2-3b"].reduced(dtype="float32")
    mesh = make_host_mesh()
    agg = AggregationConfig(hierarchy="flat", timing="eager", num_microbatches=4)
    opts = ModelOptions(attn_impl="chunked", moe_impl="dense", ssm_chunk=8,
                        loss_chunk=16, block_kv=8, remat=False)
    trainer = FusedFLTrainer(cfg, mesh, agg, opts=opts)
    trainer.init(seed=0)
    loader = CohortTokenLoader(cfg.vocab_size, seq_len=32, n_cohorts=4)
    for r in range(rounds):
        rec = trainer.train_round(loader.round_batch(16, r))
        print(f"  round {r}: loss={rec['loss']:.4f} "
              f"updates={rec['updates_aggregated']:.0f} "
              f"|Δ|={rec['update_norm']:.4f}")


if __name__ == "__main__":
    fast = "--fast" in sys.argv[1:]
    part1_paper_faithful(rounds=2 if fast else 4)
    part2_fused_round(rounds=2 if fast else 6)
    print("quickstart OK")
