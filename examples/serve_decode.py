"""Serving example: batched autoregressive decoding with ring KV caches
(the path the decode_32k / long_500k dry-run cells lower).

Prefills a batch of prompts on a tiny llama-family model, then decodes
greedily with the ring-buffer cache, reporting per-step latency.

  PYTHONPATH=src python examples/serve_decode.py [--steps 32]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.data import TokenTaskStream
from repro.models import ModelOptions, build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--steps", type=int, default=32)
    args = ap.parse_args()

    cfg = ARCHS[args.arch].reduced(dtype="float32")
    total = args.prompt_len + args.steps
    model = build_model(cfg, ModelOptions(
        attn_impl="chunked", moe_impl="dense", block_kv=32, remat=False,
        prefill_cache_capacity=total + 8,
    ))
    params = model.init(jax.random.PRNGKey(0))

    stream = TokenTaskStream(cfg.vocab_size, args.prompt_len, seed=1)
    prompts = jnp.asarray(stream.batch(args.batch)["tokens"])
    batch = {"tokens": prompts}
    if cfg.frontend:
        batch["frontend"] = jnp.zeros(
            (args.batch, cfg.frontend_tokens, cfg.d_model), jnp.float32)

    t0 = time.perf_counter()
    logits, caches = jax.jit(model.prefill)(params, batch)
    logits.block_until_ready()
    print(f"prefill {args.batch}x{args.prompt_len}: "
          f"{(time.perf_counter()-t0)*1e3:.1f} ms")

    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    generated = [tok]
    lat = []
    for i in range(args.steps):
        pos = jnp.int32(args.prompt_len + i)
        t0 = time.perf_counter()
        logits, caches = decode(params, tok, caches, pos)
        logits.block_until_ready()
        lat.append(time.perf_counter() - t0)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    out = jnp.concatenate(generated, axis=1)
    lat = np.asarray(lat[1:]) * 1e3  # skip compile step
    print(f"decoded {args.steps} tokens/seq; per-step "
          f"p50={np.percentile(lat,50):.2f}ms p99={np.percentile(lat,99):.2f}ms")
    # the synthetic task is affine-recurrent: a well-trained model would
    # continue it; untrained output is random — we just show the plumbing
    print("sample continuation:", np.asarray(out[0, :12]))
    print("serve_decode OK")


if __name__ == "__main__":
    main()
