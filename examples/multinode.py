"""Multi-node LIFL: two netd daemons, one Session, node-rooted rounds.

Spawns two per-node daemons as real OS processes (each owning its own
local runtime — shared-memory workers where /dev/shm exists), connects
a Session to the fleet, and drives hierarchical rounds under the
**node-top** fold topology: the round's top fold runs ON the busiest
worker node (the FoldPlan root), the other node ships its sealed
partial daemon→daemon, and only the final folded Σ c·u returns to the
controller — ~1 × model per round instead of nodes × model.  Then
turns the session into an ingest endpoint (`serve`) and pushes an
external update over the wire from a separate process, exactly as an
edge client would.

  PYTHONPATH=src python examples/multinode.py [--fast]
"""
import os
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

import jax
import numpy as np

from repro.api import Session
from repro.configs.resnet import RESNET18
from repro.core import ClientInfo, RoundConfig
from repro.data import build_client_datasets, dirichlet_partition, synthetic_femnist
from repro.models import build_resnet
from repro.runtime import ClientRuntime, PartialReady
from repro.runtime.events import PartialShipped, TopFolded
from repro.runtime.netrt import spawn_local_daemon

SRC = str(Path(__file__).parent.parent / "src")




def main(fast: bool = False):
    rounds = 2 if fast else 4
    node_rt = "shmproc" if os.path.isdir("/dev/shm") else "inproc"
    print(f"=== Multi-node LIFL: 2 × netd({node_rt}) over loopback TCP ===")

    cfg = RESNET18.reduced()
    model = build_resnet(cfg)
    params = model.init(jax.random.PRNGKey(0))
    imgs, labels = synthetic_femnist(240, num_classes=10, seed=0)
    shards = dirichlet_partition(labels, 10, alpha=0.5)
    clients = [ClientRuntime(ClientInfo(d.client_id, d.num_samples), d)
               for d in build_client_datasets(imgs, labels, shards)]

    # capacity 4 < the over-provisioned cohort: the locality packer must
    # spill onto the second node, so the round actually exercises the
    # daemon→daemon partial ship (capacity 20 would fit on one node)
    daemons = [spawn_local_daemon(f"node{i}", runtime=node_rt, capacity=4)
               for i in range(2)]
    addrs = [a for _, a in daemons]
    try:
        with Session.open(
            model, params, clients, nodes=addrs,     # ← multi-node mode
            round_cfg=RoundConfig(aggregation_goal=4, over_provision=1.5,
                                  placement_policy="locality",
                                  topology="node"),  # ← node-side top fold
        ) as s:
            print(f"connected nodes: {list(s.nodes)}  "
                  f"(runtime={s.metrics()['runtime']})")
            n_model = sum(int(np.prod(np.shape(l)))
                          for l in jax.tree.leaves(params))
            model_mb = 4 * n_model / 1e6
            s.on(PartialReady,
                 lambda ev: print(f"  partial from {ev.agg_id}: "
                                  f"count={ev.count} Σc={ev.weight:.0f}"))
            s.on(PartialShipped,
                 lambda ev: print(f"  partial shipped {ev.src} → {ev.dst} "
                                  f"({ev.nbytes / 1e6:.2f} MB, "
                                  f"daemon→daemon)"))
            s.on(TopFolded,
                 lambda ev: print(f"  round rooted on {ev.node} "
                                  f"(tier={ev.tier}): top folded "
                                  f"count={ev.count}"))
            rx0 = 0.0
            for _ in range(rounds):
                rec = s.run_round(client_lr=0.05)
                rx1 = s.metrics()["sidecar"].get("net/rx_bytes", 0.0)
                ret_mb = (rx1 - rx0) / 1e6
                rx0 = rx1
                ctrl_mb = rec["nodes_used"] * model_mb
                print(f"round {int(rec['round'])}: updates={rec['updates']:.0f} "
                      f"nodes_used={rec['nodes_used']:.0f} "
                      f"workers={rec['workers']:.0f} "
                      f"wall={rec['wall_s']:.2f}s  return={ret_mb:.2f} MB "
                      f"(controller-top would return {ctrl_mb:.2f} MB)")
                # the TTA breakdown (§4.3): driver spans + each daemon's
                # telemetry, drained over the wire, one line per tier
                trace = s.trace()
                print(f"  {trace.summary()}")
                ship_s, ships = trace.telemetry_series("netd/ship_s")
                if ships:
                    print(f"  telemetry: {ships} partial ship(s) "
                          f"{ship_s * 1e3:.1f}ms on the shipping daemon, "
                          f"nodes drained: {sorted(trace.telemetry)}")

            # --- serve mode: external client process pushes an update --
            addr = s.serve("127.0.0.1:0")
            n = sum(int(np.prod(np.shape(l)))
                    for l in jax.tree.leaves(params))
            code = (
                "import numpy as np\n"
                "from repro.runtime.netrt import push_update\n"
                f"print('client:', push_update({addr!r}, 'edge-0', "
                f"np.zeros({n}, np.float32), weight=2.0))\n")
            env = dict(os.environ)
            env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
            subprocess.run([sys.executable, "-c", code], env=env, check=True)
            rec = s.run_round(client_lr=0.05)
            print(f"round {int(rec['round'])} (with external update): "
                  f"updates={rec['updates']:.0f}")
            print("sidecar bytes:",
                  {k: int(v) for k, v in s.metrics()["sidecar"].items()
                   if k.endswith("tx_bytes")})
    finally:
        for proc, _ in daemons:
            proc.terminate()
        for proc, _ in daemons:
            proc.wait(timeout=10)
    print("done: cross-node rounds drove the same RoundDriver loop; only "
          "sealed partials crossed the wire.")


if __name__ == "__main__":
    main(fast="--fast" in sys.argv)
