"""Trip-count-aware cost extraction from post-SPMD optimized HLO text.

``compiled.cost_analysis()`` visits every computation exactly once, so a
``lax.scan`` (compiled to a ``while`` with
``backend_config={"known_trip_count":{"n":...}}``) under-counts its body
by the trip count — a 28-layer scanned transformer reports ~1/28 of its
FLOPs.  This module rebuilds the cost from the HLO text with call-graph
multiplicities:

  * ENTRY has multiplicity 1;
  * ``while(condition=%c, body=%b)`` multiplies both by known_trip_count;
  * fusion/call/to_apply propagate the caller's multiplicity;
  * conditional branches count once (upper bound of a single taken path).

Per computation we account:
  * flops   — dot ops (2·prod(out)·prod(contracting)); convolutions
              (2·prod(out)·kernel_elems·Cin/groups);
  * bytes   — operands + outputs of *top-level* (non-fusion-body)
              instructions, mirroring HloCostAnalysis' fusion handling;
  * collectives — kind/bytes/tier (ICI vs DCN via replica groups),
              scaled by multiplicity.

All numbers are PER-DEVICE (post-partitioning shapes).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.analysis.hlo import _DTYPE_BYTES, _parse_groups

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP = re.compile(r'known_trip_count\D*(\d+)')
_CALLED = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)"
)
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _match_paren(s: str, i: int) -> int:
    """Index of the ')' matching the '(' at s[i]."""
    depth = 0
    for j in range(i, len(s)):
        if s[j] == "(":
            depth += 1
        elif s[j] == ")":
            depth -= 1
            if depth == 0:
                return j
    return len(s) - 1


_OPC = re.compile(r"\s*([\w\-]+)\(")


def _split_rhs(rhs: str):
    """'(T1, /*index=5*/T2) opcode(%a, %b), attrs' -> (type, opcode, args).

    Tuple types may contain '=' inside /*index=N*/ comments, so this is a
    paren-aware scanner, not a regex."""
    rhs = rhs.lstrip()
    if rhs.startswith("("):
        end = _match_paren(rhs, 0)
        type_str = rhs[: end + 1]
        rest = rhs[end + 1 :]
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return rhs, "", ""
        type_str = rhs[:sp]
        rest = rhs[sp:]
    m = _OPC.match(rest)
    if not m:
        return type_str, "", ""
    op = m.group(1)
    i = rest.find("(", m.start(1))
    j = _match_paren(rest, i)
    return type_str, op, rest[i + 1 : j]


def _result_type(rhs: str) -> str:
    return _split_rhs(rhs)[0]


def _opcode(rhs: str) -> str:
    return _split_rhs(rhs)[1]


def _operand_names(rhs: str) -> List[str]:
    return re.findall(r"%([\w.\-]+)", _split_rhs(rhs)[2])


@dataclass
class CompCost:
    flops: float = 0.0
    bytes_: float = 0.0
    transcendental: float = 0.0
    collectives: List[Tuple[str, int, str]] = field(default_factory=list)
    calls: List[Tuple[str, float]] = field(default_factory=list)  # (name, mult)
    is_fusion_body: bool = False
    attributions: List[Tuple[str, float]] = field(default_factory=list)


@dataclass
class HloCost:
    flops: float = 0.0
    bytes_: float = 0.0
    coll_total: float = 0.0
    coll_dcn: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_count: Dict[str, int] = field(default_factory=dict)
    byte_attribution: Dict[str, float] = field(default_factory=dict)

    def to_dict(self):
        return {
            "flops": self.flops,
            "bytes": self.bytes_,
            "coll_total": self.coll_total,
            "coll_dcn": self.coll_dcn,
            "coll_by_kind": self.coll_by_kind,
            "coll_count": self.coll_count,
        }


_NEW_UNIT = re.compile(
    r"^(\s*(ROOT\s+)?%[\w.\-]+\s*=\s*|ENTRY\b|%[\w.\-]+\s*\(|\s*\}\s*$)"
)


def _logical_lines(text: str):
    """Join wrapped HLO lines (long tuples/param lists span lines)."""
    cur: List[str] = []
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if _NEW_UNIT.match(line):
            if cur:
                yield " ".join(cur)
            cur = [line]
        else:
            cur.append(line.strip())
    if cur:
        yield " ".join(cur)


def parse_hlo_cost(text: str, pod_size: int = 256,
                   attribute: bool = False) -> HloCost:
    comps: Dict[str, CompCost] = {}
    fusion_bodies = set()
    entry: Optional[str] = None

    # ---- pass 1: per-computation instruction index -----------------------
    # Records (op, operands, result_type) per instruction, the unwrapped
    # root opcode, and a per-fusion-parameter usage classification so the
    # call site can charge sliced reads at slice size (HloCostAnalysis'
    # fusion handling) instead of full-operand size.
    _WRAPPERS = ("bitcast", "copy", "convert", "transpose", "reshape")
    _SLICERS = ("dynamic-slice", "slice", "gather")
    comp_root_op: Dict[str, str] = {}
    comp_ops: Dict[str, Dict[str, Tuple[str, List[str], str]]] = {}
    comp_root_name: Dict[str, str] = {}
    comp_param_name: Dict[Tuple[str, int], str] = {}
    cur: Optional[str] = None
    for line in _logical_lines(text):
        if (not line.startswith(" ") and line.endswith("{")
                and (line.startswith("%") or line.startswith("ENTRY")) and "->" in line):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comp_ops[cur] = {}
                if line.startswith("ENTRY"):
                    entry = cur
                continue
        if cur is None or line.strip() == "}":
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        nm, rhs_ = mi.group(1), mi.group(2)
        t_, op_, args_ = _split_rhs(rhs_)
        comp_ops[cur][nm] = (op_, _operand_names(rhs_), t_)
        if op_ == "parameter":
            mp = re.match(r"\s*(\d+)", args_)
            if mp:
                comp_param_name[(cur, int(mp.group(1)))] = nm
        if line.lstrip().startswith("ROOT"):
            comp_root_name[cur] = nm
    for cname, rootnm in comp_root_name.items():
        ops = comp_ops[cname]
        nm = rootnm
        for _ in range(6):  # unwrap bitcast/copy/convert chains
            op_, operands, _t = ops.get(nm, ("", [], ""))
            if op_ in _WRAPPERS and operands:
                nm = operands[0]
            else:
                break
        comp_root_op[cname] = ops.get(nm, ("", [], ""))[0]

    # classification: (comp, param_index) -> ("alias"|"sliced"|"full", bytes)
    param_class: Dict[Tuple[str, int], Tuple[str, float]] = {}

    def _classify(cname: str):
        ops = comp_ops[cname]
        uses: Dict[str, List[Tuple[str, str]]] = {}
        for nm, (op_, operands, t_) in ops.items():
            for on in operands:
                uses.setdefault(on, []).append((op_, t_))
        i = 0
        while (cname, i) in comp_param_name:
            pnm = comp_param_name[(cname, i)]
            u = uses.get(pnm, [])
            if not u:
                param_class[(cname, i)] = ("sliced", 0.0)
            elif all(op_ in _SLICERS for op_, _ in u):
                b = max(_type_bytes(t_) for _, t_ in u)
                param_class[(cname, i)] = ("sliced", float(b))
            elif any(op_ == "dynamic-update-slice" for op_, _ in u):
                # in-place target of the internal DUS: charge update size
                upd = 0.0
                for nm, (op_, operands, t_) in ops.items():
                    if op_ == "dynamic-update-slice" and operands and \
                            operands[0] == pnm and len(operands) > 1:
                        ut = ops.get(operands[1], ("", [], ""))[2]
                        upd = max(upd, float(_type_bytes(ut)))
                param_class[(cname, i)] = ("alias", upd)
            else:
                param_class[(cname, i)] = ("full", 0.0)
            i += 1

    for cname in comp_ops:
        _classify(cname)

    # ---- pass 2: account ---------------------------------------------------
    cur = None
    shapes: Dict[str, str] = {}
    for line in _logical_lines(text):
        if (not line.startswith(" ") and line.endswith("{")
                and (line.startswith("%") or line.startswith("ENTRY")) and "->" in line):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = CompCost()
                shapes = {}
                if line.startswith("ENTRY"):
                    entry = cur
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        rtype = _result_type(rhs)
        shapes[name] = rtype
        op = _opcode(rhs)
        if not op:
            continue
        cc = comps[cur]
        bytes_before = cc.bytes_

        # ---- calls ---------------------------------------------------------
        trip = 1.0
        if op == "while":
            mt = _TRIP.search(rhs)
            trip = float(mt.group(1)) if mt else 1.0
        for cm in _CALLED.finditer(rhs):
            cc.calls.append((cm.group(1), trip))
            if op == "fusion":
                fusion_bodies.add(cm.group(1))
        mb = _BRANCHES.search(rhs)
        if mb:
            for b in re.findall(r"%?([\w.\-]+)", mb.group(1)):
                cc.calls.append((b, 1.0))

        # ---- flops ---------------------------------------------------------
        if op == "dot":
            out_elems = 1
            for _, dims in _shape_dims(rtype):
                for d in dims:
                    out_elems *= d
            ops_names = _operand_names(rhs)
            lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            contract = 1
            if ops_names and lc and lc.group(1):
                lhs_type = shapes.get(ops_names[0], "")
                sd = _shape_dims(lhs_type)
                if sd:
                    dims = sd[0][1]
                    for ci in lc.group(1).split(","):
                        ci = int(ci)
                        if ci < len(dims):
                            contract *= dims[ci]
            cc.flops += 2.0 * out_elems * contract
        elif op == "convolution":
            out_elems = 1
            for _, dims in _shape_dims(rtype):
                for d in dims:
                    out_elems *= d
            ops_names = _operand_names(rhs)
            kern = shapes.get(ops_names[1], "") if len(ops_names) > 1 else ""
            sd = _shape_dims(kern)
            kelems = 1
            if sd:
                for d in sd[0][1]:
                    kelems *= d
                # kernel = spatial × Cin × Cout; flops = 2·out·spatial·Cin
                cout = sd[0][1][-1] if sd[0][1] else 1
                kelems = max(kelems // max(cout, 1), 1)
            cc.flops += 2.0 * out_elems * kelems
        elif op in ("exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                    "logistic", "sine", "cosine"):
            out_elems = 1
            for _, dims in _shape_dims(rtype):
                for d in dims:
                    out_elems *= d
            cc.transcendental += out_elems

        # ---- bytes ----------------------------------------------------------
        # Mirrors HloCostAnalysis' data-movement special cases: slicing
        # ops (and fusions rooted at them) touch only the slice, not the
        # sliced-into buffer — naive operand counting charged a 256-step
        # scan 256 full-array reads/writes of its ys/residual buffers.
        if op == "fusion":
            called = _CALLED.search(rhs)
            body_name = called.group(1) if called else ""
            root = comp_root_op.get(body_name, "")
            b = 0.0
            for i, on in enumerate(_operand_names(rhs)):
                cls, bi = param_class.get((body_name, i), ("full", 0.0))
                if cls == "alias":
                    b += 2.0 * bi            # rmw of the updated region
                elif cls == "sliced":
                    b += bi                   # read only the slice(s)
                else:
                    b += _type_bytes(shapes.get(on, ""))
            if root == "dynamic-update-slice":
                pass                          # write charged via alias param
            else:
                b += _type_bytes(rtype)       # result write
            cc.bytes_ += b
        elif op in ("dynamic-slice", "slice", "gather"):
            cc.bytes_ += 2.0 * _type_bytes(rtype)  # slice read + write
        elif op == "dynamic-update-slice":
            ops_names = _operand_names(rhs)
            upd = _type_bytes(shapes.get(ops_names[1], "")) if len(ops_names) > 1 else 0
            cc.bytes_ += 2.0 * upd
        elif op in ("scatter", "scatter-add"):
            ops_names = _operand_names(rhs)
            upd = _type_bytes(shapes.get(ops_names[-1], "")) if ops_names else 0
            cc.bytes_ += 3.0 * upd  # read updates + rmw touched region
        elif op not in ("parameter", "constant", "tuple", "get-tuple-element",
                        "bitcast", "copy-done", "all-reduce-done",
                        "all-gather-done"):
            b = _type_bytes(rtype)
            for on in _operand_names(rhs):
                b += _type_bytes(shapes.get(on, ""))
            cc.bytes_ += b

        if attribute:
            delta_b = cc.bytes_ - bytes_before
            if delta_b > 0:
                mo = re.search(r'op_name="([^"]+)"', rhs)
                tag = re.sub(r"\d+", "N", (mo.group(1) if mo else op))[-90:]
                cc.attributions.append((f"{op}|{tag}", delta_b))

        # ---- collectives -----------------------------------------------------
        base_op = op[:-6] if op.endswith("-start") else op
        if base_op in COLLECTIVES:
            nb = _type_bytes(rtype)
            groups = _parse_groups(rhs)
            tier = "ici"
            if groups:
                for g in groups:
                    if len({d // pod_size for d in g}) > 1:
                        tier = "dcn"
                        break
            if nb:
                cc.collectives.append((base_op, nb, tier))

    # ---------------- multiplicity propagation (topological) ----------------
    mult: Dict[str, float] = {}
    if entry:
        indeg: Dict[str, int] = {n: 0 for n in comps}
        for cc in comps.values():
            for callee, _ in cc.calls:
                if callee in indeg:
                    indeg[callee] += 1
        mult = {n: 0.0 for n in comps}
        mult[entry] = 1.0
        stack = [n for n, d in indeg.items() if d == 0]
        while stack:
            n = stack.pop()
            m = mult.get(n, 0.0)
            for callee, trip in comps[n].calls:
                if callee in indeg:
                    mult[callee] = mult.get(callee, 0.0) + m * trip
                    indeg[callee] -= 1
                    if indeg[callee] == 0:
                        stack.append(callee)
    else:  # fallback: everything once
        for n in comps:
            mult[n] = 1.0

    total = HloCost()
    for name, cc in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        total.flops += cc.flops * m
        if name not in fusion_bodies:
            total.bytes_ += cc.bytes_ * m
        if name not in fusion_bodies:
            for tag, b in cc.attributions:
                total.byte_attribution[tag] = (
                    total.byte_attribution.get(tag, 0.0) + b * m
                )
        for kind, nb, tier in cc.collectives:
            total.coll_total += nb * m
            if tier == "dcn":
                total.coll_dcn += nb * m
            total.coll_by_kind[kind] = total.coll_by_kind.get(kind, 0.0) + nb * m
            total.coll_count[kind] = total.coll_count.get(kind, 0) + int(m)
    return total
