"""Post-SPMD HLO text analysis: collective-traffic accounting.

``cost_analysis()`` has no collective-bytes entry, so we parse the
optimized HLO (``compiled.as_text()``) and sum the result-shape bytes of
every collective op, bucketed by kind and by tier:

  * ``ici``  — replica groups stay within one pod (devices // 256 equal)
  * ``dcn``  — any group spans pods (the slow tier LIFL minimizes)

This intentionally counts *payload bytes at the collective boundary*
(what crosses links at least once), not an algorithm-specific wire
estimate; the roofline collective term divides by per-chip link bw.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")


def _shape_bytes(type_str: str) -> int:
    """Bytes of a result type, possibly a tuple: '(f32[8,2]{..}, s8[4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_groups(line: str) -> Optional[List[List[int]]]:
    m = _GROUPS_RE.search(line)
    if m:
        groups = []
        for g in re.findall(r"\{([^}]*)\}", m.group(1)):
            ids = [int(x) for x in g.split(",") if x.strip()]
            if ids:
                groups.append(ids)
        return groups or None
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        ngroups, per = int(m.group(1)), int(m.group(2))
        reshape = [int(x) for x in m.group(3).split(",")]
        total = 1
        for r in reshape:
            total *= r
        ids = list(range(total))
        if m.group(4):
            # iota transpose: reshape then permute dims then flatten
            import numpy as np

            perm = [int(x) for x in m.group(4).split(",")]
            ids = list(np.arange(total).reshape(reshape).transpose(perm).reshape(-1))
        return [ids[i * per : (i + 1) * per] for i in range(ngroups)]
    m = _PAIRS_RE.search(line)
    if m:  # collective-permute: each pair is its own "group"
        pairs = re.findall(r"\{(\d+),(\d+)\}", m.group(0))
        return [[int(a), int(b)] for a, b in pairs]
    return None


@dataclass
class CollectiveStats:
    by_kind: Dict[str, int] = field(default_factory=dict)
    by_kind_count: Dict[str, int] = field(default_factory=dict)
    ici_bytes: int = 0
    dcn_bytes: int = 0
    total_bytes: int = 0
    ops: List[Tuple[str, int, str]] = field(default_factory=list)  # (kind, bytes, tier)

    def to_dict(self):
        return {
            "by_kind": self.by_kind,
            "by_kind_count": self.by_kind_count,
            "ici_bytes": self.ici_bytes,
            "dcn_bytes": self.dcn_bytes,
            "total_bytes": self.total_bytes,
        }


def collective_stats(hlo_text: str, pod_size: int = 256) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        kind = None
        for op in COLLECTIVE_OPS:
            # match '= <type> op-name(' including variants like all-reduce-start
            if f" {op}(" in s or f" {op}-start(" in s:
                kind = op
                break
        if kind is None:
            continue
        lhs, _, rhs = s.partition("=")
        # result type sits between '=' and the op name
        type_str = rhs.split(kind)[0]
        nbytes = _shape_bytes(type_str)
        if nbytes == 0:
            continue
        groups = _parse_groups(s)
        tier = "ici"
        if groups:
            for g in groups:
                pods = {d // pod_size for d in g}
                if len(pods) > 1:
                    tier = "dcn"
                    break
        stats.by_kind[kind] = stats.by_kind.get(kind, 0) + nbytes
        stats.by_kind_count[kind] = stats.by_kind_count.get(kind, 0) + 1
        stats.total_bytes += nbytes
        if tier == "dcn":
            stats.dcn_bytes += nbytes
        else:
            stats.ici_bytes += nbytes
        stats.ops.append((kind, nbytes, tier))
    return stats


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
