"""Render the dry-run/roofline result JSONs into EXPERIMENTS.md tables.

  PYTHONPATH=src python -m repro.analysis.report results/dryrun
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Dict, List

ACTIVE_PARAMS = {}


def _fraction(r, arch):
    """Recompute decode-aware fraction from the record (older records
    lack model_bytes)."""
    ro = r["roofline"]
    if "ideal_s" in ro:
        return ro["roofline_fraction"]
    if r["shape"] in ("decode_32k", "long_500k"):
        try:
            from repro.configs import get_arch

            mb = 2.0 * get_arch(arch).active_param_count()
        except Exception:
            return ro["roofline_fraction"]
        ideal = max(ro["model_flops"] / (r["chips"] * 197e12),
                    mb / (r["chips"] * 819e9))
        return ideal / ro["step_time_s"] if ro["step_time_s"] else 0.0
    return ro["roofline_fraction"]


ARCH_ORDER = [
    "seamless-m4t-large-v2", "h2o-danube-3-4b", "gemma3-4b", "gemma3-12b",
    "llama3.2-3b", "hymba-1.5b", "internvl2-26b", "kimi-k2-1t-a32b",
    "deepseek-v2-lite-16b", "falcon-mamba-7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

V5E_HBM = 16e9


def load(outdir: Path, variant=("hierarchical", "eager", "none")) -> Dict:
    recs = {}
    for f in outdir.glob("*.json"):
        r = json.loads(f.read_text())
        key = (r["arch"], r["shape"], r["mesh"],
               r.get("hierarchy"), r.get("timing"), r.get("compress"))
        recs[key] = r
    return {
        (a, s, m): r
        for (a, s, m, h, t, c), r in recs.items()
        if (h, t, c) == variant
    }


def _fmt_t(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def dryrun_table(recs: Dict, mesh: str) -> List[str]:
    lines = [
        "| arch | shape | status | peak HBM/chip | fits v5e | FLOPs/chip | HBM bytes/chip | coll bytes/chip (DCN) | compile |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None:
                lines.append(f"| {a} | {s} | MISSING | | | | | | |")
                continue
            if r["status"] == "skipped":
                lines.append(f"| {a} | {s} | skipped ({r['reason'][:40]}…) | | | | | | |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {a} | {s} | ERROR | | | | | | |")
                continue
            mem = r["memory"].get("peak_bytes_per_device", 0)
            c = r["cost"]
            fits = "✓" if mem <= V5E_HBM else f"✗ ({mem/V5E_HBM:.1f}×)"
            lines.append(
                f"| {a} | {s} | ok | {mem/1e9:.1f} GB | {fits} "
                f"| {c['flops']:.2e} | {c['bytes']:.2e} "
                f"| {c['coll_total']:.2e} ({c['coll_dcn']:.1e}) "
                f"| {r['compile_s']:.0f}s |"
            )
    return lines


def roofline_table(recs: Dict, mesh: str) -> List[str]:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | MODEL_FLOPS | useful | roofline frac | bottleneck note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, mesh))
            if r is None or r["status"] != "ok":
                status = "skipped" if (r and r["status"] == "skipped") else "—"
                lines.append(f"| {a} | {s} | {status} | | | | | | | |")
                continue
            ro = r["roofline"]
            note = _note(ro)
            lines.append(
                f"| {a} | {s} | {_fmt_t(ro['compute_s'])} | {_fmt_t(ro['memory_s'])} "
                f"| {_fmt_t(ro['collective_s'])} | **{ro['dominant']}** "
                f"| {ro['model_flops']:.2e} | {ro['useful_ratio']:.2f} "
                f"| {_fraction(r, a):.3f} | {note} |"
            )
    return lines


def _note(ro: Dict) -> str:
    d = ro["dominant"]
    if d == "compute":
        if ro["useful_ratio"] < 0.5:
            return "cut non-model FLOPs (remat/rect. attention/dispatch)"
        return "near compute roof; overlap collectives"
    if d == "memory":
        return "raise arithmetic intensity (fuse flash/loop blocks, bf16 temps)"
    return "cut bytes on the wire (hierarchical schedule, int8, overlap)"


def summary(recs: Dict, mesh: str) -> List[str]:
    oks = [r for (a, s, m), r in recs.items() if m == mesh and r["status"] == "ok"]
    doms = {}
    for r in oks:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    worst = sorted(oks, key=lambda r: _fraction(r, r["arch"]))[:3]
    lines = [f"- {len(oks)} cells ok on {mesh}; dominant terms: {doms}"]
    for r in worst:
        lines.append(
            f"- worst roofline: {r['arch']}×{r['shape']} "
            f"frac={_fraction(r, r['arch']):.4f} "
            f"dom={r['roofline']['dominant']}"
        )
    return lines


def main():
    outdir = Path(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    recs = load(outdir)
    for mesh in ("single", "multi"):
        print(f"\n### Dry-run — {mesh} pod\n")
        print("\n".join(dryrun_table(recs, mesh)))
        print(f"\n### Roofline — {mesh} pod\n")
        print("\n".join(roofline_table(recs, mesh)))
        print()
        print("\n".join(summary(recs, mesh)))


if __name__ == "__main__":
    main()
