from repro.analysis.hlo import CollectiveStats, collective_stats, count_op
from repro.analysis.roofline import (
    DCN_BW,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    from_compiled,
    model_flops,
)

__all__ = [
    "CollectiveStats",
    "collective_stats",
    "count_op",
    "Roofline",
    "from_compiled",
    "model_flops",
    "PEAK_FLOPS",
    "HBM_BW",
    "ICI_BW",
    "DCN_BW",
]
