"""Three-term roofline model from a compiled dry-run cell.

Hardware constants: TPU v5e-class target (assignment sheet):
  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s per ICI link.
DCN (inter-pod) is modeled at 6.25 GB/s/chip (≈ 50 Gb/s NICs per chip
share) — used only to split the collective term by tier; the headline
collective term follows the assignment formula bytes/(chips·link_bw).

  compute    = HLO_FLOPs   / (chips · 197e12)
  memory     = HLO_bytes   / (chips · 819e9)
  collective = coll_bytes  / (chips · 50e9)

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for train cells
(3 ·  fwd-only for prefill; decode uses 2·N·B per step fwd).
The ratio MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch/rectangle
waste (1.0 = every compiled flop is useful; >0.33 with full remat).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs.base import ArchConfig, ShapeConfig

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link (assignment constant)
DCN_BW = 6.25e9          # B/s / chip (tier split only)


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Useful FLOPs per step: 6·N_active·tokens (train) etc."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def model_bytes(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Minimum HBM bytes per step.  Decode is weight-read-bound: every
    active param (bf16) must be read once per step regardless of batch —
    the bandwidth floor that MODEL_FLOPS alone misses at batch ≤ 128."""
    if shape.kind != "decode":
        return 0.0
    return 2.0 * cfg.active_param_count()


@dataclass
class Roofline:
    """All byte/flop inputs are PER-DEVICE (post-SPMD module totals);
    ``model_flops_`` is global and normalized by ``chips``."""

    flops: float
    hbm_bytes: float
    coll_bytes: float
    dcn_bytes: float
    chips: int
    model_flops_: float
    model_bytes_: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dcn_s(self) -> float:
        return self.dcn_bytes / DCN_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic no-overlap-free bound: max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        per_chip_model = self.model_flops_ / self.chips
        return per_chip_model / self.flops if self.flops else 0.0

    @property
    def ideal_s(self) -> float:
        """Best achievable step time: useful FLOPs at peak, or the
        weight-read bandwidth floor (decode), whichever binds."""
        return max(
            self.model_flops_ / (self.chips * PEAK_FLOPS),
            self.model_bytes_ / (self.chips * HBM_BW),
        )

    @property
    def roofline_fraction(self) -> float:
        """ideal_s / step_time — the score we hillclimb."""
        return self.ideal_s / self.step_time_s if self.step_time_s else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "dcn_bytes": self.dcn_bytes,
            "chips": self.chips,
            "model_flops": self.model_flops_,
            "model_bytes": self.model_bytes_,
            "ideal_s": self.ideal_s,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dcn_s": self.dcn_s,
            "dominant": self.dominant,
            "useful_ratio": self.useful_ratio,
            "roofline_fraction": self.roofline_fraction,
            "step_time_s": self.step_time_s,
        }


def from_compiled(
    cost: Dict[str, float],
    coll_total: float,
    coll_dcn: float,
    chips: int,
    cfg: ArchConfig,
    shape: ShapeConfig,
) -> Roofline:
    return Roofline(
        flops=float(cost.get("flops", 0.0)),
        hbm_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(coll_total),
        dcn_bytes=float(coll_dcn),
        chips=chips,
        model_flops_=model_flops(cfg, shape),
        model_bytes_=model_bytes(cfg, shape),
    )
