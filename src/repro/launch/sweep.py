import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (same constraint as dryrun.py — must precede all other imports)

"""Dry-run sweep driver: every (arch × shape × mesh) cell, resumable.

Each cell runs in-process sequentially; results land in
``results/dryrun/<tag>.json``.  Existing results are skipped, so the
sweep can be re-launched after fixes.  Failures are recorded as
status=error and do not stop the sweep.

  PYTHONPATH=src python -m repro.launch.sweep [--mesh single,multi]
      [--arch a,b,...] [--shape s,...] [--force] [--out results/dryrun]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

from repro.configs import ARCHS, SHAPES
from repro.launch.dryrun import run_cell

# riskiest families first so structural failures surface early
ARCH_ORDER = [
    "llama3.2-3b",
    "deepseek-v2-lite-16b",
    "falcon-mamba-7b",
    "hymba-1.5b",
    "seamless-m4t-large-v2",
    "gemma3-4b",
    "internvl2-26b",
    "kimi-k2-1t-a32b",
    "gemma3-12b",
    "h2o-danube-3-4b",
]
SHAPE_ORDER = ["train_4k", "decode_32k", "prefill_32k", "long_500k"]


def tag_for(arch, shape, mesh, hierarchy, timing, compress):
    return f"{arch}_{shape}_{mesh}_{hierarchy}_{timing}_{compress}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--arch", default=",".join(ARCH_ORDER))
    ap.add_argument("--shape", default=",".join(SHAPE_ORDER))
    ap.add_argument("--hierarchy", default="hierarchical")
    ap.add_argument("--timing", default="eager")
    ap.add_argument("--compress", default="none")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = [
        (a, s, m)
        for a in args.arch.split(",")
        for s in args.shape.split(",")
        for m in args.mesh.split(",")
    ]
    print(f"sweep: {len(cells)} cells -> {outdir}", flush=True)
    t_start = time.time()
    n_ok = n_skip = n_err = 0
    for arch, shape, mesh in cells:
        tag = tag_for(arch, shape, mesh, args.hierarchy, args.timing, args.compress)
        path = outdir / f"{tag}.json"
        if path.exists() and not args.force:
            prev = json.loads(path.read_text())
            if prev.get("status") in ("ok", "skipped"):
                n_skip += 1
                continue
        t0 = time.time()
        try:
            rec = run_cell(
                arch, shape, mesh,
                hierarchy=args.hierarchy, timing=args.timing,
                compress=args.compress, verbose=False,
            )
        except Exception as e:
            rec = {
                "arch": arch, "shape": shape, "mesh": mesh,
                "hierarchy": args.hierarchy, "timing": args.timing,
                "compress": args.compress,
                "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-3000:],
            }
        rec["wall_s"] = round(time.time() - t0, 1)
        path.write_text(json.dumps(rec, indent=1))
        st = rec["status"]
        n_ok += st == "ok"
        n_err += st == "error"
        extra = ""
        if st == "ok":
            r = rec["roofline"]
            extra = (f"dom={r['dominant']} frac={r['roofline_fraction']:.3f} "
                     f"mem={rec['memory'].get('peak_bytes_per_device', 0)/1e9:.1f}GB")
        elif st == "error":
            extra = rec["error"][:120]
        print(f"[{time.time()-t_start:7.0f}s] {tag}: {st} "
              f"({rec['wall_s']}s) {extra}", flush=True)
    print(f"done: ok={n_ok} skipped/cached={n_skip} err={n_err}", flush=True)


if __name__ == "__main__":
    main()
