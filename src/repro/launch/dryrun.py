import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
#   backend initialization.  Only the dry-run forces 512 placeholder
#   devices — tests/benchmarks see the single real CPU device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent end to
end: sharding rules, collective schedule, FL aggregation hierarchy, and
memory footprint, via ``jax.jit(...).lower(...).compile()`` against
ShapeDtypeStruct inputs (no allocation).  Prints
``compiled.memory_analysis()`` (fits/doesn't) and
``compiled.cost_analysis()`` (roofline terms), parses collective bytes
from the optimized HLO, and writes a JSON record consumed by
EXPERIMENTS.md §Dry-run/§Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun \
      --arch llama3.2-3b --shape train_4k --mesh multi \
      [--hierarchy hierarchical|flat] [--timing eager|lazy]
      [--compress none|int8] [--micro 4] [--out results/dryrun]
"""
import argparse
import json
import time
from functools import partial
from pathlib import Path

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo import collective_stats
from repro.compat import use_mesh
from repro.analysis.hlo_cost import parse_hlo_cost
from repro.analysis.roofline import from_compiled
from repro.configs import get_arch, get_shape, shape_applicable
from repro.fl.round import (
    AggregationConfig,
    abstract_caches,
    abstract_params,
    build_decode_step,
    build_prefill_step,
    build_train_step,
    input_specs,
    serve_shardings,
    train_shardings,
)
from repro.fl.server import init_server_state
from repro.launch.mesh import dp_axes as mesh_dp_axes
from repro.launch.mesh import make_production_mesh
from repro.sharding import batch_specs, cache_specs, divisibility_fix, to_named


def _memory_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": str(e)}
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if out:
        # peak per-device estimate: args + temps + outputs - aliased
        out["peak_bytes_per_device"] = (
            out.get("argument_size_in_bytes", 0)
            + out.get("temp_size_in_bytes", 0)
            + out.get("output_size_in_bytes", 0)
            - out.get("alias_size_in_bytes", 0)
        )
    return out or {"repr": str(ma)}


def _cost_dict(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()}


def run_cell(
    arch_name: str,
    shape_name: str,
    mesh_kind: str,
    *,
    hierarchy: str = "hierarchical",
    timing: str = "eager",
    compress: str = "none",
    micro: int = 4,
    fsdp: str = "auto",
    acc_dtype: str = "float32",
    opts_override: dict | None = None,
    verbose: bool = True,
):
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(cfg, shape)
    record = {
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
        "hierarchy": hierarchy, "timing": timing, "compress": compress,
        "micro": micro,
    }
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    dp = mesh_dp_axes(mesh)
    agg = AggregationConfig(
        hierarchy=hierarchy, timing=timing, compress=compress,
        num_microbatches=micro, acc_dtype=acc_dtype,
    )
    if fsdp == "auto":
        # FSDP costs a per-layer-per-microbatch weight all-gather (scan
        # bodies can't hoist it), so shard params over `data` only when
        # TP-only residency would blow HBM: params(bf16) + grads(bf16) +
        # fp32 accumulator ≈ 8 bytes/param over the model axis.
        model_shards = mesh.shape["model"]
        tp_only_bytes = cfg.param_count() * 8 / model_shards
        if tp_only_bytes <= 6e9:
            fsdp_axes = ()
        else:
            fsdp_axes = dp if hierarchy == "flat" else ("data",)
    else:
        fsdp_axes = tuple(a for a in fsdp.split(",") if a)

    opts = None
    if opts_override:
        from repro.models.transformer import ModelOptions
        from repro.launch.mesh import pod_axis as _pod_axis
        base = dict(
            attn_impl="chunked_sp",
            moe_impl="ep" if cfg.moe is not None else "dense",
            ssm_impl="sharded",
            dp_axes=dp if (hierarchy == "flat" or _pod_axis(mesh) is None)
            else ("data",),
            model_axis="model",
            vocab_axis="model",
        )
        base.update(opts_override)
        opts = ModelOptions(**base)

    t0 = time.time()
    with use_mesh(mesh):
        if shape.kind == "train":
            step, model = build_train_step(cfg, mesh, agg, opts=opts)
            aparams = abstract_params(model)
            pspecs, sspecs = train_shardings(model, mesh, agg, fsdp=fsdp_axes)
            astate = jax.eval_shape(
                partial(init_server_state, agg.server_opt), aparams
            )
            abatch = input_specs(cfg, shape)
            bspecs = divisibility_fix(batch_specs(abatch, dp), abatch, mesh)
            fn = jax.jit(
                step,
                in_shardings=(to_named(pspecs, mesh), to_named(sspecs, mesh),
                              to_named(bspecs, mesh)),
                out_shardings=(to_named(pspecs, mesh), to_named(sspecs, mesh),
                               None),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(aparams, astate, abatch)
        elif shape.kind == "prefill":
            step, model = build_prefill_step(cfg, mesh, opts=opts)
            aparams = abstract_params(model)
            pspecs = serve_shardings(model, mesh, fsdp=fsdp_axes)
            abatch = input_specs(cfg, shape)
            bspecs = divisibility_fix(batch_specs(abatch, dp), abatch, mesh)
            acaches = abstract_caches(model, shape)
            cspecs = divisibility_fix(
                cache_specs(acaches, dp), acaches, mesh
            )
            fn = jax.jit(
                step,
                in_shardings=(to_named(pspecs, mesh), to_named(bspecs, mesh)),
                out_shardings=(None, to_named(cspecs, mesh)),
            )
            lowered = fn.lower(aparams, abatch)
        else:  # decode
            step, model = build_decode_step(cfg, mesh, opts=opts)
            aparams = abstract_params(model)
            pspecs = serve_shardings(model, mesh, fsdp=fsdp_axes)
            inputs = input_specs(cfg, shape)
            acaches = abstract_caches(model, shape)
            cspecs = divisibility_fix(cache_specs(acaches, dp), acaches, mesh)
            ndp = 1
            for a in dp:
                ndp *= mesh.shape[a]
            tok_spec = P(dp, None) if shape.global_batch % ndp == 0 else P()
            tok_s = NamedSharding(mesh, tok_spec)
            pos_s = NamedSharding(mesh, P())
            fn = jax.jit(
                step,
                in_shardings=(to_named(pspecs, mesh), tok_s,
                              to_named(cspecs, mesh), pos_s),
                out_shardings=(None, to_named(cspecs, mesh)),
                donate_argnums=(2,),
            )
            lowered = fn.lower(aparams, inputs["tokens"], acaches, inputs["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = _memory_dict(compiled)
    cost = _cost_dict(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    pod_size = 256
    # trip-count-aware per-device totals (cost_analysis() counts each
    # scanned layer once; parse_hlo_cost scales by known_trip_count)
    hc = parse_hlo_cost(hlo, pod_size=pod_size)
    roof = from_compiled(
        {"flops": hc.flops, "bytes accessed": hc.bytes_},
        hc.coll_total, hc.coll_dcn, chips, cfg, shape,
    )

    record.update(
        status="ok",
        chips=chips,
        fsdp=list(fsdp_axes),
        acc_dtype=acc_dtype,
        opts_override=opts_override or {},
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=mem,
        cost_analysis_raw={
            k: v for k, v in cost.items() if k in ("flops", "bytes accessed")
        },
        cost=hc.to_dict(),
        roofline=roof.to_dict(),
        hlo_bytes=len(hlo),
    )
    if verbose:
        print(f"== {arch_name} × {shape_name} × {mesh_kind} "
              f"({hierarchy}/{timing}/{compress}) ==")
        print(f"memory_analysis: {mem}")
        print(f"cost(trip-aware, per-device): flops={hc.flops:.3e} "
              f"bytes={hc.bytes_:.3e} coll={hc.coll_total:.3e} "
              f"dcn={hc.coll_dcn:.3e}")
        print(f"raw cost_analysis: {cost.get('flops', 0):.3e} flops")
        r = roof.to_dict()
        print(f"roofline: compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s dominant={r['dominant']} "
              f"useful={r['useful_ratio']:.3f} frac={r['roofline_fraction']:.3f}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--hierarchy", choices=("hierarchical", "flat"),
                    default="hierarchical")
    ap.add_argument("--timing", choices=("eager", "lazy"), default="eager")
    ap.add_argument("--compress", choices=("none", "int8"), default="none")
    ap.add_argument("--micro", type=int, default=4)
    ap.add_argument("--fsdp", default="auto")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    rec = run_cell(
        args.arch, args.shape, args.mesh,
        hierarchy=args.hierarchy, timing=args.timing,
        compress=args.compress, micro=args.micro, fsdp=args.fsdp,
    )
    if args.out:
        outdir = Path(args.out)
        outdir.mkdir(parents=True, exist_ok=True)
        tag = (f"{args.arch}_{args.shape}_{args.mesh}_{args.hierarchy}"
               f"_{args.timing}_{args.compress}")
        (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
        print(f"wrote {outdir / (tag + '.json')}")


if __name__ == "__main__":
    main()
