"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state — smoke tests see
one CPU device; only the dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first
jax use.

Mesh axes (DESIGN.md §5):
  pod   — crosses DCN; LIFL's *inter-node* tier (top aggregator level)
  data  — intra-pod ICI; client cohorts / FSDP; LIFL's *intra-node*
          shared-memory tier (leaf aggregator level)
  model — intra-pod ICI; TP / EP / sequence-sharded KV
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices, found {len(devices)}; "
            "launch via repro.launch.dryrun which forces 512 host devices"
        )
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(devices[:ndev]).reshape(shape), axes
    )


def make_debug_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Small mesh over however many (forced) host devices exist."""
    import numpy as np

    ndev = 1
    for s in shape:
        ndev *= s
    devices = jax.devices()[:ndev]
    if len(devices) < ndev:
        raise RuntimeError(f"need {ndev} devices, have {len(jax.devices())}")
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def make_host_mesh():
    """1x1 (data, model) mesh on the single local device — lets every
    code path that wants mesh axes (shard_map MoE, hierarchical
    aggregation) run unchanged on CPU."""
    import numpy as np

    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model")
    )


def mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def dp_axes(mesh) -> Tuple[str, ...]:
    """Axes client cohorts / batch are sharded over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def pod_axis(mesh) -> Optional[str]:
    return "pod" if "pod" in mesh.axis_names else None
