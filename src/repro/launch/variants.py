import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb variants for the three chosen cells (EXPERIMENTS.md).

  K-series: kimi-k2-1t-a32b × train_4k  (collective-bound)
  G-series: gemma3-12b × train_4k × multi (the paper's aggregation tier)
  F-series artifacts are produced by the main sweep (ssm defaults).

  PYTHONPATH=src python -m repro.launch.variants [--only K1,G2,...]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

from repro.launch.dryrun import run_cell

VARIANTS = {
    # ---- K: kimi collective term --------------------------------------
    # K0 baseline comes from the sweep (hier/eager/none, micro=4, fp32 acc)
    "K1_micro1_bf16acc": dict(
        arch_name="kimi-k2-1t-a32b", shape_name="train_4k", mesh_kind="single",
        micro=1, acc_dtype="bfloat16",
    ),
    "K2_multi_baseline": dict(
        arch_name="kimi-k2-1t-a32b", shape_name="train_4k", mesh_kind="multi", micro=4,
    ),
    "K3_multi_int8": dict(
        arch_name="kimi-k2-1t-a32b", shape_name="train_4k", mesh_kind="multi",
        compress="int8", micro=4,
    ),
    "K4_multi_flat": dict(
        arch_name="kimi-k2-1t-a32b", shape_name="train_4k", mesh_kind="multi",
        hierarchy="flat", micro=4,
    ),
    # ---- G: gemma3-12b, the paper's knobs on the DCN tier --------------
    # G0 multi hier/eager/none baseline from the sweep
    "G1_flat": dict(
        arch_name="gemma3-12b", shape_name="train_4k", mesh_kind="multi", hierarchy="flat",
    ),
    "G2_int8": dict(
        arch_name="gemma3-12b", shape_name="train_4k", mesh_kind="multi", compress="int8",
    ),
    "G3_windowed_kv": dict(
        # same settings as the sweep baseline; the window-limited KV ring
        # (models/flash.py) is active in this process — the delta vs the
        # sweep JSON is the G3 effect
        arch_name="gemma3-12b", shape_name="train_4k", mesh_kind="multi",
    ),
    "G4_lazy": dict(
        arch_name="gemma3-12b", shape_name="train_4k", mesh_kind="multi", timing="lazy",
    ),
    # eager-vs-lazy memory effect on a big-update arch (queue blowup)
    "G5_lazy_single": dict(
        arch_name="gemma3-12b", shape_name="train_4k", mesh_kind="single", timing="lazy",
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--out", default="results/variants")
    args = ap.parse_args()
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    for name, kw in VARIANTS.items():
        if only and name not in only:
            continue
        path = outdir / f"{name}.json"
        if path.exists():
            print(f"{name}: cached", flush=True)
            continue
        t0 = time.time()
        try:
            rec = run_cell(verbose=False, **kw)
            rec["variant"] = name
        except Exception as e:
            rec = {"variant": name, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        rec["wall_s"] = round(time.time() - t0, 1)
        path.write_text(json.dumps(rec, indent=1))
        if rec.get("status") == "ok":
            r = rec["roofline"]
            print(f"{name}: compute={r['compute_s']:.2f}s "
                  f"mem={r['memory_s']:.2f}s coll={r['collective_s']:.2f}s "
                  f"dcn={r['dcn_s']:.2f}s dom={r['dominant']} "
                  f"frac={r['roofline_fraction']:.4f} ({rec['wall_s']}s)",
                  flush=True)
        else:
            print(f"{name}: {rec.get('error', rec.get('status'))}", flush=True)


if __name__ == "__main__":
    main()
