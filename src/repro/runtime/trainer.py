"""Training runtimes.

``FederatedTrainer`` — the paper-faithful engine: real per-client local
SGD (diverged mode), LIFL hierarchical aggregation through the actual
control-plane objects (selector → BestFit placement → EWMA hierarchy →
warm engines → eager aggregation), failure handling via
over-provisioning + aggregation goal, async checkpoints.  The round
itself is driven by :class:`repro.runtime.driver.RoundDriver` — one
event loop serving both the in-process and the multi-process
(``shmproc``) runtime, bit-identically.

``FusedFLTrainer`` — the large-model engine: one jitted fused round step
(fl/round.py) per round on a mesh; cohort data from the federated
pipeline; checkpoint/restart; straggler masking; elastic round sizing
through the warm-executable cache (re-plan ⇒ cache lookup, not a
recompile, when the signature matches — LIFL C8).
"""
from __future__ import annotations

import time
import warnings
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import (
    Any, Deque, Dict, Iterator, List, Optional, Sequence, Tuple,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.compat import use_mesh
from repro.core import (
    ClientInfo,
    Coordinator,
    MetricsMap,
    NodeState,
    RoundConfig,
    Selector,
)
from repro.core.reuse import ExecutableCache
from repro.fl.round import AggregationConfig, build_train_step
from repro.fl.server import apply_server_opt, init_server_state
from repro.optim import sgd_apply
from repro.obs.trace import RoundTrace, write_trace
from repro.runtime.driver import RoundDriver, make_runtime
from repro.runtime.events import (
    NodeJoined,
    NodeLost,
    NodeRejoined,
    PartialReady,
    PartialShipped,
    TopFolded,
)


# ===========================================================================
# paper-faithful engine (diverged clients, host aggregation tree)
# ===========================================================================


@dataclass
class ClientRuntime:
    """A training client: local SGD for ``epochs`` over its shard."""

    info: ClientInfo
    dataset: Any                      # ClientDataset
    hibernate_s: Tuple[float, float] = (0.0, 0.0)  # mobile availability (§6.2)
    failure_prob: float = 0.0

    def local_update(self, model, params, *, lr: float, batch_size: int,
                     epochs: int, rng: np.random.Generator
                     ) -> Optional[Tuple[Any, float]]:
        """-> (delta pytree, num_samples) or None if the client fails."""
        if rng.random() < self.failure_prob:
            return None  # detected by missing heartbeat; goal absorbs it
        p = params
        n = 0
        for batch in self.dataset.batches(batch_size, epochs=epochs,
                                          seed=int(rng.integers(1 << 30))):
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            (_, _), grads = jax.value_and_grad(model.loss, has_aux=True)(p, jb)
            p, _ = sgd_apply(p, grads, {}, lr=lr)
            n += len(batch["labels"])
        if n == 0:
            return None
        delta = jax.tree.map(
            lambda new, old: np.asarray(new, np.float32) - np.asarray(old, np.float32),
            p, params,
        )
        return delta, float(self.dataset.num_samples)


#: run_round's PR-2 era kwargs → their canonical names (the client-side
#: hyperparameters are now prefixed so they can't be confused with the
#: server optimizer's ``server_lr``).
_DEPRECATED_ROUND_KWARGS = {
    "lr": "client_lr",
    "batch_size": "client_batch_size",
    "epochs": "client_epochs",
}


class FederatedTrainer:
    """LIFL rounds over real clients with the host aggregation tree.

    One :class:`RoundDriver` loop serves every runtime; pick one with
    ``runtime="inproc"`` (single process) or ``runtime="shmproc"``
    (forked aggregator workers over shared-memory rings) — the produced
    params are bit-identical either way."""

    def __init__(
        self,
        model,                       # .loss(params, batch) -> (loss, aux)
        params: Any,
        clients: Sequence[ClientRuntime],
        *,
        nodes: Optional[Dict[str, NodeState]] = None,
        round_cfg: Optional[RoundConfig] = None,
        server_opt: str = "fedavg",
        server_lr: float = 1.0,
        agg_engine: str = "auto",
        runtime: Optional[Any] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 5,
        seed: int = 0,
        trace_path: Optional[str] = None,
        job: str = "",
        job_weight: float = 1.0,
        coordinator: Optional[Coordinator] = None,
        driver: Optional[RoundDriver] = None,
    ):
        self.model = model
        self.params = params
        self.agg_engine = agg_engine
        self.clients = {c.info.client_id: c for c in clients}
        self.nodes = nodes or {
            f"node{i}": NodeState(node=f"node{i}", max_capacity=20.0)
            for i in range(5)
        }
        self.round_cfg = round_cfg or RoundConfig(aggregation_goal=8)
        # selectable aggregation runtime: explicit arg > round config
        self.runtime = runtime if runtime is not None else self.round_cfg.runtime
        self.server_opt = server_opt
        self.server_lr = server_lr
        self.server_state = init_server_state(server_opt, params)
        # serve mode: several trainers (one per job) share ONE
        # coordinator — each registers its cohort under its job name
        # and plans against a weighted fair share of the fleet.  The
        # default (no injection) is the historical one-trainer-one-
        # coordinator library path, untouched.
        self.job = job
        if coordinator is not None:
            self.coordinator = coordinator
            if job:
                coordinator.register_job(
                    job, [c.info for c in clients], weight=job_weight,
                    seed=seed)
        else:
            self.coordinator = Coordinator(
                Selector([c.info for c in clients], seed=seed), self.nodes
            )
        self.metrics = MetricsMap()
        self.rng = np.random.default_rng(seed)
        self.ckpt = AsyncCheckpointer(checkpoint_dir) if checkpoint_dir else None
        self.checkpoint_every = checkpoint_every
        self.log: List[Dict[str, float]] = []
        # externally submitted updates (Session.submit_update): each one
        # takes a selected client's slot in the next round's cohort
        self._external: Deque[Tuple[str, np.ndarray, float]] = deque()
        # idempotent ingress: (client_id, submission_id) pairs already
        # accepted — a retried submission (lost ack, client backoff)
        # dedupes here instead of double-folding.  Bounded LRU so a
        # long job can't grow it without limit; `ingress` counts every
        # accept/dedupe/refusal for Session.metrics.
        self._seen_submissions: "OrderedDict[Tuple[str, str], int]" = \
            OrderedDict()
        self._seen_submissions_cap = 4096
        self.ingress: Dict[str, int] = {
            "queued": 0, "duplicates": 0, "refused": 0,
            "stale_round": 0, "requeued": 0, "shed": 0}
        # externals popped by the current round's cohort generator —
        # the requeue pass matches them against RoundOutcome.skipped
        self._popped_external: List[Tuple[str, np.ndarray, float]] = []
        # per-round traces (obs/): the driver's trace sink lands here;
        # bounded so a long job can't grow without limit.  trace_path
        # additionally appends each round as a JSONL record (flushed
        # per line — post-mortems survive a mid-round kill).
        self.trace_path = trace_path
        self.traces: "OrderedDict[int, RoundTrace]" = OrderedDict()
        self._traces_cap = 64
        self._runtime = None          # lazy: persists across rounds (warm)
        # an injected driver is shared infrastructure (serve mode): the
        # owner wires the coordinator's event handlers ONCE — wiring
        # them here per-trainer would double-count every EWMA sample
        self._driver: Optional[RoundDriver] = driver
        self._owns_driver = driver is None
        self._closed = False

    # ------------------------------------------------------------------
    # the one driver (lazy; wired to the control-plane event handlers)
    # ------------------------------------------------------------------
    @property
    def driver(self) -> RoundDriver:
        """The event bus is always available (subscribing a handler
        must not boot a runtime); the runtime itself attaches lazily on
        the first ``run_round``."""
        if self._driver is None:
            if self._closed:
                raise RuntimeError("trainer is closed")
            self._driver = RoundDriver(metrics=self.metrics,
                                       trace_sink=self._sink_trace)
            # node churn reshapes the next plan, and every subtree's
            # PartialReady feeds its node's RC capacity model: the
            # coordinator is an ordinary event handler on the driver.
            # TopFolded prices the root fold and PartialShipped the
            # uplink — the obs-stamped costs close the feedback loop.
            self._driver.on(NodeJoined, self.coordinator.handle_event)
            self._driver.on(NodeLost, self.coordinator.handle_event)
            self._driver.on(NodeRejoined, self.coordinator.handle_event)
            self._driver.on(PartialReady, self.coordinator.handle_event)
            self._driver.on(TopFolded, self.coordinator.handle_event)
            self._driver.on(PartialShipped, self.coordinator.handle_event)
        return self._driver

    def _sink_trace(self, trace: RoundTrace) -> None:
        self.traces[trace.round_id] = trace
        while len(self.traces) > self._traces_cap:
            self.traces.popitem(last=False)
        if self.trace_path:
            try:
                write_trace(self.trace_path, trace)
            except OSError:
                pass  # a full/vanished disk must not fail the round

    def trace(self, round_id: Optional[int] = None) -> Optional[RoundTrace]:
        """The per-round trace (latest round when ``round_id`` is None)."""
        if round_id is None:
            if not self.traces:
                return None
            round_id = next(reversed(self.traces))
        return self.traces.get(round_id)

    def _ensure_runtime(self):
        if self._runtime is None:
            self._runtime = make_runtime(
                self.runtime, metrics=self.metrics,
                agg_engine=self.agg_engine, eager=self.round_cfg.eager)
            self.driver.runtime = self._runtime
        return self._runtime

    # ------------------------------------------------------------------
    def submit_update(self, client_id: str, flat: np.ndarray,
                      weight: float = 1.0, *,
                      submission_id: Optional[str] = None,
                      round_id: Optional[int] = None) -> bool:
        """Queue an externally-computed flat update; it rides the next
        ``run_round`` in place of a locally-trained client.

        Idempotent when the caller supplies a ``submission_id``: a
        ``(client_id, submission_id)`` pair already accepted is counted
        and ignored (returns ``False``) — the retry contract that lets
        :func:`~repro.runtime.netrt.push_update` redeliver after a lost
        ack without ever double-folding.  A ``round_id`` pins the
        submission to a round: one older than the next round to run is
        refused (``ValueError``) — it could only fold into a round its
        sender never meant.  Returns ``True`` when queued."""
        next_round = self.coordinator.job_round(self.job)
        if round_id is not None and round_id < next_round:
            self.ingress["stale_round"] += 1
            raise ValueError(
                f"stale round_id {round_id}: next round is {next_round}")
        if submission_id is not None:
            seen_key = (client_id, submission_id)
            if seen_key in self._seen_submissions:
                self.ingress["duplicates"] += 1
                return False
        # any shape whose total size matches is accepted — flatten here
        # so a (rows, cols) wire payload can't reach the 1-D fold loop
        flat = np.ascontiguousarray(flat, dtype=np.float32).reshape(-1)
        if flat.size != self._flat_params_size():
            self.ingress["refused"] += 1
            raise ValueError(
                f"update has {flat.size} elements, model has "
                f"{self._flat_params_size()}")
        if submission_id is not None:
            self._seen_submissions[seen_key] = next_round
            while len(self._seen_submissions) > self._seen_submissions_cap:
                self._seen_submissions.popitem(last=False)
        self._external.append((client_id, flat, float(weight)))
        self.ingress["queued"] += 1
        return True

    # ------------------------------------------------------------------
    def run_round(self, *, client_lr: Optional[float] = None,
                  client_batch_size: Optional[int] = None,
                  client_epochs: Optional[int] = None,
                  deadline_s: Optional[float] = None,
                  sampler: Optional[Any] = None,
                  **legacy) -> Dict[str, float]:
        """One federated round through the driver (both runtimes)."""
        vals = {"client_lr": client_lr,
                "client_batch_size": client_batch_size,
                "client_epochs": client_epochs}
        for old, val in legacy.items():
            new = _DEPRECATED_ROUND_KWARGS.get(old)
            if new is None:
                raise TypeError(
                    f"run_round() got an unexpected keyword "
                    f"argument {old!r}")
            if vals[new] is not None:
                raise TypeError(
                    f"run_round() got both {old!r} and its replacement "
                    f"{new!r}")
            warnings.warn(
                f"run_round({old}=...) is deprecated; use {new}=...",
                DeprecationWarning, stacklevel=2)
            vals[new] = val
        client_lr = vals["client_lr"] if vals["client_lr"] is not None else 0.01
        client_batch_size = (vals["client_batch_size"]
                             if vals["client_batch_size"] is not None else 32)
        client_epochs = (vals["client_epochs"]
                         if vals["client_epochs"] is not None else 1)
        if self._closed:
            raise RuntimeError("trainer is closed")

        tround = self.open_round(
            client_lr=client_lr, client_batch_size=client_batch_size,
            client_epochs=client_epochs, deadline_s=deadline_s,
            sampler=sampler)
        tround.handle.run()
        return tround.finalize()

    # ------------------------------------------------------------------
    def open_round(self, *, client_lr: float = 0.01,
                   client_batch_size: int = 32, client_epochs: int = 1,
                   deadline_s: Optional[float] = None,
                   sampler: Optional[Any] = None,
                   feed: Optional[Any] = None,
                   feed_factory: Optional[Any] = None,
                   goal: Optional[int] = None,
                   driver_round_id: Optional[int] = None,
                   tag_rounds: bool = False) -> "_TrainerRound":
        """Plan one round and open it on the driver; returns a
        :class:`_TrainerRound` whose ``handle`` is resumable (the serve
        scheduler interleaves two) and whose :meth:`~_TrainerRound.
        finalize` applies the server optimizer once the handle is done.

        ``feed`` replaces the cohort generator (serve mode: the gateway
        feeds admitted external updates under a close-out policy);
        ``driver_round_id`` decouples the driver's globally-unique
        round id from the job's own round number (the plan's)."""
        if self._closed:
            raise RuntimeError("trainer is closed")
        t0 = time.perf_counter()
        self._ensure_runtime()
        if not self.driver._inflight:
            # rolling rounds share the popped-external log; reset it
            # only when nothing is in flight or the requeue pass of a
            # live round would lose its matches
            self._popped_external = []
        # sampler: per-round client selection as a pluggable policy —
        # `sampler(round_id, pool) -> cohort` replaces the built-in
        # diversity selector for this round (seed it for reproducibility)
        plan = self.coordinator.plan_round(
            self.round_cfg, sampler=sampler, job=self.job,
            tag_rounds=tag_rounds)
        goal = goal if goal is not None else self.round_cfg.aggregation_goal
        if feed_factory is not None:
            # serve mode: the feed needs the plan (node slots) before
            # the driver sees it
            updates = feed_factory(plan)
        elif feed is not None:
            updates = feed
        else:
            updates = self._cohort_updates(
                plan, lr=client_lr, batch_size=client_batch_size,
                epochs=client_epochs)
        handle = self.driver.open_round(
            round_id=(driver_round_id if driver_round_id is not None
                      else plan.round_id),
            assignment=plan.placement.assignment,
            updates=updates,
            goal=goal,
            n_elems=self._flat_params_size(),
            top_node=plan.top_node,
            deadline_s=deadline_s,
            fold_plan=plan.fold_plan,
            job=self.job,
        )
        return _TrainerRound(self, plan, handle, t0)

    # ------------------------------------------------------------------
    def _cohort_updates(self, plan, *, lr, batch_size, epochs
                        ) -> Iterator[Tuple[str, str, np.ndarray, float]]:
        """Yield ``(node, client_id, flat, weight)`` for the planned
        cohort — the one update source both runtimes consume, so
        selection/failure semantics can't drift between them.  Iteration
        *is* the client training; the driver stops pulling at the goal.
        Externally submitted updates take cohort slots first."""
        selected = plan.selected
        client_nodes: Dict[str, str] = {}
        for node, idxs in plan.placement.assignment.items():
            for i in idxs:
                if i < len(selected):
                    client_nodes[selected[i].client_id] = node

        for cid, node in client_nodes.items():
            if self._external:
                ext_cid, flat, weight = self._external.popleft()
                self._popped_external.append((ext_cid, flat, weight))
                yield node, ext_cid, flat, weight
                continue
            cr = self.clients[cid]
            out = cr.local_update(
                self.model, self.params, lr=lr, batch_size=batch_size,
                epochs=epochs, rng=self.rng,
            )
            if out is None:
                continue  # failed/hibernating client — over-provisioning absorbs
            delta, weight = out
            flat, _, _ = _flatten_tree(delta)
            yield node, cid, flat, weight

    def _flat_params_size(self) -> int:
        # must equal len(_flatten_tree(params)[0]): np.prod(()) is
        # already 1 for scalars, and a zero-size leaf contributes 0
        leaves = jax.tree.leaves(self.params)
        return int(sum(int(np.prod(np.shape(l))) for l in leaves))

    # ------------------------------------------------------------------
    def close(self) -> None:
        """Tear down the runtime (graceful drain + shm unlink for
        ``shmproc``).  Idempotent: double-close and close-after-crash
        are no-ops; ``evaluate``/``params`` stay usable after."""
        if self._closed:
            return
        self._closed = True
        if self._runtime is not None:
            self._runtime.close()
            self._runtime = None
        self._driver = None

    @property
    def closed(self) -> bool:
        return self._closed

    def __enter__(self) -> "FederatedTrainer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ------------------------------------------------------------------
    def evaluate(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, aux = self.model.loss(self.params, jb)
        out = {"loss": float(loss)}
        out.update({k: float(v) for k, v in aux.items()})
        return out


class _TrainerRound:
    """One opened round on a :class:`FederatedTrainer`: the driver's
    resumable handle plus the trainer-side close-out (requeue skipped
    externals, apply the server optimizer, finish the coordinator
    round).  ``run_round`` drives it synchronously; the serve scheduler
    steps ``handle`` itself and calls :meth:`finalize` when done."""

    def __init__(self, trainer: FederatedTrainer, plan, handle, t0: float):
        self.trainer = trainer
        self.plan = plan
        self.handle = handle
        self.t0 = t0
        self.record: Optional[Dict[str, float]] = None

    def finalize(self) -> Dict[str, float]:
        """Close the round out trainer-side (requires ``handle.done``).
        Idempotent: the second call returns the first record."""
        if self.record is not None:
            return self.record
        if not self.handle.done:
            raise RuntimeError("round still in flight")
        tr, plan, outcome = self.trainer, self.plan, self.handle.outcome

        # --- requeue skipped external submissions -----------------------
        # An external update the driver pulled but never dispatched
        # (deadline hit, lost subtree, full node) must not vanish: unlike
        # a locally trained client it cannot be regenerated, so it rides
        # the next cohort instead.  Match by array identity — the same
        # object the generator yielded comes back in outcome.skipped.
        if outcome.skipped and tr._popped_external:
            ext_ids = {id(flat): (cid, flat, w)
                       for cid, flat, w in tr._popped_external}
            requeued = [ext_ids[id(flat)]
                        for _node, _cid, flat, _w in outcome.skipped
                        if id(flat) in ext_ids]
            for item in reversed(requeued):
                tr._external.appendleft(item)
            tr.ingress["requeued"] += len(requeued)

        # --- server applies the aggregated update -----------------------
        if outcome.delta is not None:
            delta_tree = _unflatten_like(outcome.delta, tr.params)
            tr.params, tr.server_state = apply_server_opt(
                tr.server_opt, tr.params, tr.server_state, delta_tree,
                lr=-tr.server_lr,  # delta = new - old, so apply +lr·delta
            )
        # (E_{i,t}/k_{i,t} now reach the capacity model through the
        # PartialReady events the coordinator subscribes to — the same
        # events that arrive over the wire in multi-node rounds)
        version = tr.coordinator.finish_round(job=tr.job,
                                              round_id=plan.round_id)
        if tr.ckpt and version % tr.checkpoint_every == 0:
            tr.ckpt.submit(version, tr.params)
        # round over: hand accumulators back so next round's aggregators
        # at the same positions start warm instead of reallocating —
        # UNLESS another round is still in flight (rolling mode): its
        # mids share engine keys with this round's (the round tag is
        # stripped for pool lookup) and recycling a buffer someone is
        # mid-fold into would hand it out twice
        if not tr.driver._inflight:
            tr._runtime.recycle_engines()

        rec = {
            "round": plan.round_id,
            "updates": float(outcome.accepted),
            "nodes_used": float(len(plan.placement.assignment)),
            "inter_node": float(plan.inter_node_updates),
            "cold_starts": float(outcome.cold_starts),
            "reused": float(outcome.warm_starts),
            "workers": float(outcome.workers),
            "crashes": float(outcome.crashes),
            "redispatched": float(outcome.redispatched),
            "wall_s": time.perf_counter() - self.t0,
        }
        tr.log.append(rec)
        self.record = rec
        return rec


def _flatten_tree(tree: Any) -> Tuple[np.ndarray, Any, list]:
    leaves, treedef = jax.tree.flatten(tree)
    meta = [(np.shape(l), np.asarray(l).dtype) for l in leaves]
    flat = np.concatenate([np.asarray(l, np.float32).reshape(-1) for l in leaves])
    return flat, treedef, meta


def _unflatten_like(flat: np.ndarray, like: Any) -> Any:
    leaves, treedef = jax.tree.flatten(like)
    out = []
    off = 0
    for l in leaves:
        n = int(np.prod(np.shape(l))) if np.shape(l) else 1
        out.append(
            jnp.asarray(flat[off : off + n].reshape(np.shape(l)), jnp.float32)
            .astype(l.dtype)
        )
        off += n
    return jax.tree.unflatten(treedef, out)


# ===========================================================================
# fused engine (large models, one XLA program per round)
# ===========================================================================


class FusedFLTrainer:
    def __init__(
        self,
        cfg,                          # ArchConfig
        mesh,
        agg: AggregationConfig,
        *,
        opts=None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 20,
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.agg = agg
        step, model = build_train_step(cfg, mesh, agg, opts=opts)
        self.model = model
        self._cache = ExecutableCache(lambda **sig: jax.jit(
            step, donate_argnums=(0, 1)
        ))
        self.step_fn = self._cache.get(
            batch=agg.num_microbatches, opt=agg.server_opt
        )
        self.params = None
        self.server_state = None
        self.ckpt = AsyncCheckpointer(checkpoint_dir) if checkpoint_dir else None
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.round_id = 0
        self.history: List[Dict[str, float]] = []

    # ------------------------------------------------------------------
    def init(self, seed: int = 0) -> None:
        with use_mesh(self.mesh):
            self.params = self.model.init(jax.random.PRNGKey(seed))
            self.server_state = init_server_state(self.agg.server_opt, self.params)

    def maybe_restore(self) -> bool:
        """Checkpoint/restart: resume from the latest checkpoint if any."""
        if not self.checkpoint_dir or latest_step(self.checkpoint_dir) is None:
            return False
        like = self.params if self.params is not None else jax.eval_shape(
            self.model.init, jax.random.PRNGKey(0)
        )
        self.params, step = restore_checkpoint(self.checkpoint_dir, like)
        self.server_state = init_server_state(self.agg.server_opt, self.params)
        self.round_id = step
        return True

    # ------------------------------------------------------------------
    def train_round(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        assert self.params is not None, "call init() or maybe_restore() first"
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        with use_mesh(self.mesh):
            self.params, self.server_state, metrics = self.step_fn(
                self.params, self.server_state, jb
            )
        self.round_id += 1
        rec = {k: float(v) for k, v in metrics.items()}
        rec["round"] = self.round_id
        self.history.append(rec)
        if self.ckpt and self.round_id % self.checkpoint_every == 0:
            self.ckpt.submit(self.round_id, self.params)
        return rec
