"""Training runtimes.

``FederatedTrainer`` — the paper-faithful engine: real per-client local
SGD (diverged mode), LIFL hierarchical aggregation through the actual
control-plane objects (selector → BestFit placement → EWMA hierarchy →
warm pool → gateways/sockmap routing → eager aggregation), failure
handling via over-provisioning + aggregation goal, async checkpoints.

``FusedFLTrainer`` — the large-model engine: one jitted fused round step
(fl/round.py) per round on a mesh; cohort data from the federated
pipeline; checkpoint/restart; straggler masking; elastic round sizing
through the warm-executable cache (re-plan ⇒ cache lookup, not a
recompile, when the signature matches — LIFL C8).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.compat import use_mesh
from repro.core import (
    Aggregator,
    AggregatorPool,
    ClientInfo,
    Coordinator,
    EventSidecar,
    Gateway,
    InProcObjectStore,
    MetricsMap,
    NodeState,
    RoundConfig,
    Selector,
    SockMap,
    fedavg_oracle,
)
from repro.core.engine import make_engine
from repro.core.reuse import ExecutableCache
from repro.fl.round import AggregationConfig, build_train_step
from repro.fl.server import apply_server_opt, init_server_state
from repro.optim import sgd_apply


# ===========================================================================
# paper-faithful engine (diverged clients, host aggregation tree)
# ===========================================================================


@dataclass
class ClientRuntime:
    """A training client: local SGD for ``epochs`` over its shard."""

    info: ClientInfo
    dataset: Any                      # ClientDataset
    hibernate_s: Tuple[float, float] = (0.0, 0.0)  # mobile availability (§6.2)
    failure_prob: float = 0.0

    def local_update(self, model, params, *, lr: float, batch_size: int,
                     epochs: int, rng: np.random.Generator
                     ) -> Optional[Tuple[Any, float]]:
        """-> (delta pytree, num_samples) or None if the client fails."""
        if rng.random() < self.failure_prob:
            return None  # detected by missing heartbeat; goal absorbs it
        p = params
        n = 0
        for batch in self.dataset.batches(batch_size, epochs=epochs,
                                          seed=int(rng.integers(1 << 30))):
            jb = {k: jnp.asarray(v) for k, v in batch.items()}
            (_, _), grads = jax.value_and_grad(model.loss, has_aux=True)(p, jb)
            p, _ = sgd_apply(p, grads, {}, lr=lr)
            n += len(batch["labels"])
        if n == 0:
            return None
        delta = jax.tree.map(
            lambda new, old: np.asarray(new, np.float32) - np.asarray(old, np.float32),
            p, params,
        )
        return delta, float(self.dataset.num_samples)


class FederatedTrainer:
    """LIFL rounds over real clients with the host aggregation tree."""

    def __init__(
        self,
        model,                       # .loss(params, batch) -> (loss, aux)
        params: Any,
        clients: Sequence[ClientRuntime],
        *,
        nodes: Optional[Dict[str, NodeState]] = None,
        round_cfg: Optional[RoundConfig] = None,
        server_opt: str = "fedavg",
        server_lr: float = 1.0,
        agg_engine: str = "auto",
        runtime: Optional[str] = None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 5,
        seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.agg_engine = agg_engine
        # warm engines keyed by aggregator id: a re-created aggregator
        # at the same tree position re-enters the next round with its
        # accumulator/scratch already resident (§5.3 at the fold level)
        self._engines: Dict[str, Any] = {}
        self.clients = {c.info.client_id: c for c in clients}
        self.nodes = nodes or {
            f"node{i}": NodeState(node=f"node{i}", max_capacity=20.0)
            for i in range(5)
        }
        self.round_cfg = round_cfg or RoundConfig(aggregation_goal=8)
        # selectable aggregation runtime: explicit arg > round config
        self.runtime = runtime if runtime is not None else self.round_cfg.runtime
        self._shmrt = None  # lazy ShmRuntime (persists across rounds: warm)
        self.server_opt = server_opt
        self.server_lr = server_lr
        self.server_state = init_server_state(server_opt, params)
        self.coordinator = Coordinator(
            Selector([c.info for c in clients], seed=seed), self.nodes
        )
        self.metrics = MetricsMap()
        self.rng = np.random.default_rng(seed)
        self.ckpt = AsyncCheckpointer(checkpoint_dir) if checkpoint_dir else None
        self.checkpoint_every = checkpoint_every
        self.log: List[Dict[str, float]] = []

    # ------------------------------------------------------------------
    def _warm_engine(self, agg_id: str):
        eng = self._engines.get(agg_id)
        if eng is None:
            eng = make_engine(self.agg_engine)
            self._engines[agg_id] = eng
        return eng

    # ------------------------------------------------------------------
    def run_round(self, *, lr: float = 0.01, batch_size: int = 32,
                  epochs: int = 1) -> Dict[str, float]:
        t0 = time.perf_counter()
        plan = self.coordinator.plan_round(self.round_cfg)
        goal = self.round_cfg.aggregation_goal
        if self.runtime == "shmproc":
            return self._run_round_shmproc(
                plan, goal, lr=lr, batch_size=batch_size, epochs=epochs, t0=t0)

        # --- build the aggregation tree from the plan -------------------
        stores = {n: InProcObjectStore(n) for n in plan.hierarchy.nodes_used}
        top_node = plan.top_node or next(iter(stores))
        stores.setdefault(top_node, InProcObjectStore(top_node))
        top_state: Dict[str, Any] = {}

        def on_top(result, weight):
            top_state["delta"] = result
            top_state["weight"] = weight

        top = Aggregator(
            f"top@{top_node}", stores[top_node],
            goal=len(plan.hierarchy.nodes_used),
            eager=self.round_cfg.eager,
            sidecar=EventSidecar("top", self.metrics),
            on_complete=on_top,
            engine=self._warm_engine(f"top@{top_node}"),
        )

        # per-node middle aggregators feeding the top
        mids: Dict[str, Aggregator] = {}
        per_node_goal: Dict[str, int] = {}
        assignment = plan.placement.assignment
        for node, idxs in assignment.items():
            per_node_goal[node] = len(idxs)

            def make_mid(node=node):
                def done(result, weight):
                    env = Gateway(node, stores[node]).put_local(
                        result, plan.round_id, f"mid@{node}", weight
                    )
                    # intermediate update to the top (one per node, §5.2)
                    tkey = stores[top_node].put(np.asarray(result))
                    env.object_key = tkey
                    top.recv(env)

                return Aggregator(
                    f"mid@{node}", stores[node], per_node_goal[node],
                    eager=self.round_cfg.eager,
                    sidecar=EventSidecar(f"mid@{node}", self.metrics),
                    on_complete=done,
                    engine=self._warm_engine(f"mid@{node}"),
                )

            mids[node] = make_mid()

        # --- clients train; updates land at their node's middle ---------
        from repro.core.gateway import UpdateEnvelope

        def deliver(node, cid, flat, weight):
            key = stores[node].put(flat)
            env = UpdateEnvelope(key, plan.round_id, cid, weight,
                                 enqueue_ts=time.perf_counter())
            mids[node].recv(env)

        accepted, _ = self._train_cohort(
            plan, goal, deliver, lr=lr, batch_size=batch_size, epochs=epochs)

        # close out mids that got fewer than planned (stragglers); under
        # lazy timing nothing has folded yet — the queued envelopes are
        # the round's updates, so the goal is count + queue and flush's
        # batched drain performs the whole aggregation here
        for node, mid in mids.items():
            if not mid.done and (mid.state.count > 0 or mid.fifo):
                mid.goal = mid.state.count + len(mid.fifo)
                mid.flush()
                if not mid.done:
                    mid._send()
        if not top.done and (top.state.count > 0 or top.fifo):
            top.goal = top.state.count + len(top.fifo)
            top.flush()
            if not top.done:
                top._send()

        # --- server applies the aggregated update -----------------------
        if "delta" in top_state:
            delta_tree = _unflatten_like(top_state["delta"], self.params)
            self.params, self.server_state = apply_server_opt(
                self.server_opt, self.params, self.server_state, delta_tree,
                lr=-self.server_lr,  # delta = new - old, so apply +lr·delta
            )
        version = self.coordinator.finish_round()
        if self.ckpt and version % self.checkpoint_every == 0:
            self.ckpt.submit(version, self.params)

        # round over: hand accumulators back so next round's aggregators
        # at the same positions start warm instead of reallocating
        for eng in self._engines.values():
            eng.recycle()

        rec = {
            "round": plan.round_id,
            "updates": float(accepted),
            "nodes_used": float(len(assignment)),
            "inter_node": float(plan.inter_node_updates),
            "cold_starts": float(plan.cold_starts),
            "reused": float(plan.reused),
            "wall_s": time.perf_counter() - t0,
        }
        self.log.append(rec)
        return rec

    # ------------------------------------------------------------------
    def _train_cohort(self, plan, goal, deliver, *, lr, batch_size, epochs
                      ) -> Tuple[int, Dict[str, int]]:
        """Run the selected clients' local SGD and hand each flattened
        update to ``deliver(node, client_id, flat, weight)`` — the one
        cohort loop both runtimes share, so selection/failure semantics
        can't drift between them.  Returns (accepted, per-node counts)."""
        assignment = plan.placement.assignment
        selected = plan.selected
        client_nodes: Dict[str, str] = {}
        for node, idxs in assignment.items():
            for i in idxs:
                if i < len(selected):
                    client_nodes[selected[i].client_id] = node

        accepted = 0
        dispatched: Dict[str, int] = {node: 0 for node in assignment}
        for cid, node in client_nodes.items():
            if accepted >= goal:
                break  # aggregation goal reached; stragglers ignored
            cr = self.clients[cid]
            out = cr.local_update(
                self.model, self.params, lr=lr, batch_size=batch_size,
                epochs=epochs, rng=self.rng,
            )
            if out is None:
                continue  # failed/hibernating client — over-provisioning absorbs
            delta, weight = out
            flat, _, _ = _flatten_tree(delta)
            deliver(node, cid, flat, weight)
            dispatched[node] += 1
            accepted += 1
        return accepted, dispatched

    # ------------------------------------------------------------------
    # shmproc: the real multi-process runtime (repro.runtime.shmrt)
    # ------------------------------------------------------------------
    def _ensure_shmrt(self):
        if self._shmrt is None:
            from repro.runtime.shmrt import ShmRuntime

            self._shmrt = ShmRuntime(metrics=self.metrics)
        return self._shmrt

    def _flat_params_size(self) -> int:
        # must equal len(_flatten_tree(params)[0]): np.prod(()) is
        # already 1 for scalars, and a zero-size leaf contributes 0
        leaves = jax.tree.leaves(self.params)
        return int(sum(int(np.prod(np.shape(l))) for l in leaves))

    def _run_round_shmproc(self, plan, goal, *, lr, batch_size, epochs, t0
                           ) -> Dict[str, float]:
        """One round where each planned middle aggregator is a real
        worker process: client updates land in the shared-memory store,
        16-byte keys ride the rings, the parent folds the published
        partial sums zero-copy out of the store (top aggregator)."""
        from repro.runtime.shmrt import WorkerCrash

        rt = self._ensure_shmrt()
        cold0 = rt.stats["cold_starts"]
        warm0 = rt.stats["warm_starts"]
        n_elems = self._flat_params_size()
        assignment = plan.placement.assignment
        top_node = plan.top_node or (next(iter(assignment)) if assignment
                                     else "node0")

        for node, idxs in assignment.items():
            rt.submit_task(f"mid@{node}", goal=len(idxs), n_elems=n_elems,
                           round_id=plan.round_id)

        # --- clients train; keys dispatched to their node's worker ------
        update_keys: List[str] = []

        def deliver(node, cid, flat, weight):
            key = rt.store.put(flat)
            update_keys.append(key)
            rt.dispatch(f"mid@{node}", key, weight, round_id=plan.round_id)

        accepted, dispatched = self._train_cohort(
            plan, goal, deliver, lr=lr, batch_size=batch_size, epochs=epochs)

        # close out stragglers: short tasks publish what they folded
        counted = set()  # agg_ids a partial is expected from
        for node in assignment:
            if dispatched[node] == 0 or dispatched[node] < len(assignment[node]):
                rt.drain(f"mid@{node}")
            if dispatched[node] > 0:
                counted.add(f"mid@{node}")

        # --- collect partials; crashes lose a subtree, not the round ----
        partials = []
        crashes = 0
        while len(partials) < len(counted):
            try:
                for p in rt.collect(len(counted) - len(partials)):
                    if p.round_id != plan.round_id or p.agg_id not in counted:
                        # stale leftover from an aborted earlier round
                        rt.store.destroy(p.key)
                        continue
                    partials.append(p)
            except WorkerCrash as e:
                crashes += 1
                # only a crash that takes an *expected* subtree with it
                # shrinks the quota (a zero-dispatch drain worker or a
                # warming fork contributes nothing either way)
                if e.agg_id in counted and not any(
                        p.agg_id == e.agg_id for p in partials):
                    counted.discard(e.agg_id)
        # wait out zero-update drains (EMPTY closures) so a late record
        # can't collide with next round's task under the same agg_id
        rt.quiesce(timeout=5.0)
        partials.sort(key=lambda p: p.agg_id)  # deterministic fold order

        # --- top aggregator: fold partial sums zero-copy from the store -
        if partials:
            engine = self._warm_engine(f"top@{top_node}")
            from repro.core.aggregation import FedAvgState

            state = FedAvgState(engine=engine)
            sidecar = EventSidecar("top", self.metrics)
            ta = time.perf_counter()
            state._ensure_acc(n_elems)
            for p in partials:
                view = rt.store.get(p.key)      # zero-copy shm view
                state.acc = engine.add_partial(state.acc, view)
                state.weight += p.weight
                state.count += p.count
                rt.store.release(p.key)
            dt = time.perf_counter() - ta
            sidecar.on_aggregate(len(partials), dt)
            delta_flat, _ = state.result()
            sidecar.on_send(delta_flat.nbytes)
            delta_tree = _unflatten_like(delta_flat, self.params)
            self.params, self.server_state = apply_server_opt(
                self.server_opt, self.params, self.server_state, delta_tree,
                lr=-self.server_lr,
            )
            # E_{i,t} from the worker sidecars feeds the capacity model
            for p in partials:
                node = p.agg_id.split("@", 1)[-1]
                if node in self.nodes:
                    ns = self.nodes[node]
                    ns.exec_time_s = 0.5 * ns.exec_time_s + 0.5 * max(
                        p.exec_s, 1e-6)

        for p in partials:
            rt.store.destroy(p.key)
        for key in update_keys:
            rt.store.delete(key)

        version = self.coordinator.finish_round()
        if self.ckpt and version % self.checkpoint_every == 0:
            self.ckpt.submit(version, self.params)
        for eng in self._engines.values():
            eng.recycle()

        rec = {
            "round": plan.round_id,
            "updates": float(accepted),
            "nodes_used": float(len(assignment)),
            "inter_node": float(plan.inter_node_updates),
            # per-round deltas, comparable with the inproc runtime's
            # plan-level numbers under the same keys
            "cold_starts": float(rt.stats["cold_starts"] - cold0),
            "reused": float(rt.stats["warm_starts"] - warm0),
            "workers": float(len(rt.worker_pids())),
            "crashes": float(crashes),
            "wall_s": time.perf_counter() - t0,
        }
        self.log.append(rec)
        return rec

    def close(self) -> None:
        """Tear down the multi-process runtime (graceful drain + shm
        unlink).  No-op for the in-proc runtime."""
        if self._shmrt is not None:
            self._shmrt.shutdown()
            self._shmrt = None

    # ------------------------------------------------------------------
    def evaluate(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        loss, aux = self.model.loss(self.params, jb)
        out = {"loss": float(loss)}
        out.update({k: float(v) for k, v in aux.items()})
        return out


def _flatten_tree(tree: Any) -> Tuple[np.ndarray, Any, list]:
    leaves, treedef = jax.tree.flatten(tree)
    meta = [(np.shape(l), np.asarray(l).dtype) for l in leaves]
    flat = np.concatenate([np.asarray(l, np.float32).reshape(-1) for l in leaves])
    return flat, treedef, meta


def _unflatten_like(flat: np.ndarray, like: Any) -> Any:
    leaves, treedef = jax.tree.flatten(like)
    out = []
    off = 0
    for l in leaves:
        n = int(np.prod(np.shape(l))) if np.shape(l) else 1
        out.append(
            jnp.asarray(flat[off : off + n].reshape(np.shape(l)), jnp.float32)
            .astype(l.dtype)
        )
        off += n
    return jax.tree.unflatten(treedef, out)


# ===========================================================================
# fused engine (large models, one XLA program per round)
# ===========================================================================


class FusedFLTrainer:
    def __init__(
        self,
        cfg,                          # ArchConfig
        mesh,
        agg: AggregationConfig,
        *,
        opts=None,
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 20,
    ):
        self.cfg = cfg
        self.mesh = mesh
        self.agg = agg
        step, model = build_train_step(cfg, mesh, agg, opts=opts)
        self.model = model
        self._cache = ExecutableCache(lambda **sig: jax.jit(
            step, donate_argnums=(0, 1)
        ))
        self.step_fn = self._cache.get(
            batch=agg.num_microbatches, opt=agg.server_opt
        )
        self.params = None
        self.server_state = None
        self.ckpt = AsyncCheckpointer(checkpoint_dir) if checkpoint_dir else None
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.round_id = 0
        self.history: List[Dict[str, float]] = []

    # ------------------------------------------------------------------
    def init(self, seed: int = 0) -> None:
        with use_mesh(self.mesh):
            self.params = self.model.init(jax.random.PRNGKey(seed))
            self.server_state = init_server_state(self.agg.server_opt, self.params)

    def maybe_restore(self) -> bool:
        """Checkpoint/restart: resume from the latest checkpoint if any."""
        if not self.checkpoint_dir or latest_step(self.checkpoint_dir) is None:
            return False
        like = self.params if self.params is not None else jax.eval_shape(
            self.model.init, jax.random.PRNGKey(0)
        )
        self.params, step = restore_checkpoint(self.checkpoint_dir, like)
        self.server_state = init_server_state(self.agg.server_opt, self.params)
        self.round_id = step
        return True

    # ------------------------------------------------------------------
    def train_round(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        assert self.params is not None, "call init() or maybe_restore() first"
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        with use_mesh(self.mesh):
            self.params, self.server_state, metrics = self.step_fn(
                self.params, self.server_state, jb
            )
        self.round_id += 1
        rec = {k: float(v) for k, v in metrics.items()}
        rec["round"] = self.round_id
        self.history.append(rec)
        if self.ckpt and self.round_id % self.checkpoint_every == 0:
            self.ckpt.submit(self.round_id, self.params)
        return rec
