"""Event-driven dispatcher: forks, parks, routes, supervises.

The control-plane half of the multi-process runtime (paper Fig 3 +
§5.3 reuse semantics across real process boundaries):

  * **cold start** — ``submit_task`` with no idle worker forks one
    (rings + doorbells are created first and inherited), pays the
    process spin-up, and waits for READY;
  * **warm start** — an idle worker is re-tasked by writing one 64-byte
    TASK record into its ring: the process, its engine scratch, and its
    store mappings are already resident (the ``AggregatorPool``
    IDLE→BUSY transition, across processes);
  * **routing** — envelopes are routed by tree position (``agg_id``):
    the dispatcher keeps an ``agg_id → worker`` table for the round,
    the sockmap-TAG analog;
  * **supervision** — ``poll`` detects dead workers (crash ≠ drain),
    reclaims their shm segments by name prefix, and surfaces a
    :class:`WorkerCrash`; ``shutdown`` drains gracefully and unlinks
    every ring; an atexit hook backstops abnormal exits.

Metrics: every PARTIAL feeds the event sidecar (`agg_updates`,
`agg_exec_s`) — exactly the series ``placement.py``'s capacity model
(RC = MC − k·E) consumes; ``node_exec_time`` exposes the E_{i,t}
estimate per tree position.
"""
from __future__ import annotations

import atexit
import os
import secrets
import time
import warnings
from dataclasses import dataclass, field
from multiprocessing import get_context
from typing import Dict, List, Optional

import numpy as np

from repro.core.objectstore import (
    SharedMemoryObjectStore,
    new_object_key,
    unlink_segment,
)
from repro.core.sidecar import EventSidecar, MetricsMap
from repro.runtime.shmrt.messages import Record, RecordKind
from repro.runtime.shmrt.ring import Doorbell, SpscRing
from repro.runtime.shmrt.worker import worker_main

_FORK = get_context("fork")


class WorkerCrash(RuntimeError):
    def __init__(self, widx: int, agg_id: Optional[str], exitcode):
        super().__init__(
            f"aggregator worker {widx} died (exit {exitcode}) "
            f"while assigned {agg_id!r}")
        self.widx = widx
        self.agg_id = agg_id
        self.exitcode = exitcode


@dataclass
class PartialResult:
    """A published intermediate aggregate: fold via ``store.get(key)``."""

    agg_id: str
    key: str
    weight: float
    count: int
    exec_s: float
    round_id: int
    worker: int


@dataclass
class _Worker:
    idx: int
    proc: object = None
    task_ring: SpscRing = None
    result_ring: SpscRing = None
    state: str = "cold"          # cold|warming|idle|busy
    agg_id: Optional[str] = None
    seq: int = 0
    ready_ts: float = 0.0
    submit_ts: float = 0.0
    ack_latency_s: Optional[float] = None
    ack_ts: float = 0.0          # pickup ts of the open task (worker clock)
    wait_s: float = 0.0          # TELEM ring-wait for the open task
    cold_started: bool = False   # this task paid a fork
    tasks_done: int = 0


class ShmRuntime:
    """Single-node multi-process aggregation runtime.

    One instance owns the object store prefix, the worker fleet, and
    all rings.  Typical round (see ``FederatedTrainer``):

        rt = ShmRuntime()
        rt.submit_task("mid@node0", goal=4, n_elems=N)
        for u, w in updates:
            rt.dispatch("mid@node0", rt.store.put(u), w)
        for p in rt.collect(n_partials=1):
            acc += rt.store.get(p.key)      # zero-copy fold
            rt.store.destroy(p.key)
        rt.release("mid@node0")             # park the worker warm
    """

    def __init__(self, *, nslots: int = 1024, batch_k: int = 8,
                 prefix: Optional[str] = None,
                 metrics: Optional[MetricsMap] = None,
                 max_workers: int = 32):
        # per-instance nonce: two runtimes in one process (e.g. an
        # inproc-vs-shmproc comparison script) must not collide on ring
        # or object segment names
        self.prefix = prefix or (
            f"lifl{os.getpid() & 0xffff:x}{secrets.token_hex(2)}")
        self.store = SharedMemoryObjectStore(
            node="dispatcher", prefix=self.prefix)
        self.nslots = nslots
        self.batch_k = batch_k
        self.max_workers = max_workers
        self.metrics = metrics if metrics is not None else MetricsMap()
        self._workers: List[_Worker] = []
        self._route: Dict[str, _Worker] = {}     # agg_id -> worker (TAG)
        self._exec_ewma: Dict[str, float] = {}   # agg_id -> E_{i,t}
        self.stats = {
            "cold_starts": 0, "warm_starts": 0, "partials": 0,
            "crashes": 0, "forked": 0, "stale_partials": 0,
            "cold_latency_s": 0.0, "warm_latency_s": 0.0,
        }
        # poll() buffers through these queues so a WorkerCrash raised
        # mid-scan never discards partials already popped off other
        # workers' rings (they surface on the next poll), and multiple
        # same-scan crashes are raised one per call, not collapsed
        self._results: List[PartialResult] = []
        self._crashes: List[WorkerCrash] = []
        # worker-side span dicts (worker.task = ACK→PARTIAL on the
        # worker's own clock, worker.wait = TELEM's ring-wait), drained
        # by take_spans() into the round trace; bounded so an untraced
        # caller never accumulates them without limit
        self._spans: List[Dict] = []
        self._spans_cap = 4096
        self._closed = False
        atexit.register(self._atexit)

    # ------------------------------------------------------------------
    # worker lifecycle
    # ------------------------------------------------------------------
    def _fork_worker(self) -> _Worker:
        idx = len(self._workers)
        if idx >= self.max_workers:
            raise RuntimeError(f"worker fleet capped at {self.max_workers}")
        w = _Worker(idx=idx)
        w.task_ring = SpscRing(
            f"{self.prefix}-tq{idx}", nslots=self.nslots, create=True,
            data_bell=Doorbell(), space_bell=Doorbell())
        w.result_ring = SpscRing(
            f"{self.prefix}-rq{idx}", nslots=self.nslots, create=True,
            data_bell=Doorbell(), space_bell=Doorbell())
        w.proc = _FORK.Process(
            target=worker_main,
            args=(idx, w.task_ring, w.result_ring, self.prefix, self.batch_k),
            daemon=True, name=f"lifl-agg-worker-{idx}",
        )
        with warnings.catch_warnings():
            # jax warns that fork + its threads can deadlock; the worker
            # child is numpy-only by construction (worker.py) and never
            # re-enters XLA, so the hazard doesn't apply
            warnings.filterwarnings(
                "ignore", message=".*fork.*", category=RuntimeWarning)
            w.proc.start()
        w.state = "warming"
        self.stats["forked"] += 1
        self._workers.append(w)
        return w

    def _await_ready(self, w: _Worker, timeout: float = 30.0) -> None:
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            raw = w.result_ring.pop(timeout=0.5)
            if raw is not None and Record.unpack(raw).kind == RecordKind.READY:
                w.ready_ts = time.perf_counter()
                w.state = "idle"
                return
            if not w.proc.is_alive():
                raise WorkerCrash(w.idx, None, w.proc.exitcode)
        raise TimeoutError(f"worker {w.idx} did not come up in {timeout}s")

    def _acquire(self) -> _Worker:
        for w in self._workers:
            if w.state == "idle":
                if not w.proc.is_alive():
                    # died while parked (OOM-kill etc.): reap instead of
                    # pushing a task into a ring nobody drains
                    self._reap(w)
                    continue
                self.stats["warm_starts"] += 1
                w.cold_started = False
                return w
        w = self._fork_worker()
        self._await_ready(w)
        self.stats["cold_starts"] += 1
        w.cold_started = True
        return w

    # ------------------------------------------------------------------
    # round-facing API
    # ------------------------------------------------------------------
    def submit_task(self, agg_id: str, goal: int, n_elems: int,
                    round_id: int = 0) -> int:
        """Assign an aggregation task to a (warm if possible) worker.
        Returns the worker index.  The TASK record is the entire
        dispatch: one 64-byte write + a doorbell."""
        if agg_id in self._route:
            raise ValueError(f"{agg_id!r} already has an open task")
        t0 = time.perf_counter()  # cold dispatch latency includes the fork
        w = self._acquire()
        w.seq += 1
        w.agg_id = agg_id
        w.state = "busy"
        w.submit_ts = t0
        w.ack_latency_s = None
        ok = w.task_ring.push(Record(
            kind=RecordKind.TASK, key=_tag16(agg_id), round_id=round_id,
            flags=w.seq, a=goal, b=n_elems, ts=w.submit_ts,
        ).pack(), timeout=5.0)
        if not ok:
            raise RuntimeError(f"task ring full for worker {w.idx}")
        self._route[agg_id] = w
        return w.idx

    def dispatch(self, agg_id: str, object_key: str, weight: float,
                 round_id: int = 0) -> None:
        """Route one envelope (16-byte key + aux) by tree position."""
        w = self._route[agg_id]
        ok = w.task_ring.push(Record(
            kind=RecordKind.UPDATE, key=object_key, round_id=round_id,
            num_samples=weight, ts=time.perf_counter(),
        ).pack(), timeout=30.0)
        if not ok:
            if not w.proc.is_alive():
                self._reap(w)
            raise RuntimeError(
                f"update ring for {agg_id!r} blocked >30s (worker "
                f"{w.idx} alive={w.proc.is_alive()})")

    def dispatch_partial(self, agg_id: str, object_key: str, weight: float,
                         count: int, round_id: int = 0) -> None:
        """Route one published raw partial Σ c·u into a root-fold task.
        The ring is FIFO, so the worker absorbs partials exactly in the
        order they are dispatched — the caller fixes the fold order."""
        w = self._route[agg_id]
        ok = w.task_ring.push(Record(
            kind=RecordKind.PARTIAL_IN, key=object_key, round_id=round_id,
            num_samples=weight, a=int(count), ts=time.perf_counter(),
        ).pack(), timeout=30.0)
        if not ok:
            if not w.proc.is_alive():
                self._reap(w)
            raise RuntimeError(
                f"partial ring for {agg_id!r} blocked >30s (worker "
                f"{w.idx} alive={w.proc.is_alive()})")

    def drain(self, agg_id: str) -> None:
        """Close out a straggler-shortened task: the worker publishes
        whatever it has folded."""
        w = self._route.get(agg_id)
        if w is not None:
            w.task_ring.push(Record(
                kind=RecordKind.DRAIN, flags=w.seq).pack(), timeout=5.0)

    # ------------------------------------------------------------------
    def _scan(self) -> None:
        """Drain every result ring into the internal queues and reap
        dead workers.  Never raises; never drops a record."""
        for w in self._workers:
            if w.state == "dead":
                continue
            while True:
                raw = w.result_ring.pop()
                if raw is None:
                    break
                rec = Record.unpack(raw)
                if rec.kind == RecordKind.ACK:
                    if rec.flags != w.seq:
                        continue  # stale ack from a force-released task
                    w.ack_latency_s = rec.ts - w.submit_ts
                    w.ack_ts = rec.ts
                    kind = "cold" if w.cold_started else "warm"
                    self.stats[f"{kind}_latency_s"] = w.ack_latency_s
                    self.metrics.update(
                        w.agg_id or f"worker{w.idx}",
                        f"dispatch_{kind}_s", w.ack_latency_s)
                elif rec.kind == RecordKind.TELEM:
                    if rec.flags != w.seq:
                        continue  # stale telemetry, like a stale ack
                    w.wait_s = rec.num_samples
                    self.metrics.update(w.agg_id or f"worker{w.idx}",
                                        "ring_wait_s", rec.num_samples)
                    # distribution under a fixed owner (per-agg owners
                    # would mint unbounded histograms)
                    self.metrics.observe("shm", "ring_dwell_s",
                                         rec.num_samples)
                elif rec.kind == RecordKind.PARTIAL:
                    if rec.flags != w.seq:
                        # a force-released task's late partial: reclaim
                        # the orphaned object, don't surface it
                        self.stats["stale_partials"] += 1
                        unlink_segment(self.store.segment_name(rec.key))
                        continue
                    self._results.append(self._on_partial(w, rec))
                elif rec.kind == RecordKind.EMPTY:
                    if rec.flags != w.seq:
                        continue
                    # task closed with nothing folded: no partial
                    self._route.pop(w.agg_id, None)
                    w.agg_id = None
                    w.state = "idle"
                # READY/ERROR records carry no round state here
            if w.state in ("busy", "warming") and not w.proc.is_alive():
                agg_id = w.agg_id
                self._reap(w)
                self._crashes.append(
                    WorkerCrash(w.idx, agg_id, w.proc.exitcode))
            elif w.state == "idle" and not w.proc.is_alive():
                # a dead *idle* worker loses capacity, not work: reap
                # quietly, the next submit just forks a fresh one
                self._reap(w)

    def _wait_any_result(self, max_wait: float) -> None:
        """Block on the result-ring doorbells (event-driven idle) —
        capped at 50 ms so a crashed worker, which never rings, is
        still detected promptly by the next _scan."""
        bells = [w.result_ring.data_bell for w in self._workers
                 if w.state not in ("dead",) and w.result_ring is not None
                 and w.result_ring.data_bell is not None
                 and w.result_ring.data_bell.fileno() >= 0]
        slice_s = min(max_wait, 0.05)
        if not bells:
            time.sleep(min(slice_s, 0.0005))
            return
        import select as _select

        ready, _, _ = _select.select(bells, [], [], slice_s)
        for bell in ready:
            bell.drain()

    def poll(self, timeout: float = 0.0) -> List[PartialResult]:
        """Scan result rings; returns published partials.  Detects and
        reaps crashed workers: each crash raises one
        :class:`WorkerCrash` (after its segments are reclaimed), with
        already-collected partials preserved for the next call."""
        deadline = time.perf_counter() + timeout
        while True:
            self._scan()
            if self._crashes:
                raise self._crashes.pop(0)
            left = deadline - time.perf_counter()
            if self._results or left <= 0:
                out, self._results = self._results, []
                return out
            self._wait_any_result(left)

    def collect(self, n_partials: int, timeout: float = 60.0
                ) -> List[PartialResult]:
        """Block until ``n_partials`` intermediate aggregates arrived.
        On WorkerCrash, partials gathered so far are re-queued so the
        caller can retry ``collect`` with a reduced count."""
        got: List[PartialResult] = []
        deadline = time.perf_counter() + timeout
        try:
            while len(got) < n_partials:
                left = deadline - time.perf_counter()
                if left <= 0:
                    raise TimeoutError(
                        f"collected {len(got)}/{n_partials} partials in "
                        f"{timeout}s")
                got.extend(self.poll(timeout=min(left, 0.05)))
        except WorkerCrash:
            self._results = got + self._results
            raise
        return got

    def quiesce(self, timeout: float = 5.0,
                agg_ids: Optional[set] = None) -> None:
        """Wait for every open task to close (PARTIAL or EMPTY), then
        force-release stragglers.  Call between rounds so a late EMPTY
        from a zero-update drain can't collide with the next round's
        task under the same agg_id (stale records are seq-guarded).
        ``agg_ids`` scopes the barrier to those tasks only (a rolling
        round closing out while the next one's tasks stay open)."""
        def waiting():
            if agg_ids is None:
                return bool(self._route)
            return any(a in agg_ids for a in self._route)

        deadline = time.perf_counter() + timeout
        while waiting() and time.perf_counter() < deadline:
            try:
                self._scan()
            except Exception:
                pass
            if self._crashes and agg_ids is None:
                # already reaped; round is over.  (Scoped barriers keep
                # the buffer: a crash may belong to the OTHER in-flight
                # round, which still needs to see it via poll().)
                self._crashes.clear()
            if waiting():
                time.sleep(0.001)
        for agg_id in list(self._route):
            if agg_ids is None or agg_id in agg_ids:
                self.release(agg_id)

    def _on_partial(self, w: _Worker, rec: Record) -> PartialResult:
        agg_id = w.agg_id or f"worker{w.idx}"
        exec_s = rec.b / 1e9
        self.stats["partials"] += 1
        w.tasks_done += 1
        # event sidecar: the series the placement capacity model reads
        sidecar = EventSidecar(agg_id, self.metrics)
        sidecar.on_aggregate(int(rec.a), exec_s)
        sidecar.on_send(self.store.meta(rec.key).nbytes)
        prev = self._exec_ewma.get(agg_id)
        self._exec_ewma[agg_id] = (
            exec_s if prev is None else 0.5 * prev + 0.5 * exec_s)
        result = PartialResult(
            agg_id=agg_id, key=rec.key, weight=rec.num_samples,
            count=int(rec.a), exec_s=exec_s, round_id=rec.round_id,
            worker=w.idx,
        )
        # worker spans, derived entirely from records already in
        # flight: task = pickup→publish on the worker's own clock,
        # wait = the TELEM ring-starvation total inside that window
        if w.ack_ts > 0.0 and rec.ts > w.ack_ts:
            self._add_span({
                "kind": "worker.task", "owner": agg_id,
                "round_id": rec.round_id, "t0": w.ack_ts,
                "dur_s": rec.ts - w.ack_ts, "worker": w.idx,
                "n": float(rec.a)})
        if w.wait_s > 0.0:
            self._add_span({
                "kind": "worker.wait", "owner": agg_id,
                "round_id": rec.round_id, "t0": w.ack_ts,
                "dur_s": w.wait_s, "worker": w.idx, "n": float(rec.a)})
        w.ack_ts = 0.0
        w.wait_s = 0.0
        # task complete: route entry dies, worker awaits release/re-task
        self._route.pop(agg_id, None)
        w.agg_id = None
        w.state = "idle"
        return result

    def _add_span(self, d: Dict) -> None:
        if len(self._spans) >= self._spans_cap:
            del self._spans[: self._spans_cap // 2]
        self._spans.append(d)

    def take_spans(self) -> List[Dict]:
        """Return-and-clear the worker span dicts gathered since the
        last take (the runtime wrapper turns them into Span objects)."""
        out, self._spans = self._spans, []
        return out

    def release(self, agg_id: str) -> None:
        """Explicitly park a worker warm (no-op if its task finished —
        publishing a partial already IDLEs it)."""
        w = self._route.pop(agg_id, None)
        if w is not None:
            w.agg_id = None
            w.state = "idle"

    # ------------------------------------------------------------------
    # supervision / teardown
    # ------------------------------------------------------------------
    def node_exec_time(self, agg_id: str, default: float = 1.0) -> float:
        """EWMA'd E_{i,t} for the capacity model (placement.py)."""
        return self._exec_ewma.get(agg_id, default)

    def idle_count(self) -> int:
        return sum(1 for w in self._workers if w.state == "idle")

    def worker_pids(self) -> Dict[int, int]:
        return {w.idx: w.proc.pid for w in self._workers
                if w.state != "dead"}

    def health(self) -> Dict[str, int]:
        """Live pool gauges for the ``stats`` scrape: worker states and
        total ring occupancy (tasks pushed but not yet drained)."""
        busy = parked = depth = 0
        for w in self._workers:
            if w.state in ("busy", "warming"):
                busy += 1
            elif w.state == "idle":
                parked += 1
            for ring in (w.task_ring, w.result_ring):
                try:
                    depth += len(ring)
                except (TypeError, ValueError, OSError):
                    pass
        return {"workers": len(self._workers), "workers_busy": busy,
                "workers_parked": parked, "ring_depth": depth}

    def _reap(self, w: _Worker) -> None:
        """A worker died mid-task: reclaim every segment it created
        (its object keys start with ``<widx:02x>``) and its rings."""
        self.stats["crashes"] += 1
        w.state = "dead"
        if w.agg_id is not None:
            self._route.pop(w.agg_id, None)
        reclaimed = self.reclaim_worker_segments(w.idx)
        self.metrics.update(f"worker{w.idx}", "crash_segments_reclaimed",
                            float(reclaimed))

    def reclaim_worker_segments(self, widx: int) -> int:
        """Unlink /dev/shm segments created by worker ``widx`` (its
        object keys start ``w<idx>``; gateway keys are pure hex, so the
        prefix can't false-positive on a live update object)."""
        pat = f"{self.prefix}-w{widx & 0xff:02x}"
        n = 0
        shm_dir = "/dev/shm"
        if os.path.isdir(shm_dir):
            for name in os.listdir(shm_dir):
                if name.startswith(pat):
                    if unlink_segment(name):
                        n += 1
        return n

    def shutdown(self, timeout: float = 10.0) -> None:
        """Graceful drain: SHUTDOWN every worker, join, then unlink all
        runtime segments (rings + any stranded objects)."""
        if self._closed:
            return
        self._closed = True
        for w in self._workers:
            if w.state != "dead" and w.proc is not None:
                try:
                    w.task_ring.push(
                        Record(kind=RecordKind.SHUTDOWN).pack(), timeout=1.0)
                except Exception:
                    pass
        deadline = time.perf_counter() + timeout
        for w in self._workers:
            if w.proc is None:
                continue
            w.proc.join(timeout=max(0.1, deadline - time.perf_counter()))
            if w.proc.is_alive():
                w.proc.terminate()
                w.proc.join(timeout=2.0)
        for w in self._workers:
            for ring in (w.task_ring, w.result_ring):
                if ring is not None:
                    try:
                        ring.unlink()
                    except Exception:
                        pass
            for bell in (w.task_ring.data_bell, w.task_ring.space_bell,
                         w.result_ring.data_bell, w.result_ring.space_bell):
                if bell is not None:
                    bell.close()
        self._workers.clear()
        self._route.clear()
        self.store.close()

    def _atexit(self) -> None:
        try:
            self.shutdown(timeout=2.0)
        except Exception:
            pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()


def _tag16(agg_id: str) -> str:
    """Squeeze an aggregator id into the 16-char key field (a stable
    routing tag, not a store key)."""
    s = "".join(c for c in agg_id if c.isalnum())[:16]
    return s or new_object_key()
