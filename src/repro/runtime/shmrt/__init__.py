"""shmrt — the multi-process, event-driven aggregation runtime.

The paper's §4.2/App-A data plane, realized on one node: aggregator
*worker processes* connected by lock-free SPSC shared-memory rings that
carry nothing but 16-byte object keys (+ auxiliary info A_i^k), with
payloads resident in the shared-memory object store and accumulator
scratch allocated *inside* the store so intermediate aggregates are
published zero-copy.  See README.md in this package for the
architecture sketch.
"""
from repro.runtime.shmrt.dispatcher import ShmRuntime, WorkerCrash
from repro.runtime.shmrt.messages import Record, RecordKind
from repro.runtime.shmrt.ring import Doorbell, SpscRing
from repro.runtime.shmrt.shmengine import ShmAccumulatorEngine

__all__ = [
    "Doorbell",
    "Record",
    "RecordKind",
    "ShmAccumulatorEngine",
    "ShmRuntime",
    "SpscRing",
    "WorkerCrash",
]
