"""Aggregation engine whose accumulator lives in the object store.

The PR-1 blocked engine, with one change: ``begin`` allocates the fp32
accumulator *inside* a shared-memory object (``store.alloc``) instead
of the process heap.  The worker folds updates into it in place (same
cache-tiled hot loop), and when the aggregation goal is met the
accumulator is published with :meth:`publish` — ``seal`` writes the
object header, ``disown`` hands cleanup to the dispatcher, and the
16-byte key goes up the result ring.  The parent then folds this
partial straight out of the store: the intermediate aggregate is never
copied, serialized, or re-queued (paper §4.2: shared-memory processing
between hierarchical aggregators on one node).

Warm reuse: the scratch tile survives across tasks like any blocked
engine.  The accumulator segment is surrendered on publish (it *is*
the published object), so each task allocates one fresh segment — the
§5.3 warm-start win in the multi-process runtime is the resident
process + rings + scratch, measured by ``bench_shmrt``.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.engine import BLOCK_ELEMS, BlockedNumpyEngine
from repro.core.objectstore import SharedMemoryObjectStore


class ShmAccumulatorEngine(BlockedNumpyEngine):
    name = "shm_blocked"

    def __init__(self, store: SharedMemoryObjectStore,
                 block_elems: int = BLOCK_ELEMS,
                 key_prefix: str = "") -> None:
        super().__init__(block_elems)
        self.store = store
        self.key_prefix = key_prefix
        self._key: Optional[str] = None

    def _new_key(self) -> str:
        """Worker-tagged object key: the first chars identify the
        creating worker, so the dispatcher can reclaim a SIGKILLed
        worker's segments by name prefix."""
        import secrets

        from repro.core.objectstore import KEY_BYTES

        n = KEY_BYTES - len(self.key_prefix)
        return self.key_prefix + secrets.token_hex(n // 2)[:n]

    def begin(self, n: int) -> np.ndarray:
        if (self._acc_buf is not None and not self._acc_out
                and self._acc_buf.size == n):
            self._acc_buf.fill(0.0)  # warm: reuse the resident segment
            self._acc_out = True
            return self._acc_buf
        if self._key is not None and not self._acc_out:
            # idle accumulator of the wrong size: hard-unlink it —
            # delete() would park it on the store's free list, which
            # alloc-with-explicit-key (our path) never consults, so the
            # parked segment would be stranded tmpfs until shutdown
            self._acc_buf = None
            self.store.destroy(self._key)
            self._key = None
        key, view = self.store.alloc((n,), np.float32, key=self._new_key())
        view.fill(0.0)
        self.buffer_allocs += 1
        self._key = key
        self._acc_buf = view
        self._acc_out = True
        return view

    @property
    def key(self) -> Optional[str]:
        return self._key

    def publish(self) -> str:
        """Seal + disown the accumulator object; returns its key.

        Zero-copy hand-off: the buffer the folds targeted becomes the
        published partial.  The engine surrenders it — the next
        ``begin`` allocates a fresh segment."""
        assert self._key is not None, "publish() before begin()"
        key = self._key
        self.store.seal(key)
        self.store.disown(key)
        self._key = None
        self._acc_buf = None
        self._acc_out = False
        return key
