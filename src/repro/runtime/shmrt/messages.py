"""Fixed-size ring records: what actually crosses a process boundary.

One slot = one 64-byte record.  The data-plane record is exactly the
paper's envelope — a 16-byte object key plus auxiliary info A_i^k
(round, FedAvg weight c_i^k, enqueue timestamp); control records (task
assignment, drain, shutdown, ack, ready, partial) reuse the same layout
with kind-specific meaning for the scalar fields, so one codec serves
both rings.

Layout (64 bytes, little-endian):
  kind u8 | pad 7 | key 16s | round_id u32 | flags u32 |
  num_samples f64 | ts f64 | a u64 | b u64
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from enum import IntEnum

RECORD_BYTES = 64
_FMT = "<B7x16sIIddQQ"
assert struct.calcsize(_FMT) == RECORD_BYTES


class RecordKind(IntEnum):
    TASK = 1      # dispatcher → worker: key=agg tag, flags=seq,
                  #   a=goal, b=n_elems
    UPDATE = 2    # dispatcher → worker: key=object key, num_samples=c_i^k
    DRAIN = 3     # dispatcher → worker: close out the open task
    SHUTDOWN = 4  # dispatcher → worker: exit the loop (graceful)
    READY = 5     # worker → dispatcher: process up, polling (a=pid)
    ACK = 6       # worker → dispatcher: task picked up (flags=seq, ts=now)
    PARTIAL = 7   # worker → dispatcher: key=partial-sum object, flags=seq,
                  #   num_samples=Σ weight, a=count folded, b=exec ns
    ERROR = 8     # worker → dispatcher: dropped/failed record
    EMPTY = 9     # worker → dispatcher: task closed with nothing folded
                  #   (DRAIN before any update arrived)
    PARTIAL_IN = 10  # dispatcher → worker: fold a published raw partial
                  #   Σ c·u (root fold): key=partial object,
                  #   num_samples=Σ weight, a=subtree update count
    TELEM = 11    # worker → dispatcher: task telemetry at publish time
                  #   (flags=seq, num_samples=ring-wait seconds while
                  #   the task was open, ts=publish ts, a=count folded)
                  #   — rides the same result ring, fired only on the
                  #   publish edge: no polling, no extra syscalls


@dataclass
class Record:
    kind: int
    key: str = ""            # 16-char hex object key / agg tag
    round_id: int = 0
    flags: int = 0
    num_samples: float = 0.0
    ts: float = 0.0          # CLOCK_MONOTONIC (perf_counter) — one host,
                             # comparable across the node's processes
    a: int = 0
    b: int = 0

    def pack(self) -> bytes:
        return struct.pack(
            _FMT, self.kind, self.key.encode("ascii"), self.round_id,
            self.flags, self.num_samples, self.ts, self.a, self.b,
        )

    @classmethod
    def unpack(cls, raw: bytes) -> "Record":
        kind, key, round_id, flags, num_samples, ts, a, b = struct.unpack(
            _FMT, raw[:RECORD_BYTES])
        return cls(
            kind=kind, key=key.rstrip(b"\0").decode("ascii"),
            round_id=round_id, flags=flags, num_samples=num_samples,
            ts=ts, a=a, b=b,
        )
