"""Lock-free SPSC rings over shared memory — the eBPF-proxy analog.

The paper's lightweight proxy replaces a per-function message broker
with an in-kernel sockmap redirect: the only thing that moves between
functions on a node is a 16-byte object key (§4.2, App-A).  The
host-side analog is a single-producer/single-consumer ring in a shared
memory segment: fixed-size slots, a producer-owned head counter and a
consumer-owned tail counter on separate cache lines, no locks.

Correctness model (x86-64 / CPython): each counter has exactly one
writer; 8-byte aligned loads/stores through a ``memoryview`` cast are
single machine accesses, and the GIL's memory fences on bytecode
boundaries give the release/acquire ordering a C implementation would
get from atomics.  The producer writes the slot *then* bumps head; the
consumer reads head *then* the slot.

Blocking: an ``eventfd``-backed :class:`Doorbell` gives epoll-style
wakeups (the SKMSG notify analog — the paper's event-driven "no
polling" property).  Where ``os.eventfd`` is unavailable the doorbell
degrades to a bounded-backoff sleep poll (the futex/condvar fallback),
with the same API and the same observable semantics, just worse tail
latency.

Ring layout (bytes):
  [0:8)    magic  b"LIFLRING"
  [8:12)   slot_size u32
  [12:16)  nslots    u32
  [64:72)  head  u64   (producer cache line)
  [128:136) tail u64   (consumer cache line)
  [192:..) slots
"""
from __future__ import annotations

import os
import select
import struct
import time
from typing import List, Optional

from repro.core.objectstore import (
    attach_segment,
    create_segment,
    unlink_segment,
    untrack_segment,
)

_MAGIC = b"LIFLRING"
_HDR_FMT = "<8sII"
_HEAD_OFF = 64
_TAIL_OFF = 128
_DATA_OFF = 192

HAVE_EVENTFD = hasattr(os, "eventfd")


class Doorbell:
    """Cross-process wakeup: ``ring()`` on one side, ``wait()`` on the
    other.  eventfd when the platform has it (fd inherited across
    fork), else a backoff sleep poll."""

    def __init__(self) -> None:
        self._fd = os.eventfd(0, os.EFD_NONBLOCK) if HAVE_EVENTFD else -1

    # -- producer side --------------------------------------------------
    def ring(self) -> None:
        if self._fd >= 0:
            try:
                os.eventfd_write(self._fd, 1)
            except BlockingIOError:
                pass  # counter saturated: the sleeper is already woken

    # -- consumer side --------------------------------------------------
    def wait(self, timeout: Optional[float]) -> bool:
        """Block up to ``timeout`` s for a ring.  Returns True on a
        wakeup, False on timeout.  The caller re-checks its condition
        either way (wakeups can be spurious/coalesced)."""
        if self._fd >= 0:
            r, _, _ = select.select([self._fd], [], [],
                                    timeout if timeout is not None else None)
            if r:
                try:
                    os.eventfd_read(self._fd)  # drain the counter
                except BlockingIOError:
                    pass
                return True
            return False
        # fallback: bounded sleep (condvar-less poll)
        time.sleep(min(timeout if timeout is not None else 0.001, 0.001))
        return False

    def drain(self) -> None:
        if self._fd >= 0:
            try:
                os.eventfd_read(self._fd)
            except BlockingIOError:
                pass

    def fileno(self) -> int:
        """-1 when the fallback (no eventfd) is active — callers that
        multiplex over several doorbells must skip those."""
        return self._fd

    def close(self) -> None:
        if self._fd >= 0:
            try:
                os.close(self._fd)
            except OSError:
                pass
            self._fd = -1


class SpscRing:
    """Single-producer single-consumer ring of fixed-size slots.

    One side constructs with ``create=True`` (owns the segment and
    unlinks it); the other attaches by name — or, under fork, simply
    inherits the object (the mmap is shared either way).
    """

    def __init__(self, name: str, slot_size: int = 64, nslots: int = 1024,
                 *, create: bool = False,
                 data_bell: Optional[Doorbell] = None,
                 space_bell: Optional[Doorbell] = None):
        if create:
            size = _DATA_OFF + slot_size * nslots
            self._seg = create_segment(name, size)
            struct.pack_into(_HDR_FMT, self._seg.buf, 0,
                             _MAGIC, slot_size, nslots)
            self._owner = True
        else:
            self._seg = attach_segment(name)
            magic, slot_size, nslots = struct.unpack_from(
                _HDR_FMT, self._seg.buf, 0)
            if magic != _MAGIC:
                raise ValueError(f"segment {name!r} is not a LIFL ring")
            self._owner = False
        self.name = name
        self.slot_size = int(slot_size)
        self.nslots = int(nslots)
        self._q = self._seg.buf.cast("Q")  # u64 lattice over the segment
        self._buf = self._seg.buf
        # data_bell: producer rings after push (consumer sleeps on it);
        # space_bell: consumer rings after pop (backpressured producer
        # sleeps on it)
        self.data_bell = data_bell
        self.space_bell = space_bell

    # -- counters (single-writer each) ----------------------------------
    @property
    def _head(self) -> int:
        return self._q[_HEAD_OFF // 8]

    @_head.setter
    def _head(self, v: int) -> None:
        self._q[_HEAD_OFF // 8] = v

    @property
    def _tail(self) -> int:
        return self._q[_TAIL_OFF // 8]

    @_tail.setter
    def _tail(self, v: int) -> None:
        self._q[_TAIL_OFF // 8] = v

    def __len__(self) -> int:
        return self._head - self._tail

    @property
    def capacity(self) -> int:
        return self.nslots

    def full(self) -> bool:
        return len(self) >= self.nslots

    # -- producer -------------------------------------------------------
    def push(self, payload: bytes, *, timeout: Optional[float] = None) -> bool:
        """Write one slot.  Full ring: returns False immediately when
        ``timeout is None``, else blocks up to ``timeout`` s for space
        (backpressure).  Payload must fit a slot."""
        if len(payload) > self.slot_size:
            raise ValueError(f"payload {len(payload)}B > slot {self.slot_size}B")
        if self.full():
            if timeout is None:
                return False
            deadline = time.perf_counter() + timeout
            while self.full():
                left = deadline - time.perf_counter()
                if left <= 0:
                    return False
                if self.space_bell is not None:
                    self.space_bell.wait(min(left, 0.05))
                else:
                    time.sleep(0.0002)
        head = self._head
        off = _DATA_OFF + (head % self.nslots) * self.slot_size
        self._buf[off:off + len(payload)] = payload
        self._head = head + 1          # publish after the slot is written
        if self.data_bell is not None:
            self.data_bell.ring()
        return True

    # -- consumer -------------------------------------------------------
    def pop(self, *, timeout: Optional[float] = None) -> Optional[bytes]:
        """Read one slot, or None.  ``timeout`` blocks on the data
        doorbell (event-driven idle — no spin while parked warm)."""
        if self._tail >= self._head and timeout is not None:
            deadline = time.perf_counter() + timeout
            while self._tail >= self._head:
                left = deadline - time.perf_counter()
                if left <= 0:
                    break
                if self.data_bell is not None:
                    self.data_bell.wait(min(left, 0.5))
                else:
                    time.sleep(0.0002)
        tail = self._tail
        if tail >= self._head:
            return None
        off = _DATA_OFF + (tail % self.nslots) * self.slot_size
        payload = bytes(self._buf[off:off + self.slot_size])
        self._tail = tail + 1
        if self.space_bell is not None:
            self.space_bell.ring()
        return payload

    def pop_many(self, max_n: int) -> List[bytes]:
        """Drain up to ``max_n`` queued slots without blocking — the
        K-way burst the batched engine fold consumes."""
        out: List[bytes] = []
        while len(out) < max_n:
            rec = self.pop()
            if rec is None:
                break
            out.append(rec)
        return out

    # -- lifecycle ------------------------------------------------------
    def close(self) -> None:
        # release the memoryview casts before closing the mmap
        try:
            self._q.release()
        except Exception:
            pass
        try:
            self._seg.close()
        except BufferError:
            pass

    def unlink(self) -> None:
        self.close()
        untrack_segment(self.name)
        unlink_segment(self.name)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        if self._owner:
            self.unlink()
        else:
            self.close()
