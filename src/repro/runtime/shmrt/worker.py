"""Aggregator worker process: the step-based function body (App-G).

One worker = one homogenized aggregator runtime, parked warm between
tasks.  The loop is strictly event-driven: blocked on the task ring's
doorbell while idle (no polling), woken per record:

  TASK     — open an aggregation task: a FedAvgState over the
             shared-memory accumulator engine (scratch stays warm from
             the previous task; this is what makes warm dispatch cheap).
  UPDATE   — Recv∥Agg: drain the ring in K-way bursts and fold through
             the engine, reading payloads zero-copy out of the store;
             when the goal is met the partial sum is published
             (seal+disown, no copy) and a PARTIAL record goes up.
  DRAIN    — close out a short task (stragglers): publish whatever has
             been folded so far.
  SHUTDOWN — graceful exit: surrender buffers, close the store.

The worker only ever touches numpy — no jax in the child (forking a
process with live XLA threads is not safe to re-enter).
"""
from __future__ import annotations

import os
import time
from collections import deque
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.aggregation import FedAvgState
from repro.core.objectstore import SharedMemoryObjectStore
from repro.runtime.shmrt.messages import Record, RecordKind
from repro.runtime.shmrt.ring import SpscRing
from repro.runtime.shmrt.shmengine import ShmAccumulatorEngine

IDLE_TIMEOUT_S = 30.0  # doorbell wait slice while parked


@dataclass
class _OpenTask:
    agg_tag: str
    seq: int
    round_id: int
    goal: int
    n_elems: int
    state: FedAvgState
    folded: int = 0
    exec_ns: int = 0
    wait_ns: int = 0  # blocked-on-ring time while this task was open


def worker_main(widx: int, task_ring: SpscRing, result_ring: SpscRing,
                store_prefix: str, batch_k: int = 8) -> None:
    """Entry point of a forked aggregator worker (never returns)."""
    store = SharedMemoryObjectStore(
        node=f"worker{widx}", prefix=store_prefix)
    # 'w' prefix: dispatcher-generated keys are pure hex, so the crash
    # sweep by this prefix can never match a live gateway object
    engine = ShmAccumulatorEngine(store, key_prefix=f"w{widx & 0xff:02x}")
    task: Optional[_OpenTask] = None

    def publish(t: _OpenTask) -> str:
        key = engine.publish()
        # telemetry rides the publish edge (event-driven: zero cost
        # while parked) and goes up FIRST — the PARTIAL closes the task
        # dispatcher-side, so anything after it loses its agg_id
        result_ring.push(Record(
            kind=RecordKind.TELEM, key=key, round_id=t.round_id,
            flags=t.seq, num_samples=t.wait_ns / 1e9,
            ts=time.perf_counter(), a=t.state.count,
        ).pack(), timeout=5.0)
        # a = updates folded end-to-end: equals t.folded for a mid,
        # and the subtree total for a root task absorbing partials
        result_ring.push(Record(
            kind=RecordKind.PARTIAL, key=key, round_id=t.round_id,
            flags=t.seq, num_samples=t.state.weight,
            ts=time.perf_counter(), a=t.state.count, b=t.exec_ns,
        ).pack(), timeout=5.0)
        return key

    def close_task(t: Optional[_OpenTask], published_key: Optional[str]
                   ) -> None:
        """Drop the task's references, then the disowned accumulator
        mapping: a warm worker must not pin unlinked segments across
        tasks (the dispatcher owns the published object now)."""
        if t is not None:
            t.state.acc = None  # free the view before closing the mmap
        if published_key is not None:
            store.detach(published_key)
        if t is not None and published_key is None:
            # task ended without publishing: hand the accumulator back
            # to the engine's warm buffer instead of leaking its segment
            engine.recycle()

    result_ring.push(Record(
        kind=RecordKind.READY, ts=time.perf_counter(), a=os.getpid(),
    ).pack(), timeout=5.0)

    parent = os.getppid()
    pending: deque = deque()  # control records found mid-burst
    while True:
        if pending:
            rec = pending.popleft()
        else:
            # with a task open, blocked-on-ring is starvation the
            # dispatcher should see (worker.wait); parked-idle is not
            t_wait = time.perf_counter_ns() if task is not None else 0
            raw = task_ring.pop(timeout=IDLE_TIMEOUT_S)
            if task is not None:
                task.wait_ns += time.perf_counter_ns() - t_wait
            if raw is None:
                if os.getppid() != parent:
                    # orphaned: the dispatcher died without sending
                    # SHUTDOWN (SIGKILLed daemon).  Exit through the
                    # normal path so atexit sweeps our segments —
                    # otherwise the orphan pins its inherited fds and
                    # /dev/shm mappings forever
                    break
                continue
            rec = Record.unpack(raw)

        if rec.kind == RecordKind.SHUTDOWN:
            break

        if rec.kind == RecordKind.TASK:
            if task is not None:
                # force-released upstream: close the stale task so its
                # accumulator is reused, not leaked
                close_task(task, None)
            # ACK first: dispatch latency is task-pickup, not the
            # accumulator allocation that follows
            result_ring.push(Record(
                kind=RecordKind.ACK, key=rec.key, flags=rec.flags,
                ts=time.perf_counter(),
            ).pack(), timeout=5.0)
            task = _OpenTask(
                agg_tag=rec.key, seq=rec.flags, round_id=rec.round_id,
                goal=max(int(rec.a), 1), n_elems=rec.b,
                state=FedAvgState(engine=engine),
            )
            task.state._ensure_acc(rec.b)
            continue

        if rec.kind == RecordKind.DRAIN:
            if task is not None and task.folded > 0:
                key = publish(task)
                close_task(task, key)
            elif task is not None:
                result_ring.push(Record(
                    kind=RecordKind.EMPTY, flags=task.seq,
                    round_id=task.round_id, ts=time.perf_counter(),
                ).pack(), timeout=5.0)
                close_task(task, None)
            task = None
            continue

        if rec.kind == RecordKind.PARTIAL_IN:
            # root fold: absorb a published raw partial Σ c·u straight
            # out of the store (zero-copy), in ring order — the
            # dispatcher delivers in plan order, so the fold sequence
            # is deterministic and bit-identical to the controller fold
            if task is None:
                result_ring.push(Record(
                    kind=RecordKind.ERROR, key=rec.key,
                ).pack(), timeout=5.0)
                continue
            t0 = time.perf_counter_ns()
            view = store.get(rec.key)
            task.state.absorb(np.asarray(view), rec.num_samples, int(rec.a))
            del view  # drop the view before detaching the mapping
            store.release(rec.key)
            store.detach(rec.key)  # the dispatcher owns the segment
            task.folded += 1
            task.exec_ns += time.perf_counter_ns() - t0
            if task.folded >= task.goal:
                key = publish(task)
                close_task(task, key)
                task = None
            continue

        if rec.kind == RecordKind.UPDATE:
            if task is None:
                result_ring.push(Record(
                    kind=RecordKind.ERROR, key=rec.key,
                ).pack(), timeout=5.0)
                continue
            # K-way burst: this update plus whatever else is queued
            batch = [rec]
            room = min(batch_k - 1, task.goal - task.folded - 1)
            while room > 0:
                raw = task_ring.pop()
                if raw is None:
                    break
                r = Record.unpack(raw)
                if r.kind != RecordKind.UPDATE:
                    pending.append(r)  # control record: handle after burst
                    break
                batch.append(r)
                room -= 1
            updates, weights = [], []
            t0 = time.perf_counter_ns()
            for r in batch:
                updates.append(store.get(r.key))
                weights.append(r.num_samples)
            task.state.fold_many(updates, weights)
            task.folded += len(updates)
            del updates  # drop the views before detaching the mappings
            for r in batch:
                store.release(r.key)
                store.detach(r.key)  # creator (gateway) owns the segment
            task.exec_ns += time.perf_counter_ns() - t0
            if task.folded >= task.goal:
                key = publish(task)
                close_task(task, key)
                task = None

    store.close()
