"""Typed round events — the one protocol every runtime speaks.

LIFL's control plane is *event-driven*: aggregation progress, scaling,
routing and failure handling are all reactions to events, not calls
into each other.  This module is that protocol, reified: every
state transition that crosses a component boundary (runtime → driver,
driver → handlers, operator → driver) is one of the frozen dataclasses
below, and nothing else.

Design rules:

  * **Immutable** — events are facts about the past; handlers never
    mutate them (``frozen=True``).
  * **Round-scoped or not** — ``round_id`` is ``None`` for events that
    exist outside a round (node churn); the driver's ordering guards
    only apply to round-scoped events (stale-round drops, deadline
    after goal).
  * **Wire-serializable** — ``to_wire``/``from_wire`` round-trip every
    event type through JSON, so the same protocol can later ride the
    multi-node gateway TX path unchanged.

Catalog (see runtime/README.md for the full state machine):

  ``UpdateArrived``   a client/gateway update was delivered to a mid
  ``PartialReady``    a subtree published its partial sum (key in store)
  ``PartialShipped``  a sealed partial moved daemon→daemon to the root
  ``TopFolded``       the round's root fold completed (plan's root site)
  ``GoalReached``     the round's aggregation goal n was met
  ``WorkerCrashed``   an aggregator worker died mid-task (shmproc)
  ``NodeJoined``      a worker node joined the cluster
  ``NodeLost``        a worker node left / was lost
  ``NodeRejoined``    a restarted daemon was re-adopted (epoch bump)
  ``RoundDeadline``   the round's wall-clock budget expired
  ``ScaleDecision``   the elastic controller re-sized the hierarchy
  ``RoundOpened``     a (possibly rolling) round started accepting work
  ``UpdateShed``      the ingress gateway refused an update (backpressure)
  ``SLOBreached``     a job's SLO was violated on sustained live scrapes
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from typing import Dict, Optional, Type


@dataclass(frozen=True)
class RoundEvent:
    """Base class: every event may carry the round it belongs to.

    ``round_id=None`` marks an event that is not scoped to a round
    (node churn, scale decisions between rounds); the driver's
    stale-round guard ignores those."""

    round_id: Optional[int] = None


@dataclass(frozen=True)
class UpdateArrived(RoundEvent):
    """One model update landed at its middle aggregator (Recv step)."""

    client_id: str = ""
    node: str = ""
    agg_id: str = ""
    key: str = ""          # 16-byte object-store key (payload stays put)
    weight: float = 0.0    # c_i^k — the FedAvg weight


@dataclass(frozen=True)
class PartialReady(RoundEvent):
    """A subtree published its raw partial sum Σ c·u into the store."""

    agg_id: str = ""
    key: str = ""
    weight: float = 0.0    # Σ c over the subtree
    count: int = 0         # updates folded into this partial
    exec_s: float = 0.0    # aggregation execution time E_{i,t}
    worker: int = -1       # worker index (-1: in-process)


@dataclass(frozen=True)
class PartialShipped(RoundEvent):
    """A sealed partial Σ c·u was shipped daemon→daemon to the round's
    root node (node-top topology) instead of returning to the
    controller — the wire cost this event carries is exactly what the
    locality root choice minimizes."""

    agg_id: str = ""       # the root fold the partial feeds
    key: str = ""
    src: str = ""          # shipping node
    dst: str = ""          # root node
    nbytes: int = 0
    wire_s: float = 0.0    # measured serialize+send wall on the src daemon


@dataclass(frozen=True)
class TopFolded(RoundEvent):
    """The round's root fold completed at the plan's root site."""

    agg_id: str = ""
    node: str = ""         # where the root fold ran
    tier: str = ""         # 'controller' | 'worker' | 'node'
    count: int = 0         # updates folded end-to-end
    weight: float = 0.0    # Σ c over the round
    exec_s: float = 0.0    # measured root fold exec — feeds the RC model


@dataclass(frozen=True)
class GoalReached(RoundEvent):
    """The aggregation goal n (Eq. 1) was met; stragglers are ignored."""

    goal: int = 0
    accepted: int = 0


@dataclass(frozen=True)
class WorkerCrashed(RoundEvent):
    """An aggregator worker process died mid-task; its unpublished
    folds are lost but the dispatched update objects survive in the
    store (the driver re-dispatches them — see RoundDriver)."""

    agg_id: str = ""
    worker: int = -1
    exitcode: Optional[int] = None


@dataclass(frozen=True)
class NodeJoined(RoundEvent):
    node: str = ""
    capacity: float = 0.0


@dataclass(frozen=True)
class NodeLost(RoundEvent):
    node: str = ""


@dataclass(frozen=True)
class NodeRejoined(RoundEvent):
    """A daemon restarted under its old node name was re-adopted: the
    welcome handshake's epoch counter bumped, the dead epoch's
    residency/partial bookkeeping is gone, and the node is placeable
    again (the coordinator re-enters it into the RC capacity model)."""

    node: str = ""
    epoch: int = 0         # the NEW epoch (the daemon's start stamp)
    old_epoch: int = 0     # what the controller had recorded
    capacity: float = 0.0


@dataclass(frozen=True)
class RoundDeadline(RoundEvent):
    """The round's wall-clock budget expired.  Fired at most once per
    round, and ignored if the goal was already reached."""

    deadline_s: float = 0.0


@dataclass(frozen=True)
class RoundOpened(RoundEvent):
    """A round began accepting dispatches.  Under the rolling-round
    scheduler this fires while the previous round's fold is still in
    flight — the overlap window between consecutive ``RoundOpened`` /
    ``TopFolded`` pairs is the pipeline gain the serve layer measures."""

    job: str = ""          # '' = the single-job (library) path
    goal: int = 0


@dataclass(frozen=True)
class UpdateShed(RoundEvent):
    """The ingress gateway refused a submission: the job's quota (or
    the global ingress budget) was full.  Never a silent drop — the
    pusher got a ``busy`` reply carrying ``retry_after_s`` and is
    expected to come back."""

    job: str = ""
    client_id: str = ""
    retry_after_s: float = 0.0
    queued: int = 0        # queue depth at refusal (the pressure signal)


@dataclass(frozen=True)
class ScaleDecision(RoundEvent):
    """The elastic controller re-planned the hierarchy for the load."""

    aggregators_planned: int = 0
    nodes: int = 0
    levels: int = 0
    direction: str = "hold"   # 'up' | 'down' | 'hold'


@dataclass(frozen=True)
class SLOBreached(RoundEvent):
    """A job's service-level objective was violated on *sustained*
    live scrapes (FleetMonitor → SLOTracker): the measured p99 TTA or
    shed fraction exceeded its target for ``window`` consecutive
    scrapes.  Not round-scoped (``round_id=None``): the breach is a
    property of the service, and fires at most once per sustained
    episode."""

    job: str = ""
    metric: str = ""       # 'p99_tta_s' | 'shed_frac'
    measured: float = 0.0
    target: float = 0.0
    window: int = 0        # consecutive violating scrapes


#: name → class registry; the wire codec and tests iterate this.
EVENT_TYPES: Dict[str, Type[RoundEvent]] = {
    cls.__name__: cls
    for cls in (
        UpdateArrived, PartialReady, PartialShipped, TopFolded,
        GoalReached, WorkerCrashed, NodeJoined, NodeLost, NodeRejoined,
        RoundDeadline, RoundOpened, UpdateShed, ScaleDecision,
        SLOBreached,
    )
}


def to_wire(event: RoundEvent) -> bytes:
    """Serialize an event for a process/network boundary (JSON)."""
    name = type(event).__name__
    if name not in EVENT_TYPES:
        raise TypeError(f"not a wire-registered event type: {name}")
    return json.dumps({"event": name, **asdict(event)},
                      separators=(",", ":")).encode("utf-8")


def from_wire(raw) -> RoundEvent:
    """Inverse of :func:`to_wire`; accepts bytes or str."""
    if isinstance(raw, (bytes, bytearray)):
        raw = raw.decode("utf-8")
    d = json.loads(raw)
    name = d.pop("event", None)
    cls = EVENT_TYPES.get(name)
    if cls is None:
        raise ValueError(f"unknown event type on the wire: {name!r}")
    return cls(**d)
