"""RoundDriver: one plan-interpreting loop owns the round lifecycle.

A *runtime* is anything that can host aggregators and speak the event
protocol (`events.py`); the driver never cares whether aggregators are
objects in this process (``InProcRuntime``), forked worker processes
over shared-memory rings (``ShmProcRuntime`` wrapping ``shmrt``), or
daemon-side aggregators across nodes (``netrt.RemoteRuntime``).

The round's aggregation topology is an explicit
:class:`~repro.core.placement.FoldPlan` the driver *executes* (per
round)::

    PLAN ──▶ SPAWN ──▶ DISPATCH ──▶ COLLECT ──▶ FOLD(root site) ──▶ DONE
                │           │            │            │
                │           ▼            ▼            ▼ root tier:
                │    UpdateArrived  PartialReady   controller | worker | node
                │                   WorkerCrashed  (crash ⇒ re-root on a
                │                   RoundDeadline   surviving node)
                └──────────────────────▶ re-dispatch on crash

The root tier decides where the final fold runs: ``controller`` folds
fetched partials in this process (the legacy topology, bit for bit),
``worker`` spawns the top as a runtime aggregator (a parked worker
process under shmproc), and ``node`` roots the fold on the busiest
worker node — partials ship daemon→daemon and only the folded Σ c·u
returns (~1 × model per round instead of nodes × model).

Semantics every runtime shares, by construction:

  * mids fold in delivery order through the blocked-engine arithmetic
    and publish their **raw partial sum** Σ c·u (not the normalized
    mean) into the object store;
  * the root fold consumes partials sorted by ``agg_id`` — the plan
    fixes the order (explicit seq numbers on the wire), independent of
    completion timing — so every runtime × topology combination
    produces **bit-identical** params (test-asserted over multi-round
    runs);
  * a :class:`~repro.runtime.events.WorkerCrashed` mid-round loses the
    crashed subtree's *unpublished folds only*: the dispatched update
    objects still live in the store, so the driver re-dispatches the
    surviving keys to a fresh/sibling worker and the round still
    reaches its full goal (no quota shrinking);
  * ordering guards: events from finished rounds are dropped, and a
    ``RoundDeadline`` that fires after ``GoalReached`` is ignored.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import (
    Any, Callable, Deque, Dict, Iterable, List, Optional, Protocol, Set,
    Tuple, Type,
)

import numpy as np

from repro.core.aggregation import Aggregator, FedAvgState
from repro.core.engine import make_engine
from repro.core.gateway import UpdateEnvelope
from repro.core.objectstore import InProcObjectStore
from repro.core.placement import (
    FoldPlan, FoldSite, build_fold_plan, engine_key, join_agg_id,
    split_agg_id,
)
from repro.core.sidecar import EventSidecar, MetricsMap
from repro.obs.trace import RoundTrace, Tracer
from repro.runtime.events import (
    GoalReached,
    PartialReady,
    PartialShipped,
    RoundDeadline,
    RoundEvent,
    RoundOpened,
    TopFolded,
    UpdateArrived,
    WorkerCrashed,
)


# ===========================================================================
# the Runtime protocol: what a round host must provide
# ===========================================================================


class Runtime(Protocol):
    """An aggregation runtime the driver can run rounds on.

    The four protocol methods are the entire control surface; the
    concrete classes below add store plumbing (``put_update`` /
    ``get_partial`` / …) that the driver uses for payloads."""

    name: str
    stats: Dict[str, Any]
    metrics: MetricsMap

    def spawn_aggregator(self, agg_id: str, *, goal: int, n_elems: int,
                         round_id: int = 0, kind: str = "mid") -> None: ...

    def deliver(self, agg_id: str, key: str, weight: float,
                round_id: int = 0) -> None: ...

    def deliver_partial(self, agg_id: str, key: str, weight: float,
                        count: int, round_id: int = 0,
                        seq: int = 0) -> None: ...

    def poll_events(self, timeout: float = 0.0) -> List[RoundEvent]: ...

    def quiesce(self, timeout: float = 5.0) -> None: ...


def _partial_alive(rt, key: str) -> bool:
    """Whether a published partial's bytes are still reachable (a node
    death takes its store down with it).  Runtimes without the hook are
    single-node: partials live as long as the process."""
    fn = getattr(rt, "partial_alive", None)
    return True if fn is None else bool(fn(key))


#: feed-protocol sentinel: a cohort feed returns this to declare the
#: round's cohort closed (no more updates will ever arrive for it).
#: Distinct from ``None``, which means "nothing pending *yet*" and
#: keeps a serve-mode round open.
COHORT_CLOSED = object()


def _iter_feed(updates: Iterable) -> Callable[[], Any]:
    """Adapt a plain iterable of ``(node, client_id, flat, weight)``
    tuples to the pull-feed protocol: one item per call, then
    :data:`COHORT_CLOSED` forever."""
    it = iter(updates)

    def feed():
        try:
            return next(it)
        except StopIteration:
            return COHORT_CLOSED

    return feed


def _partial_node(rt, key: str) -> Optional[str]:
    """Which node a published partial physically lives on (None for
    single-node runtimes, where agg ids name logical nodes only)."""
    fn = getattr(rt, "partial_node", None)
    return fn(key) if fn is not None else None


class _WarmEngineMixin:
    """Warm aggregation engines keyed by ``(job, tree-position)``:
    a re-spawned aggregator at the same position re-enters the next
    round with its accumulator/scratch resident (§5.3 at the fold
    level).  The agg-id's per-round tag is stripped for the pool
    lookup (``placement.engine_key``) so warmth carries across rolling
    rounds, while two jobs sharing a node fleet never share an
    accumulator.  Requires ``self.agg_engine`` and ``self._engines``."""

    def engine_for(self, agg_id: str):
        key = engine_key(agg_id)
        eng = self._engines.get(key)
        if eng is None:
            eng = make_engine(self.agg_engine)
            self._engines[key] = eng
        return eng

    def recycle_engines(self) -> None:
        for eng in self._engines.values():
            eng.recycle()


class InProcRuntime(_WarmEngineMixin):
    """Single-process runtime: aggregators are :class:`Aggregator`
    objects over an in-proc object store."""

    name = "inproc"

    def __init__(self, *, metrics: Optional[MetricsMap] = None,
                 agg_engine: Any = "auto", eager: bool = True,
                 node: str = "inproc"):
        self.metrics = metrics if metrics is not None else MetricsMap()
        self.store = InProcObjectStore(node)
        self.agg_engine = agg_engine
        self.eager = eager
        self._engines: Dict[str, Any] = {}
        self._open: Dict[str, Tuple[Aggregator, int]] = {}
        self._events: Deque[RoundEvent] = deque()
        self.stats = {"cold_starts": 0, "warm_starts": 0, "crashes": 0}
        self._closed = False

    # -- protocol -------------------------------------------------------
    def spawn_aggregator(self, agg_id: str, *, goal: int, n_elems: int,
                         round_id: int = 0, kind: str = "mid") -> None:
        if agg_id in self._open:
            raise ValueError(f"{agg_id!r} already has an open task")
        # warm = an engine is already resident at this (job, position)
        key = "warm_starts" if engine_key(agg_id) in self._engines \
            else "cold_starts"
        self.stats[key] += 1
        agg = Aggregator(
            agg_id, self.store, goal, eager=self.eager,
            sidecar=EventSidecar(agg_id, self.metrics),
            engine=self.engine_for(agg_id),
            on_complete=lambda *_args, a=agg_id: self._publish(a),
        )
        self._open[agg_id] = (agg, round_id)

    def _publish(self, agg_id: str) -> None:
        """Goal met: publish the raw partial sum Σ c·u into the store
        (one copy — the in-proc analogue of the shm seal+disown)."""
        agg, round_id = self._open.pop(agg_id)
        key = self.store.put(np.asarray(agg.state.acc, dtype=np.float32))
        self._events.append(PartialReady(
            round_id=round_id, agg_id=agg_id, key=key,
            weight=agg.state.weight, count=agg.state.count,
            exec_s=agg.agg_exec_s, worker=-1))

    def deliver(self, agg_id: str, key: str, weight: float,
                round_id: int = 0) -> None:
        agg, _ = self._open[agg_id]
        agg.recv(UpdateEnvelope(key, round_id, agg_id, weight,
                                enqueue_ts=time.perf_counter()))

    def deliver_partial(self, agg_id: str, key: str, weight: float,
                        count: int, round_id: int = 0, seq: int = 0) -> None:
        agg, _ = self._open[agg_id]
        agg.recv_partial(key, weight, count)

    def partial_alive(self, key: str) -> bool:
        return self.store.contains(key)

    def drain(self, agg_id: str) -> None:
        """Close out a short/lazy task: fold whatever is queued and
        publish, or retire the task empty."""
        entry = self._open.get(agg_id)
        if entry is None:
            return  # already published (eager goal met) — no-op
        agg, _ = entry
        if agg.state.count > 0 or agg.fifo:
            agg.goal = agg.state.count + len(agg.fifo)
            agg.flush()
            if not agg.done:
                agg._send()
        else:
            self._open.pop(agg_id, None)  # EMPTY closure: nothing folded

    def poll_events(self, timeout: float = 0.0) -> List[RoundEvent]:
        evs = list(self._events)
        self._events.clear()
        if not evs and timeout > 0:
            time.sleep(min(timeout, 0.05))  # nothing pending: don't spin
        return evs

    def quiesce(self, timeout: float = 5.0,
                round_id: Optional[int] = None) -> None:
        # a published-but-unabsorbed partial would strand its store
        # object (the exception path can abandon queued events).
        # ``round_id`` scopes the barrier to one in-flight round
        # (rolling rounds): the other round's open tasks and queued
        # events survive it.
        keep: Deque[RoundEvent] = deque()
        for ev in self._events:
            if round_id is not None \
                    and getattr(ev, "round_id", None) != round_id:
                keep.append(ev)
                continue
            if isinstance(ev, PartialReady):
                self.store.delete(ev.key)
        self._events = keep
        if round_id is None:
            self._open.clear()
        else:
            self._open = {a: (agg, rid) for a, (agg, rid)
                          in self._open.items() if rid != round_id}

    # -- payload plumbing ----------------------------------------------
    def put_update(self, flat: np.ndarray) -> str:
        return self.store.put(flat)

    def update_alive(self, key: str) -> bool:
        return self.store.contains(key)

    def get_partial(self, key: str) -> np.ndarray:
        return self.store.get(key)

    def release_partial(self, key: str) -> None:
        self.store.release(key)

    def discard_partial(self, key: str) -> None:
        self.store.delete(key)

    def discard_update(self, key: str) -> None:
        self.store.delete(key)

    def worker_count(self) -> int:
        return 0

    def health(self) -> Dict[str, int]:
        """Pool gauges for live scrapes: inproc has no worker pool, so
        only the open-aggregator count is meaningful."""
        return {"workers": 0, "workers_busy": 0, "workers_parked": 0,
                "ring_depth": 0, "open_aggs": len(self._open)}

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._open.clear()
        self._engines.clear()
        self.store.close()


class ShmProcRuntime(_WarmEngineMixin):
    """Multi-process runtime: a thin event adapter over
    :class:`repro.runtime.shmrt.ShmRuntime` — mids are forked worker
    processes, partials are sealed shm objects, crashes surface as
    :class:`WorkerCrashed` events instead of exceptions."""

    name = "shmproc"

    def __init__(self, *, metrics: Optional[MetricsMap] = None,
                 agg_engine: Any = "auto", **rt_kwargs):
        from repro.runtime.shmrt import ShmRuntime, WorkerCrash

        self.metrics = metrics if metrics is not None else MetricsMap()
        self._rt = ShmRuntime(metrics=self.metrics, **rt_kwargs)
        self._crash_cls = WorkerCrash
        self.agg_engine = agg_engine
        self._engines: Dict[str, Any] = {}   # driver-side (top) engines
        self._task_rounds: Dict[str, int] = {}  # open task → its round
        self._round_id = 0
        self._closed = False

    @property
    def store(self):
        return self._rt.store

    @property
    def store_prefix(self) -> str:
        """The /dev/shm name prefix every segment of this runtime lives
        under — the welcome handshake advertises it so a controller can
        sweep a SIGKILLed daemon's leftovers on re-adoption."""
        return self._rt.prefix

    @property
    def stats(self):
        return self._rt.stats

    # -- protocol -------------------------------------------------------
    def spawn_aggregator(self, agg_id: str, *, goal: int, n_elems: int,
                         round_id: int = 0, kind: str = "mid") -> None:
        self._round_id = round_id
        self._task_rounds[agg_id] = round_id
        self._rt.submit_task(agg_id, goal=goal, n_elems=n_elems,
                             round_id=round_id)

    def deliver(self, agg_id: str, key: str, weight: float,
                round_id: int = 0) -> None:
        self._rt.dispatch(agg_id, key, weight, round_id=round_id)

    def deliver_partial(self, agg_id: str, key: str, weight: float,
                        count: int, round_id: int = 0, seq: int = 0) -> None:
        # ring FIFO ⇒ the worker absorbs in dispatch order (plan order)
        self._rt.dispatch_partial(agg_id, key, weight, count,
                                  round_id=round_id)

    def partial_alive(self, key: str) -> bool:
        return self._rt.store.contains(key)

    def drain(self, agg_id: str) -> None:
        self._rt.drain(agg_id)

    def poll_events(self, timeout: float = 0.0) -> List[RoundEvent]:
        evs: List[RoundEvent] = []
        deadline = time.perf_counter() + timeout
        while True:
            left = deadline - time.perf_counter()
            try:
                parts = self._rt.poll(timeout=max(0.0, left) if not evs
                                      else 0.0)
            except self._crash_cls as e:
                evs.append(WorkerCrashed(
                    round_id=self._round_id, agg_id=e.agg_id or "",
                    worker=e.widx, exitcode=e.exitcode))
                continue  # scoop any results buffered behind the crash
            evs.extend(
                PartialReady(round_id=p.round_id, agg_id=p.agg_id,
                             key=p.key, weight=p.weight, count=p.count,
                             exec_s=p.exec_s, worker=p.worker)
                for p in parts)
            return evs

    def quiesce(self, timeout: float = 5.0,
                round_id: Optional[int] = None) -> None:
        if round_id is None:
            self._task_rounds.clear()
            self._rt.quiesce(timeout=timeout)
            return
        # rolling rounds: close out only this round's tasks — the
        # other in-flight round keeps its workers busy
        mine = {a for a, r in self._task_rounds.items() if r == round_id}
        for a in mine:
            self._task_rounds.pop(a, None)
        self._rt.quiesce(timeout=timeout, agg_ids=mine)

    def take_spans(self) -> List["Span"]:
        """Worker-side spans (task pickup→publish, ring-wait) derived
        from records already on the result rings — no extra IPC."""
        from repro.obs.trace import Span

        out: List[Span] = []
        for d in self._rt.take_spans():
            try:
                out.append(Span(
                    kind=d["kind"], owner=d.get("owner", ""),
                    node=self.name, round_id=int(d.get("round_id", 0)),
                    t0=float(d.get("t0", 0.0)),
                    dur_s=float(d.get("dur_s", 0.0)),
                    worker=int(d.get("worker", -1)),
                    n=float(d.get("n", 0.0))))
            except (KeyError, TypeError, ValueError):
                continue
        return out

    # -- payload plumbing ----------------------------------------------
    def put_update(self, flat: np.ndarray) -> str:
        return self._rt.store.put(flat)

    def update_alive(self, key: str) -> bool:
        return self._rt.store.contains(key)

    def get_partial(self, key: str) -> np.ndarray:
        return self._rt.store.get(key)

    def release_partial(self, key: str) -> None:
        self._rt.store.release(key)

    def discard_partial(self, key: str) -> None:
        # the dispatcher owns published partials (disowned by workers)
        self._rt.store.destroy(key)

    def discard_update(self, key: str) -> None:
        self._rt.store.delete(key)  # parks the segment for recycling

    def worker_count(self) -> int:
        return len(self._rt.worker_pids())

    def health(self) -> Dict[str, int]:
        return self._rt.health()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._rt.shutdown()


def make_runtime(spec: Any, *, metrics: Optional[MetricsMap] = None,
                 agg_engine: Any = "auto", eager: bool = True,
                 **kwargs) -> Any:
    """Resolve a runtime spec: an instance passes through, a name
    builds one (``"inproc"`` | ``"shmproc"``)."""
    if not isinstance(spec, str):
        return spec
    if spec == "inproc":
        return InProcRuntime(metrics=metrics, agg_engine=agg_engine,
                             eager=eager, **kwargs)
    if spec == "shmproc":
        return ShmProcRuntime(metrics=metrics, agg_engine=agg_engine,
                              **kwargs)
    raise ValueError(f"unknown runtime {spec!r} "
                     "(expected 'inproc' or 'shmproc')")


# ===========================================================================
# the driver
# ===========================================================================


@dataclass
class RoundOutcome:
    """What one driven round produced (runtime-agnostic)."""

    round_id: int
    accepted: int = 0                      # updates that made the round
    delta: Optional[np.ndarray] = None     # flat weighted-mean update
    weight: float = 0.0
    count: int = 0                         # updates folded end-to-end
    crashes: int = 0
    redispatched: int = 0                  # crash-recovery re-dispatches
    deadline_hit: bool = False
    cold_starts: int = 0
    warm_starts: int = 0
    workers: int = 0
    exec_s: Dict[str, float] = field(default_factory=dict)  # agg_id → E
    dispatched: Dict[str, int] = field(default_factory=dict)  # node → n
    fold_tier: str = "controller"          # where the root fold ran
    root_node: str = ""                    # which node rooted the round
    # updates the dispatch loop PULLED from the cohort generator but
    # never delivered (deadline expired mid-cohort, subtree given up,
    # node already full).  Pulling IS the client's training — dropping
    # these on the floor silently loses externally submitted updates,
    # so the trainer requeues its externals from here (the locally
    # trained ones are regenerable and stay dropped, as before).
    skipped: List[Tuple[str, str, np.ndarray, float]] = \
        field(default_factory=list)


@dataclass
class _RoundState:
    """Mutable per-round bookkeeping threaded through the loop."""

    round_id: int
    n_elems: int
    out: RoundOutcome
    sent: Dict[str, List[Tuple[str, float]]]      # agg_id → delivered keys
    partials: Dict[str, PartialReady]
    spawn_goals: Dict[str, int] = field(default_factory=dict)
    lost: Set[str] = field(default_factory=set)   # subtrees given up
    attempts: Dict[str, int] = field(default_factory=dict)  # re-dispatches
    plan: Optional[FoldPlan] = None
    deadline: Optional[float] = None              # absolute perf_counter
    # runtime-side root fold in flight (worker/node tiers)
    top_id: Optional[str] = None
    top_partial: Optional[PartialReady] = None
    top_crashed: bool = False
    # deep (fanout-capped) plans: the inner fold stages in flight —
    # their PartialReady results are intercepted like the root's
    pending_tops: Set[str] = field(default_factory=set)
    top_results: Dict[str, PartialReady] = field(default_factory=dict)
    deep_crashed: bool = False
    # first-dispatch stamp per subtree (dispatch → PartialReady spans)
    first_dispatch: Dict[str, float] = field(default_factory=dict)
    # rolling-round bookkeeping: the owning job, the plan's agg-id tags
    # (mirrored onto re-rooted top ids), which phase the round is in,
    # and whether its event absorption is in draining mode — the event
    # router needs the latter when it absorbs a cross-round event on
    # behalf of the OTHER in-flight round
    job: str = ""
    tag_job: str = ""
    tag_rid: Optional[int] = None
    phase: str = "open"
    draining: bool = False


class RoundDriver:
    """The single round loop; also the event bus components hang off.

    Handlers subscribe per event type with :meth:`on` (subscribe to
    :class:`RoundEvent` for a catch-all); anything — the elastic
    controller, the coordinator, user code via ``Session.emit`` — can
    inject events with :meth:`dispatch`.  Ordering guards live here:
    stale-round events are dropped and a deadline after the goal is
    ignored, whoever emits them."""

    def __init__(self, runtime: Optional[Any] = None, *,
                 metrics: Optional[MetricsMap] = None,
                 redispatch_limit: int = 3,
                 tracer: Optional[Tracer] = None,
                 trace_sink: Optional[Callable[[RoundTrace], None]] = None,
                 max_open_rounds: int = 1):
        self.runtime = runtime
        self.metrics = metrics if metrics is not None else (
            runtime.metrics if runtime is not None else MetricsMap())
        # event-edge tracing (obs/): on by default — the enabled path is
        # FATAL-gated < 2% overhead (bench_obs); pass a disabled Tracer
        # to opt out entirely
        self.tracer = tracer if tracer is not None else Tracer(enabled=True)
        self.trace_sink = trace_sink
        self.last_trace: Optional[RoundTrace] = None
        # crash recovery gives up on a subtree after this many respawns
        # (a deterministic crasher must not hang the round)
        self.redispatch_limit = int(redispatch_limit)
        # rolling rounds: how many rounds may be open at once.  1 is
        # the library default (begin_round refuses nesting, as ever);
        # the serve scheduler runs with 2 so round N+1's dispatch can
        # overlap round N's fold.
        self.max_open_rounds = int(max_open_rounds)
        self._handlers: Dict[Type[RoundEvent],
                             List[Callable[[RoundEvent], None]]] = {}
        # per-round lifecycle state (was a single _open_round/_goal
        # global): rid → goal-reached flag, plus the in-flight round
        # states the event router targets
        self._open_rounds: Dict[int, bool] = {}
        self._inflight: Dict[int, _RoundState] = {}
        self._next_round = 0
        self.stats = {
            "events_dispatched": 0, "stale_dropped": 0,
            "deadline_ignored": 0, "crashes": 0, "redispatched": 0,
        }

    # ------------------------------------------------------------------
    # event bus
    # ------------------------------------------------------------------
    def on(self, event_type: Type[RoundEvent],
           handler: Callable[[RoundEvent], None]) -> None:
        """Subscribe ``handler`` to an event type (or ``RoundEvent``
        for every event)."""
        self._handlers.setdefault(event_type, []).append(handler)

    def dispatch(self, event: RoundEvent) -> bool:
        """Route one event through the ordering guards and handlers.
        Returns ``False`` when a guard dropped it."""
        rid = event.round_id
        if rid is not None and rid < self._next_round \
                and rid not in self._open_rounds \
                and not isinstance(event, PartialShipped):
            # leftovers from a finished round: drop, whoever sent them.
            # With rolling rounds the horizon alone isn't enough — a
            # round can close out of order while an earlier-numbered
            # one is still in flight, so membership in the open set
            # keeps a live round's events deliverable.  PartialShipped
            # is exempt: it is pure telemetry (mutates no round state)
            # pushed async by a *remote* daemon, so it routinely loses
            # the race with its own round's close-out — dropping it
            # would make observed ship counts flap
            self.stats["stale_dropped"] += 1
            return False
        if isinstance(event, RoundDeadline) and self._open_rounds.get(rid):
            # goal already reached for that round: the deadline is moot
            self.stats["deadline_ignored"] += 1
            return False
        if isinstance(event, GoalReached) and rid in self._open_rounds:
            self._open_rounds[rid] = True
        self.stats["events_dispatched"] += 1
        for etype in (type(event), RoundEvent):
            for fn in self._handlers.get(etype, ()):
                fn(event)
        return True

    # alias for external injectors (Session.emit, operators, tests)
    emit = dispatch

    # ------------------------------------------------------------------
    # round lifecycle bookkeeping (public so tests can drive the guards)
    # ------------------------------------------------------------------
    def begin_round(self, round_id: int) -> None:
        if round_id in self._open_rounds:
            raise RuntimeError(f"round {round_id} already open")
        if len(self._open_rounds) >= self.max_open_rounds:
            raise RuntimeError(
                f"round {min(self._open_rounds)} still open")
        self._open_rounds[round_id] = False

    def end_round(self, round_id: int) -> None:
        self._open_rounds.pop(round_id, None)
        self._next_round = max(self._next_round, round_id + 1)

    def abort_round(self, round_id: int) -> None:
        """The round failed before completing: close it WITHOUT
        advancing the stale-round horizon, so a retry under the same
        ``round_id`` isn't guard-dropped (runtime-level seq guards
        already fence the aborted round's late records)."""
        self._open_rounds.pop(round_id, None)

    # ------------------------------------------------------------------
    # the round loop
    # ------------------------------------------------------------------
    def run_round(
        self,
        *,
        round_id: int,
        assignment: Dict[str, List[int]],
        updates: Iterable[Tuple[str, str, np.ndarray, float]],
        goal: int,
        n_elems: int,
        top_node: Optional[str] = None,
        deadline_s: Optional[float] = None,
        fold_plan: Optional[FoldPlan] = None,
        job: str = "",
    ) -> RoundOutcome:
        """Drive one round: spawn the planned mids, pump ``updates``
        (``(node, client_id, flat, weight)`` tuples — typically a lazy
        generator whose iteration *is* the client training) until the
        goal, collect every counted subtree's partial (re-dispatching
        around crashes), and execute the plan's root fold.  Returns the
        outcome; the caller applies the server optimizer.

        ``fold_plan`` makes the aggregation topology explicit (see
        :class:`~repro.core.placement.FoldPlan`); without one, a
        controller-top plan is derived from ``assignment`` +
        ``top_node`` — the legacy behavior, bit for bit.

        This is the synchronous wrapper over :meth:`open_round`: the
        handle is stepped to completion in place, which reproduces the
        historical single-round loop exactly."""
        return self.open_round(
            round_id=round_id, assignment=assignment, updates=updates,
            goal=goal, n_elems=n_elems, top_node=top_node,
            deadline_s=deadline_s, fold_plan=fold_plan, job=job).run()

    def open_round(
        self,
        *,
        round_id: int,
        assignment: Dict[str, List[int]],
        updates: Any,
        goal: int,
        n_elems: int,
        top_node: Optional[str] = None,
        deadline_s: Optional[float] = None,
        fold_plan: Optional[FoldPlan] = None,
        job: str = "",
    ) -> "RoundHandle":
        """Open a round and return its resumable :class:`RoundHandle`
        — the rolling-round seam.  ``updates`` is either the usual
        iterable of ``(node, client_id, flat, weight)`` tuples, or a
        zero-arg *feed* callable returning one such tuple per call,
        ``None`` when nothing is pending yet (serve mode keeps the
        round open and hands control back), or :data:`COHORT_CLOSED`
        to close the cohort (a pluggable close-out policy lives inside
        the feed)."""
        rt = self.runtime
        if rt is None:
            raise RuntimeError("RoundDriver has no runtime attached")
        self.begin_round(round_id)
        if fold_plan is None:
            fold_plan = build_fold_plan(assignment, top_node=top_node,
                                        topology="controller")
        out = RoundOutcome(round_id=round_id)
        st = _RoundState(round_id=round_id, n_elems=n_elems, out=out,
                         sent={}, partials={}, plan=fold_plan, job=job)
        if fold_plan.root:
            _kind, st.tag_job, st.tag_rid, _node = split_agg_id(
                fold_plan.root)
            st.job = st.job or st.tag_job
        stats0 = {k: rt.stats.get(k, 0)
                  for k in ("cold_starts", "warm_starts")}
        self._inflight[round_id] = st
        tok_round = self.tracer.begin("round", owner="driver",
                                      round_id=round_id)
        self.dispatch(RoundOpened(round_id=round_id, job=st.job, goal=goal))
        gen = self._round_gen(st, rt, updates=updates, goal=goal,
                              top_node=top_node, deadline_s=deadline_s)
        return RoundHandle(self, st, gen, tok_round, stats0)

    def _quiesce_runtime(self, round_id: Optional[int] = None) -> None:
        """Park the runtime after a round.  With no other round in
        flight this is the legacy full barrier; while another round is
        open the barrier is scoped to ``round_id`` so the other
        round's open aggregators and queued events survive it."""
        rt = self.runtime
        if rt is None:
            return
        others = [r for r in self._inflight if r != round_id]
        if round_id is None or not others:
            rt.quiesce()
            return
        try:
            rt.quiesce(round_id=round_id)
        except TypeError:  # a runtime without the scoped barrier
            rt.quiesce()

    def _finish_trace(self, tok_round: int, round_id: int,
                      out: RoundOutcome, rt, completed: bool,
                      job: str = "") -> None:
        """Close the round span and merge this round's samples — driver
        spans, runtime-derived worker spans, and whatever per-daemon
        telemetry the quiesce edge drained — into one RoundTrace."""
        tr = self.tracer
        round_span = tr.end(tok_round, n=float(out.accepted))
        if round_span is not None:
            # per-job TTA distribution — what the SLO tracker reads
            self.metrics.observe("tta", job or "_", round_span.dur_s)
        if not tr.enabled:
            return
        spans = tr.drain()
        take_spans = getattr(rt, "take_spans", None)
        if take_spans is not None:
            try:
                spans.extend(take_spans())
            except Exception:
                pass
        if self._inflight:
            # rolling rounds share one tracer: the other in-flight
            # round's finished spans go back on the buffer (its own
            # close-out will claim them) and its open begins survive —
            # no reset while anything is still measuring
            other = [s for s in spans
                     if s.round_id is not None and s.round_id != round_id]
            spans = [s for s in spans
                     if s.round_id is None or s.round_id == round_id]
            for s in other:
                tr.add(s)
        else:
            tr.reset()                  # exception paths leave begins open
        telemetry: Dict[str, Dict[str, list]] = {}
        take_telem = getattr(rt, "take_telemetry", None)
        if take_telem is not None:
            # fold-phase samples (partial ship, node-side top fold,
            # fetch) land AFTER the quiesce drain: one on-demand pull
            # per round scoops them so each trace is self-contained
            pull = getattr(rt, "pull_telemetry", None)
            if pull is not None:
                try:
                    pull()
                except Exception:
                    pass
            try:
                telemetry = take_telem()
            except Exception:
                telemetry = {}
        trace = RoundTrace(
            round_id=round_id,
            wall_s=round_span.dur_s if round_span is not None else 0.0,
            spans=spans, telemetry=telemetry,
            meta={"completed": completed, "accepted": out.accepted,
                  "count": out.count, "crashes": out.crashes,
                  "fold_tier": out.fold_tier, "root_node": out.root_node,
                  "job": job, "runtime": getattr(rt, "name", "?")})
        self.last_trace = trace
        if self.trace_sink is not None:
            try:
                self.trace_sink(trace)
            except Exception:
                pass

    def _round_gen(self, st: "_RoundState", rt, *, updates, goal,
                   top_node, deadline_s):
        """The round body as a generator: each ``yield`` is a point the
        round can pause at (and names the phase it paused in).  Driven
        straight through by :meth:`RoundHandle.run` this is the legacy
        loop, operation for operation; interleaved by the rolling
        scheduler it pauses after every dispatch/collect increment so
        another round can make progress on the same driver."""
        out = st.out
        round_id = st.round_id
        fold_plan = st.plan
        tr = self.tracer
        traced = tr.enabled
        # --- SPAWN: one mid per planned fold site ----------------------
        st.phase = "spawn"
        tok = tr.begin("spawn", owner="driver", round_id=round_id)
        planned = {s.node: s.goal for s in fold_plan.mids}
        mid_ids = {s.node: s.agg_id for s in fold_plan.mids}
        for node, k in planned.items():
            rt.spawn_aggregator(mid_ids[node], goal=k, n_elems=st.n_elems,
                                round_id=round_id)
            st.spawn_goals[mid_ids[node]] = k
            st.sent[mid_ids[node]] = []
        tr.end(tok, n=float(len(planned)))
        yield "spawn"

        dispatched = {node: 0 for node in planned}
        accepted = 0
        deadline = (time.perf_counter() + deadline_s) if deadline_s else None
        st.deadline = deadline

        def fire_deadline() -> None:
            # the wall-clock budget always closes the round; the
            # ordering guard only decides whether handlers see the
            # RoundDeadline event (ignored once the goal was met)
            if not out.deadline_hit:
                self.dispatch(RoundDeadline(round_id=round_id,
                                            deadline_s=deadline_s))
                out.deadline_hit = True

        # --- DISPATCH: pump updates until the aggregation goal ---------
        # the pump is manually iterated so the two sub-costs the TTA
        # breakdown needs stay separable: pulling the feed IS the
        # client's local training; put+deliver is the wire/store edge
        st.phase = "dispatch"
        tok = tr.begin("dispatch", owner="driver", round_id=round_id)
        train_s = deliver_s = 0.0
        pulls = delivers = 0
        feed = updates if callable(updates) else _iter_feed(updates)
        while True:
            _t = time.perf_counter() if traced else 0.0
            item = feed()
            if item is COHORT_CLOSED:
                break
            if item is None:
                # nothing pending yet (serve mode): surface runtime
                # events, hand control back, come around
                self._route(rt.poll_events(0.0), st, draining=False)
                yield "dispatch"
                continue
            node, client_id, flat, weight = item
            if traced:
                train_s += time.perf_counter() - _t
                pulls += 1
            if deadline is not None and time.perf_counter() > deadline:
                # budget expired mid-cohort: stop pumping — but the
                # update already pulled from the feed is real work;
                # record it so the owner can requeue it
                out.skipped.append((node, client_id, flat, weight))
                fire_deadline()
                break
            agg_id = mid_ids.get(node)
            if (agg_id is None or agg_id in st.lost
                    or dispatched[node] >= planned[node]):
                # nothing planned / subtree given up / node full
                out.skipped.append((node, client_id, flat, weight))
                continue
            _t = time.perf_counter() if traced else 0.0
            key = rt.put_update(flat)
            rt.deliver(agg_id, key, weight, round_id=round_id)
            if traced:
                now = time.perf_counter()
                deliver_s += now - _t
                delivers += 1
                st.first_dispatch.setdefault(agg_id, now)
            st.sent[agg_id].append((key, weight))
            dispatched[node] += 1
            accepted += 1
            self.dispatch(UpdateArrived(
                round_id=round_id, client_id=client_id, node=node,
                agg_id=agg_id, key=key, weight=weight))
            # opportunistic: surface partials/crashes while clients train
            self._route(rt.poll_events(0.0), st, draining=False)
            if accepted >= goal:
                break
            yield "dispatch"
        if traced:
            tr.point("client_train", train_s, owner="driver",
                     round_id=round_id, parent=tok, n=float(pulls))
            tr.point("deliver", deliver_s, owner="driver",
                     round_id=round_id, parent=tok, n=float(delivers))
        if accepted >= goal:
            self.dispatch(GoalReached(round_id=round_id, goal=goal,
                                      accepted=accepted))
        out.accepted = accepted
        out.dispatched = dict(dispatched)
        tr.end(tok, n=float(accepted))

        # --- COLLECT: close out stragglers, wait for counted subtrees --
        st.phase = "collect"
        st.draining = True
        tok = tr.begin("collect", owner="driver", round_id=round_id)
        counted = {mid_ids[node] for node in planned if dispatched[node]}
        for agg_id in mid_ids.values():
            rt.drain(agg_id)  # no-op if the task already published
        while (counted - st.lost) - set(st.partials):
            expired = deadline is not None and time.perf_counter() > deadline
            # on expiry, one last non-blocking sweep picks up partials
            # that already published before the budget ran out
            self._route(rt.poll_events(timeout=0.0 if expired else 0.05),
                        st, draining=True)
            if expired:
                fire_deadline()
                counted = set(st.partials)  # close with what we have
                break
            yield "collect"
        with tr.span("quiesce", owner="driver", round_id=round_id,
                     parent=tok):
            self._quiesce_runtime(round_id)
        tr.end(tok, n=float(len(st.partials)))

        # --- FOLD: execute the plan's root site ------------------------
        st.phase = "fold"
        # the rolling seam: the scheduler opens round N+1 the first
        # time round N pauses here — its SPAWN/DISPATCH overlap this
        # round's root fold
        yield "fold"
        tok = tr.begin("fold", owner="driver", round_id=round_id)
        order = sorted(set(st.partials) & counted)
        if order:
            root = fold_plan.site(fold_plan.root) if fold_plan.root \
                else None
            tier = root.tier if root is not None else "controller"
            folded = False
            if (root is not None and fold_plan.inners
                    and hasattr(rt, "deliver_partial")):
                folded = yield from self._fold_deep(st, rt, order, root)
            if (not folded and tier != "controller"
                    and hasattr(rt, "deliver_partial")):
                folded = yield from self._fold_on_runtime(
                    st, rt, order, root)
            if not folded:
                # re-collected subtrees keep their agg_ids, so the
                # counted set still names every foldable partial
                self._fold_in_controller(
                    st, rt, sorted(set(st.partials) & counted),
                    root.node if root is not None else top_node)
        tr.end(tok, n=float(len(order)))

    # ------------------------------------------------------------------
    # root-fold execution (plan interpretation)
    # ------------------------------------------------------------------
    def _fold_in_controller(self, st: "_RoundState", rt, order: List[str],
                            top_node: Optional[str]) -> None:
        """The controller-tier root fold: pull every partial to this
        process and fold sorted by agg_id — the legacy topology, kept
        bit for bit (and the fallback when a runtime-side fold gives
        up)."""
        out = st.out
        order = [a for a in order
                 if _partial_alive(rt, st.partials[a].key)]
        if not order:
            return
        top = top_node or order[0].split("@", 1)[-1]
        # the plan's job/round tags ride the top id too: warm-engine
        # pools stay per-job (engine_for strips the round tag), and the
        # TopFolded below is attributable to its job.  Untagged plans
        # produce the historical "top@node" byte for byte.
        top_id = join_agg_id("top", st.tag_job, st.tag_rid, top)
        engine = rt.engine_for(top_id)
        state = FedAvgState(engine=engine)
        state._ensure_acc(st.n_elems)
        sidecar = EventSidecar("top", self.metrics)
        t0 = time.perf_counter()
        for agg_id in order:
            p = st.partials[agg_id]
            view = rt.get_partial(p.key)   # zero-copy shm view
            state.acc = engine.add_partial(state.acc, view)
            state.weight += p.weight
            state.count += p.count
            rt.release_partial(p.key)
            out.exec_s[agg_id] = p.exec_s
        engine.sync(state.acc)
        fold_dt = time.perf_counter() - t0
        sidecar.on_aggregate(len(order), fold_dt)
        out.delta, out.weight = state.result()
        out.count = state.count
        sidecar.on_send(out.delta.nbytes)
        out.fold_tier, out.root_node = "controller", top
        if self.tracer.enabled:
            self.tracer.point(
                "fold.mid", sum(st.partials[a].exec_s for a in order),
                owner="driver", round_id=st.round_id, n=float(len(order)))
            self.tracer.point("fold.top", fold_dt, owner=top_id,
                              node=top, round_id=st.round_id, t0=t0,
                              n=float(len(order)))
        self.dispatch(TopFolded(
            round_id=st.round_id, agg_id=top_id, node=top,
            tier="controller", count=out.count, weight=out.weight,
            exec_s=fold_dt))

    def _fold_on_runtime(self, st: "_RoundState", rt, order: List[str],
                         root: FoldSite) -> bool:
        """Execute the plan's root fold *inside the runtime* — the top
        aggregator is a runtime aggregator on the root node (a parked
        worker process under shmproc; a daemon-side aggregator, fed by
        daemon→daemon partial shipping, under netrt), and only its
        folded Σ c·u comes back to the controller.

        Partials are delivered in sorted-agg_id order with an explicit
        sequence number, so the fold order — and therefore the bits —
        match the controller-tier fold exactly.  A dead root (node
        loss, spawn/ship failure) re-roots the round on the busiest
        surviving node, re-collecting any partials that died with the
        root, up to ``redispatch_limit`` attempts; returns False to
        fall back to a controller-side fold.

        A generator (driven via ``yield from`` inside the round body):
        both wait loops pause, so a rolling round N+1 keeps dispatching
        while this round's shipped partials fold remotely."""
        out = st.out
        want = set(order)
        root_node = root.node
        for attempt in range(self.redispatch_limit + 1):
            # 1. partials that died with their node: re-dispatch those
            # subtrees from their staged update keys and re-collect
            dead = [a for a in sorted(want) if a in st.partials
                    and not _partial_alive(rt, st.partials[a].key)]
            for a in dead:
                st.partials.pop(a)
                self._redispatch(
                    WorkerCrashed(round_id=st.round_id, agg_id=a),
                    st, draining=True)
            while (want - st.lost) - set(st.partials):
                expired = (st.deadline is not None
                           and time.perf_counter() > st.deadline)
                if expired:
                    break
                self._route(rt.poll_events(timeout=0.05), st,
                            draining=True)
                yield "fold"
            if st.deadline is not None \
                    and time.perf_counter() > st.deadline:
                # budget already gone: don't spawn a root and ship
                # model-size partials only to abandon the fold — close
                # controller-side with what's at hand
                return False
            live = sorted(
                a for a in (want - st.lost) & set(st.partials)
                if _partial_alive(rt, st.partials[a].key))
            if not live:
                return False
            # 2. root placement: keep the planned root while a partial
            # still lives there; otherwise re-root on the busiest
            # surviving node (largest folded count, name tie-break)
            homes = {a: (_partial_node(rt, st.partials[a].key)
                         or a.split("@", 1)[-1]) for a in live}
            if root_node not in set(homes.values()):
                by_node: Dict[str, int] = {}
                for a, n in homes.items():
                    by_node[n] = by_node.get(n, 0) + st.partials[a].count
                root_node = max(by_node, key=lambda n: (by_node[n], n))
            # a fresh agg_id per attempt: a failed attempt may have left
            # a stale open task under the old id on a surviving daemon.
            # Plan tags (job, rolling round) are mirrored onto the top
            # id; untagged plans keep the historical "top@node" form
            top_id = join_agg_id(
                "top" if attempt == 0 else f"top.{attempt}",
                st.tag_job, st.tag_rid, root_node)
            st.top_id, st.top_partial, st.top_crashed = top_id, None, False
            try:
                rt.spawn_aggregator(top_id, goal=len(live),
                                    n_elems=st.n_elems,
                                    round_id=st.round_id, kind="top")
                for seq, a in enumerate(live):
                    p = st.partials[a]
                    rt.deliver_partial(top_id, p.key, p.weight, p.count,
                                       round_id=st.round_id, seq=seq)
            except BaseException:
                st.top_id = None
                raise  # no live node at all: run_round aborts retriable
            while st.top_partial is None and not st.top_crashed:
                if (st.deadline is not None
                        and time.perf_counter() > st.deadline):
                    break
                self._route(rt.poll_events(timeout=0.05), st,
                            draining=True)
                yield "fold"
            st.top_id = None
            if st.top_partial is not None:
                p = st.top_partial
                view = rt.get_partial(p.key)
                # Σ weight accumulated in the same (sorted) order the
                # controller fold uses, so the division is bit-identical
                w, c = 0.0, 0
                for a in live:
                    w += st.partials[a].weight
                    c += st.partials[a].count
                    out.exec_s[a] = st.partials[a].exec_s
                out.delta = np.asarray(view, dtype=np.float32) \
                    / np.float32(w)
                rt.release_partial(p.key)
                out.weight, out.count = w, c
                out.exec_s[top_id] = p.exec_s
                out.fold_tier, out.root_node = root.tier, root_node
                # the end-of-round sweep reclaims the top's object too
                st.partials[top_id] = p
                if self.tracer.enabled:
                    self.tracer.point(
                        "fold.mid",
                        sum(st.partials[a].exec_s for a in live),
                        owner="driver", round_id=st.round_id,
                        n=float(len(live)))
                    self.tracer.point(
                        "fold.top", p.exec_s, owner=top_id,
                        node=root_node, round_id=st.round_id,
                        worker=p.worker, n=float(len(live)))
                self.dispatch(TopFolded(
                    round_id=st.round_id, agg_id=top_id, node=root_node,
                    tier=root.tier, count=c, weight=w, exec_s=p.exec_s))
                return True
            if st.deadline is not None \
                    and time.perf_counter() > st.deadline:
                return False  # budget expired: fold what's fetchable
            # root crashed: loop — the dead node's partials are filtered
            # and re-collected, and the next attempt re-roots
        return False

    def _fold_deep(self, st: "_RoundState", rt, order: List[str],
                   root: FoldSite):
        """Execute a deep (fanout-capped) plan's inner fold stages as
        runtime aggregators, bottom-up: a stage spawns once every one
        of its child partials is resolved, folds them in sorted-agg_id
        order (explicit seq), and its published partial feeds the next
        level — so a 100-mid round folds through log-depth stages
        instead of one 100-way root fold.

        The root's Σ weight/count are accumulated *flat over the sorted
        leaf partials* — exactly the expression the two-level fold
        evaluates — so the final division is bit-identical to the flat
        plan whenever the partial sums are (integer-valued updates, or
        any fanout that preserves the fold grouping).

        Bails out (``False`` → the flat fallback) on a crashed stage,
        an expired deadline, or a plan leaf that never published — the
        degraded paths stay on the battle-tested flat fold."""
        out = st.out
        plan = st.plan
        leaves = sorted(s.agg_id for s in plan.mids)
        if set(order) != set(leaves):
            return False          # lost subtree / deadline close-out
        resolved: Dict[str, PartialReady] = {
            a: st.partials[a] for a in leaves}
        pending = {s.agg_id: s for s in plan.inners}
        st.top_results, st.deep_crashed = {}, False
        while pending:
            batch = [a for a in sorted(pending)
                     if all(c in resolved for c in pending[a].children)]
            if not batch:
                return False      # malformed plan: no resolvable stage
            st.pending_tops = set(batch)
            try:
                for a in batch:
                    s = pending.pop(a)
                    rt.spawn_aggregator(a, goal=len(s.children),
                                        n_elems=st.n_elems,
                                        round_id=st.round_id, kind="top")
                    for seq, c in enumerate(sorted(s.children)):
                        p = resolved[c]
                        rt.deliver_partial(a, p.key, p.weight, p.count,
                                           round_id=st.round_id, seq=seq)
            except BaseException:
                st.pending_tops = set()
                raise
            while st.pending_tops - set(st.top_results) \
                    and not st.deep_crashed:
                if (st.deadline is not None
                        and time.perf_counter() > st.deadline):
                    st.pending_tops = set()
                    return False
                self._route(rt.poll_events(timeout=0.05), st,
                            draining=True)
                yield "fold"
            st.pending_tops = set()
            if st.deep_crashed:
                return False
            for a in batch:
                p = st.top_results[a]
                resolved[a] = p
                st.partials[a] = p   # end-of-round sweep reclaims it
                out.exec_s[a] = p.exec_s
        # --- the root fold over the final level ------------------------
        final = sorted(root.children)
        if any(a not in resolved for a in final):
            return False
        w, c = 0.0, 0
        for a in leaves:
            w += st.partials[a].weight
            c += st.partials[a].count
            out.exec_s[a] = st.partials[a].exec_s
        if root.tier != "controller":
            st.top_id = root.agg_id
            st.top_partial, st.top_crashed = None, False
            try:
                rt.spawn_aggregator(root.agg_id, goal=len(final),
                                    n_elems=st.n_elems,
                                    round_id=st.round_id, kind="top")
                for seq, a in enumerate(final):
                    p = resolved[a]
                    rt.deliver_partial(root.agg_id, p.key, p.weight,
                                       p.count, round_id=st.round_id,
                                       seq=seq)
            except BaseException:
                st.top_id = None
                raise
            while st.top_partial is None and not st.top_crashed:
                if (st.deadline is not None
                        and time.perf_counter() > st.deadline):
                    break
                self._route(rt.poll_events(timeout=0.05), st,
                            draining=True)
                yield "fold"
            st.top_id = None
            if st.top_partial is None:
                return False      # root crashed/expired: flat fallback
            p = st.top_partial
            view = rt.get_partial(p.key)
            out.delta = np.asarray(view, dtype=np.float32) / np.float32(w)
            rt.release_partial(p.key)
            out.exec_s[root.agg_id] = p.exec_s
            st.partials[root.agg_id] = p
            fold_dt = p.exec_s
        else:
            engine = rt.engine_for(root.agg_id)
            state = FedAvgState(engine=engine)
            state._ensure_acc(st.n_elems)
            sidecar = EventSidecar("top", self.metrics)
            t0 = time.perf_counter()
            for a in final:
                p = st.partials[a]
                view = rt.get_partial(p.key)
                state.acc = engine.add_partial(state.acc, view)
                rt.release_partial(p.key)
            engine.sync(state.acc)
            fold_dt = time.perf_counter() - t0
            sidecar.on_aggregate(len(final), fold_dt)
            state.weight, state.count = w, c
            out.delta, _w = state.result()
            sidecar.on_send(out.delta.nbytes)
        out.weight, out.count = w, c
        out.fold_tier, out.root_node = root.tier, root.node
        if self.tracer.enabled:
            self.tracer.point(
                "fold.mid", sum(st.partials[a].exec_s for a in leaves),
                owner="driver", round_id=st.round_id, n=float(len(leaves)))
            self.tracer.point(
                "fold.inner",
                sum(resolved[s.agg_id].exec_s for s in plan.inners),
                owner="driver", round_id=st.round_id,
                n=float(len(plan.inners)))
            self.tracer.point(
                "fold.top", fold_dt, owner=root.agg_id, node=root.node,
                round_id=st.round_id, n=float(len(final)))
        self.dispatch(TopFolded(
            round_id=st.round_id, agg_id=root.agg_id, node=root.node,
            tier=root.tier, count=c, weight=w, exec_s=fold_dt))
        return True

    # ------------------------------------------------------------------
    def _route(self, events: List[RoundEvent], st: "_RoundState", *,
               draining: bool) -> None:
        """Fold a batch of runtime events into per-round state.  With
        rolling rounds the poll that surfaces an event may belong to
        the OTHER in-flight round — each round-scoped event is absorbed
        into the state of the round it names, under that round's own
        draining mode; everything else lands on the polling round."""
        for ev in events:
            tgt = None
            if ev.round_id is not None and ev.round_id != st.round_id:
                tgt = self._inflight.get(ev.round_id)
            if tgt is not None:
                self._absorb_one(ev, tgt, draining=tgt.draining)
            else:
                self._absorb_one(ev, st, draining=draining)

    def _absorb_one(self, ev: RoundEvent, st: "_RoundState", *,
                    draining: bool) -> None:
        """Fold one runtime event into the round's state."""
        rt = self.runtime
        if isinstance(ev, PartialReady):
            if (st.top_id is not None and ev.agg_id == st.top_id
                    and ev.round_id == st.round_id
                    and st.top_partial is None):
                # the runtime-side root fold published its Σ c·u.
                # Absorbed silently — TopFolded is the public
                # signal: handlers (the coordinator's RC model
                # included) must see the same event stream whatever
                # tier the root ran on, or the next round's
                # placement would diverge between topologies.
                st.top_partial = ev
                return
            if (ev.agg_id in st.pending_tops
                    and ev.round_id == st.round_id
                    and ev.agg_id not in st.top_results):
                # an inner fold stage of a deep plan published its
                # partial — absorbed silently, same as the root above
                st.top_results[ev.agg_id] = ev
                return
            if (ev.round_id != st.round_id or ev.agg_id not in st.sent
                    or ev.agg_id in st.partials):
                # stale leftover (aborted round / force-released
                # task): reclaim the orphan object, don't surface
                self.stats["stale_dropped"] += 1
                rt.discard_partial(ev.key)
                return
            st.partials[ev.agg_id] = ev
            if self.tracer.enabled:
                t0d = st.first_dispatch.get(ev.agg_id)
                if t0d is not None:
                    # dispatch → publish latency for this subtree
                    self.tracer.point(
                        "subtree", time.perf_counter() - t0d,
                        owner=ev.agg_id, round_id=st.round_id,
                        t0=t0d, worker=ev.worker, n=float(ev.count))
            self.dispatch(ev)
        elif isinstance(ev, WorkerCrashed):
            if not self.dispatch(ev):
                # stale leftover from a finished round (the guard
                # counted it): the agg_id may name THIS round's
                # identically-named subtree — re-dispatching it
                # would respawn a healthy mid
                return
            st.out.crashes += 1
            self.stats["crashes"] += 1
            if st.top_id is not None and ev.agg_id == st.top_id:
                # the root fold died (node loss / ship failure):
                # _fold_on_runtime re-roots; nothing to re-dispatch
                st.top_crashed = True
                return
            if ev.agg_id in st.pending_tops:
                # an inner fold stage died: the deep fold bails out
                # and the round falls back to the flat root fold
                st.deep_crashed = True
                return
            self._redispatch(ev, st, draining=draining)
        else:
            self.dispatch(ev)

    def _redispatch(self, ev: WorkerCrashed, st: "_RoundState", *,
                    draining: bool) -> None:
        """Crash recovery: the dead worker's unpublished folds are gone,
        but every update object it was sent still lives (sealed) in the
        store — re-dispatch the surviving keys to a fresh/sibling
        worker so the round reaches its full goal.  A subtree that
        keeps crashing (poisoned update, worker-side OOM) is given up
        after ``redispatch_limit`` attempts so the round can't hang."""
        rt = self.runtime
        agg_id = ev.agg_id
        if not agg_id or agg_id not in st.sent or agg_id in st.partials:
            return  # no expected work died with it (warming fork etc.)
        tries = st.attempts.get(agg_id, 0)
        if tries >= self.redispatch_limit:
            st.lost.add(agg_id)  # deterministic crasher: drop the subtree
            return
        surviving = [(k, w) for k, w in st.sent[agg_id]
                     if rt.update_alive(k)]
        if not surviving and draining:
            st.lost.add(agg_id)  # nothing recoverable: give the subtree up
            return
        # mid-pump a zero-dispatch subtree is still respawned, so later
        # updates for its node keep a live route
        st.attempts[agg_id] = tries + 1
        rt.spawn_aggregator(agg_id, goal=st.spawn_goals[agg_id],
                            n_elems=st.n_elems, round_id=st.round_id)
        for key, weight in surviving:
            rt.deliver(agg_id, key, weight, round_id=st.round_id)
        if draining and len(surviving) < st.spawn_goals[agg_id]:
            rt.drain(agg_id)  # no more arrivals are coming
        if surviving:
            st.out.redispatched += 1
            self.stats["redispatched"] += 1


class RoundHandle:
    """A resumable in-flight round — what :meth:`RoundDriver.open_round`
    returns.  :meth:`step` advances the round one increment and reports
    the phase it paused in (``'spawn' | 'dispatch' | 'collect' |
    'fold' | 'done'``); :meth:`run` steps to completion, which is the
    legacy synchronous ``run_round`` behavior exactly.  The rolling
    scheduler interleaves two handles, opening round N+1 once round N
    first pauses in ``'fold'``."""

    def __init__(self, driver: RoundDriver, st: _RoundState,
                 gen, tok_round: int, stats0: Dict[str, int]):
        self.driver = driver
        self.st = st
        self._gen = gen
        self._tok_round = tok_round
        self._stats0 = stats0
        self.phase = "open"

    @property
    def round_id(self) -> int:
        return self.st.round_id

    @property
    def outcome(self) -> RoundOutcome:
        return self.st.out

    @property
    def done(self) -> bool:
        return self.phase == "done"

    def step(self) -> str:
        """Advance the round to its next pause point; returns the phase
        paused in, or ``'done'`` once the round closed (outcome final)."""
        if self.phase == "done":
            return "done"
        try:
            self.phase = next(self._gen)
        except StopIteration:
            self._finish(completed=True)
        except BaseException:
            # a failing client/handler must not brick the driver: park
            # the runtime (scoped, so a co-open round survives) and
            # close this round retriable, then re-raise
            try:
                self.driver._quiesce_runtime(self.st.round_id)
            except Exception:
                pass
            self._finish(completed=False)
            raise
        return self.phase

    def run(self) -> RoundOutcome:
        """Drive the round to completion in place."""
        while not self.done:
            self.step()
        return self.st.out

    def abort(self) -> RoundOutcome:
        """Close an unfinished round early: release its staged store
        objects and free its driver slot WITHOUT advancing the
        stale-round horizon (a retry may reuse the round id)."""
        if self.done:
            return self.st.out
        self._gen.close()
        try:
            self.driver._quiesce_runtime(self.st.round_id)
        except Exception:
            pass
        self._finish(completed=False)
        return self.st.out

    def _finish(self, completed: bool) -> None:
        # always release the round's store objects and close the
        # round, success or not — same sweep order as ever
        drv, st = self.driver, self.st
        rt = drv.runtime
        for p in st.partials.values():
            try:
                rt.discard_partial(p.key)
            except Exception:
                pass
        for keys in st.sent.values():
            for key, _ in keys:
                try:
                    rt.discard_update(key)
                except Exception:
                    pass
        if completed:
            drv.end_round(st.round_id)
        else:
            drv.abort_round(st.round_id)  # retriable: same rid stays live
        drv._inflight.pop(st.round_id, None)
        drv._finish_trace(self._tok_round, st.round_id, st.out, rt,
                          completed, job=st.job)
        out = st.out
        out.cold_starts = rt.stats.get("cold_starts", 0) \
            - self._stats0["cold_starts"]
        out.warm_starts = rt.stats.get("warm_starts", 0) \
            - self._stats0["warm_starts"]
        out.workers = rt.worker_count()
        self.phase = st.phase = "done"
