"""Elastic scaling + failure handling for the FL service.

LIFL's elasticity story at pod scale:
  * load changes (clients arriving/leaving) → the EWMA planner resizes
    the hierarchy; warm aggregators are reused, idle ones terminated
    (load-proportional resources, Fig 10);
  * node/pod loss → drop the pod from the dp axes, re-plan, restore
    params from the last async checkpoint if the top aggregator's pod
    died; over-provisioned cohorts mean the aggregation goal still
    closes the round;
  * stragglers → rounds close at the aggregation goal n < n_selected;
    late updates are discarded (synchronous FL, §6.2).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.hierarchy import HierarchyPlanner
from repro.core.placement import NodeState
from repro.core.reuse import AggregatorPool


@dataclass
class ArrivalTrace:
    """Synthetic arrival-rate trace like Fig 10(a): hibernating mobile
    clients produce a varying load."""

    base_rate: float
    variability: float = 0.5
    period_rounds: int = 20
    seed: int = 0

    def rate(self, round_id: int) -> float:
        rng = np.random.default_rng((self.seed, round_id))
        wave = 1 + self.variability * np.sin(2 * np.pi * round_id / self.period_rounds)
        noise = rng.uniform(1 - self.variability / 2, 1 + self.variability / 2)
        return max(0.0, self.base_rate * wave * noise)


@dataclass
class ElasticEvent:
    round_id: int
    kind: str           # 'scale_up' | 'scale_down' | 'node_lost' | 'node_joined'
    detail: Dict


class ElasticController:
    """Drives plan→scale→reuse across rounds; tolerates node churn."""

    def __init__(self, nodes: Dict[str, NodeState],
                 planner: Optional[HierarchyPlanner] = None,
                 pool: Optional[AggregatorPool] = None):
        self.nodes = dict(nodes)
        self.planner = planner or HierarchyPlanner()
        self.pool = pool or AggregatorPool()
        self.events: List[ElasticEvent] = []
        self._last_total = 0

    # ------------------------------------------------------------------
    def lose_node(self, node: str, round_id: int) -> None:
        self.nodes.pop(node, None)
        # its aggregators are gone; stateless design means no state sync
        victims = [a for a, i in self.pool.instances.items() if i.node == node]
        for a in victims:
            self.pool.terminate(a)
        self.events.append(ElasticEvent(round_id, "node_lost",
                                        {"node": node, "killed": len(victims)}))

    def join_node(self, node: str, capacity: float, round_id: int) -> None:
        self.nodes[node] = NodeState(node=node, max_capacity=capacity)
        self.events.append(ElasticEvent(round_id, "node_joined", {"node": node}))

    # ------------------------------------------------------------------
    def step(self, round_id: int, expected_updates: float) -> Dict:
        """Re-plan for the expected load; create/terminate instances."""
        if not self.nodes:
            raise RuntimeError("no nodes available")
        per_node = expected_updates / len(self.nodes)
        plan = self.planner.plan({n: per_node for n in self.nodes})
        total = plan.total_aggregators
        if total > self._last_total:
            self.events.append(ElasticEvent(round_id, "scale_up",
                                            {"from": self._last_total, "to": total}))
        elif total < self._last_total:
            self.pool.terminate_idle()
            self.events.append(ElasticEvent(round_id, "scale_down",
                                            {"from": self._last_total, "to": total}))
        self._last_total = total
        return {
            "aggregators_planned": total,
            "nodes": len(self.nodes),
            "levels": plan.levels(),
        }

    # ------------------------------------------------------------------
    # event-protocol face: the controller is an ordinary handler on the
    # round driver (subscribe ``handle`` to NodeJoined/NodeLost) and a
    # ScaleDecision producer (``decide`` wraps ``step``)
    # ------------------------------------------------------------------
    def handle(self, event) -> None:
        """React to a typed runtime event (repro.runtime.events)."""
        from repro.runtime.events import NodeJoined, NodeLost

        rid = event.round_id if event.round_id is not None else 0
        if isinstance(event, NodeLost):
            self.lose_node(event.node, rid)
        elif isinstance(event, NodeJoined):
            self.join_node(event.node, event.capacity or 20.0, rid)

    def decide(self, round_id: int, expected_updates: float):
        """Re-plan and return the result as a :class:`ScaleDecision`
        event, ready for ``driver.dispatch``/``Session.emit``."""
        from repro.runtime.events import ScaleDecision

        before = self._last_total
        st = self.step(round_id, expected_updates)
        after = st["aggregators_planned"]
        direction = ("up" if after > before
                     else "down" if after < before else "hold")
        return ScaleDecision(
            round_id=round_id, aggregators_planned=after,
            nodes=st["nodes"], levels=st["levels"], direction=direction)
