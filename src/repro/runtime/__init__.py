from repro.runtime.elastic import ArrivalTrace, ElasticController, ElasticEvent
from repro.runtime.trainer import (
    ClientRuntime,
    FederatedTrainer,
    FusedFLTrainer,
)

__all__ = [
    "ArrivalTrace",
    "ElasticController",
    "ElasticEvent",
    "ClientRuntime",
    "FederatedTrainer",
    "FusedFLTrainer",
]
