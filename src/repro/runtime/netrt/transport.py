"""Frame transport — length-prefixed JSON + binary blob over sockets.

The multi-node control plane (netd ↔ RemoteRuntime, external clients ↔
``Session.serve``) speaks one frame format on TCP or Unix sockets::

    ┌─────────────┬─────────────┬───────────────┬──────────────┐
    │ json_len u32│ blob_len u32│  JSON body    │  blob bytes  │
    │  (big-end.) │  (big-end.) │  {"kind":...} │  (optional)  │
    └─────────────┴─────────────┴───────────────┴──────────────┘

Control fields ride the JSON body (``kind`` names the frame type; typed
round events are carried verbatim as ``events.to_wire`` dicts under
``kind="event"``); payloads — serialized-once model updates and sealed
partial sums — ride the blob, so a frame is decoded without ever
copying the payload through a JSON string.  Because ``kind`` belongs
to the codec, frame metas must not use it for their own fields (spawn
frames carry ``agg_kind`` instead).

Optional zlib compression (``FrameConn(compress=level)``): outbound
blobs ≥ :data:`COMPRESS_MIN_BYTES` are compressed when that actually
shrinks them — the ``_z`` meta key then carries the raw size, so any
receiver can decode without negotiation; incompressible blobs ship
raw.  ``tx_raw_by_kind``/``rx_raw_by_kind`` track pre-compression
frame sizes next to the wire counters, making the win measurable.

Failure model: every socket error, EOF, or handshake timeout surfaces
as :class:`PeerDead`; callers translate that into a ``NodeLost`` event
(see ``remote.py``).  ``connect`` retries until its deadline so a
controller can start before its daemons finish binding.  Byte counters
(total and per frame kind, both directions) make the wire cost of a
round directly measurable — ``benchmarks/bench_net.py`` gates on them.
"""
from __future__ import annotations

import json
import os
import random
import select
import socket
import struct
import time
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

_HEADER = struct.Struct("!II")
#: sanity bounds: a corrupt/foreign header must not trigger a GB recv
MAX_JSON_BYTES = 1 << 22
MAX_BLOB_BYTES = 1 << 31
_RECV_CHUNK = 1 << 16
#: blobs below this never compress (zlib overhead dominates tiny frames)
COMPRESS_MIN_BYTES = 512


class PeerDead(ConnectionError):
    """The remote end of a frame connection is unreachable (EOF, reset,
    refused, or a hard send/handshake timeout)."""


class Backoff:
    """Jittered exponential backoff — the one retry schedule every
    redial in this package uses (``connect``, netd's peer redials, the
    ``push_update`` client helper, RemoteRuntime's re-adoption probe).

    Delays grow ``base · factor^k`` up to ``cap``, each scaled by a
    uniform jitter in ``[1-jitter, 1+jitter]`` so a fleet of retriers
    never thunders in lockstep.  Deterministic under ``seed`` (tests
    pin schedules); an unseeded instance draws from the process RNG.
    ``deadline_s`` bounds the TOTAL time budget: ``next_delay`` returns
    ``None`` (and ``sleep`` returns ``False``) once sleeping again
    would overrun it, and the last delay is clipped to the remainder.
    """

    def __init__(self, base: float = 0.05, factor: float = 2.0,
                 cap: float = 2.0, jitter: float = 0.25,
                 deadline_s: Optional[float] = None,
                 seed: Optional[int] = None):
        if base <= 0 or factor < 1.0 or not (0.0 <= jitter < 1.0):
            raise ValueError(
                f"bad backoff policy (base={base}, factor={factor}, "
                f"jitter={jitter})")
        self.base = float(base)
        self.factor = float(factor)
        self.cap = float(cap)
        self.jitter = float(jitter)
        self.deadline_s = deadline_s
        self._rng = random.Random(seed)
        self._attempt = 0
        self._raw = self.base               # grown by factor, capped
        self._deadline: Optional[float] = None  # armed at first use

    def _arm(self) -> None:
        if self.deadline_s is not None and self._deadline is None:
            self._deadline = time.perf_counter() + self.deadline_s

    @property
    def attempt(self) -> int:
        """Delays handed out so far."""
        return self._attempt

    def remaining(self) -> Optional[float]:
        """Seconds left in the budget (``None`` = unbounded)."""
        self._arm()
        if self._deadline is None:
            return None
        return max(0.0, self._deadline - time.perf_counter())

    def next_delay(self) -> Optional[float]:
        """The next delay in seconds, or ``None`` when the deadline
        budget is exhausted."""
        self._arm()
        # incremental growth, clamped at the cap — never an overflowing
        # factor**attempt, however long the schedule runs
        raw = min(self.cap, self._raw)
        self._raw = min(self.cap, self._raw * self.factor)
        delay = raw * (1.0 + self.jitter * (2.0 * self._rng.random() - 1.0))
        if self._deadline is not None:
            left = self._deadline - time.perf_counter()
            if left <= 0:
                return None
            delay = min(delay, left)
        self._attempt += 1
        return delay

    def sleep(self) -> bool:
        """Sleep the next delay; ``False`` once the budget is gone (the
        caller's cue to give up and surface the failure)."""
        delay = self.next_delay()
        if delay is None:
            return False
        time.sleep(delay)
        return True

    def hint_delay(self, hint_s: float) -> Optional[float]:
        """A server-supplied pacing hint (a ``busy`` reply's
        ``retry_after_s``): jittered and deadline-clipped like a
        scheduled delay, counted as an attempt, but the exponential
        schedule does NOT advance — backpressure is the server pacing
        the client, not a failure to punish."""
        self._arm()
        delay = max(0.0, float(hint_s)) * (
            1.0 + self.jitter * (2.0 * self._rng.random() - 1.0))
        if self._deadline is not None:
            left = self._deadline - time.perf_counter()
            if left <= 0:
                return None
            delay = min(delay, left)
        self._attempt += 1
        return delay

    def sleep_hint(self, hint_s: float) -> bool:
        """Sleep a server-supplied ``retry_after_s`` hint; ``False``
        once the deadline budget is gone."""
        delay = self.hint_delay(hint_s)
        if delay is None:
            return False
        time.sleep(delay)
        return True

    def __iter__(self) -> Iterator[float]:
        """Yield the schedule (for tests / non-sleeping pacers); ends
        when the deadline budget does, never for an unbounded policy."""
        while True:
            delay = self.next_delay()
            if delay is None:
                return
            yield delay


def resolve_dtype(name: str) -> np.dtype:
    """``np.dtype`` by name, registering ml_dtypes (bfloat16, fp8) on
    demand so bf16 wire updates decode in processes that never imported
    jax."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes  # noqa: F401  (registers the extended dtypes)
        return np.dtype(name)


def parse_addr(addr: str) -> Tuple[int, object]:
    """``"host:port"`` → TCP, ``"unix:/path"`` → AF_UNIX."""
    if addr.startswith("unix:"):
        return socket.AF_UNIX, addr[len("unix:"):]
    host, _, port = addr.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"bad address {addr!r} "
                         "(expected 'host:port' or 'unix:/path')")
    return socket.AF_INET, (host, int(port))


def format_addr(family: int, sockaddr) -> str:
    if family == socket.AF_UNIX:
        return f"unix:{sockaddr}"
    host, port = sockaddr[:2]
    return f"{host}:{port}"


@dataclass
class Frame:
    """One decoded frame: ``kind`` + JSON meta + optional payload."""

    kind: str
    meta: Dict
    blob: bytes = b""


class FrameConn:
    """One frame connection over a connected socket.

    ``recv`` is an incremental parser (partial frames survive across
    calls); ``send`` is a blocking write with a hard timeout.  Both
    raise :class:`PeerDead` on any transport failure, after which the
    connection is closed and unusable."""

    def __init__(self, sock: socket.socket, peer: str = "?",
                 send_timeout: float = 30.0, compress: Any = 0,
                 faults: Any = None, metrics: Any = None):
        sock.setblocking(True)
        try:  # latency matters more than throughput for 64-byte frames
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass  # AF_UNIX
        self._sock: Optional[socket.socket] = sock
        self.peer = peer
        self.send_timeout = send_timeout
        # zlib level for outbound blobs (0 = off).  Sender-only choice:
        # the `_z` meta marker makes every receiver able to decode, so
        # no negotiation is needed.  Incompressible blobs fall back to
        # raw (the marker is only set when compression actually won).
        self.compress = 6 if compress is True else int(compress or 0)
        # deterministic fault injection (faults.FaultPlan): consulted on
        # every outbound frame; None (production) costs one attr check
        self.faults = faults
        # optional core.sidecar.MetricsMap: when set, every outbound
        # frame lands a per-kind serialize+compress+write timing sample
        # (owner "wire") — the SKMSG-hook analogue of the obs layer,
        # fired only on the send edge; None costs one attr check
        self.metrics = metrics
        self._rbuf = bytearray()
        self.tx_bytes = 0
        self.rx_bytes = 0
        self.tx_by_kind: Dict[str, int] = {}
        self.rx_by_kind: Dict[str, int] = {}
        # pre-compression ("raw") frame sizes, per kind, both ways —
        # wire minus raw is the measured compression win
        self.tx_raw_by_kind: Dict[str, int] = {}
        self.rx_raw_by_kind: Dict[str, int] = {}

    # ------------------------------------------------------------------
    def fileno(self) -> int:
        return self._sock.fileno() if self._sock is not None else -1

    @property
    def alive(self) -> bool:
        return self._sock is not None

    def close(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def _dead(self, why: str) -> PeerDead:
        self.close()
        return PeerDead(f"peer {self.peer} gone: {why}")

    # ------------------------------------------------------------------
    def send(self, kind: str, meta: Optional[Dict] = None,
             blob: bytes = b"") -> None:
        """Write one frame (header + JSON + blob, single syscall path).
        ``blob`` may be any buffer (bytes, memoryview, C-contiguous
        numpy array) — it is never copied into the JSON body."""
        if self._sock is None:
            raise PeerDead(f"peer {self.peer} gone: already closed")
        if self.faults is not None:
            action, delay = self.faults.on_send(kind, len(memoryview(
                blob).cast("B")) if not isinstance(blob, bytes) else
                len(blob))
            if action == "drop":
                return  # the frame never reaches the wire
            if action == "reset":
                raise self._dead("fault-injected reset")
            if action == "delay":
                time.sleep(delay)
        t_send = time.perf_counter() if self.metrics is not None else 0.0
        body = dict(meta or {})
        body["kind"] = kind
        mv = memoryview(blob).cast("B") if not isinstance(blob, bytes) \
            else blob
        raw_blob = len(mv)
        if self.compress and raw_blob >= COMPRESS_MIN_BYTES:
            comp = zlib.compress(mv, self.compress)  # buffer proto: no copy
            if len(comp) < raw_blob:
                body["_z"] = raw_blob   # marker + uncompressed size
                mv = comp
        js = json.dumps(body, separators=(",", ":")).encode("utf-8")
        head = _HEADER.pack(len(js), len(mv))
        n = len(head) + len(js) + len(mv)
        # one gathered write per frame: header+meta+blob leave as a
        # single sendmsg, so a frame costs one syscall and one skb —
        # three separate sendalls triple the kernel's per-skb buffer
        # accounting and can wedge a burst of small frames against an
        # unread peer long before the nominal SO_SNDBUF is full
        bufs: List[memoryview] = [memoryview(head), memoryview(js)]
        if len(mv):
            bufs.append(mv if isinstance(mv, memoryview)
                        else memoryview(mv))
        try:
            self._sock.settimeout(self.send_timeout)
            while bufs:
                sent = self._sock.sendmsg(bufs)
                while sent:
                    if sent >= len(bufs[0]):
                        sent -= len(bufs[0])
                        bufs.pop(0)
                    else:
                        bufs[0] = bufs[0][sent:]
                        sent = 0
        except (OSError, ValueError) as e:
            raise self._dead(f"send failed ({e})") from e
        self.tx_bytes += n
        self.tx_by_kind[kind] = self.tx_by_kind.get(kind, 0) + n
        raw_n = len(head) + len(js) + raw_blob
        self.tx_raw_by_kind[kind] = self.tx_raw_by_kind.get(kind, 0) + raw_n
        if self.metrics is not None:
            self.metrics.update("wire", f"tx_{kind}_s",
                                time.perf_counter() - t_send)
            self.metrics.update("wire", f"tx_{kind}_bytes", float(n))

    # ------------------------------------------------------------------
    def _parse_one(self) -> Optional[Frame]:
        buf = self._rbuf
        if len(buf) < _HEADER.size:
            return None
        jlen, blen = _HEADER.unpack_from(buf, 0)
        if jlen > MAX_JSON_BYTES or blen > MAX_BLOB_BYTES:
            raise self._dead(f"oversized frame header ({jlen}/{blen})")
        total = _HEADER.size + jlen + blen
        if len(buf) < total:
            return None
        meta = json.loads(bytes(buf[_HEADER.size:_HEADER.size + jlen]))
        blob = bytes(buf[_HEADER.size + jlen:total])
        del buf[:total]
        kind = meta.pop("kind", "?")
        self.rx_by_kind[kind] = self.rx_by_kind.get(kind, 0) + total
        raw_total = total
        z = meta.pop("_z", None)
        if z is not None:
            z = int(z)
            if z > MAX_BLOB_BYTES:
                raise self._dead(f"oversized compressed blob ({z})")
            try:
                # bound the EXPANSION, not just the declared size — a
                # frame lying about _z must not decompress to GBs
                d = zlib.decompressobj()
                blob = d.decompress(blob, z)
                if d.unconsumed_tail or not d.eof or len(blob) != z:
                    raise self._dead("compressed blob size mismatch")
            except zlib.error as e:
                raise self._dead(f"corrupt compressed blob ({e})") from e
            raw_total = _HEADER.size + jlen + len(blob)
        self.rx_raw_by_kind[kind] = \
            self.rx_raw_by_kind.get(kind, 0) + raw_total
        return Frame(kind=kind, meta=meta, blob=blob)

    def recv(self, timeout: float = 0.0) -> Optional[Frame]:
        """Next frame, or ``None`` if nothing complete arrives within
        ``timeout``.  Raises :class:`PeerDead` on EOF/reset."""
        deadline = time.perf_counter() + timeout
        while True:
            frame = self._parse_one()
            if frame is not None:
                return frame
            if self._sock is None:
                raise PeerDead(f"peer {self.peer} gone: already closed")
            left = deadline - time.perf_counter()
            r, _, _ = select.select([self._sock], [], [], max(0.0, left))
            if not r:
                return None
            try:
                data = self._sock.recv(_RECV_CHUNK)
            except OSError as e:
                raise self._dead(f"recv failed ({e})") from e
            if not data:
                raise self._dead("EOF")
            self._rbuf += data
            self.rx_bytes += len(data)

    def recv_expect(self, kinds: Tuple[str, ...], timeout: float,
                    stash: Optional[List[Frame]] = None) -> Frame:
        """Read until a frame of one of ``kinds`` arrives; unrelated
        frames (event pushes racing a reply) go to ``stash``."""
        deadline = time.perf_counter() + timeout
        while True:
            left = deadline - time.perf_counter()
            if left <= 0:
                raise self._dead(f"timed out waiting for {kinds}")
            frame = self.recv(timeout=left)
            if frame is None:
                continue
            if frame.kind in kinds:
                return frame
            if stash is not None:
                stash.append(frame)

    # ------------------------------------------------------------------
    def ping(self, timeout: float = 5.0,
             stash: Optional[List[Frame]] = None) -> float:
        """Liveness probe: round-trip one ``ping`` frame, returns the
        RTT in seconds (raises :class:`PeerDead` on a dead peer)."""
        t0 = time.perf_counter()
        self.send("ping", {"t": t0})
        self.recv_expect(("pong",), timeout, stash=stash)
        rtt = time.perf_counter() - t0
        if self.metrics is not None:
            observe = getattr(self.metrics, "observe", None)
            if observe is not None:
                observe("wire", "rtt_s", rtt)
        return rtt


class FrameServer:
    """Non-blocking accept loop + frame demux over all connections.

    ``poll`` returns ``(conn, frame)`` pairs; a dying connection yields
    one final ``(conn, None)`` so the owner can unregister it."""

    def __init__(self, addr: str, backlog: int = 16, faults: Any = None,
                 metrics: Any = None):
        family, sockaddr = parse_addr(addr)
        self._family = family
        self.faults = faults   # inherited by every accepted FrameConn
        self.metrics = metrics  # likewise (per-kind tx timings)
        sock = socket.socket(family, socket.SOCK_STREAM)
        if family == socket.AF_INET:
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        sock.bind(sockaddr)
        sock.listen(backlog)
        sock.setblocking(False)
        self._listener = sock
        self._unix_path = sockaddr if family == socket.AF_UNIX else None
        self.addr = format_addr(family, sock.getsockname())
        self.conns: List[FrameConn] = []

    def poll(self, timeout: float = 0.0) -> List[Tuple[FrameConn,
                                                       Optional[Frame]]]:
        out: List[Tuple[FrameConn, Optional[Frame]]] = []
        for conn in list(self.conns):
            if not conn.alive:
                # died on the SEND path (a push hit PeerDead): emit the
                # (conn, None) notification recv-side deaths get, so
                # owners run their disconnect cleanup either way
                self.conns.remove(conn)
                out.append((conn, None))
                continue
            # frames already buffered from a previous read: no select
            self._pump(conn, out, readable=False)
        watch = [self._listener] + [c for c in self.conns if c.alive]
        r, _, _ = select.select(watch, [], [], 0.0 if out else timeout)
        for sock in r:
            if sock is self._listener:
                try:
                    raw, peer_addr = self._listener.accept()
                except OSError:
                    continue
                peer = format_addr(self._family, peer_addr) \
                    if self._family == socket.AF_INET else "unix-peer"
                self.conns.append(FrameConn(raw, peer=peer,
                                            faults=self.faults,
                                            metrics=self.metrics))
            else:
                self._pump(sock, out, readable=True)
        return out

    def _pump(self, conn: FrameConn, out, *, readable: bool) -> None:
        try:
            while True:
                frame = conn.recv(timeout=0.0) if readable \
                    else conn._parse_one()
                if frame is None:
                    return
                out.append((conn, frame))
                readable = False  # drain what's buffered, don't re-select
        except PeerDead:
            if conn in self.conns:
                self.conns.remove(conn)
            out.append((conn, None))

    def close(self) -> None:
        for conn in self.conns:
            conn.close()
        self.conns.clear()
        try:
            self._listener.close()
        except OSError:
            pass
        if self._unix_path:
            try:
                os.unlink(self._unix_path)
            except OSError:
                pass


def connect(addr: str, *, timeout: float = 10.0,
            retry_interval: float = 0.05, peer: Optional[str] = None,
            compress: Any = 0, faults: Any = None,
            backoff: Optional[Backoff] = None,
            metrics: Any = None) -> FrameConn:
    """Connect to a frame server, retrying until ``timeout`` — a
    controller may race its daemons' bind.  Retries follow the shared
    jittered-exponential :class:`Backoff` schedule (``retry_interval``
    is its base; ``timeout`` its total deadline), so a refused port is
    probed densely at first and gently once it looks genuinely down."""
    family, sockaddr = parse_addr(addr)
    bo = backoff if backoff is not None else Backoff(
        base=retry_interval, cap=max(retry_interval, 0.5),
        deadline_s=timeout)
    deadline = time.perf_counter() + timeout
    while True:
        sock = socket.socket(family, socket.SOCK_STREAM)
        try:
            sock.settimeout(max(0.1, deadline - time.perf_counter()))
            sock.connect(sockaddr)
            return FrameConn(sock, peer=peer or addr, compress=compress,
                             faults=faults, metrics=metrics)
        except (ConnectionError, FileNotFoundError, socket.timeout,
                OSError) as e:
            sock.close()
            if not bo.sleep():
                raise PeerDead(f"connect to {addr} failed: {e}") from e
