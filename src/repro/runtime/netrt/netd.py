"""netd — the per-node daemon of the multi-node transport.

One netd process runs on each worker node.  It owns that node's *local*
aggregation runtime — ``InProcRuntime`` or (the real deployment)
``ShmProcRuntime`` with its forked workers and shared-memory rings —
and exposes it over the frame transport (``transport.py``): a
controller's :class:`~repro.runtime.netrt.remote.RemoteRuntime` speaks
the same ``spawn``/``deliver``/``drain``/``quiesce`` verbs the
``RoundDriver`` already uses, and typed round events travel back as
``events.to_wire`` JSON riding ``event`` frames.

Data-plane contract (the reason this layer exists):

  * a leaf update is serialized **once**, at the node boundary — the
    ``deliver`` frame's blob lands in the node's object store under the
    controller-chosen key, and every intra-node hop after that is the
    usual zero-copy shared-memory path;
  * a re-delivery of a key the store already holds (crash re-dispatch
    to the same node) ships **no blob** — just the 16-byte key;
  * only the sealed partial Σ c·u leaves the node, when the controller
    ``fetch``es it for the top fold: one model-size payload per node
    per round.

Run it::

    python -m repro.runtime.netrt.netd --node nodeA \
        --listen 127.0.0.1:0 --runtime shmproc --port-file /tmp/a.addr

The daemon is single-threaded: one loop multiplexes the socket server
and the local runtime's event queue.  SIGTERM/SIGINT drain gracefully
(the local runtime shuts down, shm segments are unlinked).
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import time
import weakref
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core.sidecar import MetricsMap, series_flatten
from repro.runtime.driver import make_runtime
from repro.runtime.events import (
    PartialReady,
    PartialShipped,
    RoundEvent,
    WorkerCrashed,
    to_wire,
)
from repro.runtime.netrt.faults import FaultPlan
from repro.runtime.netrt.transport import (
    Backoff,
    Frame,
    FrameConn,
    FrameServer,
    PeerDead,
    connect,
    resolve_dtype,
)

PROTO_VERSION = 1

# shmproc aggregator workers are fork()ed while the daemon holds its
# listening socket and every accepted/peer connection.  Without
# intervention the workers inherit those fds: SIGKILL the daemon and
# the orphaned workers keep the port bound (a same-port restart can't
# bind → re-adoption is impossible) and keep the controller's TCP
# connections ESTABLISHED (dead-peer EOF never fires).  Every live
# daemon registers here and an at-fork hook closes its sockets in the
# child, so only the daemon process itself ever owns them.
_LIVE_DAEMONS: "weakref.WeakSet" = weakref.WeakSet()


def _at_fork_close_daemon_sockets() -> None:
    for d in list(_LIVE_DAEMONS):
        try:
            d._close_inherited_sockets()
        except Exception:
            pass


os.register_at_fork(after_in_child=_at_fork_close_daemon_sockets)


class NodeDaemon:
    """One node's frame-server front end over a local runtime."""

    def __init__(self, node: str, listen: str = "127.0.0.1:0", *,
                 runtime: str = "inproc", agg_engine: str = "auto",
                 capacity: float = 20.0, poll_interval: float = 0.02,
                 compress: int = 0, fault_plan: Optional[FaultPlan] = None):
        self.node = node
        self.capacity = float(capacity)
        self.poll_interval = poll_interval
        self.compress = int(compress)
        # the re-adoption epoch: a start stamp unique across restarts
        # of this node name.  The welcome handshake carries it, so a
        # controller re-dialing a known name can tell "same daemon,
        # transient disconnect" from "fresh process, empty store".
        self.epoch = time.time_ns()
        self.t0_mono = time.perf_counter()   # uptime for live scrapes
        self.faults = fault_plan
        # the per-daemon MetricsMap — the paper's in-kernel metric map,
        # now actually living in the remote process: the local runtime's
        # sidecars, every outbound frame's per-kind timing (FrameConn),
        # and the ship/fetch/land samples below all land here, and the
        # controller drains it over the wire (quiesce / telemetry frame)
        self.metrics = MetricsMap()
        self.rt = make_runtime(runtime, agg_engine=agg_engine,
                               metrics=self.metrics)
        self.server = FrameServer(listen, faults=fault_plan,
                                  metrics=self.metrics)
        self.addr = self.server.addr
        self._controllers: List[FrameConn] = []
        # node-top state: open root folds buffering their inputs until
        # all `goal` partials arrived (controller `deliver` + peer
        # `partial` frames race — the seq numbers fix the fold order),
        # cached peer connections, and peer-shipped copies to reclaim
        self._tops: Dict[str, Dict] = {}
        self._peers: Dict[str, FrameConn] = {}
        self._peer_landed: Set[str] = set()
        # keys whose lifetime the CONTROLLER owns: landed update blobs
        # and published-but-unfetched partials.  Swept when the last
        # controller disconnects — its delivered-set died with it, so
        # nothing will ever discard them over the wire.
        self._landed: Set[str] = set()
        self._published: Set[str] = set()
        self._stop = False
        self._closed = False
        self.stats = {"frames": 0, "events_pushed": 0, "updates_landed": 0,
                      "redelivered_keys": 0, "partials_served": 0,
                      "partials_shipped": 0, "ship_tx_bytes": 0,
                      "partials_landed": 0, "ship_rx_bytes": 0}
        _LIVE_DAEMONS.add(self)

    # ------------------------------------------------------------------
    def _close_inherited_sockets(self) -> None:
        """Runs in a freshly fork()ed child (shmproc worker): close the
        socket fds the child inherited so the daemon process is their
        sole owner — see the at-fork hook above."""
        try:
            self.server._listener.close()
        except OSError:
            pass
        for c in list(self.server.conns) + list(self._peers.values()):
            try:
                c._sock.close()
            except OSError:
                pass

    # ------------------------------------------------------------------
    def step(self, timeout: Optional[float] = None) -> None:
        """One loop iteration: demux frames, push local runtime events."""
        for conn, frame in self.server.poll(
                self.poll_interval if timeout is None else timeout):
            if frame is None:  # peer went away (recv- or send-side)
                self._drop_controller(conn)
                continue
            self.stats["frames"] += 1
            if (self.faults is not None
                    and self.faults.kill_after is not None
                    and self.stats["frames"] >= self.faults.kill_after):
                # the FaultPlan's deterministic restart trigger: die the
                # way a crashed daemon dies (no drain, no goodbye)
                os.kill(os.getpid(), signal.SIGKILL)
            try:
                self._handle(conn, frame)
            except PeerDead:
                self._drop_controller(conn)
            except Exception as e:
                # a bad frame must not take the node down with it; the
                # agg_id/key (when present) let the controller repair
                # its bookkeeping instead of waiting forever
                try:
                    conn.send("error", {"msg": f"{type(e).__name__}: {e}",
                                        "for": frame.kind,
                                        "agg_id": frame.meta.get(
                                            "agg_id", ""),
                                        "key": frame.meta.get("key", "")})
                except PeerDead:
                    self._drop_controller(conn)
        self._push_events()

    def _drop_controller(self, conn: FrameConn) -> None:
        """A controller is gone: unregister it, and once the last one
        leaves, park the local runtime clean so a reconnecting
        controller can spawn the same agg_ids again."""
        if conn in self._controllers:
            self._controllers.remove(conn)
            if not self._controllers:
                try:
                    self.rt.quiesce()
                except Exception:
                    pass
                self._round_cleanup()
                # controller-owned objects must not outlive the
                # controller: its delivered-set and partial-home maps
                # died with the connection, so no discard frame will
                # ever reclaim these — sweep them now (a re-adopting
                # controller re-ships blobs from its staging dict)
                for key in list(self._landed):
                    try:
                        self.rt.discard_update(key)
                    except Exception:
                        pass
                for key in list(self._published):
                    try:
                        self.rt.discard_partial(key)
                    except Exception:
                        pass
                self._landed.clear()
                self._published.clear()

    def _round_cleanup(self) -> None:
        """Inter-round housekeeping for the node-top path: drop stale
        root-fold buffers and reclaim peer-shipped partial copies (the
        originals are discarded by their home's controller sweep; the
        shipped copies are ours to delete)."""
        self._tops.clear()
        for key in list(self._peer_landed):
            try:
                self.rt.discard_update(key)
            except Exception:
                pass
        self._peer_landed.clear()

    # ------------------------------------------------------------------
    # node-top: daemon→daemon partial shipping + ordered root folds
    # ------------------------------------------------------------------
    def _peer_conn(self, addr: str, timeout: float = 5.0) -> FrameConn:
        conn = self._peers.get(addr)
        if conn is not None and conn.alive:
            return conn
        conn = connect(addr, timeout=timeout, peer=addr,
                       compress=self.compress, faults=self.faults,
                       metrics=self.metrics)
        self._peers[addr] = conn
        return conn

    def _ship_partial(self, m: Dict) -> None:
        """Send our sealed partial Σ c·u to the root node's daemon.
        Raises on failure (translated below so the generic error reply
        reaches the *controller*, never misread as a controller
        death)."""
        key = m["key"]
        t_ship = time.perf_counter()
        view = self.rt.get_partial(key)
        arr = np.ascontiguousarray(view)
        meta = {"agg_id": m["agg_id"], "key": key,
                "weight": float(m["weight"]), "count": int(m["count"]),
                "seq": int(m.get("seq", 0)), "round_id": int(m["round_id"]),
                "dtype": str(arr.dtype), "shape": list(arr.shape),
                "src": self.node}
        addr = m["peer"]
        # redial on the shared backoff schedule: a stale cached conn
        # (root restarted) or a root mid-restart gets a few dense
        # probes, then the deadline surfaces the failure — the
        # controller answers by re-rooting, so a daemon must never
        # block long on a dead peer
        bo = Backoff(base=0.05, cap=0.5, deadline_s=4.0)
        try:
            while True:
                try:
                    self._peer_conn(addr, timeout=2.0).send(
                        "partial", meta, blob=arr)
                    break
                except PeerDead as e:
                    self._peers.pop(addr, None)
                    if not bo.sleep():
                        raise RuntimeError(
                            f"peer {addr} unreachable: {e}") from e
        finally:
            self.rt.release_partial(key)
        # wire_s is the whole daemon-side ship wall (serialize + redial
        # backoff + send): what the src node's uplink was busy for —
        # the sample the controller's RC model prices as ship load
        wire_s = time.perf_counter() - t_ship
        self.metrics.update("netd", "ship_s", wire_s)
        self.metrics.update("netd", "ship_bytes", float(arr.nbytes))
        self.stats["partials_shipped"] += 1
        self.stats["ship_tx_bytes"] += arr.nbytes
        self._push_event_obj(PartialShipped(
            round_id=int(m["round_id"]), agg_id=m["agg_id"], key=key,
            src=self.node, dst=m.get("dst", ""), nbytes=arr.nbytes,
            wire_s=wire_s))

    def _top_in(self, agg_id: str, key: str, weight: float, count: int,
                seq: int, round_id: int) -> None:
        t = self._tops.setdefault(
            agg_id, {"goal": None, "round_id": round_id, "buf": {}})
        t["buf"][int(seq)] = (key, weight, count)
        self._flush_top(agg_id)

    def _flush_top(self, agg_id: str) -> None:
        """All inputs at hand: fold them in seq order — arrival order
        races (controller deliver vs peer ships) never reach the
        engine, so the root fold is bit-identical wherever it runs."""
        t = self._tops.get(agg_id)
        if t is None or t["goal"] is None or len(t["buf"]) < t["goal"]:
            return
        del self._tops[agg_id]
        try:
            for seq in sorted(t["buf"]):
                key, weight, count = t["buf"][seq]
                self.rt.deliver_partial(agg_id, key, weight, count,
                                        round_id=t["round_id"], seq=seq)
        except Exception:
            # the root fold is wedged (an input vanished mid-fold): it
            # will never publish — surface its crash so the driver
            # re-roots instead of waiting forever
            self._push_event_obj(WorkerCrashed(
                round_id=t["round_id"], agg_id=agg_id, worker=-1))
            return
        self._push_events()

    def _push_events(self) -> None:
        for ev in self.rt.poll_events(0.0):
            self._push_event_obj(ev)

    def _push_event_obj(self, ev: RoundEvent) -> None:
        """Push one typed event to every controller (``to_wire`` JSON
        riding an ``event`` frame)."""
        if isinstance(ev, PartialReady):
            # published partials are controller-owned from here on;
            # swept at controller-disconnect if never fetched/discarded
            self._published.add(ev.key)
        self.stats["events_pushed"] += 1
        payload = json.loads(to_wire(ev))
        for conn in list(self._controllers):
            if not conn.alive:
                continue  # server.poll emits (conn, None) next tick
            try:
                conn.send("event", payload)
            except PeerDead:
                pass  # ditto: the park-clean path runs via poll

    # ------------------------------------------------------------------
    def _handle(self, conn: FrameConn, frame: Frame) -> None:
        kind, m = frame.kind, frame.meta
        if kind == "hello":
            if m.get("role", "controller") == "controller":
                if conn not in self._controllers:
                    self._controllers.append(conn)
            # mirror the controller's compression choice on our replies
            conn.compress = int(m.get("compress", 0) or 0)
            conn.send("welcome", {
                "node": self.node, "proto": PROTO_VERSION,
                "capacity": self.capacity, "runtime": self.rt.name,
                "pid": os.getpid(), "epoch": self.epoch,
                # the shm name space this process owns (shmproc only):
                # whoever learns this daemon died can reclaim every
                # segment under it — atexit never runs after SIGKILL
                "store_prefix": getattr(self.rt, "store_prefix", ""),
            })
        elif kind == "spawn":
            agg_id = m["agg_id"]
            if m.get("agg_kind") == "top":
                # a root fold: inputs are buffered until all `goal`
                # partials arrived, then folded in seq order
                t = self._tops.setdefault(
                    agg_id, {"goal": None, "round_id": int(m["round_id"]),
                             "buf": {}})
                t["goal"] = int(m["goal"])
                t["round_id"] = int(m["round_id"])
            self.rt.spawn_aggregator(
                m["agg_id"], goal=int(m["goal"]), n_elems=int(m["n_elems"]),
                round_id=int(m["round_id"]), kind=m.get("agg_kind", "mid"))
            if m.get("agg_kind") == "top":
                self._flush_top(agg_id)  # peer partials may have raced
        elif kind == "deliver":
            if m.get("partial"):
                # a resident sealed partial routed into the root fold
                self._top_in(m["agg_id"], m["key"], float(m["weight"]),
                             int(m.get("count", 0)), int(m.get("seq", 0)),
                             int(m["round_id"]))
                return
            key = m["key"]
            if frame.blob and not self.rt.update_alive(key):
                # serialize-once boundary: the blob becomes a sealed
                # store object; intra-node delivery is the key alone
                arr = np.frombuffer(
                    frame.blob, dtype=resolve_dtype(m["dtype"]),
                ).reshape(m["shape"])
                self.rt.store.put(arr, key=key)
                self._landed.add(key)
                self.stats["updates_landed"] += 1
            elif not frame.blob and not self.rt.update_alive(key):
                raise KeyError(f"deliver without blob for unknown {key!r}")
            else:
                self.stats["redelivered_keys"] += 1
            self.rt.deliver(m["agg_id"], key, float(m["weight"]),
                            round_id=int(m["round_id"]))
            self._push_events()  # eager mids may have published already
        elif kind == "ship_partial":
            # daemon→daemon: send our sealed partial straight to the
            # round's root node — the controller never carries it
            self._ship_partial(m)
        elif kind == "partial":
            # a peer daemon shipped us a partial for our root fold.
            # Failures must reach the CONTROLLER as a root crash — the
            # generic error reply would go back on this write-only peer
            # conn, which the shipper never reads, and a starved root
            # fold would hang a deadline-less round forever.
            key = m["key"]
            try:
                if frame.blob and not self.rt.update_alive(key):
                    arr = np.frombuffer(
                        frame.blob, dtype=resolve_dtype(m["dtype"]),
                    ).reshape(m["shape"])
                    self.rt.store.put(arr, key=key)
                    self._peer_landed.add(key)  # reclaimed at quiesce
                self.stats["partials_landed"] += 1
                self.stats["ship_rx_bytes"] += len(frame.blob)
                self._top_in(m["agg_id"], key, float(m["weight"]),
                             int(m.get("count", 0)), int(m.get("seq", 0)),
                             int(m["round_id"]))
            except Exception:
                self._push_event_obj(WorkerCrashed(
                    round_id=int(m.get("round_id", 0)),
                    agg_id=m.get("agg_id", ""), worker=-1))
        elif kind == "drain":
            self.rt.drain(m["agg_id"])
            self._push_events()
        elif kind == "fetch":
            # the one model-size payload that crosses the wire per node
            # per round: the sealed raw partial Σ c·u
            t_fetch = time.perf_counter()
            view = self.rt.get_partial(m["key"])
            arr = np.ascontiguousarray(view)
            conn.send("object", {
                "key": m["key"], "dtype": str(arr.dtype),
                "shape": list(arr.shape),
            }, blob=arr)
            self.rt.release_partial(m["key"])
            self._published.discard(m["key"])
            self.stats["partials_served"] += 1
            self.metrics.update("netd", "fetch_serve_s",
                                time.perf_counter() - t_fetch)
        elif kind == "discard_partial":
            self._published.discard(m["key"])
            try:
                self.rt.discard_partial(m["key"])
            except Exception:
                pass  # already reclaimed (quiesce raced the discard)
        elif kind == "discard_update":
            self._landed.discard(m["key"])
            try:
                self.rt.discard_update(m["key"])
            except Exception:
                pass
        elif kind == "quiesce":
            self._push_events()  # published partials reach the wire first
            rid = m.get("round_id")
            if rid is None:
                self.rt.quiesce()
                self._round_cleanup()
            else:
                # rolling rounds: a round-scoped barrier must not tear
                # down the OTHER in-flight round's open tasks or its
                # root-fold buffers
                try:
                    self.rt.quiesce(round_id=int(rid))
                except TypeError:
                    self.rt.quiesce()
                for tid in [t for t, st in self._tops.items()
                            if st.get("round_id") == int(rid)]:
                    self._tops.pop(tid, None)
            conn.send("quiesced", {
                "stats": {k: v for k, v in self.rt.stats.items()
                          if isinstance(v, (int, float))},
                "workers": self.rt.worker_count(),
                "daemon": dict(self.stats),
                # the LIFL-agent drain: the whole per-daemon MetricsMap
                # rides the reply the controller already waits for — no
                # extra round trip, and the map resets for next round
                "telemetry": self.metrics.drain_series(),
                "telemetry_hists": self.metrics.drain_hists(),
            })
        elif kind == "telemetry":
            # on-demand drain (the agent's pull outside quiesce):
            # destructive like the quiesce drain, so samples are never
            # double-counted across pulls
            conn.send("telemetry_map", {
                "node": self.node,
                "telemetry": self.metrics.drain_series(),
                "telemetry_hists": self.metrics.drain_hists(),
            })
        elif kind == "stats":
            # the LIVE drain (paper agent, §4.3): answerable at ANY
            # time — mid-round included — and non-destructive, so a
            # scrape never erases what the round-edge drain will
            # collect.  Series + histogram snapshot + health gauges.
            rt_health = getattr(self.rt, "health", None)
            health = {
                "open_conns": len(self.server.conns),
                "controllers": len(self._controllers),
                "open_tops": len(self._tops),
                "landed_keys": len(self._landed),
                "published_keys": len(self._published),
                "shm_bytes": _shm_bytes(
                    getattr(self.rt, "store_prefix", "")),
            }
            if callable(rt_health):
                health.update(rt_health())
            else:
                health["workers"] = self.rt.worker_count()
            conn.send("stats_reply", {
                "node": self.node,
                "epoch": self.epoch,
                "uptime_s": time.perf_counter() - self.t0_mono,
                "series": series_flatten(self.metrics.snapshot()),
                "hists": self.metrics.hists_snapshot(),
                "health": health,
                "daemon": dict(self.stats),
                "workers": self.rt.worker_count(),
            })
        elif kind == "recycle":
            self.rt.recycle_engines()
        elif kind == "ping":
            conn.send("pong", {"t": m.get("t")})
        elif kind == "shutdown":
            conn.send("bye", {"node": self.node})
            self._stop = True
        else:
            conn.send("error", {"msg": f"unknown frame kind {kind!r}"})

    # ------------------------------------------------------------------
    def run(self) -> None:
        try:
            while not self._stop:
                self.step()
        finally:
            self.close()

    def stop(self, *_sig) -> None:
        self._stop = True

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for conn in self._peers.values():
            conn.close()
        self._peers.clear()
        self.server.close()
        self.rt.close()


def _shm_bytes(prefix: str) -> int:
    """Bytes resident in /dev/shm under ``prefix`` — the live-scrape
    gauge for 'how much store does this daemon hold right now'."""
    if not prefix:
        return 0
    total = 0
    try:
        for fn in os.listdir("/dev/shm"):
            if fn.startswith(prefix):
                try:
                    total += os.stat(os.path.join("/dev/shm", fn)).st_size
                except OSError:
                    pass
    except OSError:
        pass
    return total


def spawn_local_daemon(node: str, *, runtime: str = "inproc",
                       agg_engine: str = "auto", capacity: float = 20.0,
                       listen: str = "127.0.0.1:0", timeout: float = 30.0,
                       compress: int = 0, stdout=None,
                       fault_spec: Optional[FaultPlan] = None):
    """Spawn a netd as a local child process and wait for its bound
    address (the port-file handshake).  Returns ``(Popen, addr)`` —
    the caller owns the process.  One helper so benches, tests, and
    examples don't each reimplement the spawn.

    The child's stdout/stderr go to a per-daemon log file by default
    (``proc.lifl_log_path``; pass ``stdout=`` to override).  Never
    inherit the caller's pipes: an orphaned/SIGKILLed daemon's forked
    workers would keep them open and hang any harness draining them.
    The log is removed on a clean :func:`reap_local_daemon`; on
    failure the reaper reports its path instead."""
    import shutil
    import subprocess
    import tempfile
    import time

    # a private directory owns the handshake file: no mktemp-style race
    # with other processes guessing the predictable /tmp name
    tmpd = tempfile.mkdtemp(prefix=f"netd-{node}-")
    pf = os.path.join(tmpd, "addr")
    env = dict(os.environ)
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    argv = [sys.executable, "-m", "repro.runtime.netrt.netd",
            "--node", node, "--listen", listen, "--runtime", runtime,
            "--agg-engine", agg_engine, "--capacity", str(capacity),
            "--compress", str(int(compress)), "--port-file", pf]
    if fault_spec is not None:
        argv += ["--fault-spec", fault_spec.to_json()]
    # own session: reap_local_daemon can killpg the daemon AND its
    # forked shm workers (SIGKILLing just the daemon orphans them)
    log_path = ""
    log_f = None
    if stdout is None:
        log_path = os.path.join(
            tempfile.gettempdir(),
            f"netd-{node}-{os.getpid()}-{time.time_ns()}.log")
        log_f = open(log_path, "ab")
        stdout = log_f
    try:
        proc = subprocess.Popen(argv, env=env, stdout=stdout,
                                stderr=subprocess.STDOUT,
                                start_new_session=True)
    finally:
        if log_f is not None:
            log_f.close()   # the child owns the fd now
    proc.lifl_log_path = log_path
    deadline = time.perf_counter() + timeout
    try:
        while not os.path.exists(pf):
            if proc.poll() is not None or time.perf_counter() > deadline:
                proc.kill()
                tail = ""
                if log_path and os.path.exists(log_path):
                    with open(log_path, "rb") as lf:
                        tail = lf.read()[-2048:].decode("utf-8", "replace")
                raise RuntimeError(
                    f"netd {node} failed to start"
                    + (f" (log: {log_path}):\n{tail}" if log_path else ""))
            time.sleep(0.02)
        with open(pf) as f:
            lines = f.read().splitlines()
        addr = lines[0].strip()
        # second port-file line: the daemon's shm prefix — kept on the
        # Popen so reap_local_daemon can sweep after a SIGKILL
        proc.lifl_store_prefix = (lines[1].strip()
                                  if len(lines) > 1 else "")
    finally:
        shutil.rmtree(tmpd, ignore_errors=True)
    return proc, addr


def reap_local_daemon(proc, *, timeout: float = 5.0) -> int:
    """Tear down a ``spawn_local_daemon`` child for good: kill its
    whole process group (the daemon plus any forked shm workers —
    plain ``proc.kill()`` orphans them), wait, then sweep whatever its
    shm prefix left in /dev/shm.  Safe after the process already died
    (the FaultPlan kill path); returns the number of segments swept."""
    import signal as _signal
    import subprocess

    from repro.core.objectstore import sweep_dead_segments

    log_path = getattr(proc, "lifl_log_path", "")
    reaped = True
    if proc.poll() is None:
        try:
            os.killpg(proc.pid, _signal.SIGKILL)
        except (ProcessLookupError, PermissionError):
            proc.kill()
    try:
        proc.wait(timeout=timeout)
    except subprocess.TimeoutExpired:
        reaped = False
        if log_path:
            print(f"reap_local_daemon: pid {proc.pid} did not exit; "
                  f"daemon log kept at {log_path}", file=sys.stderr)
    else:
        # the group may still hold workers even after the leader died
        try:
            os.killpg(proc.pid, _signal.SIGKILL)
        except (ProcessLookupError, PermissionError):
            pass
    if reaped and log_path:
        # clean reap: the log served its purpose (kept on failure so
        # the operator can read why the daemon wouldn't die)
        try:
            os.unlink(log_path)
        except OSError:
            pass
    return sweep_dead_segments(getattr(proc, "lifl_store_prefix", ""))


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="netd", description="LIFL per-node aggregation daemon")
    ap.add_argument("--node", required=True, help="node name (placement id)")
    ap.add_argument("--listen", default="127.0.0.1:0",
                    help="host:port or unix:/path (port 0 = ephemeral)")
    ap.add_argument("--runtime", default="inproc",
                    choices=("inproc", "shmproc"))
    ap.add_argument("--agg-engine", default="auto")
    ap.add_argument("--capacity", type=float, default=20.0,
                    help="MC_i for the controller's placement model")
    ap.add_argument("--compress", type=int, default=0,
                    help="zlib level for outbound blobs (0 = off)")
    ap.add_argument("--port-file", default="",
                    help="write the bound address here (atomic rename)")
    ap.add_argument("--fault-spec", default="",
                    help="FaultPlan JSON (deterministic fault injection "
                         "for chaos tests; see netrt/faults.py)")
    args = ap.parse_args(argv)

    daemon = NodeDaemon(
        args.node, args.listen, runtime=args.runtime,
        agg_engine=args.agg_engine, capacity=args.capacity,
        compress=args.compress,
        fault_plan=FaultPlan.from_json(args.fault_spec)
        if args.fault_spec else None)
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(daemon.addr + "\n")
            f.write(getattr(daemon.rt, "store_prefix", "") + "\n")
        os.rename(tmp, args.port_file)
    print(f"netd {args.node} ({args.runtime}) listening on {daemon.addr}",
          flush=True)
    signal.signal(signal.SIGTERM, daemon.stop)
    signal.signal(signal.SIGINT, daemon.stop)
    daemon.run()
    return 0


if __name__ == "__main__":
    sys.exit(main())
