"""netd — the per-node daemon of the multi-node transport.

One netd process runs on each worker node.  It owns that node's *local*
aggregation runtime — ``InProcRuntime`` or (the real deployment)
``ShmProcRuntime`` with its forked workers and shared-memory rings —
and exposes it over the frame transport (``transport.py``): a
controller's :class:`~repro.runtime.netrt.remote.RemoteRuntime` speaks
the same ``spawn``/``deliver``/``drain``/``quiesce`` verbs the
``RoundDriver`` already uses, and typed round events travel back as
``events.to_wire`` JSON riding ``event`` frames.

Data-plane contract (the reason this layer exists):

  * a leaf update is serialized **once**, at the node boundary — the
    ``deliver`` frame's blob lands in the node's object store under the
    controller-chosen key, and every intra-node hop after that is the
    usual zero-copy shared-memory path;
  * a re-delivery of a key the store already holds (crash re-dispatch
    to the same node) ships **no blob** — just the 16-byte key;
  * only the sealed partial Σ c·u leaves the node, when the controller
    ``fetch``es it for the top fold: one model-size payload per node
    per round.

Run it::

    python -m repro.runtime.netrt.netd --node nodeA \
        --listen 127.0.0.1:0 --runtime shmproc --port-file /tmp/a.addr

The daemon is single-threaded: one loop multiplexes the socket server
and the local runtime's event queue.  SIGTERM/SIGINT drain gracefully
(the local runtime shuts down, shm segments are unlinked).
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
from typing import List, Optional

import numpy as np

from repro.runtime.driver import make_runtime
from repro.runtime.events import to_wire
from repro.runtime.netrt.transport import (
    Frame,
    FrameConn,
    FrameServer,
    PeerDead,
    resolve_dtype,
)

PROTO_VERSION = 1


class NodeDaemon:
    """One node's frame-server front end over a local runtime."""

    def __init__(self, node: str, listen: str = "127.0.0.1:0", *,
                 runtime: str = "inproc", agg_engine: str = "auto",
                 capacity: float = 20.0, poll_interval: float = 0.02):
        self.node = node
        self.capacity = float(capacity)
        self.poll_interval = poll_interval
        self.rt = make_runtime(runtime, agg_engine=agg_engine)
        self.server = FrameServer(listen)
        self.addr = self.server.addr
        self._controllers: List[FrameConn] = []
        self._stop = False
        self._closed = False
        self.stats = {"frames": 0, "events_pushed": 0, "updates_landed": 0,
                      "redelivered_keys": 0, "partials_served": 0}

    # ------------------------------------------------------------------
    def step(self, timeout: Optional[float] = None) -> None:
        """One loop iteration: demux frames, push local runtime events."""
        for conn, frame in self.server.poll(
                self.poll_interval if timeout is None else timeout):
            if frame is None:  # peer went away (recv- or send-side)
                self._drop_controller(conn)
                continue
            self.stats["frames"] += 1
            try:
                self._handle(conn, frame)
            except PeerDead:
                self._drop_controller(conn)
            except Exception as e:
                # a bad frame must not take the node down with it; the
                # agg_id/key (when present) let the controller repair
                # its bookkeeping instead of waiting forever
                try:
                    conn.send("error", {"msg": f"{type(e).__name__}: {e}",
                                        "for": frame.kind,
                                        "agg_id": frame.meta.get(
                                            "agg_id", ""),
                                        "key": frame.meta.get("key", "")})
                except PeerDead:
                    self._drop_controller(conn)
        self._push_events()

    def _drop_controller(self, conn: FrameConn) -> None:
        """A controller is gone: unregister it, and once the last one
        leaves, park the local runtime clean so a reconnecting
        controller can spawn the same agg_ids again."""
        if conn in self._controllers:
            self._controllers.remove(conn)
            if not self._controllers:
                try:
                    self.rt.quiesce()
                except Exception:
                    pass

    def _push_events(self) -> None:
        for ev in self.rt.poll_events(0.0):
            self.stats["events_pushed"] += 1
            payload = json.loads(to_wire(ev))
            for conn in list(self._controllers):
                if not conn.alive:
                    continue  # server.poll emits (conn, None) next tick
                try:
                    conn.send("event", payload)
                except PeerDead:
                    pass  # ditto: the park-clean path runs via poll

    # ------------------------------------------------------------------
    def _handle(self, conn: FrameConn, frame: Frame) -> None:
        kind, m = frame.kind, frame.meta
        if kind == "hello":
            if m.get("role", "controller") == "controller":
                if conn not in self._controllers:
                    self._controllers.append(conn)
            conn.send("welcome", {
                "node": self.node, "proto": PROTO_VERSION,
                "capacity": self.capacity, "runtime": self.rt.name,
                "pid": os.getpid(),
            })
        elif kind == "spawn":
            self.rt.spawn_aggregator(
                m["agg_id"], goal=int(m["goal"]), n_elems=int(m["n_elems"]),
                round_id=int(m["round_id"]))
        elif kind == "deliver":
            key = m["key"]
            if frame.blob and not self.rt.update_alive(key):
                # serialize-once boundary: the blob becomes a sealed
                # store object; intra-node delivery is the key alone
                arr = np.frombuffer(
                    frame.blob, dtype=resolve_dtype(m["dtype"]),
                ).reshape(m["shape"])
                self.rt.store.put(arr, key=key)
                self.stats["updates_landed"] += 1
            elif not frame.blob and not self.rt.update_alive(key):
                raise KeyError(f"deliver without blob for unknown {key!r}")
            else:
                self.stats["redelivered_keys"] += 1
            self.rt.deliver(m["agg_id"], key, float(m["weight"]),
                            round_id=int(m["round_id"]))
            self._push_events()  # eager mids may have published already
        elif kind == "drain":
            self.rt.drain(m["agg_id"])
            self._push_events()
        elif kind == "fetch":
            # the one model-size payload that crosses the wire per node
            # per round: the sealed raw partial Σ c·u
            view = self.rt.get_partial(m["key"])
            arr = np.ascontiguousarray(view)
            conn.send("object", {
                "key": m["key"], "dtype": str(arr.dtype),
                "shape": list(arr.shape),
            }, blob=arr)
            self.rt.release_partial(m["key"])
            self.stats["partials_served"] += 1
        elif kind == "discard_partial":
            try:
                self.rt.discard_partial(m["key"])
            except Exception:
                pass  # already reclaimed (quiesce raced the discard)
        elif kind == "discard_update":
            try:
                self.rt.discard_update(m["key"])
            except Exception:
                pass
        elif kind == "quiesce":
            self._push_events()  # published partials reach the wire first
            self.rt.quiesce()
            conn.send("quiesced", {
                "stats": {k: v for k, v in self.rt.stats.items()
                          if isinstance(v, (int, float))},
                "workers": self.rt.worker_count(),
                "daemon": dict(self.stats),
            })
        elif kind == "recycle":
            self.rt.recycle_engines()
        elif kind == "ping":
            conn.send("pong", {"t": m.get("t")})
        elif kind == "shutdown":
            conn.send("bye", {"node": self.node})
            self._stop = True
        else:
            conn.send("error", {"msg": f"unknown frame kind {kind!r}"})

    # ------------------------------------------------------------------
    def run(self) -> None:
        try:
            while not self._stop:
                self.step()
        finally:
            self.close()

    def stop(self, *_sig) -> None:
        self._stop = True

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self.server.close()
        self.rt.close()


def spawn_local_daemon(node: str, *, runtime: str = "inproc",
                       agg_engine: str = "auto", capacity: float = 20.0,
                       listen: str = "127.0.0.1:0", timeout: float = 30.0,
                       stdout=None):
    """Spawn a netd as a local child process and wait for its bound
    address (the port-file handshake).  Returns ``(Popen, addr)`` —
    the caller owns the process.  One helper so benches, tests, and
    examples don't each reimplement the spawn."""
    import shutil
    import subprocess
    import tempfile
    import time

    # a private directory owns the handshake file: no mktemp-style race
    # with other processes guessing the predictable /tmp name
    tmpd = tempfile.mkdtemp(prefix=f"netd-{node}-")
    pf = os.path.join(tmpd, "addr")
    env = dict(os.environ)
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.runtime.netrt.netd",
         "--node", node, "--listen", listen, "--runtime", runtime,
         "--agg-engine", agg_engine, "--capacity", str(capacity),
         "--port-file", pf],
        env=env, stdout=stdout)
    deadline = time.perf_counter() + timeout
    try:
        while not os.path.exists(pf):
            if proc.poll() is not None or time.perf_counter() > deadline:
                proc.kill()
                raise RuntimeError(f"netd {node} failed to start")
            time.sleep(0.02)
        with open(pf) as f:
            addr = f.read().strip()
    finally:
        shutil.rmtree(tmpd, ignore_errors=True)
    return proc, addr


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="netd", description="LIFL per-node aggregation daemon")
    ap.add_argument("--node", required=True, help="node name (placement id)")
    ap.add_argument("--listen", default="127.0.0.1:0",
                    help="host:port or unix:/path (port 0 = ephemeral)")
    ap.add_argument("--runtime", default="inproc",
                    choices=("inproc", "shmproc"))
    ap.add_argument("--agg-engine", default="auto")
    ap.add_argument("--capacity", type=float, default=20.0,
                    help="MC_i for the controller's placement model")
    ap.add_argument("--port-file", default="",
                    help="write the bound address here (atomic rename)")
    args = ap.parse_args(argv)

    daemon = NodeDaemon(
        args.node, args.listen, runtime=args.runtime,
        agg_engine=args.agg_engine, capacity=args.capacity)
    if args.port_file:
        tmp = args.port_file + ".tmp"
        with open(tmp, "w") as f:
            f.write(daemon.addr + "\n")
        os.rename(tmp, args.port_file)
    print(f"netd {args.node} ({args.runtime}) listening on {daemon.addr}",
          flush=True)
    signal.signal(signal.SIGTERM, daemon.stop)
    signal.signal(signal.SIGINT, daemon.stop)
    daemon.run()
    return 0


if __name__ == "__main__":
    sys.exit(main())
