"""Deterministic fault injection for the frame transport.

A :class:`FaultPlan` is a *seeded schedule* of transport failures —
frame drops, connection resets, delayed sends, and a daemon self-kill —
hooked into the send path of :class:`~.transport.FrameConn` (every
conn a :class:`~.transport.FrameServer` accepts inherits its server's
plan, and ``netd --fault-spec`` arms a daemon-side plan at spawn).
One soak test with three seeds then exercises every failure mode the
survivability layer handles — lost updates, dead peers, mid-round
daemon restarts — instead of bespoke SIGKILL choreography per mode.

Determinism: all randomness comes from one ``random.Random(seed)``
stream consumed exactly once per *eligible* outbound frame, so the
same seed over the same frame sequence always injects the same
faults.  Rates are per-action probabilities over one uniform draw
(``drop`` wins below ``drop``, ``reset`` below ``drop+reset``, …).

Safety rails for tests that must terminate:

  * ``drop_kinds``/``reset_kinds`` scope each action to frame kinds
    whose loss the protocol absorbs (dropping a ``quiesce`` or
    ``fetch`` would stall its sender on a reply timeout, not exercise
    recovery);
  * ``max_faults`` caps the total injections, after which the plan
    passes everything — a soak provably converges once the fault
    budget is spent;
  * ``kill_after`` is consumed by :class:`~.netd.NodeDaemon` itself
    (SIGKILL after N handled frames), giving the restart mode a
    deterministic trigger point.

Usage::

    plan = FaultPlan(seed=1, drop=0.05, reset=0.02, max_faults=6)
    rt = RemoteRuntime(addrs, fault_plan=plan)        # controller side
    spawn_local_daemon("nodeB", fault_spec=FaultPlan(  # daemon side
        seed=2, delay=0.1, kill_after=40))
"""
from __future__ import annotations

import json
import random
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

#: actions FrameConn.send understands (the plan is duck-typed there)
PASS, DROP, RESET, DELAY = "pass", "drop", "reset", "delay"

#: default drop scope: frame kinds with no reply to time out on —
#: their loss is absorbed by drain/teardown, never by a blocked recv
SAFE_DROP_KINDS: Tuple[str, ...] = ("deliver", "event", "partial")


@dataclass
class FaultPlan:
    """One seeded fault schedule (see module docstring)."""

    seed: int = 0
    drop: float = 0.0          # P(drop) per eligible frame
    reset: float = 0.0         # P(inject connection reset)
    delay: float = 0.0         # P(delay the send)
    delay_s: float = 0.002     # how long a delayed send sleeps
    #: frame kinds eligible for drops (None → SAFE_DROP_KINDS)
    drop_kinds: Optional[Tuple[str, ...]] = None
    #: frame kinds eligible for resets (None → every kind)
    reset_kinds: Optional[Tuple[str, ...]] = None
    #: total injection budget (None = unbounded)
    max_faults: Optional[int] = None
    #: netd only: SIGKILL self after handling this many frames
    kill_after: Optional[int] = None
    #: injections so far, by action (shared across every hooked conn)
    injected: Dict[str, int] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.drop_kinds is None:
            self.drop_kinds = SAFE_DROP_KINDS
        else:
            self.drop_kinds = tuple(self.drop_kinds)
        if self.reset_kinds is not None:
            self.reset_kinds = tuple(self.reset_kinds)
        self._rng = random.Random(self.seed)

    # ------------------------------------------------------------------
    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())

    def _exhausted(self) -> bool:
        return (self.max_faults is not None
                and self.total_injected >= self.max_faults)

    def on_send(self, kind: str, nbytes: int = 0) -> Tuple[str, float]:
        """Decide one outbound frame's fate: ``(action, delay_s)``.
        Called by ``FrameConn.send``; one RNG draw per eligible frame
        keeps the schedule reproducible."""
        can_drop = kind in self.drop_kinds and self.drop > 0
        can_reset = (self.reset_kinds is None
                     or kind in self.reset_kinds) and self.reset > 0
        can_delay = self.delay > 0
        if self._exhausted() or not (can_drop or can_reset or can_delay):
            return PASS, 0.0
        r = self._rng.random()
        edge = self.drop if can_drop else 0.0
        if can_drop and r < edge:
            self.injected[DROP] = self.injected.get(DROP, 0) + 1
            return DROP, 0.0
        if can_reset:
            edge += self.reset
            if r < edge:
                self.injected[RESET] = self.injected.get(RESET, 0) + 1
                return RESET, 0.0
        if can_delay and r < edge + self.delay:
            self.injected[DELAY] = self.injected.get(DELAY, 0) + 1
            return DELAY, self.delay_s
        return PASS, 0.0

    # ------------------------------------------------------------------
    # CLI boundary (netd --fault-spec '<json>')
    # ------------------------------------------------------------------
    def to_json(self) -> str:
        d = {"seed": self.seed, "drop": self.drop, "reset": self.reset,
             "delay": self.delay, "delay_s": self.delay_s,
             "drop_kinds": list(self.drop_kinds),
             "max_faults": self.max_faults, "kill_after": self.kill_after}
        if self.reset_kinds is not None:
            d["reset_kinds"] = list(self.reset_kinds)
        return json.dumps(d, separators=(",", ":"))

    @classmethod
    def from_json(cls, raw: str) -> "FaultPlan":
        d = json.loads(raw)
        for k in ("drop_kinds", "reset_kinds"):
            if d.get(k) is not None:
                d[k] = tuple(d[k])
        return cls(**{k: v for k, v in d.items()
                      if k in cls.__dataclass_fields__})
