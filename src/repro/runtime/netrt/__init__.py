"""netrt — the multi-node transport: netd daemons + RemoteRuntime.

The layer that turns the single-node event-driven runtime into the
paper's platform: per-node daemons (``netd.py``) own their local
shared-memory runtimes, the frame transport (``transport.py``) carries
the typed event protocol plus serialize-once payloads, and
``RemoteRuntime`` (``remote.py``) implements the ``Runtime`` protocol
so the unchanged ``RoundDriver`` drives cross-node hierarchical
rounds.  See README.md in this package for the frame format, the
handshake, and the failure model.
"""
from repro.runtime.netrt.faults import FaultPlan
from repro.runtime.netrt.remote import (
    BusyError,
    NoLiveNodeError,
    RemoteRuntime,
    push_update,
)
from repro.runtime.netrt.transport import (
    Backoff,
    Frame,
    FrameConn,
    FrameServer,
    PeerDead,
    connect,
)

def __getattr__(name):
    # lazy: `python -m repro.runtime.netrt.netd` must not re-import the
    # daemon module through the package (runpy double-import warning)
    if name in ("NodeDaemon", "spawn_local_daemon", "reap_local_daemon"):
        from repro.runtime.netrt import netd
        return getattr(netd, name)
    raise AttributeError(name)


__all__ = [
    "Backoff",
    "BusyError",
    "FaultPlan",
    "Frame",
    "FrameConn",
    "FrameServer",
    "NodeDaemon",
    "NoLiveNodeError",
    "PeerDead",
    "RemoteRuntime",
    "connect",
    "push_update",
    "reap_local_daemon",
    "spawn_local_daemon",
]
