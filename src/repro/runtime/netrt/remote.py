"""RemoteRuntime — the multi-node Runtime the unchanged RoundDriver drives.

One ``RemoteRuntime`` fronts a fleet of :mod:`netd` daemons.  It
implements the full :class:`~repro.runtime.driver.Runtime` protocol
(``spawn_aggregator`` / ``deliver`` / ``poll_events`` / ``quiesce`` +
store plumbing), so ``RoundDriver.run_round`` — and therefore
``FederatedTrainer`` and ``Session`` — runs a cross-node hierarchical
round with **zero new round-loop code**:

  * ``put_update`` stages the flat update locally (one reference, no
    copy); ``deliver`` serializes it once into the owning node's store
    (the node-boundary copy) and the node's intra-node path stays
    zero-copy shared memory;
  * mid-aggregators run on their home nodes (``mid@<node>`` routes to
    the daemon named ``<node>``); only the sealed partial Σ c·u comes
    back over the wire (``fetch``), one model-size payload per node
    per round, for the driver's top fold;
  * a dead daemon (EOF/reset/send failure) becomes one ``NodeLost``
    plus one synthesized ``WorkerCrashed`` per open subtree routed
    there — the driver's existing crash re-dispatch then replays the
    staged update keys, which this runtime re-routes to a surviving
    node.  Dead-peer teardown releases every in-flight bookkeeping
    entry for that node (delivered-key sets, partial homes) so nothing
    leaks with the peer.

Staged updates live until the driver's end-of-round ``discard_update``
sweep, which is exactly what makes crash re-dispatch to a *different*
node possible: ``update_alive`` answers from the staging dict, not the
dead node's store.
"""
from __future__ import annotations

import json
import select
import time
from collections import deque
from typing import Any, Deque, Dict, Iterable, List, Optional, Set

import numpy as np

from repro.core.objectstore import new_object_key
from repro.core.sidecar import MetricsMap
from repro.runtime.driver import _WarmEngineMixin
from repro.runtime.events import (
    NodeLost,
    NodeRejoined,
    PartialReady,
    RoundEvent,
    WorkerCrashed,
    from_wire,
)
from repro.runtime.netrt.transport import (
    Backoff,
    Frame,
    FrameConn,
    PeerDead,
    connect,
    resolve_dtype,
)


class NoLiveNodeError(ConnectionError):
    """Every node daemon of this runtime is unreachable."""


class BusyError(RuntimeError):
    """The serving side kept shedding this submission (admission
    backpressure) past the client's patience — carries the server's
    last ``retry_after_s`` hint for an outer scheduler to honor."""

    def __init__(self, msg: str, *, retry_after_s: float = 0.0):
        super().__init__(msg)
        self.retry_after_s = retry_after_s


class _Node:
    """Controller-side state for one netd peer."""

    __slots__ = ("name", "addr", "conn", "capacity", "workers", "alive",
                 "delivered", "stats", "runtime_name", "epoch",
                 "store_prefix", "telemetry")

    def __init__(self, name: str, addr: str, conn: FrameConn,
                 capacity: float, runtime_name: str, epoch: int = 0,
                 store_prefix: str = ""):
        self.name = name
        self.addr = addr
        self.conn = conn
        self.capacity = capacity
        self.runtime_name = runtime_name
        self.epoch = epoch                 # welcome's restart counter
        self.store_prefix = store_prefix   # its /dev/shm name space
        self.workers = 0
        self.alive = True
        self.delivered: Set[str] = set()   # keys resident in its store
        self.stats: Dict[str, float] = {}  # last quiesced totals
        # accumulated drained telemetry ("owner/metric" → [sum, count]);
        # emptied by RemoteRuntime.take_telemetry (the trace grab)
        self.telemetry: Dict[str, List[float]] = {}


class RemoteRuntime(_WarmEngineMixin):
    """The cross-node aggregation runtime (see module docstring)."""

    name = "net"

    def __init__(self, nodes: Iterable[str], *,
                 metrics: Optional[MetricsMap] = None,
                 agg_engine: Any = "auto",
                 connect_timeout: float = 10.0,
                 compress: Any = 0,
                 readopt: bool = True,
                 readopt_timeout: float = 0.5,
                 fault_plan: Any = None):
        self.metrics = metrics if metrics is not None else MetricsMap()
        self.agg_engine = agg_engine
        # zlib level for outbound update/partial blobs; the hello meta
        # carries it so the daemon compresses its replies too
        self.compress = 6 if compress is True else int(compress or 0)
        # re-adoption: probe dead nodes' addresses (jittered backoff)
        # on every poll — a daemon restarted under its old node name
        # re-registers via the welcome handshake and rejoins the fleet
        self.readopt = bool(readopt)
        self.readopt_timeout = float(readopt_timeout)
        self.fault_plan = fault_plan   # faults.FaultPlan (chaos tests)
        self._engines: Dict[str, Any] = {}    # driver-side (top) engines
        self._staged: Dict[str, np.ndarray] = {}
        self._route: Dict[str, str] = {}      # agg_id → node name
        self._open: Dict[str, int] = {}       # agg_id → spawn round_id
        self._partial_home: Dict[str, str] = {}
        self._pending: Deque[RoundEvent] = deque()
        self._local = {"node_lost": 0, "synth_crashes": 0, "refused": 0,
                       "readopted": 0, "epoch_bumps": 0}
        self._readopt_bo: Dict[str, Backoff] = {}   # dead node → schedule
        self._readopt_next: Dict[str, float] = {}   # dead node → next try
        self._closed = False
        self._nodes: Dict[str, _Node] = {}
        addrs = list(nodes)
        if not addrs:
            raise ValueError("RemoteRuntime needs at least one node address")
        for addr in addrs:
            self._attach(addr, connect_timeout)

    # ------------------------------------------------------------------
    # connection management
    # ------------------------------------------------------------------
    def _attach(self, addr: str, timeout: float) -> None:
        conn = connect(addr, timeout=timeout, compress=self.compress,
                       faults=self.fault_plan)
        conn.send("hello", {"role": "controller", "proto": 1,
                            "compress": self.compress})
        stash: List[Frame] = []
        w = conn.recv_expect(("welcome",), timeout, stash=stash).meta
        node = _Node(w["node"], addr, conn, float(w.get("capacity", 20.0)),
                     w.get("runtime", "?"), epoch=int(w.get("epoch", 0)),
                     store_prefix=w.get("store_prefix", "") or "")
        if node.name in self._nodes:
            conn.close()
            raise ValueError(f"duplicate node name {node.name!r} "
                             f"({addr} vs {self._nodes[node.name].addr})")
        self._nodes[node.name] = node

    # ------------------------------------------------------------------
    # re-adoption of restarted daemons
    # ------------------------------------------------------------------
    def try_readopt(self, force: bool = False) -> List[str]:
        """One re-adoption pass over the dead nodes: re-dial each one's
        recorded address (non-blocking-ish: a single connect attempt
        per node, paced by a per-node jittered backoff unless
        ``force``), re-run the welcome handshake, and re-adopt a daemon
        that answers under the old node name.  The epoch counter in the
        welcome tells a restarted process (epoch bump — its store is
        empty, so residency is cleared and staged keys re-ship on the
        driver's re-dispatch) from a transient disconnect (same epoch —
        the daemon parked and swept when we vanished, so the controller
        treats both identically).  Dead-epoch teardown itself already
        ran in ``_lose_node``; re-adoption only has to bring the node
        back.  Returns the re-adopted node names; a ``NodeRejoined``
        event per adoption reaches the driver on the next poll."""
        if not self.readopt or self._closed:
            return []
        adopted: List[str] = []
        now = time.perf_counter()
        for node in self._nodes.values():
            if node.alive:
                continue
            if not force and now < self._readopt_next.get(node.name, 0.0):
                continue
            bo = self._readopt_bo.setdefault(
                node.name, Backoff(base=0.2, cap=2.0))
            try:
                # single dial (deadline_s=0 disables connect's retry
                # loop): a refused port must cost one syscall, not a
                # blocking retry window inside poll_events
                conn = connect(node.addr, timeout=self.readopt_timeout,
                               compress=self.compress,
                               faults=self.fault_plan,
                               backoff=Backoff(deadline_s=0.0))
                conn.send("hello", {"role": "controller", "proto": 1,
                                    "compress": self.compress})
                w = conn.recv_expect(
                    ("welcome",), max(self.readopt_timeout, 2.0)).meta
            except PeerDead:
                self._readopt_next[node.name] = \
                    time.perf_counter() + (bo.next_delay() or bo.cap)
                continue
            if w.get("node") != node.name:
                # the address answers, but it isn't our daemon anymore
                conn.close()
                self._readopt_next[node.name] = \
                    time.perf_counter() + (bo.next_delay() or bo.cap)
                continue
            self._adopt(node, conn, w)
            adopted.append(node.name)
        return adopted

    def _adopt(self, node: _Node, conn: FrameConn, w: Dict) -> None:
        old_epoch = node.epoch
        old_prefix = node.store_prefix
        node.conn = conn
        node.alive = True
        node.capacity = float(w.get("capacity", node.capacity))
        node.runtime_name = w.get("runtime", node.runtime_name)
        node.epoch = int(w.get("epoch", 0))
        node.store_prefix = w.get("store_prefix", "") or ""
        # whatever epoch we got, the daemon-side store owes us nothing:
        # a restarted process is empty, a parked one swept on our
        # disconnect — every staged key re-ships its blob on demand
        node.delivered.clear()
        self._readopt_bo.pop(node.name, None)
        self._readopt_next.pop(node.name, None)
        self._local["readopted"] += 1
        if node.epoch != old_epoch:
            self._local["epoch_bumps"] += 1
            if old_prefix and old_prefix != node.store_prefix:
                # a fresh process under the old name: its predecessor
                # died without atexit (SIGKILL), so the old epoch's shm
                # segments are orphans — reclaim the whole name space.
                # Best-effort: on a remote host the names simply don't
                # exist in our /dev/shm and nothing happens.
                from repro.core.objectstore import sweep_dead_segments

                swept = sweep_dead_segments(old_prefix)
                if swept:
                    self._local["swept_segments"] = (
                        self._local.get("swept_segments", 0) + swept)
        self._pending.append(NodeRejoined(
            node=node.name, epoch=node.epoch, old_epoch=old_epoch,
            capacity=node.capacity))

    def _alive(self) -> List[_Node]:
        return [n for n in self._nodes.values() if n.alive]

    @property
    def _net_sidecar(self):
        """Wire-traffic sidecar (``net/tx_bytes``/``net/rx_bytes`` in
        ``Session.metrics()``).  Lazy: Session re-points ``metrics`` at
        the trainer's map after construction, so the sidecar must bind
        at first use, not in ``__init__``."""
        sc = self.__dict__.get("_net_sidecar_inst")
        if sc is None or sc.metrics is not self.metrics:
            from repro.core.sidecar import EventSidecar

            sc = EventSidecar("net", self.metrics)
            self.__dict__["_net_sidecar_inst"] = sc
        return sc

    def node_info(self) -> Dict[str, float]:
        """name → capacity (MC_i), in daemon-connection order — feeds
        the controller's placement model."""
        return {n.name: n.capacity for n in self._nodes.values()}

    def _lose_node(self, node: _Node, why: str = "") -> List[RoundEvent]:
        """Dead-peer teardown: close, release the node's in-flight round
        state, surface NodeLost + one synthetic WorkerCrashed per open
        subtree so the driver re-dispatches to a survivor."""
        if not node.alive:
            return []
        node.alive = False
        node.conn.close()
        self._local["node_lost"] += 1
        # fresh re-adoption schedule: the first probe may run at the
        # very next poll (a rolling restart should rejoin quickly)
        self._readopt_bo.pop(node.name, None)
        self._readopt_next.pop(node.name, None)
        evs: List[RoundEvent] = [NodeLost(node=node.name)]
        # its store died with it: partials homed there are unreachable
        for key, home in list(self._partial_home.items()):
            if home == node.name:
                del self._partial_home[key]
        node.delivered.clear()
        for agg_id, name in list(self._route.items()):
            if name != node.name:
                continue
            del self._route[agg_id]
            rid = self._open.pop(agg_id, None)
            if rid is not None:
                self._local["synth_crashes"] += 1
                evs.append(WorkerCrashed(round_id=rid, agg_id=agg_id,
                                         worker=-1, exitcode=None))
        return evs

    def _send(self, node: _Node, kind: str, meta: Dict,
              blob: bytes = b"") -> bool:
        """Best-effort send; a dead peer is torn down (events queued for
        the next poll) and the send reports failure."""
        if not node.alive:
            return False
        try:
            node.conn.send(kind, meta, blob=blob)
            return True
        except PeerDead as e:
            self._pending.extend(self._lose_node(node, str(e)))
            return False

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------
    def _resolve(self, agg_id: str) -> _Node:
        """Home node for a subtree: ``mid@<node>`` prefers ``<node>``;
        a lost home falls back to the first surviving node (the crash
        re-dispatch path)."""
        name = self._route.get(agg_id)
        if name is not None:
            node = self._nodes.get(name)
            if node is not None and node.alive:
                return node
        home = agg_id.split("@", 1)[-1]
        node = self._nodes.get(home)
        if node is None or not node.alive:
            live = self._alive()
            if not live:
                # last resort before giving up the round: a restarted
                # daemon may already be listening again — force one
                # re-adoption pass (ignores the backoff pacing)
                self.try_readopt(force=True)
                live = self._alive()
                if not live:
                    raise NoLiveNodeError("all node daemons are unreachable")
            node = live[0]
        self._route[agg_id] = node.name
        return node

    # ------------------------------------------------------------------
    # Runtime protocol
    # ------------------------------------------------------------------
    def spawn_aggregator(self, agg_id: str, *, goal: int, n_elems: int,
                         round_id: int = 0, kind: str = "mid") -> None:
        # "agg_kind", not "kind": the frame codec owns the meta key
        # "kind" (it is the frame type itself)
        meta = {"agg_id": agg_id, "goal": goal, "n_elems": n_elems,
                "round_id": round_id, "agg_kind": kind}
        # each failed send tears one dead node down, so this walks the
        # survivors and terminates: _resolve raises NoLiveNodeError
        # once nobody is left
        while not self._send(self._resolve(agg_id), "spawn", meta):
            pass
        self._open[agg_id] = round_id

    def deliver(self, agg_id: str, key: str, weight: float,
                round_id: int = 0) -> None:
        node = self._resolve(agg_id)
        meta = {"agg_id": agg_id, "key": key, "weight": weight,
                "round_id": round_id}
        if key in node.delivered:
            # the store already holds it: 16-byte key, no payload
            self._send(node, "deliver", meta)
            return
        flat = self._staged[key]
        meta["dtype"] = str(flat.dtype)
        meta["shape"] = list(flat.shape)
        # a failed send is NOT an error: the teardown queued a synthetic
        # WorkerCrashed, and the driver replays this key from staging
        if self._send(node, "deliver", meta, blob=flat):
            node.delivered.add(key)
            self._net_sidecar.on_send(flat.nbytes)

    def deliver_partial(self, agg_id: str, key: str, weight: float,
                        count: int, round_id: int = 0, seq: int = 0) -> None:
        """Route a published partial into the node-side root fold.

        A partial homed on the root node is delivered by key alone (its
        bytes never move); one homed elsewhere triggers daemon→daemon
        shipping — the home daemon dials the root and sends the sealed
        Σ c·u directly, so the controller never carries it.  Any
        failure surfaces as a :class:`WorkerCrashed` for the root fold,
        which the driver answers by re-rooting."""
        root = self._resolve(agg_id)
        meta = {"agg_id": agg_id, "key": key, "weight": float(weight),
                "count": int(count), "seq": int(seq),
                "round_id": round_id, "partial": True}
        home_name = self._partial_home.get(key)
        home = self._nodes.get(home_name) if home_name else None
        if home is None or not home.alive:
            # lost between the driver's liveness filter and this send:
            # the root fold can never complete — tell the driver now
            self._local["synth_crashes"] += 1
            self._pending.append(WorkerCrashed(
                round_id=round_id, agg_id=agg_id, worker=-1))
            return
        if home_name == root.name:
            # resident on the root already: 16-byte key, no payload.
            # A send failure means the ROOT died — _lose_node already
            # queued the root fold's WorkerCrashed (it is in _open).
            self._send(root, "deliver", meta)
            return
        meta["peer"] = root.addr
        meta["dst"] = root.name
        if not self._send(home, "ship_partial", meta):
            # the home died mid-ship: its teardown only covers subtrees
            # routed *there* — the root fold is routed to the root, so
            # surface its crash explicitly
            self._local["synth_crashes"] += 1
            self._pending.append(WorkerCrashed(
                round_id=round_id, agg_id=agg_id, worker=-1))

    def partial_alive(self, key: str) -> bool:
        home = self._partial_home.get(key)
        node = self._nodes.get(home) if home else None
        return node is not None and node.alive

    def partial_node(self, key: str) -> Optional[str]:
        return self._partial_home.get(key)

    def drain(self, agg_id: str) -> None:
        name = self._route.get(agg_id)
        node = self._nodes.get(name) if name else None
        if node is not None:
            self._send(node, "drain", {"agg_id": agg_id})

    def poll_events(self, timeout: float = 0.0) -> List[RoundEvent]:
        # restarted daemons rejoin through the ordinary poll loop: the
        # probe is backoff-paced per dead node, so a fleet with no
        # deaths pays one attribute check per node here
        self.try_readopt()
        out: List[RoundEvent] = list(self._pending)
        self._pending.clear()
        deadline = time.perf_counter() + timeout
        while True:
            live = self._alive()
            if not live:
                return out
            budget = 0.0 if out else max(0.0, deadline - time.perf_counter())
            try:
                r, _, _ = select.select([n.conn for n in live], [], [],
                                        budget)
            except (OSError, ValueError):
                r = [n.conn for n in live]  # a racing close: probe each
            progressed = False
            for node in live:
                if node.conn not in r:
                    continue
                try:
                    while True:
                        frame = node.conn.recv(timeout=0.0)
                        if frame is None:
                            break
                        progressed = True
                        ev = self._absorb_frame(node, frame)
                        if ev is not None:
                            out.append(ev)
                except PeerDead:
                    out.extend(self._lose_node(node))
                    progressed = True
            if out or not progressed and time.perf_counter() >= deadline:
                return out

    def _absorb_frame(self, node: _Node, frame: Frame
                      ) -> Optional[RoundEvent]:
        if frame.kind == "event":
            ev = from_wire(json.dumps(frame.meta))
            self._note(node, ev)
            return ev
        if frame.kind == "error":
            self._local["refused"] += 1
            agg_id = frame.meta.get("agg_id", "")
            key = frame.meta.get("key", "")
            if frame.meta.get("for") == "deliver" and key:
                # the blob never landed in the node store: forget the
                # residency so any re-delivery re-ships it (the update
                # itself is lost to this subtree — the drain closes it
                # with the folds at hand, like a failed client)
                for n in self._nodes.values():
                    n.delivered.discard(key)
            # a daemon-side SPAWN failure must not hang the round: no
            # aggregator exists, so nothing will ever publish — surface
            # it as a WorkerCrashed so the driver's re-dispatch (or its
            # give-up cap) takes over.  Deliver/drain errors must NOT
            # synthesize a crash: the daemon aggregator is still alive
            # and open, and a respawn+re-deliver would double-fold its
            # already-delivered keys.
            if frame.meta.get("for") == "spawn" and agg_id in self._open:
                rid = self._open.pop(agg_id)
                self._route.pop(agg_id, None)
                self._local["synth_crashes"] += 1
                return WorkerCrashed(round_id=rid, agg_id=agg_id,
                                     worker=-1, exitcode=None)
            # a failed ship (home daemon couldn't read the partial or
            # dial the root) starves the root fold of one input — it
            # will never publish, so surface its crash; the driver
            # re-roots on a survivor
            if frame.meta.get("for") == "ship_partial" \
                    and agg_id in self._open:
                rid = self._open.pop(agg_id)
                self._local["synth_crashes"] += 1
                return WorkerCrashed(round_id=rid, agg_id=agg_id,
                                     worker=-1, exitcode=None)
        return None  # stray pong / late reply: bookkeeping only

    def _note(self, node: _Node, ev: RoundEvent) -> None:
        if isinstance(ev, PartialReady):
            self._partial_home[ev.key] = node.name
            self._open.pop(ev.agg_id, None)

    def quiesce(self, timeout: float = 5.0,
                round_id: Optional[int] = None) -> None:
        """Fleet-wide settle barrier.  With ``round_id`` the barrier is
        scoped: each daemon quiesces only that round's tasks and
        root-fold buffers, so a rolling round can settle while the next
        one keeps dispatching (the driver passes the scope whenever
        another round is in flight)."""
        self._flush_round_scoped_pending()
        # a genuinely dead daemon surfaces as an immediate EOF/reset;
        # the timeout only fires for a connected-but-busy one (a shm
        # node draining model-size accumulators can take a while), so
        # the reply budget is deliberately generous — declaring a slow
        # healthy node dead would remove it from the fleet for good
        reply_timeout = max(timeout, 60.0)
        scope = {} if round_id is None else {"round_id": int(round_id)}
        for node in self._alive():
            if not self._send(node, "quiesce", scope):
                continue
            try:
                stash: List[Frame] = []
                reply = node.conn.recv_expect(("quiesced",), reply_timeout,
                                              stash=stash)
                for f in stash:
                    ev = self._absorb_frame(node, f)
                    if ev is not None:
                        self._pending.append(ev)
                node.stats = dict(reply.meta.get("stats", {}))
                # daemon-level counters (ship_tx_bytes & co) ride along
                # so bench_net can bound inter-node partial shipping
                node.stats.update(reply.meta.get("daemon", {}))
                node.workers = int(reply.meta.get("workers", 0))
                # the LIFL-agent drain: the daemon's MetricsMap rides
                # the quiesced reply (no extra round trip) — merge it
                self._absorb_telemetry(node,
                                       reply.meta.get("telemetry") or {},
                                       reply.meta.get("telemetry_hists"))
            except PeerDead:
                self._pending.extend(self._lose_node(node))
        self._open.clear()
        # a peer death during the barrier queued fresh events: apply
        # the same round-scoped filtering to those too
        self._flush_round_scoped_pending()

    # ------------------------------------------------------------------
    # telemetry (the controller side of the LIFL agent)
    # ------------------------------------------------------------------
    def _absorb_telemetry(self, node: _Node,
                          series: Dict[str, List[float]],
                          hists: Optional[Dict[str, dict]] = None) -> None:
        """One daemon drain landed: accumulate it on the node record
        (for the round trace) and merge it into the controller's
        MetricsMap under node-prefixed owners, counts intact.  Drained
        distribution histograms (if the daemon sent any) merge the same
        way — node-prefixed, bucket counts added."""
        if hists:
            try:
                self.metrics.absorb_hists(hists, prefix=f"{node.name}.")
            except (ValueError, KeyError, TypeError):
                pass   # malformed/mismatched wire hist must not kill a drain
        if not series:
            return
        acc = node.telemetry
        for k, sc in series.items():
            try:
                s, c = float(sc[0]), int(sc[1])
            except (TypeError, ValueError, IndexError):
                continue
            cur = acc.setdefault(k, [0.0, 0])
            cur[0] += s
            cur[1] += c
        self.metrics.absorb_series(series, prefix=f"{node.name}.")

    def take_telemetry(self) -> Dict[str, Dict[str, List[float]]]:
        """Return-and-clear the accumulated per-node telemetry — the
        driver grabs this when it seals a :class:`RoundTrace`, so each
        round's trace carries exactly the samples drained since the
        previous grab."""
        out = {n.name: dict(n.telemetry)
               for n in self._nodes.values() if n.telemetry}
        for n in self._nodes.values():
            n.telemetry = {}
        return out

    def pull_telemetry(self, node: Optional[str] = None,
                       timeout: float = 5.0
                       ) -> Dict[str, Dict[str, List[float]]]:
        """On-demand drain (outside the quiesce barrier): ask each live
        daemon — or just ``node`` — for its MetricsMap via the
        ``telemetry`` frame.  The drained series are merged exactly
        like a quiesce drain and also returned per node."""
        peers = [self._nodes[node]] if node else self._alive()
        pulled: Dict[str, Dict[str, List[float]]] = {}
        for n in peers:
            if not n.alive or not self._send(n, "telemetry", {}):
                continue
            stash: List[Frame] = []
            try:
                reply = n.conn.recv_expect(("telemetry_map",), timeout,
                                           stash=stash)
            except PeerDead:
                self._pending.extend(self._lose_node(n))
                continue
            finally:
                for f in stash:
                    ev = self._absorb_frame(n, f)
                    if ev is not None:
                        self._pending.append(ev)
            series = reply.meta.get("telemetry") or {}
            self._absorb_telemetry(n, series,
                                   reply.meta.get("telemetry_hists"))
            pulled[n.name] = series
        return pulled

    def poll_stats(self, node: Optional[str] = None, timeout: float = 5.0
                   ) -> Dict[str, Dict[str, Any]]:
        """Live scrape (the agent's periodic pull, answerable
        mid-round): ask each live daemon — or just ``node`` — for its
        ``stats`` frame.  NON-destructive, unlike :meth:`pull_telemetry`:
        the reply is a snapshot (series + hist wire dicts + health
        gauges + uptime/epoch), so scraping never steals samples from
        the round-edge drain.  Nothing is merged into the controller
        map — a snapshot absorbed repeatedly would double-count."""
        peers = [self._nodes[node]] if node else self._alive()
        out: Dict[str, Dict[str, Any]] = {}
        for n in peers:
            if not n.alive or not self._send(n, "stats", {}):
                continue
            stash: List[Frame] = []
            t0 = time.perf_counter()
            try:
                reply = n.conn.recv_expect(("stats_reply",), timeout,
                                           stash=stash)
            except PeerDead:
                self._pending.extend(self._lose_node(n))
                continue
            finally:
                for f in stash:
                    ev = self._absorb_frame(n, f)
                    if ev is not None:
                        self._pending.append(ev)
            self.metrics.observe("wire", "stats_rtt_s",
                                 time.perf_counter() - t0)
            out[n.name] = dict(reply.meta)
        return out

    def _flush_round_scoped_pending(self) -> None:
        """Drop queued round-scoped leftovers at the inter-round
        barrier — a queued-but-undelivered PartialReady would strand
        its remote store object (mirror of InProcRuntime.quiesce) and
        a WorkerCrashed for the closed round would spuriously
        re-dispatch next round's identically-named subtree — while
        KEEPING cluster-state events (NodeLost) that the driver's
        handlers must still see."""
        keep: List[RoundEvent] = []
        for ev in self._pending:
            if isinstance(ev, PartialReady):
                self.discard_partial(ev.key)
            elif isinstance(ev, WorkerCrashed):
                pass  # its round is over; nothing left to re-dispatch
            else:
                keep.append(ev)
        self._pending.clear()
        self._pending.extend(keep)

    # ------------------------------------------------------------------
    # payload plumbing
    # ------------------------------------------------------------------
    def put_update(self, flat: np.ndarray) -> str:
        key = new_object_key()
        self._staged[key] = np.ascontiguousarray(flat)
        return key

    def update_alive(self, key: str) -> bool:
        # staging, not the (possibly dead) node's store, answers: this
        # is what lets a subtree re-dispatch to a *different* node
        return key in self._staged

    def get_partial(self, key: str) -> np.ndarray:
        home = self._partial_home.get(key)
        node = self._nodes.get(home) if home else None
        if node is None or not node.alive:
            raise KeyError(f"partial {key!r} unreachable (node lost)")
        # event frames racing the reply (a straggler's PartialReady
        # publishing mid-FOLD) must reach _pending, not the floor —
        # a dropped one would strand its sealed object in the node
        # store (nobody left to discard it)
        stash: List[Frame] = []
        try:
            node.conn.send("fetch", {"key": key})
            while True:
                frame = node.conn.recv_expect(("object", "error"), 30.0,
                                              stash=stash)
                if frame.kind == "error":
                    raise KeyError(
                        f"fetch {key!r} failed: {frame.meta['msg']}")
                if frame.meta.get("key") == key:
                    break
        except PeerDead as e:
            # the node died between publishing and the fetch: run the
            # full teardown (NodeLost reaches the driver's handlers on
            # the next poll) and abort the round's fold — run_round's
            # exception path closes the round retriable
            self._pending.extend(self._lose_node(node))
            raise KeyError(
                f"partial {key!r} lost with its node ({e})") from e
        finally:
            for f in stash:
                ev = self._absorb_frame(node, f)
                if ev is not None:
                    self._pending.append(ev)
        arr = np.frombuffer(
            frame.blob, dtype=resolve_dtype(frame.meta["dtype"]),
        ).reshape(frame.meta["shape"])
        self._net_sidecar.on_recv(arr.nbytes, 0.0)
        return arr

    def release_partial(self, key: str) -> None:
        pass  # the fetched copy is local; the daemon released at fetch

    def discard_partial(self, key: str) -> None:
        home = self._partial_home.pop(key, None)
        node = self._nodes.get(home) if home else None
        if node is not None and node.alive:
            self._send(node, "discard_partial", {"key": key})

    def discard_update(self, key: str) -> None:
        self._staged.pop(key, None)
        for node in self._alive():
            if key in node.delivered:
                node.delivered.discard(key)
                self._send(node, "discard_update", {"key": key})

    # ------------------------------------------------------------------
    def recycle_engines(self) -> None:
        super().recycle_engines()
        for node in self._alive():
            self._send(node, "recycle", {})

    @property
    def stats(self) -> Dict[str, float]:
        """Aggregated monotonic counters: the sum of every node's last
        quiesced totals plus local transport counters."""
        out: Dict[str, float] = dict(self._local)
        for node in self._nodes.values():
            for k, v in node.stats.items():
                out[k] = out.get(k, 0) + v
        return out

    def worker_count(self) -> int:
        return sum(n.workers for n in self._alive())

    def wire_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-node transport byte counters (bench_net's raw input)."""
        out = {}
        for node in self._nodes.values():
            out[node.name] = {
                "tx_bytes": node.conn.tx_bytes,
                "rx_bytes": node.conn.rx_bytes,
                "tx_by_kind": dict(node.conn.tx_by_kind),
                "rx_by_kind": dict(node.conn.rx_by_kind),
            }
        return out

    def ping(self, node: Optional[str] = None, timeout: float = 5.0) -> float:
        """RTT to one node (default: the first live one)."""
        peers = [self._nodes[node]] if node else self._alive()
        if not peers:
            raise NoLiveNodeError("all node daemons are unreachable")
        stash: List[Frame] = []
        rtt = peers[0].conn.ping(timeout, stash=stash)
        for f in stash:
            ev = self._absorb_frame(peers[0], f)
            if ev is not None:
                self._pending.append(ev)
        return rtt

    def shutdown_nodes(self, timeout: float = 5.0) -> None:
        """Ask every daemon to exit (bench/test teardown helper)."""
        for node in self._alive():
            if self._send(node, "shutdown", {}):
                try:
                    node.conn.recv_expect(("bye",), timeout)
                except PeerDead:
                    pass
                node.alive = False
                node.conn.close()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for node in self._nodes.values():
            node.conn.close()
            node.alive = False
        self._staged.clear()
        self._engines.clear()


# ---------------------------------------------------------------------------
# external-client helper (Session.serve's wire counterpart)
# ---------------------------------------------------------------------------

def push_update(addr: str, client_id: str, update: np.ndarray,
                weight: float = 1.0, *, timeout: float = 10.0,
                submission_id: Optional[str] = None,
                round_id: Optional[int] = None,
                job: str = "",
                retries: int = 2,
                busy_retries: int = 64,
                backoff: Optional[Backoff] = None) -> Dict:
    """Submit one externally-computed model update to a serving
    :class:`~repro.api.Session` (``Session.serve(addr)``) or
    :class:`~repro.serve.AggregationService` from any process.
    Returns the server's ack meta; raises on rejection.

    Transport failures (connect refused, the connection dying before
    the ack) are retried up to ``retries`` times on the shared
    jittered-exponential :class:`Backoff` schedule, and every attempt
    carries the same ``submission_id`` (client-chosen, or generated
    once per call) — the serving session dedupes on
    ``(round_id, client_id, submission_id)``, so a retry racing an
    ack that was sent but never read can never double-fold (its ack
    comes back ``duplicate=True`` instead).

    Admission backpressure (a ``busy`` frame carrying
    ``retry_after_s``) is not a failure: the client sleeps the
    *server's* hint via :meth:`Backoff.sleep_hint` — the exponential
    schedule doesn't advance — and resubmits, up to ``busy_retries``
    times or the backoff's ``deadline_s``.  The final ack meta carries
    ``shed``: how many times this submission was pushed back before it
    landed.  An explicit *rejection* (``error`` frame: wrong size,
    stale ``round_id``) raises ``ValueError`` immediately — retrying a
    refusal cannot succeed."""
    flat = np.ascontiguousarray(update)
    if submission_id is None:
        submission_id = new_object_key()
    meta = {"client_id": client_id, "weight": float(weight),
            "submission_id": submission_id,
            "dtype": str(flat.dtype), "shape": list(flat.shape)}
    if round_id is not None:
        meta["round_id"] = int(round_id)
    if job:
        meta["job"] = job     # multi-job service routing (repro.serve)
    bo = backoff if backoff is not None else Backoff(base=0.1, cap=1.0)
    attempt = 0
    sheds = 0
    while True:
        try:
            conn = connect(addr, timeout=timeout)
            try:
                conn.send("hello", {"role": "client"})
                conn.recv_expect(("welcome",), timeout)
                conn.send("submit_update", meta, blob=flat)
                reply = conn.recv_expect(("ack", "error", "busy"),
                                         timeout)
            finally:
                conn.close()
            if reply.kind == "error":
                raise ValueError(
                    f"submit_update rejected: {reply.meta['msg']}")
            if reply.kind == "busy":
                sheds += 1
                hint = reply.meta.get("retry_after_s", 0.05)
                if sheds > busy_retries or not bo.sleep_hint(hint):
                    raise BusyError(
                        f"submit_update shed {sheds} times by {addr} "
                        f"(queued={reply.meta.get('queued')}); giving "
                        f"up", retry_after_s=hint)
                continue
            out = dict(reply.meta)
            out["shed"] = sheds
            return out
        except PeerDead:
            attempt += 1
            if attempt > retries or not bo.sleep():
                raise
