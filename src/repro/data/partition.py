"""Non-IID federated partitioning (paper §6.2 uses FedScale's real
client-data mapping; we reproduce the statistical shape with Dirichlet
label-skew partitioning, the standard FL benchmark protocol)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np


@dataclass
class ClientShard:
    client_id: str
    indices: np.ndarray

    @property
    def num_samples(self) -> int:
        return len(self.indices)


def dirichlet_partition(
    labels: np.ndarray,
    n_clients: int,
    alpha: float = 0.3,
    seed: int = 0,
    min_samples: int = 2,
) -> List[ClientShard]:
    """Label-skew Dirichlet split: each client's class mix ~ Dir(α)."""
    rng = np.random.default_rng(seed)
    n_classes = int(labels.max()) + 1
    by_class = [np.where(labels == c)[0] for c in range(n_classes)]
    for idxs in by_class:
        rng.shuffle(idxs)
    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for c, idxs in enumerate(by_class):
        if len(idxs) == 0:
            continue
        props = rng.dirichlet(np.full(n_clients, alpha))
        cuts = (np.cumsum(props) * len(idxs)).astype(int)[:-1]
        for cid, part in enumerate(np.split(idxs, cuts)):
            client_idx[cid].extend(part.tolist())
    shards = []
    spare = []
    for cid, idxs in enumerate(client_idx):
        if len(idxs) < min_samples:
            spare.extend(idxs)
            idxs = []
        shards.append(ClientShard(f"client{cid}", np.asarray(idxs, np.int64)))
    # round-robin spare samples into starved clients
    starved = [s for s in shards if s.num_samples < min_samples]
    for i, idx in enumerate(spare):
        if not starved:
            break
        tgt = starved[i % len(starved)]
        tgt.indices = np.append(tgt.indices, idx)
    return shards


def client_sample_counts(shards: List[ClientShard]) -> Dict[str, int]:
    return {s.client_id: s.num_samples for s in shards}
