"""Synthetic data sources.

* ``TokenTaskStream`` — a *learnable* synthetic LM task (affine-recurrent
  token sequences): next-token entropy is genuinely reducible, so the
  end-to-end training examples show real loss curves, not noise fitting.
* ``synthetic_femnist`` — FEMNIST-shaped image classification (28×28×1,
  62 classes) with per-class Gaussian prototypes; learnable by the
  ResNet examples, partitionable non-IID per client.
* ``StragglerModel`` — heavy-tailed client execution times (lognormal /
  shifted-Pareto), the realistic arrival process behind
  ``RoundDeadline`` partial-round coverage: a handful of clients in
  every cohort take many multiples of the median, so a deadline-closed
  round with the partials at hand is the *normal* case, not a corner.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass
class StragglerModel:
    """Heavy-tailed per-client execution times (mobile/edge cohorts).

    ``lognormal``: ``median_s · exp(sigma·Z)`` — the classic device-speed
    spread; with ``sigma≈1`` the p99/p50 ratio is ~10×.
    ``pareto``: shifted Pareto (Lomax + 1 floor), ``median_s`` scales the
    floor; ``alpha ≤ 2`` gives the infinite-variance tail where a single
    client can dominate the round — exactly what the aggregation goal +
    deadline are designed to absorb.

    Deterministic under a seeded ``np.random.Generator``; ``sample``
    never mutates shared state, so two schedulers with equal seeds see
    equal cohorts.
    """

    dist: str = "lognormal"     # "lognormal" | "pareto"
    median_s: float = 1.0
    sigma: float = 1.0          # lognormal shape (log-space std)
    alpha: float = 1.5          # Pareto tail index (≤2 ⇒ inf. variance)

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        """``n`` i.i.d. client exec times (seconds, float64)."""
        if self.dist == "lognormal":
            return self.median_s * np.exp(
                self.sigma * rng.standard_normal(n))
        if self.dist == "pareto":
            # Lomax sample + 1 == Pareto with x_m = 1: floor at median_s
            return self.median_s * (rng.pareto(self.alpha, size=n) + 1.0)
        raise ValueError(f"unknown straggler dist {self.dist!r} "
                         "(expected 'lognormal' or 'pareto')")

    def tail_ratio(self, n: int, rng: np.random.Generator,
                   q: float = 0.99) -> float:
        """p_q / p50 of a size-``n`` sample — the straggler severity
        figure benches and tests assert on."""
        s = self.sample(n, rng)
        return float(np.quantile(s, q) / np.quantile(s, 0.5))


@dataclass
class TokenTaskStream:
    """Markov-ish token stream: next = (a·cur + b + drift(pos)) mod V."""

    vocab_size: int
    seq_len: int
    seed: int = 0
    a: int = 5
    b: int = 17

    def batch(self, batch_size: int, round_id: int = 0) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, round_id))
        V = self.vocab_size
        starts = rng.integers(0, V, size=(batch_size, 1))
        toks = np.zeros((batch_size, self.seq_len), np.int32)
        toks[:, 0] = starts[:, 0]
        noise = rng.random((batch_size, self.seq_len)) < 0.05
        rand_toks = rng.integers(0, V, size=(batch_size, self.seq_len))
        for t in range(1, self.seq_len):
            nxt = (self.a * toks[:, t - 1] + self.b) % V
            toks[:, t] = np.where(noise[:, t], rand_toks[:, t], nxt)
        labels = np.roll(toks, -1, axis=1).astype(np.int32)
        labels[:, -1] = -1
        return {"tokens": toks, "labels": labels}


def synthetic_femnist(
    n_samples: int,
    num_classes: int = 62,
    image_size: int = 28,
    seed: int = 0,
    class_distribution: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """-> images (N, H, W, 1) fp32, labels (N,) int32.

    Class prototypes are fixed Gaussian blobs + frequency gratings so a
    small CNN separates them after a few dozen steps."""
    rng = np.random.default_rng(seed)
    proto_rng = np.random.default_rng(1234)  # shared across clients
    protos = proto_rng.normal(0, 1, size=(num_classes, image_size, image_size))
    # low-pass the prototypes so they're smooth/learnable
    for c in range(num_classes):
        f = np.fft.rfft2(protos[c])
        f[6:, :] = 0
        f[:, 6:] = 0
        protos[c] = np.fft.irfft2(f, s=(image_size, image_size))
    protos /= protos.std(axis=(1, 2), keepdims=True) + 1e-6

    if class_distribution is None:
        class_distribution = np.full((num_classes,), 1.0 / num_classes)
    labels = rng.choice(num_classes, size=n_samples, p=class_distribution)
    noise = rng.normal(0, 0.6, size=(n_samples, image_size, image_size))
    images = protos[labels] + noise
    return images[..., None].astype(np.float32), labels.astype(np.int32)
