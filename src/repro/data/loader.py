"""Federated data pipeline: per-client loaders + cohort batching.

For the fused LM rounds, a cohort loader packs per-client token batches
into the (global_batch, seq) array consumed by the jitted round step;
client boundaries align with microbatches so each microbatch is one
arriving "model update" worth of data.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.data.partition import ClientShard
from repro.data.synthetic import TokenTaskStream


@dataclass
class ClientDataset:
    client_id: str
    images: Optional[np.ndarray] = None
    labels: Optional[np.ndarray] = None

    @property
    def num_samples(self) -> int:
        return 0 if self.labels is None else len(self.labels)

    def batches(self, batch_size: int, epochs: int = 1, seed: int = 0
                ) -> Iterator[Dict[str, np.ndarray]]:
        n = self.num_samples
        if n == 0:
            return
        rng = np.random.default_rng((hash(self.client_id) & 0xFFFF, seed))
        for _ in range(epochs):
            order = rng.permutation(n)
            for i in range(0, n, batch_size):
                sel = order[i : i + batch_size]
                yield {"images": self.images[sel], "labels": self.labels[sel]}


def build_client_datasets(
    images: np.ndarray, labels: np.ndarray, shards: Sequence[ClientShard]
) -> List[ClientDataset]:
    return [
        ClientDataset(s.client_id, images[s.indices], labels[s.indices])
        for s in shards
    ]


class CohortTokenLoader:
    """LM cohorts: ``round_batch`` returns {tokens, labels} of shape
    (global_batch, seq) where each contiguous microbatch slice holds one
    cohort's data (cohort i ⇔ arriving update i)."""

    def __init__(self, vocab_size: int, seq_len: int, n_cohorts: int,
                 seed: int = 0):
        self.streams = [
            TokenTaskStream(vocab_size, seq_len, seed=seed * 1000 + i)
            for i in range(n_cohorts)
        ]
        self.n_cohorts = n_cohorts

    def round_batch(self, global_batch: int, round_id: int) -> Dict[str, np.ndarray]:
        assert global_batch % self.n_cohorts == 0
        per = global_batch // self.n_cohorts
        parts = [s.batch(per, round_id) for s in self.streams]
        return {
            k: np.concatenate([p[k] for p in parts], axis=0)
            for k in parts[0]
        }
