from repro.data.loader import ClientDataset, CohortTokenLoader, build_client_datasets
from repro.data.partition import ClientShard, client_sample_counts, dirichlet_partition
from repro.data.synthetic import (StragglerModel, TokenTaskStream,
                                 synthetic_femnist)

__all__ = [
    "ClientDataset",
    "CohortTokenLoader",
    "build_client_datasets",
    "ClientShard",
    "client_sample_counts",
    "dirichlet_partition",
    "StragglerModel",
    "TokenTaskStream",
    "synthetic_femnist",
]
