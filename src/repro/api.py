"""repro.api — the platform's public surface.

A :class:`Session` is the one object user code talks to: it owns a
:class:`~repro.runtime.trainer.FederatedTrainer`, its
:class:`~repro.runtime.driver.RoundDriver` event loop, and the selected
aggregation runtime (``"inproc"``, ``"shmproc"``, or — when ``nodes``
is a list of daemon addresses — the multi-node ``RemoteRuntime``), and
exposes the whole platform as four verbs::

    with Session.open(model, params, clients, runtime="shmproc") as s:
        s.submit_update("edge-7", flat_delta, weight=12)   # external client
        rec = s.run_round(client_lr=0.05)                   # drive one round
        print(s.metrics()["rounds"][-1], s.evaluate(batch))
    # context exit closes the runtime (idempotent; shm segments unlinked)

Multi-node: point ``nodes`` at running ``netd`` daemons and the same
round loop drives a cross-node hierarchical round (only sealed partial
sums cross the wire); ``serve`` turns the session into an ingest
endpoint for external client processes::

    with Session.open(model, params, clients,
                      nodes=["10.0.0.2:7000", "10.0.0.3:7000"]) as s:
        addr = s.serve("0.0.0.0:7500")   # accepts submit_update frames
        s.run_round(client_lr=0.05)

Everything else — typed events, elastic scaling, node churn — plugs in
through the same event protocol::

    s.on(WorkerCrashed, lambda ev: print("crash:", ev.agg_id))
    s.emit(NodeLost(node="node3"))      # next plan excludes the node
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Type

import numpy as np

from repro.runtime.events import RoundEvent
from repro.runtime.trainer import ClientRuntime, FederatedTrainer, _flatten_tree


class Session:
    """Public facade over one federated-learning job.

    Build with :meth:`open`; use as a context manager.  ``close`` is
    idempotent — double-close and close-after-crash neither raise nor
    leak shared-memory segments."""

    def __init__(self, trainer: FederatedTrainer,
                 admission: Optional[Any] = None):
        self._trainer = trainer
        self._server = None           # Session.serve ingest endpoint
        self._serve_thread: Optional[threading.Thread] = None
        self._serve_stop: Optional[threading.Event] = None
        # admission control (serve/gateway.py): a bounded ingress valve
        # in front of submit_update — over-budget submissions get a
        # busy verdict + retry_after_s instead of unbounded queueing
        self._gateway = None
        if admission is not None and admission is not False:
            from repro.serve.gateway import AdmissionPolicy, IngressGateway

            policy = (AdmissionPolicy() if admission is True
                      else admission)
            self._gateway = IngressGateway(
                policy, emit=trainer.driver.dispatch)
            self._gateway.register("", trainer.submit_update,
                                   lambda: len(trainer._external))

    # ------------------------------------------------------------------
    @classmethod
    def open(
        cls,
        model,
        params: Any,
        clients: Sequence[ClientRuntime],
        *,
        runtime: Any = "inproc",
        nodes: Any = None,        # {name: NodeState} | [netd addresses]
        round_cfg: Optional[Any] = None,
        server_opt: str = "fedavg",
        server_lr: float = 1.0,
        agg_engine: str = "auto",
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 5,
        seed: int = 0,
        wire_compress: Any = 0,
        trace_path: Optional[str] = None,
        admission: Optional[Any] = None,
    ) -> "Session":
        """Open a session: ``model.loss(params, batch)`` plus a client
        fleet, on the chosen aggregation runtime.

        ``nodes`` is either the usual ``{name: NodeState}`` mapping
        (single-node runtimes) or a list of ``netd`` daemon addresses
        (``"host:port"`` / ``"unix:/path"``) — the multi-node mode: a
        :class:`~repro.runtime.netrt.RemoteRuntime` is connected to the
        fleet, and each daemon's name/capacity (from its welcome
        handshake) becomes a placement ``NodeState``.  When
        ``round_cfg`` is omitted, the multi-node default config uses
        the locality placement policy (minimizes cross-node partials)
        and the ``node`` fold topology — the round's top fold runs on
        the busiest worker node, partials ship daemon→daemon, and only
        the final folded Σc·u returns to the controller; a caller
        supplying its own ``RoundConfig`` picks both explicitly
        (``topology`` defaults to ``"controller"``).
        ``wire_compress`` (zlib level, or True for 6) compresses
        update/partial blobs on the frame transport.

        ``trace_path`` appends every round's :class:`RoundTrace` as one
        JSONL record (flushed per line) — read back with
        :func:`repro.obs.read_traces`, which tolerates the truncated
        tail a mid-round kill leaves behind.

        ``admission`` (an :class:`~repro.serve.AdmissionPolicy`, or
        ``True`` for the defaults) puts the serve-plane ingress valve
        in front of ``submit_update``: over-budget submissions get a
        busy verdict carrying ``retry_after_s`` (a ``busy`` frame on
        the serve endpoint) instead of queueing without bound."""
        remote = None
        if wire_compress and not isinstance(nodes, (list, tuple)):
            # single-node runtimes never touch the frame transport, so
            # silently accepting the flag would leave the caller
            # believing their traffic is compressed
            raise ValueError(
                "wire_compress= requires multi-node mode (nodes as a "
                "list of netd addresses) — single-node runtimes have "
                "no wire to compress")
        if isinstance(nodes, (list, tuple)):
            from repro.core.placement import NodeState
            from repro.runtime.netrt import RemoteRuntime

            if runtime != "inproc":
                # the node-side runtime was fixed when each netd was
                # launched (netd --runtime); silently ignoring the
                # caller's choice would be worse than refusing
                raise ValueError(
                    "runtime= cannot be combined with a list of node "
                    "addresses — multi-node sessions always run on the "
                    "RemoteRuntime; pick the per-node runtime with "
                    "netd --runtime instead")
            remote = RemoteRuntime(nodes, agg_engine=agg_engine,
                                   compress=wire_compress)
            nodes = {name: NodeState(node=name, max_capacity=cap)
                     for name, cap in remote.node_info().items()}
            runtime = remote
            if round_cfg is None:
                from repro.core import RoundConfig
                round_cfg = RoundConfig(aggregation_goal=8,
                                        placement_policy="locality",
                                        topology="node")
        try:
            sess = cls(FederatedTrainer(
                model, params, clients,
                nodes=nodes, round_cfg=round_cfg, server_opt=server_opt,
                server_lr=server_lr, agg_engine=agg_engine, runtime=runtime,
                checkpoint_dir=checkpoint_dir,
                checkpoint_every=checkpoint_every,
                seed=seed,
                trace_path=trace_path,
            ), admission=admission)
        except BaseException:
            if remote is not None:
                remote.close()   # the fleet connections must not leak
            raise
        if remote is not None:
            # the connections already exist, so attach eagerly: a
            # session closed before its first round must still close
            # them (single-node runtimes stay lazy), and the wire
            # sidecar should land in the session's metrics map
            remote.metrics = sess._trainer.metrics
            sess._trainer._ensure_runtime()
        return sess

    # ------------------------------------------------------------------
    # the four verbs
    # ------------------------------------------------------------------
    def run_round(self, **kwargs) -> Dict[str, float]:
        """Drive one federated round (see
        :meth:`FederatedTrainer.run_round` for kwargs)."""
        return self._trainer.run_round(**kwargs)

    def submit_update(self, client_id: str, update: Any,
                      weight: float = 1.0, *,
                      submission_id: Optional[str] = None,
                      round_id: Optional[int] = None) -> bool:
        """Inject an externally-computed model update (a flat float32
        vector or a params-shaped pytree delta); it takes a cohort slot
        in the next round.  Pass a ``submission_id`` to make retries
        idempotent (duplicates return ``False`` without queueing) and a
        ``round_id`` to refuse submissions aimed at an already-finished
        round.  Returns ``True`` when the update was queued.

        With ``admission`` configured (:meth:`open`) the submission
        runs through the ingress gateway and the full verdict dict
        comes back instead: ``{"admitted", "busy", "duplicate",
        "queued", "retry_after_s"}`` — ``busy`` means over budget,
        retry after the hint (nothing was queued or dropped)."""
        if isinstance(update, np.ndarray) and update.ndim == 1:
            flat = update
        else:
            flat, _, _ = _flatten_tree(update)
        if self._gateway is not None:
            return self._gateway.admit(
                "", client_id, flat, weight,
                submission_id=submission_id, round_id=round_id)
        return self._trainer.submit_update(
            client_id, flat, weight,
            submission_id=submission_id, round_id=round_id)

    def metrics(self) -> Dict[str, Any]:
        """Snapshot of the job: per-round records, model version, the
        event sidecar series, and driver/event-loop counters.

        ``sidecar`` keeps the legacy flat-sum shape
        (``"owner/metric": total``); ``sidecar_series`` carries the full
        per-series statistics — ``{"sum", "count", "mean"}`` — that the
        sums alone were hiding (a big ``agg_exec_s`` total can mean one
        slow fold or a thousand fast ones)."""
        tr = self._trainer
        snap = tr.metrics.snapshot()
        out: Dict[str, Any] = {
            "rounds": list(tr.log),
            "model_version": tr.coordinator.model_version,
            "runtime": tr.runtime if isinstance(tr.runtime, str)
            else getattr(tr.runtime, "name", "custom"),
            "sidecar": {f"{owner}/{metric}": total for
                        (owner, metric), (total, _n) in snap.items()},
            "sidecar_series": {
                f"{owner}/{metric}": {
                    "sum": total, "count": n,
                    "mean": (total / n) if n else 0.0}
                for (owner, metric), (total, n) in snap.items()},
        }
        out["ingress"] = dict(tr.ingress)
        if self._gateway is not None:
            gw = self._gateway.counters
            out["ingress"]["admitted"] = gw["admitted"]
            out["ingress"]["shed"] += gw["shed"]
            out["ingress"]["queued_now"] = self._gateway.depth()
        if tr._driver is not None:
            out["driver"] = dict(tr._driver.stats)
        # serve/live gauges — same names as the service health surface
        # (tests/test_live.py holds the key parity)
        out["open_rounds"] = (len(tr._driver._open_rounds)
                              if tr._driver is not None else 0)
        out["gateway_queue_depth"] = (self._gateway.depth()
                                      if self._gateway is not None
                                      else len(tr._external))
        out["fleet_nodes_alive"] = self._fleet_nodes_alive()
        out["planner"] = dict(tr.coordinator.plan_cache_stats)
        return out

    def _fleet_nodes_alive(self) -> int:
        rt = self._trainer._runtime
        nodes = getattr(rt, "_nodes", None)
        if isinstance(nodes, dict):
            return sum(1 for n in nodes.values()
                       if getattr(n, "alive", False))
        return 1   # a local runtime IS its one (alive) node

    def status(self) -> Dict[str, Any]:
        """One structured fleet snapshot — the single-job mirror of
        :meth:`AggregationService.health` (identical top-level keys,
        test-enforced), renderable with
        :func:`repro.obs.to_prometheus` / :func:`repro.obs.summary_line`.
        """
        tr = self._trainer
        job = tr.job or ""
        h = tr.metrics.hist("tta", job)
        jobs = {job: {
            "queue_depth": len(tr._external),
            "rounds": len(tr.log),
            "tta": (h.quantiles() if h is not None else
                    {"p50": 0.0, "p90": 0.0, "p99": 0.0,
                     "count": 0, "mean": 0.0}),
            "slo": None,    # per-job SLO targets live on the service
        }}
        if self._gateway is not None:
            gw = self._gateway
            gateway = {"counters": dict(gw.counters),
                       "queue_depth": gw.depth(),
                       "ingest": gw.ingest_quantiles(),
                       "retry_after_s_now": gw.retry_after_now()}
            gw_depth = gw.depth()
        else:
            gateway = {"counters": dict(tr.ingress),
                       "queue_depth": len(tr._external),
                       "ingest": {}, "retry_after_s_now": 0.0}
            gw_depth = len(tr._external)
        rt = tr._runtime
        nodes = getattr(rt, "_nodes", None)
        if isinstance(nodes, dict):
            fleet = {name: {"stale": not getattr(n, "alive", False),
                            "epoch": getattr(n, "epoch", 0)}
                     for name, n in nodes.items()}
        else:
            rt_health = getattr(rt, "health", None)
            fleet = {"local": {"stale": False,
                               "health": (rt_health()
                                          if callable(rt_health)
                                          else {})}}
        return {
            "open_rounds": (len(tr._driver._open_rounds)
                            if tr._driver is not None else 0),
            "gateway_queue_depth": gw_depth,
            "fleet_nodes_alive": self._fleet_nodes_alive(),
            "jobs": jobs,
            "gateway": gateway,
            "fleet": fleet,
            "driver": (dict(tr._driver.stats)
                       if tr._driver is not None else {}),
            "rounds_closed": len(tr.log),
            "monitor": None,   # the FleetMonitor belongs to the service
            "planner": dict(tr.coordinator.plan_cache_stats),
        }

    def trace(self, round_id: Optional[int] = None):
        """The :class:`~repro.obs.RoundTrace` for ``round_id`` (latest
        round when omitted): driver/worker spans plus any per-daemon
        telemetry drained over the wire.  ``trace.breakdown()``
        attributes the round's wall time to tiers (client train, wire,
        mid folds, top fold, control + unaccounted residual)."""
        return self._trainer.trace(round_id)

    def evaluate(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        return self._trainer.evaluate(batch)

    # ------------------------------------------------------------------
    # serve mode: ingest updates from external client processes
    # ------------------------------------------------------------------
    def serve(self, addr: str = "127.0.0.1:0") -> str:
        """Start accepting ``submit_update`` frames from external
        client processes on ``addr`` (see
        :func:`repro.runtime.netrt.push_update` for the client side).
        Each accepted update is queued exactly like
        :meth:`submit_update` — it takes a cohort slot in the next
        round.  Returns the bound address (ephemeral ports resolved);
        idempotent while already serving.  The listener runs on a
        daemon thread and stops with :meth:`close`."""
        if self._server is not None:
            return self._server.addr
        from repro.runtime.netrt.transport import FrameServer, PeerDead

        server = FrameServer(addr)
        stop = threading.Event()

        def loop() -> None:
            while not stop.is_set():
                for conn, frame in server.poll(0.1):
                    if frame is None:
                        continue
                    try:
                        self._serve_frame(conn, frame)
                    except PeerDead:
                        pass
                    except Exception as e:  # reject, don't die
                        try:
                            conn.send("error",
                                      {"msg": f"{type(e).__name__}: {e}"})
                        except PeerDead:
                            pass

        self._server = server
        self._serve_stop = stop
        self._serve_thread = threading.Thread(
            target=loop, name="session-serve", daemon=True)
        self._serve_thread.start()
        return server.addr

    def _serve_frame(self, conn, frame) -> None:
        from repro.runtime.netrt.transport import resolve_dtype

        if frame.kind == "hello":
            conn.send("welcome", {"node": "session", "proto": 1,
                                  "capacity": 0.0, "runtime": "serve"})
        elif frame.kind == "ping":
            conn.send("pong", {"t": frame.meta.get("t")})
        elif frame.kind == "submit_update":
            # the frombuffer view is already a fresh read-only array
            # over this frame's blob; the trainer copies iff it must
            # (dtype/contiguity), so no extra model-size memcpy here
            flat = np.frombuffer(
                frame.blob, dtype=resolve_dtype(frame.meta["dtype"]),
            ).reshape(frame.meta["shape"])
            verdict = self.submit_update(
                frame.meta["client_id"], flat,
                weight=frame.meta.get("weight", 1.0),
                submission_id=frame.meta.get("submission_id"),
                round_id=frame.meta.get("round_id"))
            if isinstance(verdict, dict):       # admission configured
                if verdict["busy"]:
                    conn.send("busy", {
                        "client_id": frame.meta["client_id"],
                        "retry_after_s": verdict["retry_after_s"],
                        "queued": verdict["queued"]})
                    return
                conn.send("ack", {"client_id": frame.meta["client_id"],
                                  "queued": verdict["queued"],
                                  "duplicate": verdict["duplicate"]})
                return
            conn.send("ack", {"client_id": frame.meta["client_id"],
                              "queued": len(self._trainer._external),
                              "duplicate": not verdict})
        else:
            conn.send("error", {"msg": f"unknown frame {frame.kind!r}"})

    @property
    def serve_addr(self) -> Optional[str]:
        return self._server.addr if self._server is not None else None

    # ------------------------------------------------------------------
    # event protocol
    # ------------------------------------------------------------------
    def on(self, event_type: Type[RoundEvent],
           handler: Callable[[RoundEvent], None]) -> None:
        """Subscribe a handler to a typed round event."""
        self._trainer.driver.on(event_type, handler)

    def emit(self, event: RoundEvent) -> bool:
        """Inject an event into the driver (node churn, scale
        decisions, deadlines).  Returns False if an ordering guard
        dropped it."""
        return self._trainer.driver.emit(event)

    # ------------------------------------------------------------------
    @property
    def params(self) -> Any:
        return self._trainer.params

    @property
    def trainer(self) -> FederatedTrainer:
        return self._trainer

    @property
    def nodes(self) -> Dict[str, Any]:
        return self._trainer.nodes

    @property
    def closed(self) -> bool:
        return self._trainer.closed

    def close(self) -> None:
        if self._serve_stop is not None:
            self._serve_stop.set()
            self._serve_thread.join(timeout=5.0)
            self._server.close()
            self._server = self._serve_thread = self._serve_stop = None
        self._trainer.close()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
