"""repro.api — the platform's public surface.

A :class:`Session` is the one object user code talks to: it owns a
:class:`~repro.runtime.trainer.FederatedTrainer`, its
:class:`~repro.runtime.driver.RoundDriver` event loop, and the selected
aggregation runtime (``"inproc"`` or ``"shmproc"``), and exposes the
whole platform as four verbs::

    with Session.open(model, params, clients, runtime="shmproc") as s:
        s.submit_update("edge-7", flat_delta, weight=12)   # external client
        rec = s.run_round(client_lr=0.05)                   # drive one round
        print(s.metrics()["rounds"][-1], s.evaluate(batch))
    # context exit closes the runtime (idempotent; shm segments unlinked)

Everything else — typed events, elastic scaling, node churn — plugs in
through the same event protocol::

    s.on(WorkerCrashed, lambda ev: print("crash:", ev.agg_id))
    s.emit(NodeLost(node="node3"))      # next plan excludes the node
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Type

import numpy as np

from repro.runtime.events import RoundEvent
from repro.runtime.trainer import ClientRuntime, FederatedTrainer, _flatten_tree


class Session:
    """Public facade over one federated-learning job.

    Build with :meth:`open`; use as a context manager.  ``close`` is
    idempotent — double-close and close-after-crash neither raise nor
    leak shared-memory segments."""

    def __init__(self, trainer: FederatedTrainer):
        self._trainer = trainer

    # ------------------------------------------------------------------
    @classmethod
    def open(
        cls,
        model,
        params: Any,
        clients: Sequence[ClientRuntime],
        *,
        runtime: Any = "inproc",
        nodes: Optional[Dict[str, Any]] = None,
        round_cfg: Optional[Any] = None,
        server_opt: str = "fedavg",
        server_lr: float = 1.0,
        agg_engine: str = "auto",
        checkpoint_dir: Optional[str] = None,
        checkpoint_every: int = 5,
        seed: int = 0,
    ) -> "Session":
        """Open a session: ``model.loss(params, batch)`` plus a client
        fleet, on the chosen aggregation runtime."""
        return cls(FederatedTrainer(
            model, params, clients,
            nodes=nodes, round_cfg=round_cfg, server_opt=server_opt,
            server_lr=server_lr, agg_engine=agg_engine, runtime=runtime,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            seed=seed,
        ))

    # ------------------------------------------------------------------
    # the four verbs
    # ------------------------------------------------------------------
    def run_round(self, **kwargs) -> Dict[str, float]:
        """Drive one federated round (see
        :meth:`FederatedTrainer.run_round` for kwargs)."""
        return self._trainer.run_round(**kwargs)

    def submit_update(self, client_id: str, update: Any,
                      weight: float = 1.0) -> None:
        """Inject an externally-computed model update (a flat float32
        vector or a params-shaped pytree delta); it takes a cohort slot
        in the next round."""
        if isinstance(update, np.ndarray) and update.ndim == 1:
            flat = update
        else:
            flat, _, _ = _flatten_tree(update)
        self._trainer.submit_update(client_id, flat, weight)

    def metrics(self) -> Dict[str, Any]:
        """Snapshot of the job: per-round records, model version, the
        event sidecar series, and driver/event-loop counters."""
        tr = self._trainer
        out: Dict[str, Any] = {
            "rounds": list(tr.log),
            "model_version": tr.coordinator.model_version,
            "runtime": tr.runtime if isinstance(tr.runtime, str)
            else getattr(tr.runtime, "name", "custom"),
            "sidecar": {f"{owner}/{metric}": total for
                        (owner, metric), (total, _n)
                        in tr.metrics.snapshot().items()},
        }
        if tr._driver is not None:
            out["driver"] = dict(tr._driver.stats)
        return out

    def evaluate(self, batch: Dict[str, np.ndarray]) -> Dict[str, float]:
        return self._trainer.evaluate(batch)

    # ------------------------------------------------------------------
    # event protocol
    # ------------------------------------------------------------------
    def on(self, event_type: Type[RoundEvent],
           handler: Callable[[RoundEvent], None]) -> None:
        """Subscribe a handler to a typed round event."""
        self._trainer.driver.on(event_type, handler)

    def emit(self, event: RoundEvent) -> bool:
        """Inject an event into the driver (node churn, scale
        decisions, deadlines).  Returns False if an ordering guard
        dropped it."""
        return self._trainer.driver.emit(event)

    # ------------------------------------------------------------------
    @property
    def params(self) -> Any:
        return self._trainer.params

    @property
    def trainer(self) -> FederatedTrainer:
        return self._trainer

    @property
    def nodes(self) -> Dict[str, Any]:
        return self._trainer.nodes

    @property
    def closed(self) -> bool:
        return self._trainer.closed

    def close(self) -> None:
        self._trainer.close()

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
