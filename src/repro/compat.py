"""Version-compat shims for the pinned jax.

The code targets the modern mesh API (``jax.set_mesh``,
``jax.shard_map(..., axis_names=..., check_vma=...)``); the image pins
jax 0.4.37, where neither exists.  Call sites go through these shims so
the semantics stay identical on both API generations:

  * ``use_mesh(mesh)``   — context manager activating ``mesh``:
    ``jax.set_mesh`` (new) → ``jax.sharding.use_mesh`` (mid) → the
    ``Mesh`` object itself (0.4.x: ``Mesh.__enter__`` installs the
    resource env used by jit/shard_map).
  * ``shard_map(...)``   — new-style partial-manual mapping: axes in
    ``axis_names`` are manual, the rest stay GSPMD-auto.  On 0.4.x this
    lowers to ``jax.experimental.shard_map.shard_map`` with
    ``auto = mesh.axis_names - axis_names`` and
    ``check_rep = check_vma``.
"""
from __future__ import annotations

from typing import Any, Optional, Set

import jax


def use_mesh(mesh):
    """``with use_mesh(mesh):`` — works on every supported jax."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    um = getattr(jax.sharding, "use_mesh", None)
    if um is not None:
        return um(mesh)
    return mesh  # 0.4.x: Mesh is itself a context manager


# 0.4.x jaxlib hard-crashes (SIGFPE in the SPMD partitioner) on nested
# shard_map — callers with a nested-manual structure (fl/round's
# per-pod hierarchy wrapping a model that shard_maps internally) must
# use a non-nested formulation when this is False.
NESTED_SHARD_MAP_OK = hasattr(jax, "shard_map")


def axis_size(name) -> int:
    """``jax.lax.axis_size`` (new) — on 0.4.x ``psum(1, name)``, which
    folds to the static axis size."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    return jax.lax.psum(1, name)


def _context_mesh():
    """The mesh installed by ``use_mesh`` on 0.4.x (resource env)."""
    from jax._src import mesh as mesh_lib

    m = mesh_lib.thread_resources.env.physical_mesh
    if m.empty:
        raise ValueError(
            "shard_map without an explicit mesh needs an active "
            "`with use_mesh(mesh):` context"
        )
    return m


def shard_map(
    f,
    mesh=None,
    *,
    in_specs: Any,
    out_specs: Any,
    axis_names: Optional[Set[str]] = None,
    check_vma: bool = True,
):
    """New-API ``jax.shard_map`` signature on old and new jax.

    ``mesh=None`` resolves the context mesh (``use_mesh``), matching the
    modern API's behavior."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kwargs = dict(in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if mesh is not None:
            kwargs["mesh"] = mesh
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return sm(f, **kwargs)
    from jax.experimental.shard_map import shard_map as legacy_shard_map

    if mesh is None:
        mesh = _context_mesh()
    # Always full-manual on 0.4.x: partial-auto (``auto=...``) lowers
    # ``axis_index`` to a PartitionId instruction the SPMD partitioner
    # rejects.  Axes not named in the specs are simply replicated, which
    # preserves semantics (at worst it costs an extra boundary gather).
    return legacy_shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=bool(check_vma),
    )
