"""Sharding-aware checkpointing (paper App-B).

The LIFL agent checkpoints global model params to external persistent
storage after the aggregation goal is met; checkpointing runs
*asynchronously* so it never adds to the aggregation completion time.

Format: one ``.npz`` per checkpoint with flattened path keys +
a JSON manifest (step, model version, pytree structure).  Restore
re-shards onto whatever mesh the restoring process runs (device count
may differ — elastic restart).
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":
            # npz has no bf16; fp32 holds every bf16 exactly (lossless),
            # restore casts back to the target leaf dtype
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(
    directory: str | Path,
    step: int,
    params: Any,
    extra: Optional[Dict[str, Any]] = None,
) -> Path:
    """Synchronous save: gathers shards to host and writes npz + manifest."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"ckpt_{step:08d}.npz.tmp"
    final = directory / f"ckpt_{step:08d}.npz"
    flat = _flatten(params)
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
    tmp.rename(final)  # atomic publish
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(flat.keys()),
        "extra": extra or {},
    }
    (directory / f"ckpt_{step:08d}.json").write_text(json.dumps(manifest))
    (directory / "LATEST").write_text(str(step))
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    marker = Path(directory) / "LATEST"
    if not marker.exists():
        return None
    return int(marker.read_text().strip())


def restore_checkpoint(
    directory: str | Path,
    like: Any,
    step: Optional[int] = None,
    shardings: Optional[Any] = None,
) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (a params pytree or
    ShapeDtypeStructs); re-shards with ``shardings`` when given."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    data = np.load(directory / f"ckpt_{step:08d}.npz")
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path
        )
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf {key}: shape {arr.shape} != expected {leaf.shape}"
            )
        leaves.append(arr.astype(leaf.dtype))
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.device_put(restored, shardings)
    return restored, step


class AsyncCheckpointer:
    """Background-thread checkpointing (App-B): ``submit`` returns
    immediately; the previous write is joined first so at most one write
    is in flight and checkpoints commit in order."""

    def __init__(self, directory: str | Path):
        self.directory = Path(directory)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.completed: int = 0

    def submit(self, step: int, params: Any,
               extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        # snapshot to host *before* returning so the training step can
        # donate/overwrite device buffers safely
        host = _flatten(params)

        def run():
            try:
                directory = self.directory
                directory.mkdir(parents=True, exist_ok=True)
                tmp = directory / f"ckpt_{step:08d}.npz.tmp"
                final = directory / f"ckpt_{step:08d}.npz"
                with open(tmp, "wb") as f:
                    np.savez(f, **host)
                tmp.rename(final)
                manifest = {"step": step, "time": time.time(),
                            "keys": sorted(host.keys()), "extra": extra or {}}
                (directory / f"ckpt_{step:08d}.json").write_text(
                    json.dumps(manifest)
                )
                (directory / "LATEST").write_text(str(step))
                self.completed += 1
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
