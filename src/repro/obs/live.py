"""Live fleet telemetry: streaming histograms + the scraping agent.

The paper's LIFL agent (§4.3) *periodically* drains each node's
in-kernel metric maps toward a metrics server that drives scaling and
admission decisions.  PR 7 gave the repro the round-edge half of that
loop (quiesce piggyback + on-demand ``telemetry`` pull); this module
adds the live half:

  * :class:`Histogram` — a log-bucketed streaming histogram with a
    bounded relative error and a *fixed* bucket count (the in-kernel
    map analogue: constant memory however many samples land).  It is
    mergeable (daemon → controller absorb), JSON-wire-serializable on
    the same seam as spans/events, and answers p50/p90/p99 with
    relative error ≤ ``rel_err`` for any value in its tracked range.
  * :class:`SLOTracker` — per-job targets (p99 TTA, max shed fraction)
    fed by scrapes; a *sustained* violation emits one typed
    :class:`~repro.runtime.events.SLOBreached` on the driver bus.
  * :class:`FleetMonitor` — the agent: a thread that scrapes every
    daemon's ``stats`` frame on a jittered period *mid-round* (its own
    monitor connections — never the driver's), detects stale daemons
    faster than round-edge EOF detection, and feeds the SLO tracker.

Everything here is host-side bookkeeping: the histograms record only
at existing event edges (gateway admit, trace seal, TELEM records,
ping), so the idle-cost contract of ``obs/`` holds.
"""
from __future__ import annotations

import math
import random
import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

__all__ = [
    "FleetMonitor",
    "Histogram",
    "SLOTarget",
    "SLOTracker",
]


class Histogram:
    """Log-bucketed streaming histogram (DDSketch-flavoured).

    Bucket ``i`` covers ``(γ^(lo+i-1), γ^(lo+i)]`` with
    ``γ = (1+rel_err)/(1-rel_err)``; a quantile answers the bucket's
    geometric representative ``2·γ^g/(γ+1)``, which is within
    ``rel_err`` of any value in the bucket.  The bucket count is fixed
    at construction — values outside ``[min_value, min_value·γ^n)``
    clamp into the edge buckets (the error bound holds only inside the
    tracked range), and values ≤ 0 or below ``min_value`` land in a
    dedicated zero bucket.  Defaults track 10 ns … ~10 h, which covers
    every latency this platform measures.
    """

    __slots__ = ("rel_err", "min_value", "n_buckets", "_gamma",
                 "_log_gamma", "_lo", "zero", "sum", "_buckets")

    WIRE_KEYS = ("rel_err", "min_value", "n_buckets", "zero", "sum",
                 "buckets")

    def __init__(self, rel_err: float = 0.05, min_value: float = 1e-8,
                 n_buckets: int = 288):
        if not 0.0 < rel_err < 1.0:
            raise ValueError(f"rel_err must be in (0, 1): {rel_err}")
        if min_value <= 0.0:
            raise ValueError(f"min_value must be > 0: {min_value}")
        self.rel_err = float(rel_err)
        self.min_value = float(min_value)
        self.n_buckets = int(n_buckets)
        self._gamma = (1.0 + self.rel_err) / (1.0 - self.rel_err)
        self._log_gamma = math.log(self._gamma)
        self._lo = math.ceil(math.log(self.min_value) / self._log_gamma)
        self.zero = 0              # samples ≤ min_value (incl. 0, <0)
        self.sum = 0.0             # exact running sum (for the mean)
        self._buckets: Dict[int, int] = {}   # sparse; index ∈ [0, n)

    # -- recording ---------------------------------------------------
    def observe(self, value: float, count: int = 1) -> None:
        v = float(value)
        self.sum += v * count
        if not v > self.min_value or v != v:       # ≤ min, or NaN
            self.zero += count
            return
        i = math.ceil(math.log(v) / self._log_gamma) - self._lo
        i = 0 if i < 0 else (self.n_buckets - 1
                             if i >= self.n_buckets else i)
        self._buckets[i] = self._buckets.get(i, 0) + count

    @property
    def count(self) -> int:
        return self.zero + sum(self._buckets.values())

    @property
    def mean(self) -> float:
        n = self.count
        return self.sum / n if n else 0.0

    # -- queries -----------------------------------------------------
    def _value_of(self, bucket: int) -> float:
        g = self._lo + bucket
        return 2.0 * (self._gamma ** g) / (self._gamma + 1.0)

    def quantile(self, q: float, default: float = 0.0) -> float:
        """The q-quantile estimate (q ∈ [0, 1]); ``default`` when the
        histogram is empty.  Relative error ≤ ``rel_err`` for values
        inside the tracked range."""
        n = self.count
        if n == 0:
            return default
        rank = q * (n - 1)
        cum = self.zero
        if cum > rank:
            return 0.0
        for i in sorted(self._buckets):
            cum += self._buckets[i]
            if cum > rank:
                return self._value_of(i)
        return self._value_of(max(self._buckets))   # q == 1 edge

    @property
    def p50(self) -> float:
        return self.quantile(0.50)

    @property
    def p90(self) -> float:
        return self.quantile(0.90)

    @property
    def p99(self) -> float:
        return self.quantile(0.99)

    def quantiles(self) -> Dict[str, float]:
        """The standard export tuple plus count/mean — what health
        snapshots and Prometheus rendering consume."""
        return {"p50": self.p50, "p90": self.p90, "p99": self.p99,
                "count": self.count, "mean": self.mean}

    # -- merge / drain -----------------------------------------------
    def _compatible(self, other: "Histogram") -> bool:
        return (self.rel_err == other.rel_err
                and self.min_value == other.min_value
                and self.n_buckets == other.n_buckets)

    def merge(self, other: "Histogram") -> "Histogram":
        """Absorb ``other`` in place (bucket-count addition — exact,
        associative, commutative).  Shapes must match."""
        if not self._compatible(other):
            raise ValueError("cannot merge histograms with different "
                             "rel_err/min_value/n_buckets")
        self.zero += other.zero
        self.sum += other.sum
        for i, c in other._buckets.items():
            self._buckets[i] = self._buckets.get(i, 0) + c
        return self

    def copy(self) -> "Histogram":
        h = Histogram(self.rel_err, self.min_value, self.n_buckets)
        h.zero = self.zero
        h.sum = self.sum
        h._buckets = dict(self._buckets)
        return h

    def drain(self) -> "Histogram":
        """Return-and-reset (the agent's destructive map retrieval —
        the histogram analogue of ``MetricsMap.drain``)."""
        out = self.copy()
        self.zero = 0
        self.sum = 0.0
        self._buckets.clear()
        return out

    # -- wire --------------------------------------------------------
    def to_wire(self) -> Dict[str, Any]:
        """JSON-safe codec on the spans/events seam: plain dict, string
        bucket keys, round-trips through :meth:`from_wire`."""
        return {
            "rel_err": self.rel_err,
            "min_value": self.min_value,
            "n_buckets": self.n_buckets,
            "zero": self.zero,
            "sum": self.sum,
            "buckets": {str(i): int(c)
                        for i, c in sorted(self._buckets.items())},
        }

    @classmethod
    def from_wire(cls, d: Dict[str, Any]) -> "Histogram":
        h = cls(rel_err=float(d["rel_err"]),
                min_value=float(d["min_value"]),
                n_buckets=int(d["n_buckets"]))
        h.zero = int(d.get("zero", 0))
        h.sum = float(d.get("sum", 0.0))
        for k, c in dict(d.get("buckets", {})).items():
            h._buckets[int(k)] = int(c)
        return h


# ---------------------------------------------------------------------------
# per-job SLO tracking
# ---------------------------------------------------------------------------


@dataclass
class SLOTarget:
    """One job's service-level objective: the p99 time-to-aggregate it
    promises its pushers, and how much admission shedding it tolerates
    before the platform should act (scale, re-weight, alert)."""

    p99_tta_s: float = float("inf")
    max_shed_frac: float = 1.0

    @classmethod
    def coerce(cls, spec: Any) -> "SLOTarget":
        if isinstance(spec, cls):
            return spec
        if isinstance(spec, dict):
            return cls(**spec)
        raise TypeError(f"not an SLO spec: {spec!r}")


class SLOTracker:
    """Scrape-fed per-job SLO evaluation with hysteresis.

    Each :meth:`observe` compares one scrape's measured p99 TTA and
    shed fraction against the job's target.  ``breach_after``
    *consecutive* violating scrapes emit one typed ``SLOBreached``
    event through ``emit`` (the driver bus); the breach re-arms after
    a clean scrape, so a persistent straggler fires once per sustained
    episode, not once per scrape."""

    def __init__(self, *, breach_after: int = 3,
                 emit: Optional[Callable[[Any], Any]] = None):
        self.breach_after = int(breach_after)
        self._emit = emit
        self._lock = threading.Lock()
        self._targets: Dict[str, SLOTarget] = {}
        self._state: Dict[str, Dict[str, Any]] = {}
        self.breaches = 0

    def set_target(self, job: str, target: Any) -> None:
        with self._lock:
            self._targets[job] = SLOTarget.coerce(target)
            self._state.setdefault(job, {
                "violations": 0, "breached": False,
                "p99_tta_s": 0.0, "shed_frac": 0.0, "scrapes": 0})

    def target(self, job: str) -> Optional[SLOTarget]:
        return self._targets.get(job)

    def observe(self, job: str, *, p99_tta_s: float,
                shed_frac: float) -> Optional[Any]:
        """Feed one scrape; returns the emitted ``SLOBreached`` when
        this scrape crossed the sustained-violation threshold."""
        with self._lock:
            tgt = self._targets.get(job)
            st = self._state.setdefault(job, {
                "violations": 0, "breached": False,
                "p99_tta_s": 0.0, "shed_frac": 0.0, "scrapes": 0})
            st["scrapes"] += 1
            st["p99_tta_s"] = float(p99_tta_s)
            st["shed_frac"] = float(shed_frac)
            if tgt is None:
                return None
            over_tta = p99_tta_s > tgt.p99_tta_s
            over_shed = shed_frac > tgt.max_shed_frac
            if not (over_tta or over_shed):
                st["violations"] = 0
                st["breached"] = False
                return None
            st["violations"] += 1
            if st["violations"] < self.breach_after or st["breached"]:
                return None
            st["breached"] = True
            self.breaches += 1
            metric, measured, target = (
                ("p99_tta_s", float(p99_tta_s), tgt.p99_tta_s)
                if over_tta else
                ("shed_frac", float(shed_frac), tgt.max_shed_frac))
            window = st["violations"]
        from repro.runtime.events import SLOBreached

        ev = SLOBreached(job=job, metric=metric, measured=measured,
                         target=target, window=window)
        if self._emit is not None:
            try:
                self._emit(ev)
            except Exception:
                pass
        return ev

    def status(self, job: Optional[str] = None) -> Dict[str, Any]:
        """Per-job view: target (if any) + the last scrape's numbers."""
        with self._lock:
            jobs = [job] if job is not None else sorted(
                set(self._targets) | set(self._state))
            out: Dict[str, Any] = {}
            for j in jobs:
                tgt = self._targets.get(j)
                st = self._state.get(j, {
                    "violations": 0, "breached": False,
                    "p99_tta_s": 0.0, "shed_frac": 0.0, "scrapes": 0})
                out[j] = {
                    "target": ({"p99_tta_s": tgt.p99_tta_s,
                                "max_shed_frac": tgt.max_shed_frac}
                               if tgt is not None else None),
                    **st,
                }
        return out[job] if job is not None else out


# ---------------------------------------------------------------------------
# the scraping agent
# ---------------------------------------------------------------------------

#: driver phases that mean "a round is in flight between SPAWN and FOLD"
_MID_ROUND_PHASES = frozenset(("spawn", "dispatch", "collect", "fold"))


class FleetMonitor(threading.Thread):
    """The paper's per-node agent, controller-side: scrape every netd's
    ``stats`` frame on a jittered period — *while rounds run* — plus
    the service's own gateway/driver surfaces, and feed the SLO
    tracker.

    The monitor owns its connections (``role="monitor"`` hello): the
    driver thread's controller conns are never touched, so a scrape
    can land mid-``recv_expect`` without corrupting a round.  A daemon
    that stops answering (SIGKILL, hang) shows ``stale=True`` on the
    very next scrape — typically well before the driver's round-edge
    EOF detection notices.
    """

    def __init__(self, service: Any, *, period_s: float = 0.5,
                 jitter_frac: float = 0.3, scrape_timeout: float = 1.0,
                 seed: int = 0, log_cap: int = 256):
        super().__init__(name="fleet-monitor", daemon=True)
        self.service = service
        self.period_s = float(period_s)
        self.jitter_frac = float(jitter_frac)
        self.scrape_timeout = float(scrape_timeout)
        self._rng = random.Random(seed)
        self._stopev = threading.Event()
        self._lock = threading.Lock()
        self._conns: Dict[str, Any] = {}     # monitor-owned, per node
        #: node → last scrape result (stale flag, health, epoch, age)
        self.fleet: Dict[str, Dict[str, Any]] = {}
        self.scrapes = 0
        self.mid_round_scrapes = 0
        self.stale_events = 0
        self.scrape_wall_s = 0.0             # Σ time inside scrape_once
        self.log: Deque[Dict[str, Any]] = deque(maxlen=log_cap)

    # -- lifecycle ---------------------------------------------------
    def stop(self, timeout: float = 5.0) -> None:
        self._stopev.set()
        if self.is_alive():
            self.join(timeout=timeout)
        for conn in self._conns.values():
            try:
                conn.close()
            except Exception:
                pass
        self._conns.clear()

    def run(self) -> None:
        while not self._stopev.is_set():
            t0 = time.perf_counter()
            try:
                self.scrape_once()
            except Exception:
                pass                 # the agent must outlive bad scrapes
            self.scrape_wall_s += time.perf_counter() - t0
            # jittered period: a fleet of monitors must not thundering-
            # herd their daemons on synchronized ticks
            delay = self.period_s * (
                1.0 + self.jitter_frac * (2.0 * self._rng.random() - 1.0))
            self._stopev.wait(max(0.01, delay))

    # -- node targets ------------------------------------------------
    def _node_addrs(self) -> Dict[str, str]:
        rt = getattr(self.service, "runtime", None)
        nodes = getattr(rt, "_nodes", None)
        if not isinstance(nodes, dict):
            return {}
        out = {}
        for name, node in nodes.items():
            addr = getattr(node, "addr", None)
            if addr:
                out[name] = addr
        return out

    def _monitor_conn(self, name: str, addr: str):
        conn = self._conns.get(name)
        if conn is not None and getattr(conn, "alive", False):
            return conn
        from repro.runtime.netrt.transport import connect

        conn = connect(addr, timeout=self.scrape_timeout)
        conn.send("hello", {"role": "monitor", "proto": 1})
        conn.recv_expect(("welcome",), self.scrape_timeout)
        self._conns[name] = conn
        return conn

    def _scrape_node(self, name: str, addr: str) -> Dict[str, Any]:
        from repro.runtime.netrt.transport import PeerDead

        now = time.perf_counter()
        prev = self.fleet.get(name, {})
        try:
            conn = self._monitor_conn(name, addr)
            t0 = time.perf_counter()
            conn.send("stats", {})
            reply = conn.recv_expect(("stats_reply",),
                                     self.scrape_timeout)
            rtt = time.perf_counter() - t0
        except (PeerDead, OSError) as e:
            self._conns.pop(name, None)
            if not prev.get("stale", False):
                self.stale_events += 1
            return {"stale": True, "error": f"{type(e).__name__}: {e}",
                    "last_ok_age_s": (now - prev["t_scrape"]
                                      if "t_scrape" in prev else -1.0),
                    "t_scrape": prev.get("t_scrape", now),
                    "epoch": prev.get("epoch", 0),
                    "health": prev.get("health", {})}
        m = reply.meta
        self.service.metrics.observe("wire", "stats_rtt_s", rtt)
        return {"stale": False, "t_scrape": now, "rtt_s": rtt,
                "epoch": int(m.get("epoch", 0)),
                "uptime_s": float(m.get("uptime_s", 0.0)),
                "health": dict(m.get("health", {})),
                "series": dict(m.get("series", {})),
                "hists": dict(m.get("hists", {}))}

    # -- one scrape --------------------------------------------------
    def scrape_once(self) -> Dict[str, Any]:
        """One agent tick: daemons, driver phases, gateway, SLOs."""
        svc = self.service
        # is a round between SPAWN and FOLD right now? (the live-drain
        # point the round-edge path can never see)
        drv = getattr(svc, "driver", None)
        phases = []
        if drv is not None:
            phases = [st.phase for st in
                      list(getattr(drv, "_inflight", {}).values())]
        mid_round = any(p in _MID_ROUND_PHASES for p in phases)

        fleet: Dict[str, Dict[str, Any]] = {}
        for name, addr in self._node_addrs().items():
            fleet[name] = self._scrape_node(name, addr)

        gw = getattr(svc, "gateway", None)
        shed_fracs = {}
        slo_fired = []
        trainers = getattr(svc, "_trainers", {})
        slo = getattr(svc, "slo", None)
        for job in list(trainers):
            p99 = svc.metrics.quantile("tta", job, 0.99)
            frac = gw.shed_frac(job) if gw is not None else 0.0
            shed_fracs[job] = frac
            if slo is not None:
                ev = slo.observe(job, p99_tta_s=p99, shed_frac=frac)
                if ev is not None:
                    slo_fired.append(ev)

        with self._lock:
            self.fleet = fleet
            self.scrapes += 1
            if mid_round:
                self.mid_round_scrapes += 1
            rec = {"t": time.perf_counter(), "mid_round": mid_round,
                   "phases": phases,
                   "stale": sorted(n for n, f in fleet.items()
                                   if f.get("stale")),
                   "shed_fracs": shed_fracs,
                   "slo_fired": [type(e).__name__ for e in slo_fired]}
            self.log.append(rec)
        return rec

    # -- views -------------------------------------------------------
    def fleet_view(self) -> Dict[str, Dict[str, Any]]:
        """Snapshot of the last scrape's per-node state (stale flags,
        health gauges, epochs) — what ``service.health()`` embeds."""
        with self._lock:
            return {n: dict(f) for n, f in self.fleet.items()}

    def counters(self) -> Dict[str, Any]:
        with self._lock:
            return {"scrapes": self.scrapes,
                    "mid_round_scrapes": self.mid_round_scrapes,
                    "stale_events": self.stale_events,
                    "scrape_wall_s": self.scrape_wall_s,
                    "period_s": self.period_s}
