"""repro.obs — event-driven tracing + telemetry (paper §4.3).

See README.md in this package for the paper mapping; trace.py for the
span/trace machinery, live.py for streaming histograms and the fleet
monitor (the agent's periodic live drain), export.py for the
Prometheus/human renderers.  The instrumentation hook points live in
the components themselves (runtime/driver.py, runtime/shmrt,
runtime/netrt) — this package only defines the sample types and the
merge/accounting layer, keeping the "zero cost when idle" contract
auditable in one place.
"""
from repro.obs.export import summary_line, to_prometheus
from repro.obs.live import FleetMonitor, Histogram, SLOTarget, SLOTracker
from repro.obs.trace import (
    NULL_TRACER,
    RoundTrace,
    SPAN_KINDS,
    Span,
    Tracer,
    read_traces,
    span_from_wire,
    span_to_wire,
    write_trace,
)

__all__ = [
    "FleetMonitor",
    "Histogram",
    "NULL_TRACER",
    "RoundTrace",
    "SLOTarget",
    "SLOTracker",
    "SPAN_KINDS",
    "Span",
    "Tracer",
    "read_traces",
    "span_from_wire",
    "span_to_wire",
    "summary_line",
    "to_prometheus",
    "write_trace",
]
