"""repro.obs — event-driven tracing + telemetry (paper §4.3).

See README.md in this package for the paper mapping; trace.py for the
span/trace machinery.  The instrumentation hook points live in the
components themselves (runtime/driver.py, runtime/shmrt, runtime/netrt)
— this package only defines the sample types and the merge/accounting
layer, keeping the "zero cost when idle" contract auditable in one
place.
"""
from repro.obs.trace import (
    NULL_TRACER,
    RoundTrace,
    SPAN_KINDS,
    Span,
    Tracer,
    read_traces,
    span_from_wire,
    span_to_wire,
    write_trace,
)

__all__ = [
    "NULL_TRACER",
    "RoundTrace",
    "SPAN_KINDS",
    "Span",
    "Tracer",
    "read_traces",
    "span_from_wire",
    "span_to_wire",
    "write_trace",
]
