"""Render a fleet health snapshot for machines and humans.

Input is the structured dict ``AggregationService.health()`` /
``Session.status()`` return; output is either Prometheus text
exposition format (``to_prometheus``) — the lingua franca every
metrics server scrapes, the repro's stand-in for the paper's metrics
server ingest — or a one-line operator summary (``summary_line``).

Pure functions over plain dicts: no service types imported, so the
renderer works on a snapshot that crossed a process boundary as JSON.
"""
from __future__ import annotations

from typing import Any, Dict, List

__all__ = ["summary_line", "to_prometheus"]

_QUANTS = ("p50", "p90", "p99")


def _esc(v: Any) -> str:
    return str(v).replace("\\", "\\\\").replace('"', '\\"').replace(
        "\n", "\\n")


def _line(out: List[str], name: str, value: Any,
          **labels: Any) -> None:
    try:
        v = float(value)
    except (TypeError, ValueError):
        return
    if labels:
        lab = ",".join(f'{k}="{_esc(labels[k])}"' for k in sorted(labels))
        out.append(f"{name}{{{lab}}} {v:g}")
    else:
        out.append(f"{name} {v:g}")


def to_prometheus(snap: Dict[str, Any], prefix: str = "lifl") -> str:
    """Prometheus text format (one sample per line, sorted label sets,
    trailing newline) from a health snapshot."""
    out: List[str] = []
    for key in ("open_rounds", "gateway_queue_depth",
                "fleet_nodes_alive", "rounds_closed"):
        if key in snap:
            _line(out, f"{prefix}_{key}", snap[key])

    for job, j in sorted(dict(snap.get("jobs") or {}).items()):
        _line(out, f"{prefix}_job_queue_depth", j.get("queue_depth"),
              job=job)
        _line(out, f"{prefix}_job_rounds_total", j.get("rounds"), job=job)
        tta = j.get("tta") or {}
        for q in _QUANTS:
            _line(out, f"{prefix}_job_tta_seconds", tta.get(q),
                  job=job, quantile=q)
        _line(out, f"{prefix}_job_tta_count", tta.get("count"), job=job)
        slo = j.get("slo") or {}
        _line(out, f"{prefix}_job_slo_breached", int(bool(
            slo.get("breached"))), job=job)
        _line(out, f"{prefix}_job_shed_frac", slo.get("shed_frac"),
              job=job)

    gw = snap.get("gateway") or {}
    for k, v in sorted(dict(gw.get("counters") or {}).items()):
        _line(out, f"{prefix}_gateway_{k}_total", v)
    _line(out, f"{prefix}_gateway_queue_depth", gw.get("queue_depth"))
    _line(out, f"{prefix}_gateway_retry_after_seconds",
          gw.get("retry_after_s_now"))
    ing = gw.get("ingest") or {}
    for q in _QUANTS:
        _line(out, f"{prefix}_gateway_ingest_seconds", ing.get(q),
              quantile=q)
    _line(out, f"{prefix}_gateway_ingest_count", ing.get("count"))

    for node, f in sorted(dict(snap.get("fleet") or {}).items()):
        _line(out, f"{prefix}_node_up", 0 if f.get("stale") else 1,
              node=node)
        _line(out, f"{prefix}_node_uptime_seconds", f.get("uptime_s"),
              node=node)
        _line(out, f"{prefix}_node_epoch", f.get("epoch"), node=node)
        for k, v in sorted(dict(f.get("health") or {}).items()):
            _line(out, f"{prefix}_node_{k}", v, node=node)

    for k, v in sorted(dict(snap.get("driver") or {}).items()):
        _line(out, f"{prefix}_driver_{k}_total", v)

    mon = snap.get("monitor") or {}
    _line(out, f"{prefix}_monitor_scrapes_total", mon.get("scrapes"))
    _line(out, f"{prefix}_monitor_mid_round_scrapes_total",
          mon.get("mid_round_scrapes"))
    _line(out, f"{prefix}_monitor_stale_events_total",
          mon.get("stale_events"))
    _line(out, f"{prefix}_monitor_scrape_wall_seconds",
          mon.get("scrape_wall_s"))
    return "\n".join(out) + "\n"


def summary_line(snap: Dict[str, Any]) -> str:
    """One operator-readable line: fleet liveness, rounds, gateway
    pressure, and each job's p99 TTA + SLO state."""
    fleet = dict(snap.get("fleet") or {})
    stale = sorted(n for n, f in fleet.items() if f.get("stale"))
    parts = [
        f"fleet {snap.get('fleet_nodes_alive', '?')}/{len(fleet)} up"
        + (f" (stale: {','.join(stale)})" if stale else ""),
        f"rounds open={snap.get('open_rounds', 0)} "
        f"closed={snap.get('rounds_closed', 0)}",
    ]
    gw = snap.get("gateway") or {}
    counters = gw.get("counters") or {}
    parts.append(
        f"gateway q={gw.get('queue_depth', 0)} "
        f"admitted={counters.get('admitted', 0)} "
        f"shed={counters.get('shed', 0)} "
        f"retry={float(gw.get('retry_after_s_now') or 0.0) * 1e3:.0f}ms")
    for job, j in sorted(dict(snap.get("jobs") or {}).items()):
        tta = j.get("tta") or {}
        slo = j.get("slo") or {}
        flag = " SLO-BREACH" if slo.get("breached") else ""
        parts.append(f"{job or '<job>'}: "
                     f"p99={float(tta.get('p99') or 0.0) * 1e3:.0f}ms"
                     f"{flag}")
    return " | ".join(parts)
