"""Event-driven spans + per-round traces (paper §4.3, the LIFL agent).

The paper's monitoring plane has three pieces: eBPF programs that fire
*only* on send events (zero cost when idle), in-kernel metric maps the
samples land in, and a LIFL agent that drains those maps toward the
metrics server.  This module is the host-side reification of the first
and last pieces for the repro:

  * :class:`Span` / :class:`Tracer` — monotonic-clock begin/end samples
    produced only at existing event edges (driver phase transitions,
    worker publishes, daemon frame handling).  No resident thread, no
    polling; a disabled tracer is two attribute loads per hook.
  * :class:`RoundTrace` — the per-round merge target: driver spans,
    worker spans derived from ring records, and the per-daemon
    ``MetricsMap`` series drained over the wire on quiesce (the agent's
    periodic retrieval, piggybacked on an event edge the round already
    has).
  * :meth:`RoundTrace.breakdown` — attributes round wall time to the
    paper's tiers (client train, wire, mid folds, top fold, control)
    with an explicit unaccounted residual, from *disjoint* driver-side
    intervals so the tiers always sum to the wall clock.

Spans ride the same wire seam as ``runtime/events.py``: frozen
dataclass, JSON codec, a name registry (``SPAN_KINDS``) the tests
iterate.
"""
from __future__ import annotations

import io
import json
import threading
import time
from contextlib import contextmanager
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Tuple


@dataclass(frozen=True)
class Span:
    """One timed interval on somebody's monotonic clock.

    ``t0`` is ``time.perf_counter()`` *of the process that measured it*
    — comparable within one process, not across hosts.  Cross-process
    aggregation therefore happens on durations (``dur_s``), never on
    absolute stamps.
    """

    kind: str = ""
    owner: str = ""            # agg_id / "driver" / node name
    node: str = ""             # where the interval was measured
    round_id: Optional[int] = None
    t0: float = 0.0            # perf_counter at begin (measurer's clock)
    dur_s: float = 0.0
    id: int = -1
    parent: int = -1           # id of the enclosing span (-1: root)
    worker: int = -1           # shm worker index (-1: not a worker span)
    n: float = 0.0             # payload: update count, bytes, ...


#: every span kind the subsystem emits; the wire codec and tests
#: iterate this (same contract as events.EVENT_TYPES).
SPAN_KINDS: Tuple[str, ...] = (
    "round",          # whole run_round call (driver)
    "spawn",          # SPAWN phase: aggregator placement on the runtime
    "dispatch",       # DISPATCH phase: the pump loop, contiguous
    "collect",        # COLLECT phase: waiting on outstanding subtrees
    "fold",           # FOLD phase: root-site fold orchestration
    "client_train",   # Σ time pulling the updates generator (child of dispatch)
    "deliver",        # Σ time in runtime deliver/put_update (child of dispatch)
    "quiesce",        # runtime quiesce barrier (child of collect)
    "subtree",        # per-subtree first-dispatch → PartialReady latency
    "fold.mid",       # Σ measured mid-fold exec over absorbed partials
    "fold.top",       # measured root fold exec at the plan's root site
    "worker.task",    # shm worker: task pickup (ACK) → publish (PARTIAL)
    "worker.wait",    # shm worker: ring-pop wait inside the task (TELEM)
)

_SPAN_KIND_SET = frozenset(SPAN_KINDS)


def span_to_wire(span: Span) -> bytes:
    """Serialize a span for a process/network boundary (JSON) — the
    same seam as ``events.to_wire``."""
    if span.kind not in _SPAN_KIND_SET:
        raise TypeError(f"not a wire-registered span kind: {span.kind!r}")
    d = asdict(span)
    kind = d.pop("kind")
    return json.dumps({"span": kind, **d},
                      separators=(",", ":")).encode("utf-8")


def span_from_wire(raw) -> Span:
    """Inverse of :func:`span_to_wire`; accepts bytes or str."""
    if isinstance(raw, (bytes, bytearray)):
        raw = raw.decode("utf-8")
    d = json.loads(raw)
    kind = d.pop("span", None)
    if kind not in _SPAN_KIND_SET:
        raise ValueError(f"unknown span kind on the wire: {kind!r}")
    return Span(kind=kind, **d)


class Tracer:
    """Edge-driven span recorder.  ``begin``/``end`` cost one clock read
    each; a disabled tracer costs one attribute load per hook and emits
    nothing, which is what ``bench_obs`` holds the enabled path against.
    """

    __slots__ = ("enabled", "_clock", "_lock", "_spans", "_open", "_next")

    def __init__(self, enabled: bool = True, clock=time.perf_counter):
        self.enabled = enabled
        self._clock = clock
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._open: Dict[int, tuple] = {}
        self._next = 0

    def begin(self, kind: str, owner: str = "", node: str = "",
              round_id: Optional[int] = None, parent: int = -1,
              worker: int = -1) -> int:
        """Open a span; returns a token for :meth:`end` (-1 when
        disabled — ``end(-1)`` is a no-op, so callers never branch)."""
        if not self.enabled:
            return -1
        t0 = self._clock()
        with self._lock:
            tok = self._next
            self._next += 1
            self._open[tok] = (kind, owner, node, round_id, parent, worker, t0)
        return tok

    def end(self, token: int, n: float = 0.0) -> Optional[Span]:
        if token < 0 or not self.enabled:
            return None
        t1 = self._clock()
        with self._lock:
            opened = self._open.pop(token, None)
            if opened is None:
                return None
            kind, owner, node, round_id, parent, worker, t0 = opened
            span = Span(kind=kind, owner=owner, node=node,
                        round_id=round_id, t0=t0, dur_s=t1 - t0,
                        id=token, parent=parent, worker=worker, n=n)
            self._spans.append(span)
        return span

    def point(self, kind: str, dur_s: float, owner: str = "",
              node: str = "", round_id: Optional[int] = None,
              parent: int = -1, worker: int = -1, n: float = 0.0,
              t0: float = 0.0) -> Optional[Span]:
        """Record an already-measured interval (aggregates, spans
        reconstructed from ring records / remote clocks)."""
        if not self.enabled:
            return None
        with self._lock:
            tok = self._next
            self._next += 1
            span = Span(kind=kind, owner=owner, node=node,
                        round_id=round_id, t0=t0, dur_s=dur_s,
                        id=tok, parent=parent, worker=worker, n=n)
            self._spans.append(span)
        return span

    @contextmanager
    def span(self, kind: str, **kw) -> Iterator[int]:
        tok = self.begin(kind, **kw)
        try:
            yield tok
        finally:
            self.end(tok)

    def add(self, span: Span) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._spans.append(span)

    def drain(self) -> List[Span]:
        """Take every finished span (the agent's map retrieval)."""
        with self._lock:
            out, self._spans = self._spans, []
        return out

    def reset(self) -> None:
        """Drop any still-open spans — exception paths can abandon
        begins; the driver resets between rounds so they can't pile up."""
        with self._lock:
            self._open.clear()


#: a process-wide disabled tracer, handed to components whose caller
#: did not ask for tracing — keeps every hook unconditional.
NULL_TRACER = Tracer(enabled=False)


@dataclass
class RoundTrace:
    """Everything the subsystem learned about one round, merged: driver
    + worker spans, and the per-daemon ``MetricsMap`` series drained
    over the wire (``{node: {"owner/metric": [sum, count]}}``)."""

    round_id: int = 0
    wall_s: float = 0.0
    spans: List[Span] = field(default_factory=list)
    telemetry: Dict[str, Dict[str, List[float]]] = field(default_factory=dict)
    meta: Dict[str, Any] = field(default_factory=dict)

    # -- queries -----------------------------------------------------
    def sum_kind(self, kind: str) -> float:
        return sum(s.dur_s for s in self.spans if s.kind == kind)

    def spans_of(self, kind: str) -> List[Span]:
        return [s for s in self.spans if s.kind == kind]

    def telemetry_series(self, series: str) -> Tuple[float, int]:
        """Sum one ``owner/metric`` series across every drained node."""
        tot, cnt = 0.0, 0
        for per_node in self.telemetry.values():
            v = per_node.get(series)
            if v:
                tot += float(v[0])
                cnt += int(v[1])
        return tot, cnt

    # -- accounting --------------------------------------------------
    def breakdown(self) -> Dict[str, float]:
        """Attribute round wall time to the paper's tiers.

        The driver loop is single-threaded, so its phase spans (spawn /
        dispatch / collect / fold) are disjoint intervals of the wall
        clock; ``client_train`` and ``deliver`` are measured sub-sums of
        the dispatch phase.  Tiers are a re-binning of that partition:

          client_train  time spent pulling the updates generator
                        (iteration *is* local training)
          mid_folds     measured mid-tier fold exec, clamped to the
                        deliver+collect window it can occupy (shmproc
                        folds run in parallel workers and may overlap)
          wire          what remains of deliver+collect after mid-fold
                        exec: serialize, ring/socket, ship, waiting
          top_fold      measured root fold exec within the fold phase
          control       spawn + loop glue + fold orchestration overhead
          unaccounted   wall − Σ(phases): inter-phase bookkeeping

        The six tiers sum to ``wall_s`` by construction; ``coverage``
        is the attributed fraction (acceptance: ≥ 0.95).
        """
        wall = self.wall_s or self.sum_kind("round")
        spawn = self.sum_kind("spawn")
        dispatch = self.sum_kind("dispatch")
        collect = self.sum_kind("collect")
        fold = self.sum_kind("fold")

        train = min(self.sum_kind("client_train"), dispatch)
        deliver = min(self.sum_kind("deliver"), dispatch - train)
        dispatch_other = max(0.0, dispatch - train - deliver)

        mid = min(self.sum_kind("fold.mid"), deliver + collect)
        wire = max(0.0, deliver + collect - mid)
        top = min(self.sum_kind("fold.top"), fold)
        control = spawn + dispatch_other + max(0.0, fold - top)
        unaccounted = max(0.0, wall - (spawn + dispatch + collect + fold))
        coverage = 1.0 - (unaccounted / wall) if wall > 0 else 0.0
        return {
            "wall_s": wall,
            "client_train_s": train,
            "wire_s": wire,
            "mid_fold_s": mid,
            "top_fold_s": top,
            "control_s": control,
            "unaccounted_s": unaccounted,
            "coverage": coverage,
        }

    def summary(self) -> str:
        """One human line per tier — what examples print."""
        b = self.breakdown()
        wall = b["wall_s"] or 1.0
        parts = []
        for key, label in (("client_train_s", "train"), ("wire_s", "wire"),
                           ("mid_fold_s", "mid-fold"), ("top_fold_s", "top-fold"),
                           ("control_s", "control"), ("unaccounted_s", "other")):
            parts.append(f"{label} {b[key] * 1e3:7.2f}ms ({b[key] / wall:5.1%})")
        return (f"round {self.round_id}: wall {b['wall_s'] * 1e3:.2f}ms | "
                + " | ".join(parts))

    # -- wire --------------------------------------------------------
    def to_wire(self) -> Dict[str, Any]:
        spans = []
        for s in self.spans:
            d = asdict(s)
            kind = d.pop("kind")
            spans.append({"span": kind, **d})
        return {
            "round_id": self.round_id,
            "wall_s": self.wall_s,
            "spans": spans,
            "telemetry": self.telemetry,
            "meta": self.meta,
        }

    @classmethod
    def from_wire(cls, d: Dict[str, Any]) -> "RoundTrace":
        spans = []
        for sd in d.get("spans", ()):
            sd = dict(sd)
            kind = sd.pop("span", "")
            spans.append(Span(kind=kind, **sd))
        return cls(round_id=int(d["round_id"]),
                   wall_s=float(d.get("wall_s", 0.0)),
                   spans=spans,
                   telemetry={str(n): {str(k): [float(v[0]), int(v[1])]
                                       for k, v in per.items()}
                              for n, per in d.get("telemetry", {}).items()},
                   meta=dict(d.get("meta", {})))

    def to_json_line(self) -> str:
        return json.dumps(self.to_wire(), separators=(",", ":"))


def write_trace(path: str, trace: RoundTrace) -> None:
    """Append one round's trace as a JSONL record (flushed per line, so
    a killed process loses at most the line it was writing)."""
    with io.open(path, "a", encoding="utf-8") as f:
        f.write(trace.to_json_line())
        f.write("\n")
        f.flush()


def read_traces(path: str) -> List[RoundTrace]:
    """Tolerant JSONL reader for post-mortems of chaos/fault runs: a
    truncated tail line (daemon/driver killed mid-write) or a corrupt
    record is skipped, everything parseable is returned in file order."""
    out: List[RoundTrace] = []
    try:
        f = io.open(path, "r", encoding="utf-8", errors="replace")
    except OSError:
        return out
    with f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                d = json.loads(line)
            except ValueError:
                continue          # truncated by a kill mid-write
            try:
                out.append(RoundTrace.from_wire(d))
            except (KeyError, TypeError, ValueError):
                continue          # schema drift / corrupt record
    return out
