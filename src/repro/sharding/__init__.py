from repro.sharding.rules import (
    batch_specs,
    cache_specs,
    divisibility_fix,
    param_specs,
    to_named,
)

__all__ = [
    "batch_specs",
    "cache_specs",
    "divisibility_fix",
    "param_specs",
    "to_named",
]
