"""Logical sharding rules: param/batch/cache pytrees -> PartitionSpecs.

Policy (DESIGN.md §5):
  * TP over ``model``: attention heads, FFN hidden, vocab, d_inner (SSM),
    MoE expert axis (EP).
  * FSDP over ``fsdp_axes`` (default ``('data',)``; the flat multi-pod
    policy may add ``'pod'``): the d_model axis of every large matrix.
  * Extra leading axes (layer-stack inside scanned segments) are
    unsharded.
  * Small vectors (norm scales, biases) are replicated.

Rules are name-keyed on the *last* path components, mirroring the
models/* param trees exactly; unseen names fall back to replication
with a loud error in strict mode.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

Fsdp = Tuple[str, ...]


def _base_spec(path: Tuple[str, ...], ndim_base_hint: int, fsdp, model: str):
    """Return (base_rank, spec tuple) for a param identified by path."""
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""

    F = fsdp if fsdp else None
    # --- embeddings / heads -------------------------------------------------
    if name == "embed":
        return 2, (model, None)  # vocab-sharded; lookup is mask+psum
    if name == "lm_head":
        return 2, (None, model)
    if name == "frontend_proj":
        return 2, (F, model)
    # --- attention -----------------------------------------------------------
    if name in ("wq", "wk", "wv", "wq_a", "wq_b", "wkv_a"):
        return 2, (F, model)
    if name == "wkv_b":  # (R, h*(dn+dv)) — latent small, heads sharded
        return 2, (None, model)
    if name == "wo":
        return 2, (model, F)
    if name in ("q_norm", "k_norm"):
        return 1, (None,)
    # --- MoE -----------------------------------------------------------------
    if parent == "experts" and name in ("gate", "up"):
        return 3, (model, F, None)
    if parent == "experts" and name == "down":
        return 3, (model, None, F)
    if name == "router":
        return 2, (F, None)
    # --- dense FFN (incl. shared experts) -----------------------------------
    if name in ("gate", "up"):
        return 2, (F, model)
    if name == "down":
        return 2, (model, F)
    # --- SSM -----------------------------------------------------------------
    if name == "in_proj":
        return 2, (F, model)
    if name == "conv_w":
        return 2, (None, model)
    if name == "x_proj":
        return 2, (model, None)
    if name == "dt_proj":
        return 2, (None, model)
    if name in ("dt_bias", "D"):
        return 1, (model,)
    if name == "A_log":
        return 2, (model, None)
    if name == "out_proj":
        return 2, (model, F)
    # --- norms / scalars ------------------------------------------------------
    if name == "scale" or name.startswith("ln") or "norm" in name:
        return 1, (None,)
    # ResNet leaves (small) and anything unknown: replicate.
    return 0, ()


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            names.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            names.append(str(k.idx))
        elif isinstance(k, jax.tree_util.GetAttrKey):
            names.append(str(k.name))
        else:
            names.append(str(k))
    return tuple(names)


def param_specs(params: Any, *, fsdp: Fsdp = ("data",), model: str = "model") -> Any:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""

    def leaf_spec(path, leaf):
        names = _path_names(path)
        ndim = len(leaf.shape)
        base_rank, base = _base_spec(names, ndim, fsdp, model)
        extra = ndim - base_rank
        if extra < 0:  # rule expects more dims than present (reduced configs)
            base = base[-ndim:] if ndim else ()
            extra = 0
        spec = (None,) * extra + tuple(base)
        # never shard an axis the array can't divide — drop to replicated
        fixed = []
        for size, ax in zip(leaf.shape, spec):
            if ax is None:
                fixed.append(None)
            else:
                fixed.append(ax)
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def divisibility_fix(specs: Any, shapes: Any, mesh) -> Any:
    """Replace any axis assignment that doesn't divide evenly with None.

    (GSPMD requires divisibility; reduced smoke configs and odd dims like
    danube's head_dim=120 shard only where legal.)"""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec: P, leaf):
        out = []
        for i, ax in enumerate(tuple(spec) + (None,) * (len(leaf.shape) - len(spec))):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for a in axes:
                total *= sizes[a]
            out.append(ax if leaf.shape[i] % total == 0 else None)
        return P(*out)

    return jax.tree.map(fix, specs, shapes)


def batch_specs(batch: Any, dp: Tuple[str, ...]) -> Any:
    """Shard the leading (batch) dim of every batch leaf over dp axes."""

    def leaf(x):
        if x.ndim == 0:
            return P()
        return P(dp, *([None] * (x.ndim - 1)))

    return jax.tree.map(leaf, batch)


def cache_specs(caches: Any, dp: Tuple[str, ...], model: str = "model") -> Any:
    """Decode-cache sharding: batch over dp, sequence/capacity over model
    (sequence parallelism for long contexts); SSM state d_inner over model.

    Cache leaves (per segment, layer-stacked):
      k/v      (L, B, cap, KVh, hd)   -> (None, dp, model, None, None)
      c        (L, B, cap, R)         -> (None, dp, model, None)
      k_rope   (L, B, cap, Dr)        -> (None, dp, model, None)
      h (ssm)  (L, B, d_in, N)        -> (None, dp, model, None)
      conv     (L, B, K-1, d_in)      -> (None, dp, None, model)
      cross k/v(L, B, M, KVh, hd)     -> (None, dp, None, None, None)
    """

    def leaf(path, x):
        names = _path_names(path)
        name = names[-1]
        parent = names[-2] if len(names) >= 2 else ""
        if name == "conv":
            return P(None, dp, None, model)
        if name == "h":
            return P(None, dp, model, None)
        if parent == "cross":
            return P(None, dp, *([None] * (x.ndim - 3)))
        # k/v/c/k_rope ring caches: capacity dim sharded over model
        return P(None, dp, model, *([None] * (x.ndim - 3)))

    return jax.tree_util.tree_map_with_path(leaf, caches)


def to_named(specs: Any, mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P),
    )
