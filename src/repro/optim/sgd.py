"""Client-side optimizers (pure JAX).  The paper's clients run plain SGD
(lr 0.01, batch 32, one local epoch — §6.2)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def sgd_init(params: Any) -> Dict:
    return {}


def sgd_apply(params: Any, grads: Any, state: Dict, *, lr: float) -> Tuple[Any, Dict]:
    new = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads,
    )
    return new, state


def momentum_init(params: Any) -> Dict:
    return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def momentum_apply(params: Any, grads: Any, state: Dict, *, lr: float,
                   beta: float = 0.9) -> Tuple[Any, Dict]:
    m = jax.tree.map(
        lambda mm, g: beta * mm + g.astype(jnp.float32), state["m"], grads
    )
    new = jax.tree.map(
        lambda p, mm: (p.astype(jnp.float32) - lr * mm).astype(p.dtype), params, m
    )
    return new, {"m": m}
