"""Adam / AdamW (pure JAX) for the LM training examples."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def adam_init(params: Any) -> Dict:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"step": jnp.zeros((), jnp.int32), "m": zeros(), "v": zeros()}


def adam_apply(
    params: Any,
    grads: Any,
    state: Dict,
    *,
    lr: float,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Tuple[Any, Dict]:
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    m = jax.tree.map(
        lambda mm, g: beta1 * mm + (1 - beta1) * g.astype(jnp.float32),
        state["m"], grads,
    )
    v = jax.tree.map(
        lambda vv, g: beta2 * vv + (1 - beta2) * jnp.square(g.astype(jnp.float32)),
        state["v"], grads,
    )
    bc1 = 1 - beta1 ** t
    bc2 = 1 - beta2 ** t

    def upd(p, mm, vv):
        u = (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new = jax.tree.map(upd, params, m, v)
    return new, {"step": step, "m": m, "v": v}


def clip_by_global_norm(grads: Any, max_norm: float) -> Any:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)
