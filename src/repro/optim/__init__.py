from repro.optim.adam import adam_apply, adam_init, clip_by_global_norm
from repro.optim.schedule import constant, warmup_cosine
from repro.optim.sgd import momentum_apply, momentum_init, sgd_apply, sgd_init

__all__ = [
    "adam_apply", "adam_init", "clip_by_global_norm",
    "constant", "warmup_cosine",
    "momentum_apply", "momentum_init", "sgd_apply", "sgd_init",
]
