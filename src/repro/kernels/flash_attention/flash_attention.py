"""Pallas TPU flash attention (forward) with explicit VMEM tiling.

Grid (B, H, n_q, n_kv); the kv axis is innermost and sequential on TPU,
so the online-softmax state (acc, m, l) lives in VMEM scratch that
persists across kv steps of one (b, h, i) cell.  GQA is expressed in the
BlockSpec index map — kv blocks are fetched from head ``h // group`` —
so grouped heads share K/V bytes in HBM without materializing a
repeated tensor.

Block sizes default to (128, 512): MXU-aligned (multiples of 128 on the
contracting/lane dims) and small enough that the working set
(q 128×D + k/v 512×D + scores 128×512 fp32 + acc 128×D fp32) fits VMEM
for every assigned head_dim (64…256).

The backward pass reuses the flash custom-VJP in models/flash.py (its
jnp twin has identical blocking); training on TPU would pair this
forward with a Pallas backward — out of scope for the CPU container,
noted in DESIGN.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

GLOBAL = -1
_NEG_INF = -1e30


def _flash_fwd_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    scale: float,
    window: int,
    causal: bool,
    bq: int,
    bk: int,
    seq_len: int,
):
    i = pl.program_id(2)
    j = pl.program_id(3)
    n_kv = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (bk, Dv)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                     # (bq, bk)

    rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = cols < seq_len
    if causal:
        mask &= rows >= cols
    if window != GLOBAL:
        mask &= (rows - cols) < window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=1)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * corr[:, None] + pv
    m_ref[...] = m_new

    @pl.when(j == n_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, ...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd_pallas(
    q: jnp.ndarray,   # (B, H, S, D)
    k: jnp.ndarray,   # (B, K, S, D)
    v: jnp.ndarray,   # (B, K, S, Dv)
    *,
    scale: float,
    window: int = GLOBAL,
    causal: bool = True,
    bq: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, S, D = q.shape
    K = k.shape[1]
    Dv = v.shape[-1]
    group = H // K
    bq = min(bq, S)
    bk = min(bk, S)
    n_q = pl.cdiv(S, bq)
    n_kv = pl.cdiv(S, bk)
    grid = (B, H, n_q, n_kv)

    kernel = functools.partial(
        _flash_fwd_kernel,
        scale=scale, window=window, causal=causal,
        bq=bq, bk=bk, seq_len=S,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, Dv), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, Dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dv), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
