"""Jitted wrapper exposing the Pallas flash kernel through the model
attention interface ((B, S, K, G, D) layout used by models/attention)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import (
    GLOBAL,
    flash_attention_fwd_pallas,
)
from repro.kernels.flash_attention.ref import attention_ref


@partial(
    jax.jit,
    static_argnames=("window", "causal", "scale", "impl", "bq", "bk"),
)
def flash_attention(
    q: jnp.ndarray,      # (B, S, K, G, D)
    k: jnp.ndarray,      # (B, S, K, D)
    v: jnp.ndarray,      # (B, S, K, Dv)
    qpos=None,
    kpos=None,
    *,
    window: int = GLOBAL,
    causal: bool = True,
    scale: float = 1.0,
    impl: str = "auto",
    bq: int = 128,
    bk: int = 512,
) -> jnp.ndarray:
    """-> (B, S, K, G, Dv).  qpos/kpos accepted for interface parity with
    the chunked impl; the kernel assumes self-attention (arange)."""
    B, S, K, G, D = q.shape
    Dv = v.shape[-1]
    qh = q.reshape(B, S, K * G, D).transpose(0, 2, 1, 3)   # (B,H,S,D)
    kh = k.transpose(0, 2, 1, 3)                            # (B,K,S,D)
    vh = v.transpose(0, 2, 1, 3)
    interp = impl == "pallas_interpret" or (
        impl == "auto" and jax.default_backend() != "tpu"
    )
    if impl == "jnp":
        out = attention_ref(qh, kh, vh, scale=scale, window=window, causal=causal)
    else:
        out = flash_attention_fwd_pallas(
            qh, kh, vh, scale=scale, window=window, causal=causal,
            bq=bq, bk=bk, interpret=interp,
        )
    return out.transpose(0, 2, 1, 3).reshape(B, S, K, G, Dv)
