"""Naive jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

GLOBAL = -1
_NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,   # (B, H, S, D)
    k: jnp.ndarray,   # (B, K, S, D)
    v: jnp.ndarray,   # (B, K, S, Dv)
    *,
    scale: float,
    window: int = GLOBAL,
    causal: bool = True,
) -> jnp.ndarray:
    B, H, S, D = q.shape
    K = k.shape[1]
    g = H // K
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= rows >= cols
    if window != GLOBAL:
        mask &= (rows - cols) < window
    s = jnp.where(mask[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32)).astype(v.dtype)
