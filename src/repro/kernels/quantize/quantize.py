"""Pallas TPU kernels: per-block int8 quantization (DCN compression).

The cross-pod hop moves the aggregated update over slow DCN links;
quantizing to int8 with one fp32 scale per 256-lane block cuts wire
bytes ~4× (fp32) / ~2× (bf16).  Layout: flat N padded to blocks of
``QBLOCK``; kernel tiles ``ROWS_PER_CALL`` blocks per grid step so each
VMEM slab is (rows, 256) — lane-aligned for the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 256          # elements per scale
ROWS_PER_CALL = 256   # quant blocks per grid step -> (256, 256) VMEM slab


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)               # (rows, QBLOCK)
    amax = jnp.max(jnp.abs(x), axis=1)               # (rows,)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...]
    o_ref[...] = (q * s[:, None]).astype(o_ref.dtype)


def quantize_pallas(x_blocks: jnp.ndarray, *, interpret: bool = False):
    """x_blocks: (n_blocks, QBLOCK) fp32 -> (int8 same shape, fp32 scales)."""
    nb, qb = x_blocks.shape
    rows = min(ROWS_PER_CALL, nb)
    grid = (pl.cdiv(nb, rows),)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rows, qb), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, qb), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, qb), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(x_blocks)


def dequantize_pallas(q: jnp.ndarray, scales: jnp.ndarray,
                      *, out_dtype=jnp.float32, interpret: bool = False):
    nb, qb = q.shape
    rows = min(ROWS_PER_CALL, nb)
    grid = (pl.cdiv(nb, rows),)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, qb), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows, qb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, qb), out_dtype),
        interpret=interpret,
    )(q, scales)
