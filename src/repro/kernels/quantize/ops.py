"""Jitted wrappers: flat-array int8 compress/decompress."""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quantize.quantize import (
    QBLOCK,
    dequantize_pallas,
    quantize_pallas,
)
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref


def _use_pallas(impl: str) -> Tuple[bool, bool]:
    if impl == "auto":
        return (jax.default_backend() == "tpu"), False
    if impl == "pallas":
        return True, False
    if impl == "pallas_interpret":
        return True, True
    if impl == "jnp":
        return False, False
    raise ValueError(f"unknown impl {impl!r}")


@partial(jax.jit, static_argnames=("impl",))
def quantize(x: jnp.ndarray, *, impl: str = "auto"):
    """flat (N,) -> (q (nb, QBLOCK) int8, scales (nb,) fp32, N)."""
    n = x.shape[0]
    nb = -(-n // QBLOCK)
    xp = jnp.pad(x.astype(jnp.float32).reshape(-1), (0, nb * QBLOCK - n))
    blocks = xp.reshape(nb, QBLOCK)
    pallas, interp = _use_pallas(impl)
    if pallas:
        q, s = quantize_pallas(blocks, interpret=interp)
    else:
        q, s = quantize_ref(blocks)
    return q, s


@partial(jax.jit, static_argnames=("n", "impl"))
def dequantize(q: jnp.ndarray, scales: jnp.ndarray, n: int,
               *, impl: str = "auto") -> jnp.ndarray:
    pallas, interp = _use_pallas(impl)
    if pallas:
        out = dequantize_pallas(q, scales, interpret=interp)
    else:
        out = dequantize_ref(q, scales)
    return out.reshape(-1)[:n]
