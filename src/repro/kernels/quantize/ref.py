"""Pure-jnp oracle for the int8 block quantizer (matches
fl/compression.py semantics)."""
from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x_blocks: jnp.ndarray):
    x = x_blocks.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q: jnp.ndarray, scales: jnp.ndarray, out_dtype=jnp.float32):
    return (q.astype(jnp.float32) * scales[:, None]).astype(out_dtype)
