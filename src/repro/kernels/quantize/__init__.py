from repro.kernels.quantize.ops import dequantize, quantize
from repro.kernels.quantize.quantize import QBLOCK

__all__ = ["quantize", "dequantize", "QBLOCK"]
