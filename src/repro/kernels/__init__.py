"""Pallas TPU kernels for the paper's compute hot-spots, each with an
ops.py jit wrapper and a ref.py pure-jnp oracle:

  fedavg/          K-way weighted reduce + in-place eager accumulate
                   (the §4.1 aggregation hot loop; input_output_aliases
                   = the kernel-level zero-copy consume)
  quantize/        per-block int8 quant/dequant (DCN update compression)
  flash_attention/ blockwise online-softmax attention forward

All validated against their oracles with interpret=True shape/dtype
sweeps in tests/test_kernels.py.
"""
