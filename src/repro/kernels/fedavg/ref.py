"""Pure-jnp oracles for the fedavg kernels."""
from __future__ import annotations

import jax.numpy as jnp


def fedavg_reduce_ref(updates: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """(K, N) × (K,) -> (N,) fp32 weighted sum (weights pre-normalized)."""
    return jnp.sum(
        updates.astype(jnp.float32) * weights.astype(jnp.float32)[:, None], axis=0
    )


def eager_accumulate_ref(acc: jnp.ndarray, update: jnp.ndarray,
                         weight) -> jnp.ndarray:
    return (
        acc.astype(jnp.float32)
        + jnp.float32(weight) * update.astype(jnp.float32)
    ).astype(acc.dtype)


def fedavg_accumulate_k_ref(acc: jnp.ndarray, updates: jnp.ndarray,
                            weights: jnp.ndarray) -> jnp.ndarray:
    """(N,) + (K, N) × (K,) -> (N,): running-sum burst fold (weights raw)."""
    return (
        acc.astype(jnp.float32)
        + jnp.sum(
            updates.astype(jnp.float32)
            * weights.astype(jnp.float32)[:, None],
            axis=0,
        )
    ).astype(acc.dtype)
