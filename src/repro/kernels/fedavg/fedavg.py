"""Pallas TPU kernels for the FedAvg aggregation hot loop (paper §4.1).

The aggregation data plane streams GB-scale flat update vectors; the two
hot ops are:

  * ``fedavg_reduce``  — K-way weighted reduce: (K, N) updates ×
    (K,) weights -> (N,) weighted mean (lazy aggregation's batch fold,
    and each tree level's combine);
  * ``eager_accumulate`` — acc += w·u with ``input_output_aliasing`` so
    the accumulator is updated *in place* (the kernel-level analogue of
    LIFL's zero-copy shared-memory consume; eager timing, App-G);
  * ``fedavg_accumulate_k`` — K-way burst fold: acc += Σ_k w[k]·u[k, :]
    with the accumulator aliased, one grid sweep over the (K, N) slab —
    a burst of K arrivals costs one read of the accumulator, not K
    (the batched drain in core/aggregation.py).

Memory-bound streaming: N is tiled into lane-aligned VMEM blocks
(BLOCK_N = 64·128 elements = 32 KiB fp32 per operand slab); the K axis
is kept resident per block so each update element is read exactly once
and accumulation happens in fp32 VREGs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 64 * 128  # lane-aligned (8, 128)-tileable block


def _reduce_kernel(w_ref, u_ref, o_ref, *, inv_total: float):
    """One N-block: o = Σ_k w[k]·u[k, :] · inv_total."""
    u = u_ref[...].astype(jnp.float32)          # (K, BLOCK_N)
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    o_ref[...] = (jnp.sum(u * w, axis=0) * inv_total).astype(o_ref.dtype)


def fedavg_reduce_pallas(
    updates: jnp.ndarray,   # (K, N)
    weights: jnp.ndarray,   # (K,)
    *,
    block_n: int = BLOCK_N,
    interpret: bool = False,
) -> jnp.ndarray:
    """Weighted mean over K updates; N tiled into VMEM blocks."""
    K, N = updates.shape
    block_n = min(block_n, N)
    grid = (pl.cdiv(N, block_n),)
    inv_total = 1.0  # weights pre-normalized by ops.py wrapper
    w2 = weights.reshape(K, 1).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_reduce_kernel, inv_total=inv_total),
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),          # weights resident
            pl.BlockSpec((K, block_n), lambda i: (0, i)),     # update slab
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=interpret,
    )(w2, updates)


def _accum_kernel(acc_ref, u_ref, w_ref, o_ref):
    """One N-block of acc += w·u (fp32 accumulate)."""
    acc = acc_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    w = w_ref[0, 0]
    o_ref[...] = (acc + w * u).astype(o_ref.dtype)


def _accum_k_kernel(acc_ref, w_ref, u_ref, o_ref):
    """One N-block of acc += Σ_k w[k]·u[k, :] (fp32 accumulate)."""
    acc = acc_ref[...].astype(jnp.float32)        # (BLOCK_N,)
    u = u_ref[...].astype(jnp.float32)            # (K, BLOCK_N)
    w = w_ref[...].astype(jnp.float32)            # (K, 1)
    o_ref[...] = (acc + jnp.sum(u * w, axis=0)).astype(o_ref.dtype)


def fedavg_accumulate_k_pallas(
    acc: jnp.ndarray,       # (N,) fp32 running Σ w·u
    updates: jnp.ndarray,   # (K, N) burst slab
    weights: jnp.ndarray,   # (K,)
    *,
    block_n: int = BLOCK_N,
    interpret: bool = False,
) -> jnp.ndarray:
    """K-way in-place burst fold: output aliases ``acc`` (zero-copy);
    the K axis stays VMEM-resident per block so each update element is
    read exactly once and the accumulator once per block."""
    K, N = updates.shape
    block_n = min(block_n, N)
    grid = (pl.cdiv(N, block_n),)
    w2 = weights.reshape(K, 1).astype(jnp.float32)
    return pl.pallas_call(
        _accum_k_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),       # weights resident
            pl.BlockSpec((K, block_n), lambda i: (0, i)),  # burst slab
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), acc.dtype),
        input_output_aliases={0: 0},  # acc consumed in place
        interpret=interpret,
    )(acc, w2, updates)


def eager_accumulate_pallas(
    acc: jnp.ndarray,      # (N,) fp32 running Σ w·u
    update: jnp.ndarray,   # (N,) any float dtype
    weight: jnp.ndarray,   # scalar
    *,
    block_n: int = BLOCK_N,
    interpret: bool = False,
) -> jnp.ndarray:
    """In-place eager fold: the output aliases ``acc`` (zero-copy)."""
    N = acc.shape[0]
    block_n = min(block_n, N)
    grid = (pl.cdiv(N, block_n),)
    w2 = jnp.asarray(weight, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _accum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), acc.dtype),
        input_output_aliases={0: 0},  # acc consumed in place
        interpret=interpret,
    )(acc, update, w2)
