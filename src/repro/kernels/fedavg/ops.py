"""Jitted public wrappers for the fedavg kernels.

``impl='auto'`` picks Pallas on TPU backends, the jnp twin elsewhere
(CPU dry-run / tests); ``impl='pallas_interpret'`` runs the kernel body
in Python for correctness tests.  Pytree helpers flatten an update
pytree into the (K, N) layout the kernel streams.
"""
from __future__ import annotations

from functools import partial
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.fedavg.fedavg import eager_accumulate_pallas, fedavg_reduce_pallas
from repro.kernels.fedavg.ref import eager_accumulate_ref, fedavg_reduce_ref


def _use_pallas(impl: str) -> Tuple[bool, bool]:
    if impl == "auto":
        return (jax.default_backend() == "tpu"), False
    if impl == "pallas":
        return True, False
    if impl == "pallas_interpret":
        return True, True
    if impl == "jnp":
        return False, False
    raise ValueError(f"unknown impl {impl!r}")


@partial(jax.jit, static_argnames=("impl",))
def fedavg_reduce(updates: jnp.ndarray, weights: jnp.ndarray,
                  *, impl: str = "auto") -> jnp.ndarray:
    """Weighted mean of K stacked flat updates: (K,N) × (K,) -> (N,)."""
    wn = weights.astype(jnp.float32)
    wn = wn / jnp.maximum(jnp.sum(wn), 1e-30)
    pallas, interp = _use_pallas(impl)
    if pallas:
        return fedavg_reduce_pallas(updates, wn, interpret=interp)
    return fedavg_reduce_ref(updates, wn)


@partial(jax.jit, static_argnames=("impl",), donate_argnums=(0,))
def eager_accumulate(acc: jnp.ndarray, update: jnp.ndarray, weight,
                     *, impl: str = "auto") -> jnp.ndarray:
    """acc += w·u, donated/aliased accumulator (zero-copy fold)."""
    pallas, interp = _use_pallas(impl)
    if pallas:
        return eager_accumulate_pallas(acc, update, weight, interpret=interp)
    return eager_accumulate_ref(acc, update, weight)


# ---------------------------------------------------------------------------
# pytree adapters (model updates are parameter pytrees)
# ---------------------------------------------------------------------------


def flatten_update(tree: Any) -> Tuple[jnp.ndarray, Any, List]:
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    meta = [(l.shape, l.dtype) for l in leaves]
    return flat, treedef, meta


def unflatten_update(flat: jnp.ndarray, treedef, meta) -> Any:
    out = []
    off = 0
    for shape, dtype in meta:
        n = 1
        for d in shape:
            n *= d
        out.append(flat[off : off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def fedavg_reduce_tree(updates: Sequence[Any], weights: Sequence[float],
                       *, impl: str = "auto") -> Any:
    """Weighted mean of update pytrees via the flat kernel."""
    flats, treedef, meta = None, None, None
    rows = []
    for u in updates:
        f, treedef, meta = flatten_update(u)
        rows.append(f)
    stacked = jnp.stack(rows)
    flat = fedavg_reduce(stacked, jnp.asarray(weights, jnp.float32), impl=impl)
    return unflatten_update(flat, treedef, meta)
