"""Jitted public wrappers for the fedavg kernels.

``impl='auto'`` picks Pallas on TPU backends, the jnp twin elsewhere
(CPU dry-run / tests); ``impl='pallas_interpret'`` runs the kernel body
in Python for correctness tests.  Pytree helpers flatten an update
pytree into the (K, N) layout the kernel streams.
"""
from __future__ import annotations

from functools import partial
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fedavg.fedavg import (
    eager_accumulate_pallas,
    fedavg_accumulate_k_pallas,
    fedavg_reduce_pallas,
)
from repro.kernels.fedavg.ref import (
    eager_accumulate_ref,
    fedavg_accumulate_k_ref,
    fedavg_reduce_ref,
)


def _use_pallas(impl: str) -> Tuple[bool, bool]:
    if impl == "auto":
        return (jax.default_backend() == "tpu"), False
    if impl == "pallas":
        return True, False
    if impl == "pallas_interpret":
        return True, True
    if impl == "jnp":
        return False, False
    raise ValueError(f"unknown impl {impl!r}")


@partial(jax.jit, static_argnames=("impl",))
def fedavg_reduce(updates: jnp.ndarray, weights: jnp.ndarray,
                  *, impl: str = "auto") -> jnp.ndarray:
    """Weighted mean of K stacked flat updates: (K,N) × (K,) -> (N,)."""
    wn = weights.astype(jnp.float32)
    wn = wn / jnp.maximum(jnp.sum(wn), 1e-30)
    pallas, interp = _use_pallas(impl)
    if pallas:
        return fedavg_reduce_pallas(updates, wn, interpret=interp)
    return fedavg_reduce_ref(updates, wn)


@partial(jax.jit, static_argnames=("impl",), donate_argnums=(0,))
def eager_accumulate(acc: jnp.ndarray, update: jnp.ndarray, weight,
                     *, impl: str = "auto") -> jnp.ndarray:
    """acc += w·u, donated/aliased accumulator (zero-copy fold)."""
    pallas, interp = _use_pallas(impl)
    if pallas:
        return eager_accumulate_pallas(acc, update, weight, interpret=interp)
    return eager_accumulate_ref(acc, update, weight)


@partial(jax.jit, static_argnames=("impl",), donate_argnums=(0,))
def fedavg_accumulate_k(acc: jnp.ndarray, updates: jnp.ndarray, weights,
                        *, impl: str = "auto") -> jnp.ndarray:
    """K-way burst fold acc += Σ_k w[k]·u[k], donated accumulator.

    Weights are raw (not normalized): this extends the running weighted
    *sum*; the caller divides by Σ w at the end (cumulative averaging,
    §2.1), so eager bursts and lazy batches stay numerically aligned.
    """
    pallas, interp = _use_pallas(impl)
    if pallas:
        return fedavg_accumulate_k_pallas(acc, updates, weights,
                                          interpret=interp)
    return fedavg_accumulate_k_ref(acc, updates, weights)


# ---------------------------------------------------------------------------
# pytree adapters (model updates are parameter pytrees)
# ---------------------------------------------------------------------------


def _tree_meta(tree: Any) -> Tuple[Any, List, int]:
    leaves, treedef = jax.tree.flatten(tree)
    meta = [(l.shape, l.dtype) for l in leaves]
    n = sum(int(np.prod(s)) if s else 1 for s, _ in meta)
    return treedef, meta, n


def _host_staging() -> bool:
    """Stage through a preallocated host slab only on CPU backends —
    on TPU/GPU the leaves are device-resident and a host round trip
    would cost K full-model transfers; keep the all-device path there."""
    return jax.default_backend() == "cpu"


def _fill_row(row: np.ndarray, tree: Any) -> None:
    """Copy a pytree's leaves into a flat fp32 row — one write pass, no
    per-leaf temporaries and no concatenate."""
    off = 0
    for l in jax.tree.leaves(tree):
        a = np.asarray(l)
        k = a.size
        row[off : off + k] = a.reshape(-1)   # dtype-converting copy in place
        off += k


def flatten_update(tree: Any) -> Tuple[jnp.ndarray, Any, List]:
    treedef, meta, n = _tree_meta(tree)
    if not _host_staging():
        leaves = jax.tree.leaves(tree)
        flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                                for l in leaves])
        return flat, treedef, meta
    flat = np.empty((n,), np.float32)        # single staging buffer
    _fill_row(flat, tree)
    return jnp.asarray(flat), treedef, meta


def unflatten_update(flat: jnp.ndarray, treedef, meta) -> Any:
    out = []
    off = 0
    for shape, dtype in meta:
        n = 1
        for d in shape:
            n *= d
        out.append(flat[off : off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def fedavg_reduce_tree(updates: Sequence[Any], weights: Sequence[float],
                       *, impl: str = "auto") -> Any:
    """Weighted mean of update pytrees via the flat kernel.

    On hosts the (K, N) slab is preallocated once and each pytree's
    leaves are written straight into its row — no per-update concatenate
    and no stack (the seed's double copy).  On accelerator backends the
    leaves stay on device (a host slab would add K model transfers)."""
    treedef, meta, n = _tree_meta(updates[0])
    if not _host_staging():
        stacked = jnp.stack([flatten_update(u)[0] for u in updates])
    else:
        stacked = np.empty((len(updates), n), np.float32)
        for k, u in enumerate(updates):
            _fill_row(stacked[k], u)
        stacked = jnp.asarray(stacked)
    flat = fedavg_reduce(stacked, jnp.asarray(weights, jnp.float32),
                         impl=impl)
    return unflatten_update(flat, treedef, meta)
