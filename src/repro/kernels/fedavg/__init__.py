from repro.kernels.fedavg.ops import (
    eager_accumulate,
    fedavg_accumulate_k,
    fedavg_reduce,
    fedavg_reduce_tree,
    flatten_update,
    unflatten_update,
)

__all__ = [
    "eager_accumulate",
    "fedavg_accumulate_k",
    "fedavg_reduce",
    "fedavg_reduce_tree",
    "flatten_update",
    "unflatten_update",
]
