"""Core pure-JAX layers: norms, RoPE, SwiGLU, embeddings, init helpers.

No flax/haiku — params are nested dicts of jnp arrays, every layer is a
pair of ``init_*`` / ``apply`` functions.  All inits are shape-driven so
``jax.eval_shape`` can abstract them for the dry-run (no allocation).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


def _dtype(name: str):
    return jnp.dtype(name)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    orig = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(orig)


def rmsnorm_headwise(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6):
    """QK-norm: normalize over the trailing head_dim."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) rotated pairwise; positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    if head_dim % 2:  # odd head dims (e.g. reduced configs) skip the tail lane
        tail = x[..., -1:]
        body = apply_rope(x[..., :-1], positions, theta)
        return jnp.concatenate([body, tail], axis=-1)
    freqs = rope_frequencies(head_dim, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    sin = jnp.sin(angles)[..., None, :]  # (..., S, 1, D/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU FFN
# ---------------------------------------------------------------------------


def init_ffn(key, d_model: int, d_ff: int, dtype) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    return {
        "gate": dense_init(kg, (d_model, d_ff), dtype),
        "up": dense_init(ku, (d_model, d_ff), dtype),
        "down": dense_init(kd, (d_ff, d_model), dtype),
    }


def ffn(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ params["gate"]) * (x @ params["up"])
    return h @ params["down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype) -> jnp.ndarray:
    # σ = 1/√d pairs with the √d embedding multiplier (unit-variance
    # activations) and keeps tied-unembedding logits O(1) at init.
    return dense_init(key, (vocab, d_model), dtype, scale=d_model ** -0.5)


def embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed(table_or_head: jnp.ndarray, x: jnp.ndarray, tied: bool) -> jnp.ndarray:
    """Project hidden states to vocab logits (fp32)."""
    w = table_or_head.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    if tied:
        return xf @ w.T
    return xf @ w


# ---------------------------------------------------------------------------
# Chunked (sequence-blocked) cross-entropy
# ---------------------------------------------------------------------------


def chunked_lm_loss(
    hidden: jnp.ndarray,       # (B, S, D)
    unembed_w: jnp.ndarray,    # (V, D) if tied else (D, V)
    labels: jnp.ndarray,       # (B, S) int32, -1 = ignore
    tied: bool,
    chunk: int = 256,
):
    """Cross-entropy without ever materializing the full (B, S, V) logits.

    ``lax.scan`` over sequence chunks, each chunk rematerialized in the
    backward pass (``jax.checkpoint``) so the residual is O(B·chunk·V)
    instead of O(B·S·V) — essential for vocab 262k at 1M tokens.
    """
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    @jax.checkpoint
    def chunk_loss(h_c, y_c):
        logits = unembed(unembed_w, h_c, tied)  # (B, c, V) fp32
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        tok = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1
        )[..., 0]
        valid = (y_c >= 0).astype(jnp.float32)
        return jnp.sum((logz - tok) * valid), jnp.sum(valid)

    def body(carry, xs):
        h_c, y_c = xs
        l, c = chunk_loss(h_c, y_c)
        return (carry[0] + l, carry[1] + c), None

    hs = hidden[:, : n * chunk].reshape(B, n, chunk, D).swapaxes(0, 1)
    ys = labels[:, : n * chunk].reshape(B, n, chunk).swapaxes(0, 1)
    (total, count), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hs, ys))
    if rem:
        l, c = chunk_loss(hidden[:, n * chunk :], labels[:, n * chunk :])
        total, count = total + l, count + c
    return total / jnp.maximum(count, 1.0)
