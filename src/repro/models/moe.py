"""Mixture-of-Experts: router + two dispatch implementations.

* ``dense``  — every expert computes every token, outputs weighted by the
  top-k gates.  O(E/k) FLOP waste; numerically exact (no token dropping).
  The oracle for tests and the impl for tiny smoke configs.
* ``ep``     — production expert-parallel dispatch as a ``shard_map`` over
  the mesh: experts sharded over the ``model`` axis, tokens sharded over
  the data axes and replicated across ``model``.  Each model shard
  gathers (via per-expert top-capacity selection) the tokens routed to
  its local experts, runs the expert FFNs as one batched matmul, and
  scatter-adds weighted outputs; a single ``psum`` over ``model``
  combines expert contributions — the same collective shape as a TP FFN,
  so no all-to-all is needed while activations are model-replicated.
  Capacity-overflow tokens are dropped (standard capacity-factor MoE).

Shared experts (DeepSeek/Kimi) are dense FFNs applied to every token.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map as compat_shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, MoEConfig
from repro.models.layers import dense_init, ffn, init_ffn


def init_moe(key, cfg: ArchConfig, dtype) -> dict:
    moe = cfg.moe
    d = cfg.d_model
    kr, ke, ks = jax.random.split(key, 3)
    ek = jax.random.split(ke, 3)
    p = {
        "router": dense_init(kr, (d, moe.num_experts), jnp.float32),
        # experts stacked on a leading E axis (sharded over `model`)
        "experts": {
            "gate": dense_init(ek[0], (moe.num_experts, d, moe.expert_d_ff), dtype),
            "up": dense_init(ek[1], (moe.num_experts, d, moe.expert_d_ff), dtype),
            "down": dense_init(ek[2], (moe.num_experts, moe.expert_d_ff, d), dtype),
        },
    }
    if moe.num_shared_experts:
        p["shared"] = init_ffn(
            ks, d, moe.num_shared_experts * moe.shared_d_ff, dtype
        )
    return p


def router_probs(router_w: jnp.ndarray, x: jnp.ndarray, top_k: int):
    """-> (gates (T,k) fp32 normalized, idx (T,k) int32, probs (T,E))."""
    logits = x.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return gates, idx, probs


def load_balance_loss(probs: jnp.ndarray, idx: jnp.ndarray, num_experts: int):
    """Switch-style aux loss: E * Σ_e f_e · p_e."""
    T = probs.shape[0]
    counts = jnp.zeros((num_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(T * idx.shape[-1], 1)
    p = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p)


# ---------------------------------------------------------------------------
# dense dispatch (oracle)
# ---------------------------------------------------------------------------


def _moe_dense(moe: MoEConfig, experts: dict, x2: jnp.ndarray, gates, idx):
    # x2: (T, D)
    h = jnp.einsum("td,edf->tef", x2, experts["gate"])
    u = jnp.einsum("td,edf->tef", x2, experts["up"])
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(h) * u, experts["down"])  # (T,E,D)
    onehot = jax.nn.one_hot(idx, moe.num_experts, dtype=gates.dtype)  # (T,k,E)
    w = jnp.einsum("tk,tke->te", gates, onehot)  # (T,E)
    return jnp.einsum("te,ted->td", w.astype(y.dtype), y)


# ---------------------------------------------------------------------------
# expert-parallel dispatch (shard_map)
# ---------------------------------------------------------------------------


def _ep_local(moe: MoEConfig, gate_w, up_w, down_w, x2, gates, idx, *, model_axis: str):
    """Body executed per model shard.  x2 (T,D) is replicated across the
    model axis; gate/up/down are the LOCAL (E_loc, ...) expert shards."""
    E_loc = gate_w.shape[0]
    T = x2.shape[0]
    shard = jax.lax.axis_index(model_axis)
    e_lo = shard * E_loc

    # gate matrix restricted to local experts: (T, E_loc) fp32
    local = (idx[..., None] == (e_lo + jnp.arange(E_loc))[None, None, :])
    g_local = jnp.sum(jnp.where(local, gates[..., None], 0.0), axis=1)  # (T,E_loc)

    cap = int(min(T, max(1, -(-T * moe.top_k * moe.capacity_factor // moe.num_experts))))
    # per-expert top-C token selection (capacity-based dispatch)
    chosen = (g_local > 0).astype(jnp.float32)
    sel_score = chosen.T  # (E_loc, T)
    _, sel_idx = jax.lax.top_k(sel_score, cap)  # (E_loc, C) token ids
    sel_gate = jnp.take_along_axis(g_local.T, sel_idx, axis=1)  # (E_loc, C)
    sel_valid = sel_gate > 0

    xe = jnp.take(x2, sel_idx.reshape(-1), axis=0).reshape(E_loc, cap, -1)
    h = jnp.einsum("ecd,edf->ecf", xe, gate_w)
    u = jnp.einsum("ecd,edf->ecf", xe, up_w)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, down_w)
    y = y * (sel_gate * sel_valid)[..., None].astype(y.dtype)

    out = jnp.zeros_like(x2).at[sel_idx.reshape(-1)].add(y.reshape(E_loc * cap, -1))
    return jax.lax.psum(out, model_axis)


def _moe_ep(
    moe: MoEConfig,
    experts: dict,
    x2: jnp.ndarray,
    gates,
    idx,
    *,
    dp_axes: Tuple[str, ...],
    model_axis: str,
):
    # Ambient-mesh shard_map: composes with an enclosing manual-`pod`
    # shard_map (hierarchical aggregation) and with plain GSPMD (flat).
    body = partial(_ep_local, moe, model_axis=model_axis)
    tok_spec = P(dp_axes)  # (T, D): T sharded over data axes, D replicated
    w_spec = P(model_axis)  # (E, ...) sharded over model axis
    return compat_shard_map(
        lambda g, u, d, x, gg, ii: body(g, u, d, x, gg, ii),
        in_specs=(w_spec, w_spec, w_spec, tok_spec, tok_spec, tok_spec),
        out_specs=tok_spec,
        check_vma=False,
        axis_names=set(dp_axes) | {model_axis},
    )(experts["gate"], experts["up"], experts["down"], x2, gates, idx)


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def moe_block(
    cfg: ArchConfig,
    params: dict,
    x: jnp.ndarray,  # (B, S, D)
    *,
    impl: str = "dense",
    mesh=None,
    dp_axes: Tuple[str, ...] = (),
    model_axis: str = "model",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (output (B,S,D), aux load-balance loss scalar)."""
    moe = cfg.moe
    B, S, D = x.shape
    x2 = x.reshape(B * S, D)
    gates, idx, probs = router_probs(params["router"], x2, moe.top_k)
    aux = load_balance_loss(probs, idx, moe.num_experts)

    if impl == "dense":
        y = _moe_dense(moe, params["experts"], x2, gates, idx)
    elif impl == "ep":
        y = _moe_ep(
            moe, params["experts"], x2, gates, idx,
            dp_axes=dp_axes, model_axis=model_axis,
        )
    else:
        raise ValueError(f"unknown moe impl {impl!r}")

    if "shared" in params:
        y = y + ffn(params["shared"], x2)
    return y.reshape(B, S, D).astype(x.dtype), aux
