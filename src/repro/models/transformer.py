"""Generic decoder stack covering every assigned family.

Layers are grouped into *segments*: maximal runs of layers with an
identical static :class:`LayerSpec` (attention window, MoE flag, block
kind).  Each segment is a single ``lax.scan`` over its stacked params —
compile time stays O(#distinct specs), not O(num_layers), which keeps
the 512-device dry-run tractable (61-layer kimi-k2 lowers two scan
bodies).  Static specs also mean sliding-window layers get *static*
window sizes (bounded decode caches, statically-pruned KV loops).

Param pytree:
  {"embed": (V,D), "frontend_proj": (D,D)?, "segments": [stacked pytree],
   "final_norm": {...}, "lm_head": (D,V)? }
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GLOBAL, ArchConfig
from repro.models import attention as attn_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    chunked_lm_loss,
    dense_init,
    embed,
    ffn,
    init_embedding,
    init_ffn,
    init_rmsnorm,
    rmsnorm,
)


class LayerSpec(NamedTuple):
    kind: str      # 'attn' | 'ssm' | 'hybrid'
    window: int    # GLOBAL or static window size (attn/hybrid only)
    moe: bool
    cross: bool    # decoder layer with cross-attention (enc-dec)
    causal: bool   # False for encoder self-attention


@dataclass(frozen=True)
class ModelOptions:
    """Execution options — orthogonal to the architecture config."""

    attn_impl: str = "chunked"          # naive | chunked | pallas
    moe_impl: str = "dense"             # dense | ep
    mesh: Any = None                     # required for moe_impl='ep'
    dp_axes: Tuple[str, ...] = ()        # mesh axes tokens are sharded over
    model_axis: str = "model"
    vocab_axis: Any = None  # mesh axis for vocab sharding ('model') or None
    ssm_impl: str = "chunked"  # chunked | sharded (shard_map, §Perf F1)
    ssm_chunk: int = 256
    loss_chunk: int = 256
    block_kv: int = 512
    remat: bool = True
    decode_capacity_factor: float = 4.0
    # ring-cache capacity built by prefill; None -> prefill length (the
    # dry-run decode cells use exactly seq_len); tests use > prefill
    # length so no slot is evicted and decode matches the full forward.
    prefill_cache_capacity: int = 0  # 0 -> prefill length


def layer_specs(cfg: ArchConfig, *, decoder: bool = True) -> List[LayerSpec]:
    windows = cfg.layer_windows()
    moe_flags = cfg.moe_layer_flags()
    cross = decoder and cfg.encoder_layers > 0
    out = []
    for i in range(cfg.num_layers):
        if cfg.attention_free:
            out.append(LayerSpec("ssm", GLOBAL, False, False, True))
        elif cfg.hybrid_parallel_ssm:
            out.append(LayerSpec("hybrid", windows[i], moe_flags[i], cross, True))
        else:
            out.append(LayerSpec("attn", windows[i], moe_flags[i], cross, True))
    return out


def encoder_specs(cfg: ArchConfig) -> List[LayerSpec]:
    return [
        LayerSpec("attn", GLOBAL, False, False, False)
        for _ in range(cfg.encoder_layers)
    ]


def segment_specs(specs: List[LayerSpec]) -> List[Tuple[int, LayerSpec]]:
    """Run-length encode consecutive identical specs."""
    segs: List[Tuple[int, LayerSpec]] = []
    for s in specs:
        if segs and segs[-1][1] == s:
            segs[-1] = (segs[-1][0] + 1, s)
        else:
            segs.append((1, s))
    return segs


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ArchConfig, spec: LayerSpec, dtype) -> dict:
    ks = iter(jax.random.split(key, 8))
    p: dict = {}
    d = cfg.d_model
    if spec.kind == "ssm" and not cfg.hybrid_parallel_ssm:
        p["ln1"] = init_rmsnorm(d, dtype)
        p["ssm"] = ssm_mod.init_ssm(next(ks), cfg, d, dtype)
        return p
    p["ln1"] = init_rmsnorm(d, dtype)
    if cfg.mla is not None:
        p["attn"] = mla_mod.init_mla(next(ks), cfg, dtype)
    else:
        p["attn"] = attn_mod.init_attention(next(ks), cfg, dtype)
    if spec.kind == "hybrid":
        p["ssm"] = ssm_mod.init_ssm(next(ks), cfg, d, dtype)
        p["branch_norm_attn"] = init_rmsnorm(d, dtype)
        p["branch_norm_ssm"] = init_rmsnorm(d, dtype)
    if spec.cross:
        p["ln_cross"] = init_rmsnorm(d, dtype)
        p["cross"] = attn_mod.init_attention(next(ks), cfg, dtype, cross=True)
    p["ln2"] = init_rmsnorm(d, dtype)
    if spec.moe:
        p["moe"] = moe_mod.init_moe(next(ks), cfg, dtype)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and not spec.moe:
            d_ff = cfg.moe.dense_d_ff
        p["ffn"] = init_ffn(next(ks), d, d_ff, dtype)
    return p


def init_stack(key, cfg: ArchConfig, specs: List[LayerSpec], dtype):
    """-> list of stacked per-segment param pytrees."""
    segs = segment_specs(specs)
    seg_params = []
    for count, spec in segs:
        keys = jax.random.split(jax.random.fold_in(key, len(seg_params)), count)
        seg_params.append(
            jax.vmap(lambda k: _init_block(k, cfg, spec, dtype))(keys)
        )
    return seg_params


# ---------------------------------------------------------------------------
# Block apply (train / prefill)
# ---------------------------------------------------------------------------


def _apply_block(
    cfg: ArchConfig,
    spec: LayerSpec,
    opts: ModelOptions,
    params: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    memory: Optional[jnp.ndarray],
    collect_cache: bool,
):
    """-> (x, aux, cache_ys_or_None)."""
    aux = jnp.float32(0.0)
    cache_out = None
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)

    if spec.kind == "ssm" and not cfg.hybrid_parallel_ssm:
        y = ssm_mod.ssm_block(
            cfg, params["ssm"], h, chunk=opts.ssm_chunk,
            dp_axes=opts.dp_axes, model_axis=opts.model_axis,
            sharded=opts.ssm_impl == "sharded",
        )
        x = x + y
        if collect_cache:
            cache_out = _ssm_cache_from_prefill(cfg, params["ssm"], h)
        return x, aux, cache_out

    if cfg.mla is not None:
        a = mla_mod.mla_attention(
            cfg, params["attn"], h, positions, causal=spec.causal,
            impl=opts.attn_impl, block_kv=opts.block_kv,
            dp_axes=opts.dp_axes, model_axis=opts.model_axis,
        )
    else:
        a = attn_mod.attention(
            cfg, params["attn"], h, positions,
            window=spec.window, causal=spec.causal,
            impl=opts.attn_impl, block_kv=opts.block_kv,
            dp_axes=opts.dp_axes, model_axis=opts.model_axis,
        )
    if spec.kind == "hybrid":
        s = ssm_mod.ssm_block(
            cfg, params["ssm"], h, chunk=opts.ssm_chunk,
            dp_axes=opts.dp_axes, model_axis=opts.model_axis,
            sharded=opts.ssm_impl == "sharded",
        )
        a = 0.5 * (
            rmsnorm(params["branch_norm_attn"], a, cfg.norm_eps)
            + rmsnorm(params["branch_norm_ssm"], s, cfg.norm_eps)
        )
    x = x + a

    if spec.cross:
        hc = rmsnorm(params["ln_cross"], x, cfg.norm_eps)
        x = x + attn_mod.attention(
            cfg, params["cross"], hc, positions,
            memory=memory, impl="naive" if memory.shape[1] <= 1024 else opts.attn_impl,
        )

    h2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if spec.moe:
        y, aux = moe_mod.moe_block(
            cfg, params["moe"], h2,
            impl=opts.moe_impl, mesh=opts.mesh,
            dp_axes=opts.dp_axes, model_axis=opts.model_axis,
        )
    else:
        y = ffn(params["ffn"], h2)
    x = x + y

    if collect_cache:
        cap = opts.prefill_cache_capacity or h.shape[1]
        cache_out = _attn_cache_from_prefill(cfg, spec, params, h, positions, memory, cap)
        if spec.kind == "hybrid":
            cache_out["ssm"] = _ssm_cache_from_prefill(cfg, params["ssm"], h)
    return x, aux, cache_out


def _ring_place(t: jnp.ndarray, cap: int):
    """Scatter (B, S, ...) sequence into a ring cache of ``cap`` slots.

    Position p lands in slot p % cap; when S > cap only the trailing
    ``cap`` positions survive (ring eviction, matching decode)."""
    B, S = t.shape[:2]
    keep = min(S, cap)
    pos_tail = jnp.arange(S - keep, S)
    out = jnp.zeros((B, cap) + t.shape[2:], t.dtype)
    return out.at[:, pos_tail % cap].set(t[:, S - keep :])


def _attn_cache_from_prefill(cfg, spec, params, h, positions, memory, cap):
    """Recompute (cheap projections) the roped K/V for the decode cache."""
    out = {}
    if cfg.mla is not None:
        c, k_rope = mla_mod._latent(cfg, params["attn"], h, positions)
        out["c"] = _ring_place(c, cap)
        out["k_rope"] = _ring_place(k_rope, cap)
    else:
        _, k, v = attn_mod._project_qkv(
            cfg, params["attn"], h, h, positions, positions, rope=True
        )
        cap_w = cap if spec.window == GLOBAL else min(spec.window, cap)
        out["k"] = _ring_place(k, cap_w)
        out["v"] = _ring_place(v, cap_w)
    if spec.cross:
        out["cross"] = attn_mod.init_cross_cache(cfg, params["cross"], memory)
    return out


def _ssm_cache_from_prefill(cfg, ssm_params, h):
    d_in = ssm_params["dt_proj"].shape[1]
    B, S, _ = h.shape
    xz = h @ ssm_params["in_proj"]
    raw = xz[..., :d_in]
    u = jax.nn.silu(ssm_mod._causal_conv(raw, ssm_params["conv_w"]))
    h0 = jnp.zeros((B, d_in, cfg.ssm.d_state), jnp.float32)
    _, h_final = ssm_mod.ssm_scan_chunked(cfg, ssm_params, u, h0, chunk=min(256, S))
    conv = raw[:, -(cfg.ssm.d_conv - 1) :, :]
    return {"h": h_final, "conv": conv}


# ---------------------------------------------------------------------------
# Block decode
# ---------------------------------------------------------------------------


def _decode_block(
    cfg: ArchConfig,
    spec: LayerSpec,
    opts: ModelOptions,
    params: dict,
    x: jnp.ndarray,   # (B, 1, D)
    cache: dict,
    pos: jnp.ndarray,
):
    h = rmsnorm(params["ln1"], x, cfg.norm_eps)
    new_cache = dict(cache)

    if spec.kind == "ssm" and not cfg.hybrid_parallel_ssm:
        y, new_ssm = ssm_mod.ssm_decode(cfg, params["ssm"], h, cache)
        return x + y, new_ssm

    if cfg.mla is not None:
        a, upd = mla_mod.mla_decode(
            cfg, params["attn"], h, {"c": cache["c"], "k_rope": cache["k_rope"]}, pos
        )
        new_cache.update(upd)
    else:
        a, upd = attn_mod.attention_decode(
            cfg, params["attn"], h, {"k": cache["k"], "v": cache["v"]}, pos,
            window=spec.window,
        )
        new_cache.update(upd)
    if spec.kind == "hybrid":
        s, new_ssm = ssm_mod.ssm_decode(cfg, params["ssm"], h, cache["ssm"])
        new_cache["ssm"] = new_ssm
        a = 0.5 * (
            rmsnorm(params["branch_norm_attn"], a, cfg.norm_eps)
            + rmsnorm(params["branch_norm_ssm"], s, cfg.norm_eps)
        )
    x = x + a

    if spec.cross:
        hc = rmsnorm(params["ln_cross"], x, cfg.norm_eps)
        x = x + attn_mod.cross_attention_decode(cfg, params["cross"], hc, cache["cross"])

    h2 = rmsnorm(params["ln2"], x, cfg.norm_eps)
    if spec.moe:
        y, _ = moe_mod.moe_block(
            cfg, params["moe"], h2,
            impl=opts.moe_impl, mesh=opts.mesh,
            dp_axes=opts.dp_axes, model_axis=opts.model_axis,
        )
    else:
        y = ffn(params["ffn"], h2)
    return x + y, new_cache


def init_block_cache(cfg: ArchConfig, spec: LayerSpec, batch: int, capacity: int,
                     memory_len: int, dtype):
    if spec.kind == "ssm" and not cfg.hybrid_parallel_ssm:
        return ssm_mod.init_ssm_cache(cfg, cfg.d_model, batch, dtype)
    if cfg.mla is not None:
        c = mla_mod.init_mla_cache(cfg, batch, capacity, dtype)
    else:
        c = attn_mod.init_kv_cache(cfg, batch, capacity, spec.window, dtype)
    if spec.kind == "hybrid":
        c["ssm"] = ssm_mod.init_ssm_cache(cfg, cfg.d_model, batch, dtype)
    if spec.cross:
        c["cross"] = {
            "k": jnp.zeros((batch, memory_len, cfg.num_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, memory_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        }
    return c


# ---------------------------------------------------------------------------
# Stack apply
# ---------------------------------------------------------------------------


def apply_stack(
    cfg: ArchConfig,
    seg_params: List[Any],
    specs: List[LayerSpec],
    opts: ModelOptions,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    memory: Optional[jnp.ndarray] = None,
    collect_cache: bool = False,
):
    """-> (x, aux, caches_per_segment | None)."""
    segs = segment_specs(specs)
    aux_total = jnp.float32(0.0)
    caches = [] if collect_cache else None

    for sp, (count, spec) in zip(seg_params, segs):

        def body(carry, layer_params, spec=spec):
            xx, aux = carry
            xx, a, cache = _apply_block(
                cfg, spec, opts, layer_params, xx, positions, memory, collect_cache
            )
            return (xx, aux + a), cache

        if opts.remat:
            body = jax.checkpoint(body)
        (x, aux_total), seg_cache = jax.lax.scan(body, (x, aux_total), sp)
        if collect_cache:
            caches.append(seg_cache)
    return x, aux_total, caches


def decode_stack(
    cfg: ArchConfig,
    seg_params: List[Any],
    specs: List[LayerSpec],
    opts: ModelOptions,
    x: jnp.ndarray,          # (B, 1, D)
    caches: List[Any],
    pos: jnp.ndarray,
):
    segs = segment_specs(specs)
    new_caches = []
    for sp, cache, (count, spec) in zip(seg_params, caches, segs):

        def body(xx, xs, spec=spec):
            layer_params, layer_cache = xs
            xx, new_cache = _decode_block(cfg, spec, opts, layer_params, xx, layer_cache, pos)
            return xx, new_cache

        x, seg_new = jax.lax.scan(body, x, (sp, cache))
        new_caches.append(seg_new)
    return x, new_caches


def init_stack_cache(cfg, specs, batch, capacity, memory_len, dtype):
    segs = segment_specs(specs)
    caches = []
    for count, spec in segs:
        one = init_block_cache(cfg, spec, batch, capacity, memory_len, dtype)
        caches.append(
            jax.tree.map(lambda a: jnp.broadcast_to(a, (count,) + a.shape), one)
        )
    return caches
