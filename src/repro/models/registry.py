"""Model facade: builds a uniform LM interface for every assigned arch.

``LM`` exposes exactly the functions the FL round / serving / dry-run
layers need:

  init(key) -> params
  loss(params, batch) -> (scalar loss, aux dict)           [train_4k]
  prefill(params, batch) -> (last-token logits, caches)    [prefill_32k]
  init_decode(batch, capacity) -> caches
  decode_step(params, tokens, caches, pos) -> (logits, caches)  [decode_*]

Batches are plain dicts:
  train:   {"tokens": (B,S) i32, "labels": (B,S) i32, "frontend": (B,F,D)?}
  prefill: {"tokens": (B,S) i32, "frontend": (B,F,D)?}
  decode:  tokens (B,1) i32 + caches + pos scalar
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tfm
from repro.models.layers import dense_init, init_embedding, init_rmsnorm, rmsnorm
from repro.models.sharded_vocab import (
    chunked_lm_loss_sharded,
    decode_logits,
    embed_lookup,
    padded_vocab,
)
from repro.models.transformer import ModelOptions

MOE_AUX_WEIGHT = 0.01


class LM:
    def __init__(self, cfg: ArchConfig, opts: Optional[ModelOptions] = None):
        self.cfg = cfg
        self.opts = opts or ModelOptions()
        self.specs = tfm.layer_specs(cfg)
        self.enc_specs = tfm.encoder_specs(cfg) if cfg.encoder_layers else []
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        k_emb, k_dec, k_enc, k_head, k_fp = jax.random.split(key, 5)
        vp = padded_vocab(cfg.vocab_size)
        params: Dict[str, Any] = {
            "embed": init_embedding(k_emb, vp, cfg.d_model, self.dtype),
            "segments": tfm.init_stack(k_dec, cfg, self.specs, self.dtype),
            "final_norm": init_rmsnorm(cfg.d_model, self.dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(k_head, (cfg.d_model, vp), self.dtype)
        if self.enc_specs:
            params["encoder"] = {
                "segments": tfm.init_stack(k_enc, cfg, self.enc_specs, self.dtype),
                "final_norm": init_rmsnorm(cfg.d_model, self.dtype),
            }
        if cfg.frontend:
            params["frontend_proj"] = dense_init(
                k_fp, (cfg.d_model, cfg.d_model), self.dtype
            )
        return params

    # ------------------------------------------------------------------
    def _unembed_w(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"], True
        return params["lm_head"], False

    def _encode(self, params, frontend):
        """Enc-dec encoder over stub frame embeddings -> memory (B,F,D)."""
        x = frontend.astype(self.dtype) @ params["frontend_proj"]
        positions = jnp.arange(x.shape[1])
        x, _, _ = tfm.apply_stack(
            self.cfg, params["encoder"]["segments"], self.enc_specs, self.opts,
            x, positions,
        )
        return rmsnorm(params["encoder"]["final_norm"], x, self.cfg.norm_eps)

    def _embed_inputs(self, params, tokens, frontend):
        """Token embeddings, with VLM patch embeddings prepended."""
        cfg = self.cfg
        x = embed_lookup(
            params["embed"], tokens, self.opts.vocab_axis
        ) * math.sqrt(cfg.d_model)
        x = x.astype(self.dtype)
        n_front = 0
        if cfg.frontend and not self.enc_specs:  # decoder-only multimodal
            fx = frontend.astype(self.dtype) @ params["frontend_proj"]
            x = jnp.concatenate([fx, x], axis=1)
            n_front = frontend.shape[1]
        return x, n_front

    def _forward(self, params, tokens, frontend, collect_cache=False):
        memory = None
        if self.enc_specs:
            memory = self._encode(params, frontend)
        x, n_front = self._embed_inputs(params, tokens, frontend)
        positions = jnp.arange(x.shape[1])
        x, aux, caches = tfm.apply_stack(
            self.cfg, params["segments"], self.specs, self.opts,
            x, positions, memory=memory, collect_cache=collect_cache,
        )
        x = rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        return x, aux, caches, n_front

    # ------------------------------------------------------------------
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        hidden, aux, _, n_front = self._forward(
            params, batch["tokens"], batch.get("frontend")
        )
        if n_front:
            hidden = hidden[:, n_front:]
        w, tied = self._unembed_w(params)
        ce = chunked_lm_loss_sharded(
            hidden, w, batch["labels"],
            vocab=self.cfg.vocab_size, tied=tied,
            model_axis=self.opts.vocab_axis, chunk=self.opts.loss_chunk,
        )
        total = ce + MOE_AUX_WEIGHT * aux
        return total, {"ce": ce, "moe_aux": aux}

    # ------------------------------------------------------------------
    def prefill(self, params, batch):
        hidden, _, caches, _ = self._forward(
            params, batch["tokens"], batch.get("frontend"), collect_cache=True
        )
        w, tied = self._unembed_w(params)
        logits = decode_logits(
            hidden[:, -1:], w, vocab=self.cfg.vocab_size, tied=tied,
            model_axis=self.opts.vocab_axis,
        )
        return logits, caches

    # ------------------------------------------------------------------
    def init_decode(self, batch: int, capacity: int):
        mem_len = self.cfg.frontend_tokens if self.enc_specs else 0
        return tfm.init_stack_cache(
            self.cfg, self.specs, batch, capacity, mem_len, self.dtype
        )

    def decode_step(self, params, tokens, caches, pos):
        """tokens (B,1) -> (logits (B,1,V), new caches)."""
        x = embed_lookup(
            params["embed"], tokens, self.opts.vocab_axis
        ) * math.sqrt(self.cfg.d_model)
        x = x.astype(self.dtype)
        x, new_caches = tfm.decode_stack(
            self.cfg, params["segments"], self.specs, self.opts, x, caches, pos
        )
        x = rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        w, tied = self._unembed_w(params)
        logits = decode_logits(
            x, w, vocab=self.cfg.vocab_size, tied=tied,
            model_axis=self.opts.vocab_axis,
        )
        return logits, new_caches


def build_model(cfg: ArchConfig, opts: Optional[ModelOptions] = None) -> LM:
    return LM(cfg, opts)
