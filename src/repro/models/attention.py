"""Attention: GQA with RoPE, sliding windows, three interchangeable impls.

* ``naive``   — full (S, S) score matrix; oracle for tests, small shapes.
* ``chunked`` — blockwise online-softmax (flash-style) as a ``lax.scan``
  over KV blocks; O(S·block) live memory.  This is the CPU-lowerable twin
  of the Pallas kernel (same blocking), used by the dry-run.
* ``pallas``  — kernels/flash_attention (TPU target; interpret=True in
  tests).

Decode uses a ring-buffer KV cache (capacity = context length; slot
``pos % capacity`` is overwritten), a single einsum over the cache — the
softmax reductions over a sequence-sharded cache become tiny (B, H)
all-reduces under GSPMD (sequence parallelism for long contexts).

Window convention: ``window == GLOBAL (-1)`` is full causal attention;
otherwise query i attends keys j with ``i - window < j <= i``.  Windows
are **static** per call (the transformer segments layers by window), so
local layers statically skip out-of-window KV blocks.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import GLOBAL, ArchConfig
from repro.models.layers import apply_rope, dense_init, rmsnorm_headwise

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig, dtype, cross: bool = False) -> dict:
    kq, kk, kv, ko, kn = jax.random.split(key, 5)
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(kq, (d, h * hd), dtype),
        "wk": dense_init(kk, (d, kvh * hd), dtype),
        "wv": dense_init(kv, (d, kvh * hd), dtype),
        "wo": dense_init(ko, (h * hd, d), dtype),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dtype=dtype)
        p["k_norm"] = jnp.ones((hd,), dtype=dtype)
    return p


def _project_qkv(cfg: ArchConfig, params, xq, xkv, positions_q, positions_kv, rope: bool):
    """-> q (B,Sq,K,G,D), k (B,Skv,K,D), v (B,Skv,K,D)."""
    B, Sq, _ = xq.shape
    Skv = xkv.shape[1]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    q = (xq @ params["wq"]).reshape(B, Sq, h, hd)
    k = (xkv @ params["wk"]).reshape(B, Skv, kvh, hd)
    v = (xkv @ params["wv"]).reshape(B, Skv, kvh, hd)
    if "q_norm" in params:
        q = rmsnorm_headwise(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_headwise(params["k_norm"], k, cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions_q, cfg.rope_theta)
        k = apply_rope(k, positions_kv, cfg.rope_theta)
    q = q.reshape(B, Sq, kvh, g, hd)
    return q, k, v


def _band_mask(qpos, kpos, window: int, causal: bool):
    """(…, Sq, Skv) bool mask: True = attend."""
    diff = qpos[..., :, None] - kpos[..., None, :]
    m = (diff >= 0) if causal else jnp.ones_like(diff, dtype=bool)
    if window != GLOBAL:
        m = m & (diff < window)
    return m


# ---------------------------------------------------------------------------
# naive impl (oracle)
# ---------------------------------------------------------------------------


def _attend_naive(q, k, v, qpos, kpos, window, causal, scale):
    # q: (B,Sq,K,G,D)  k,v: (B,Skv,K,D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    mask = _band_mask(qpos, kpos, window, causal)  # (Sq,Skv)
    scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out


# ---------------------------------------------------------------------------
# chunked impl (flash-style scan over KV blocks)
# ---------------------------------------------------------------------------


def _attend_chunked(q, k, v, qpos, kpos, window, causal, scale, block_kv: int):
    """Online-softmax over KV blocks.  Static skipping for window layers:
    only the last ceil(window/block)+1 KV blocks can be visible to any
    query — but queries are processed together, so skipping applies when
    the *entire* block is out of range for *all* queries; windows still
    cut FLOPs ~(window+Sq)/Skv when Sq is a chunk of a long sequence.
    For full causal self-attention this is the rectangle schedule
    (triangle waste removed by the two-level schedule, see §Perf).
    """
    B, Sq, K, G, D = q.shape
    Skv = k.shape[1]
    bk = min(block_kv, Skv)
    nkv = -(-Skv // bk)
    pad = nkv * bk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=-(10**9))

    kb = k.reshape(B, nkv, bk, K, D).swapaxes(0, 1)      # (nkv,B,bk,K,D)
    vb = v.reshape(B, nkv, bk, K, D).swapaxes(0, 1)
    pb = kpos.reshape(nkv, bk)

    qf = q.astype(jnp.float32)

    def body(carry, xs):
        acc, m, l = carry
        kc, vc, pc = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kc.astype(jnp.float32)) * scale
        mask = _band_mask(qpos, pc, window, causal)  # (Sq,bk)
        s = jnp.where(mask[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, K, G, Sq, D), jnp.float32)
    m0 = jnp.full((B, K, G, Sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, pb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    # (B,K,G,Sq,D) -> (B,Sq,K,G,D)
    return out.transpose(0, 3, 1, 2, 4).astype(v.dtype)


# ---------------------------------------------------------------------------
# public: training / prefill attention
# ---------------------------------------------------------------------------


def attention(
    cfg: ArchConfig,
    params: dict,
    x: jnp.ndarray,                       # (B, S, D)
    positions: jnp.ndarray,               # (S,)
    *,
    window: int = GLOBAL,
    causal: bool = True,
    memory: Optional[jnp.ndarray] = None,  # cross-attention memory (B, Sm, D)
    memory_positions: Optional[jnp.ndarray] = None,
    impl: str = "chunked",
    block_kv: int = 512,
    dp_axes: tuple = (),
    model_axis: str = "model",
) -> jnp.ndarray:
    cross = memory is not None
    xkv = memory if cross else x
    kpos = memory_positions if cross else positions
    if cross:
        kpos = kpos if kpos is not None else jnp.arange(xkv.shape[1])
    q, k, v = _project_qkv(cfg, params, x, xkv, positions, kpos, rope=not cross)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    causal = causal and not cross
    if impl == "naive":
        out = _attend_naive(q, k, v, positions, kpos, window, causal, scale)
    elif impl in ("chunked", "chunked_sp"):
        if not cross:
            # flash custom-VJP: O(S) residuals (out + lse), blockwise
            # recompute in backward — positions are arange for self-attn.
            # chunked_sp = context-parallel: q sequence-sharded over the
            # model axis (head counts need not divide the mesh).
            from repro.models.flash import (
                flash_self_attention,
                flash_self_attention_sp,
            )

            if impl == "chunked_sp":
                out = flash_self_attention_sp(
                    q, k, v, window, causal, scale,
                    min(block_kv, k.shape[1]),
                    dp_axes=dp_axes, model_axis=model_axis,
                )
            else:
                out = flash_self_attention(
                    q, k, v, window, causal, scale, min(block_kv, k.shape[1])
                )
        else:
            out = _attend_chunked(
                q, k, v, positions, kpos, window, causal, scale, block_kv
            )
    elif impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(
            q, k, v, positions, kpos, window=window, causal=causal, scale=scale
        )
    else:
        raise ValueError(f"unknown attention impl {impl!r}")
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return out @ params["wo"]


# ---------------------------------------------------------------------------
# decode with ring-buffer KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ArchConfig, batch: int, capacity: int, window: int, dtype):
    """Ring cache; local layers only keep ``window`` slots."""
    cap = capacity if window == GLOBAL else min(window, capacity)
    shape = (batch, cap, cfg.num_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def attention_decode(
    cfg: ArchConfig,
    params: dict,
    x: jnp.ndarray,        # (B, 1, D) current token hidden
    cache: dict,           # ring cache, fully valid (context length = capacity)
    pos: jnp.ndarray,      # scalar int32: absolute position of current token
    *,
    window: int = GLOBAL,
):
    B = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    cap = cache["k"].shape[1]

    q = (x @ params["wq"]).reshape(B, 1, h, hd)
    k_new = (x @ params["wk"]).reshape(B, 1, kvh, hd)
    v_new = (x @ params["wv"]).reshape(B, 1, kvh, hd)
    if "q_norm" in params:
        q = rmsnorm_headwise(params["q_norm"], q, cfg.norm_eps)
        k_new = rmsnorm_headwise(params["k_norm"], k_new, cfg.norm_eps)
    posv = jnp.asarray(pos)[None]
    q = apply_rope(q, posv, cfg.rope_theta)
    k_new = apply_rope(k_new, posv, cfg.rope_theta)

    # ring positions: slot s holds absolute position p such that
    # p ≡ s (mod cap) and p in (pos - cap, pos].  The *current* token is
    # written into slot pos % cap before attending.
    slot = jnp.mod(pos, cap)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    slots = jnp.arange(cap)
    abs_pos = pos - jnp.mod(slot - slots, cap)  # absolute position per slot

    qg = q.reshape(B, kvh, g, hd)
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) / math.sqrt(hd)
    visible = abs_pos >= 0
    if window != GLOBAL:
        visible = visible & (pos - abs_pos < window)
    scores = jnp.where(visible[None, None, None], scores, _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache.astype(jnp.float32))
    out = out.reshape(B, 1, h * hd).astype(x.dtype)
    return out @ params["wo"], {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# cross-attention decode (enc-dec): static memory K/V, no cache update
# ---------------------------------------------------------------------------


def init_cross_cache(cfg: ArchConfig, params: dict, memory: jnp.ndarray):
    B, Sm, _ = memory.shape
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": (memory @ params["wk"]).reshape(B, Sm, kvh, hd),
        "v": (memory @ params["wv"]).reshape(B, Sm, kvh, hd),
    }


def cross_attention_decode(cfg: ArchConfig, params: dict, x: jnp.ndarray, cross_cache: dict):
    B = x.shape[0]
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kvh
    q = (x @ params["wq"]).reshape(B, kvh, g, hd)
    scores = jnp.einsum(
        "bkgd,bskd->bkgs",
        q.astype(jnp.float32),
        cross_cache["k"].astype(jnp.float32),
    ) / math.sqrt(hd)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, cross_cache["v"].astype(jnp.float32))
    out = out.reshape(B, 1, h * hd).astype(x.dtype)
    return out @ params["wo"]
