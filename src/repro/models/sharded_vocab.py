"""Vocab-sharded embedding / unembedding / cross-entropy.

A vocab-sharded table with a plain ``jnp.take`` trips XLA's involuntary
full rematerialization (the table gets replicated per device — measured
+47 GB temp on llama3.2-3b train_4k).  The production pattern instead
keeps the table P(model, None) and does an ownership-masked local gather
with a psum over ``model``; the unembedding computes vocab-shard-local
logits so the (tokens, V) matrix is never assembled, with logsumexp /
label-gather reduced by tiny (tokens,) psums.

Vocab is padded to a multiple of 256 (model-axis shards × lane
alignment); padded logits are masked out of the CE and stripped from
decode logits.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map as compat_shard_map
from jax.sharding import PartitionSpec as P

VOCAB_PAD_MULTIPLE = 256


def padded_vocab(vocab: int) -> int:
    return -(-vocab // VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE


# ---------------------------------------------------------------------------
# embedding lookup
# ---------------------------------------------------------------------------


def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray,
                 model_axis: Optional[str]) -> jnp.ndarray:
    """tokens (B,S) -> (B,S,D).  Table rows sharded over ``model_axis``."""
    if model_axis is None:
        return jnp.take(table, tokens, axis=0)

    def body(tbl, tok):
        v_loc = tbl.shape[0]
        lo = jax.lax.axis_index(model_axis) * v_loc
        idx = tok - lo
        ok = (idx >= 0) & (idx < v_loc)
        rows = jnp.take(tbl, jnp.clip(idx, 0, v_loc - 1), axis=0)
        rows = jnp.where(ok[..., None], rows, jnp.zeros((), rows.dtype))
        # f32 psum: exactly one shard contributes per token (no precision
        # cost) and bf16 collectives trip an XLA:CPU float-normalization
        # CHECK ("Invalid binary instruction opcode copy") in this path.
        return jax.lax.psum(rows.astype(jnp.float32), model_axis).astype(tbl.dtype)

    return compat_shard_map(
        body,
        in_specs=(P(model_axis, None), P()),
        out_specs=P(),
        check_vma=False,
        axis_names={model_axis},
    )(table, tokens)


# ---------------------------------------------------------------------------
# chunked cross-entropy with vocab-shard-local logits
# ---------------------------------------------------------------------------


def _ce_chunk_local(w_chunk, h, y, *, vocab: int, tied: bool, model_axis: str):
    """Executed per model shard: local logits + CE partials."""
    v_loc = w_chunk.shape[0] if tied else w_chunk.shape[1]
    lo = jax.lax.axis_index(model_axis) * v_loc
    hf = h.astype(jnp.float32)
    wf = w_chunk.astype(jnp.float32)
    logits = hf @ (wf.T if tied else wf)  # (B, c, v_loc)
    # mask padded vocab rows out of the softmax
    col = lo + jnp.arange(v_loc)
    logits = jnp.where((col < vocab)[None, None, :], logits, -1e30)

    # softmax is shift-invariant: the max needs no gradient (and pmax has
    # no differentiation rule anyway)
    m_loc = jnp.max(jax.lax.stop_gradient(logits), axis=-1)
    m = jax.lax.stop_gradient(jax.lax.pmax(m_loc, model_axis))
    z = jax.lax.psum(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1), model_axis)
    logz = m + jnp.log(z)

    idx = y - lo
    ok = (idx >= 0) & (idx < v_loc)
    tok_logit = jnp.take_along_axis(
        logits, jnp.clip(idx, 0, v_loc - 1)[..., None], axis=-1
    )[..., 0]
    tok_logit = jax.lax.psum(jnp.where(ok, tok_logit, 0.0), model_axis)

    valid = (y >= 0).astype(jnp.float32)
    return jnp.sum((logz - tok_logit) * valid), jnp.sum(valid)


def chunked_lm_loss_sharded(
    hidden: jnp.ndarray,     # (B, S, D)
    w: jnp.ndarray,          # (Vp, D) tied or (D, Vp)
    labels: jnp.ndarray,     # (B, S) int32, -1 ignore
    *,
    vocab: int,
    tied: bool,
    model_axis: Optional[str],
    chunk: int = 256,
):
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    n = S // chunk

    if model_axis is None:
        from repro.models.layers import chunked_lm_loss

        wt = w[:vocab] if tied else w[:, :vocab]
        return chunked_lm_loss(hidden, wt, labels, tied, chunk=chunk)

    w_spec = P(model_axis, None) if tied else P(None, model_axis)

    @jax.checkpoint
    def chunk_loss(h_c, y_c):
        # f32 at the shard_map boundary: the transpose rule psums the
        # replicated-input cotangent over `model`, and bf16 collectives
        # hit an XLA:CPU float-normalization CHECK failure.
        return compat_shard_map(
            lambda wc, hh, yy: _ce_chunk_local(
                wc, hh, yy, vocab=vocab, tied=tied, model_axis=model_axis
            ),
            in_specs=(w_spec, P(), P()),
            out_specs=(P(), P()),
            check_vma=False,
            axis_names={model_axis},
        )(w, h_c.astype(jnp.float32), y_c)

    def body(carry, xs):
        h_c, y_c = xs
        l, c = chunk_loss(h_c, y_c)
        return (carry[0] + l, carry[1] + c), None

    hs = hidden.reshape(B, n, chunk, D).swapaxes(0, 1)
    ys = labels.reshape(B, n, chunk).swapaxes(0, 1)
    (total, count), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0)), (hs, ys)
    )
    return total / jnp.maximum(count, 1.0)


# ---------------------------------------------------------------------------
# decode logits
# ---------------------------------------------------------------------------


def decode_logits(hidden: jnp.ndarray, w: jnp.ndarray, *, vocab: int,
                  tied: bool, model_axis: Optional[str]) -> jnp.ndarray:
    """(B, 1, D) -> (B, 1, vocab) fp32 (replicated)."""
    if model_axis is None:
        wt = w[:vocab] if tied else w[:, :vocab]
        return hidden.astype(jnp.float32) @ (
            wt.T.astype(jnp.float32) if tied else wt.astype(jnp.float32)
        )

    w_spec = P(model_axis, None) if tied else P(None, model_axis)

    def body(wc, h):
        hf = h.astype(jnp.float32)
        wf = wc.astype(jnp.float32)
        logits = hf @ (wf.T if tied else wf)  # (B, 1, v_loc)
        return jax.lax.all_gather(logits, model_axis, axis=2, tiled=True)

    full = compat_shard_map(
        body,
        in_specs=(w_spec, P()),
        out_specs=P(),
        check_vma=False,
        axis_names={model_axis},
    )(w, hidden)
    return full[..., :vocab]
