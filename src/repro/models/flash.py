"""Blockwise self-attention with a flash-style custom VJP.

Differentiating through the online-softmax ``lax.scan`` saves the
(acc, m, l) carry per KV block — O(S·n_blocks) residuals (~25 GB/device
measured on llama3.2-3b train_4k).  Flash attention's defining trick is
the backward pass: save only (out, lse) per query and *recompute* the
probability block inside the gradient loop.  This module is that
backward, in pure JAX (the Pallas kernel in kernels/flash_attention is
its TPU twin; this one also lowers on CPU for the dry-run).

Positions are explicit: ``sq0`` (scalar offset of the q rows — the
shard's slice start under context parallelism) and ``kpos`` (int32
(Skv,) absolute positions of the kv rows, enabling window-limited KV
exchange where a shard holds a non-contiguous kv working set).  Both are
integer operands of the custom_vjp (float0 cotangents).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.compat import axis_size as compat_axis_size
from repro.compat import shard_map as compat_shard_map

from repro.configs.base import GLOBAL

_NEG_INF = -1e30


def _mask(sq0, Sq: int, kposb, window: int, causal: bool):
    """(Sq, bk) mask for q rows [sq0, sq0+Sq) vs kv rows at ``kposb``."""
    qpos = sq0 + jnp.arange(Sq)
    diff = qpos[:, None] - kposb[None, :]
    m = (diff >= 0) if causal else jnp.ones((Sq, kposb.shape[0]), bool)
    if window != GLOBAL:
        m = m & (diff < window)
    return m


def _fwd_scan(q, k, v, sq0, kpos, window, causal, scale, bk):
    """q (B,Sq,K,G,D), k/v (B,Skv,K,Dk/Dv) -> out (B,K,G,Sq,Dv), lse."""
    B, Sq, K, G, D = q.shape
    Skv = k.shape[1]
    Dk = k.shape[-1]
    Dv = v.shape[-1]
    nkv = -(-Skv // bk)
    pad = nkv * bk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # padded rows land in the future -> masked by causality/window
        kpos = jnp.concatenate(
            [kpos, jnp.full((pad,), 2 ** 30, kpos.dtype)]
        )
    kb = k.reshape(B, nkv, bk, K, Dk).swapaxes(0, 1)
    vb = v.reshape(B, nkv, bk, K, Dv).swapaxes(0, 1)
    pb = kpos.reshape(nkv, bk)
    qf = q.astype(jnp.float32)

    def body(carry, xs):
        acc, m, l = carry
        kc, vc, pc = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kc.astype(jnp.float32)) * scale
        msk = _mask(sq0, Sq, pc, window, causal)
        s = jnp.where(msk[None, None, None], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, K, G, Sq, Dv), jnp.float32)
    m0 = jnp.full((B, K, G, Sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(body, (acc0, m0, l0), (kb, vb, pb))
    l = jnp.maximum(l, 1e-30)
    out = acc / l[..., None]
    lse = m + jnp.log(l)
    return out, lse  # out (B,K,G,Sq,Dv) fp32


@partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash_core(q, k, v, sq0, kpos, window: int, causal: bool, scale: float,
                bk: int):
    out, _ = _fwd_scan(q, k, v, sq0, kpos, window, causal, scale, bk)
    return out.transpose(0, 3, 1, 2, 4).astype(v.dtype)  # (B,Sq,K,G,Dv)


def _flash_fwd(q, k, v, sq0, kpos, window, causal, scale, bk):
    out, lse = _fwd_scan(q, k, v, sq0, kpos, window, causal, scale, bk)
    out_t = out.transpose(0, 3, 1, 2, 4).astype(v.dtype)
    return out_t, (q, k, v, sq0, kpos, out, lse)


def _flash_bwd(window, causal, scale, bk, res, do):
    q, k, v, sq0, kpos, out, lse = res  # out (B,K,G,Sq,Dv) fp32
    B, Sq, K, G, D = q.shape
    Skv = k.shape[1]
    Dk, Dv = k.shape[-1], v.shape[-1]
    nkv = -(-Skv // bk)
    pad = nkv * bk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.concatenate(
            [kpos, jnp.full((pad,), 2 ** 30, kpos.dtype)]
        )
    kb = k.reshape(B, nkv, bk, K, Dk).swapaxes(0, 1)
    vb = v.reshape(B, nkv, bk, K, Dv).swapaxes(0, 1)
    pb = kpos.reshape(nkv, bk)

    qf = q.astype(jnp.float32)
    dof = do.transpose(0, 2, 3, 1, 4).astype(jnp.float32)  # (B,K,G,Sq,Dv)
    delta = jnp.sum(dof * out, axis=-1)  # (B,K,G,Sq)

    def body(dq, xs):
        kc, vc, pc = xs
        kcf = kc.astype(jnp.float32)
        vcf = vc.astype(jnp.float32)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kcf) * scale
        msk = _mask(sq0, Sq, pc, window, causal)
        s = jnp.where(msk[None, None, None], s, _NEG_INF)
        p = jnp.exp(s - lse[..., None])  # (B,K,G,Sq,bk)
        dv_j = jnp.einsum("bkgqs,bkgqd->bskd", p, dof)
        dp = jnp.einsum("bkgqd,bskd->bkgqs", dof, vcf)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bkgqs,bskd->bqkgd", ds, kcf)
        dk_j = jnp.einsum("bkgqs,bqkgd->bskd", ds, qf)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)
    dq, (dk, dv) = jax.lax.scan(body, dq0, (kb, vb, pb))
    dk = dk.swapaxes(0, 1).reshape(B, nkv * bk, K, Dk)[:, :Skv]
    dv = dv.swapaxes(0, 1).reshape(B, nkv * bk, K, Dv)[:, :Skv]
    f0 = lambda x: jnp.zeros(jnp.shape(x), jax.dtypes.float0)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            f0(sq0), f0(kpos))


_flash_core.defvjp(_flash_fwd, _flash_bwd)


def flash_self_attention(q, k, v, window: int, causal: bool, scale: float,
                         bk: int):
    """Single-region flash attention (q rows start at position 0)."""
    Skv = k.shape[1]
    return _flash_core(
        q, k, v, jnp.int32(0), jnp.arange(Skv, dtype=jnp.int32),
        window, causal, scale, bk,
    )


def flash_self_attention_sp(
    q, k, v, window: int, causal: bool, scale: float, bk: int,
    dp_axes, model_axis: str,
    window_limited: bool = True,
):
    """Context-parallel flash: q sequence-sharded over ``model_axis``.

    Global layers all-gather K/V over `model` (the textbook context-
    parallelism cost).  Sliding-window layers (§Perf G3) instead fetch
    only ceil(window/shard_len) neighbor shards via a collective-permute
    ring — wire bytes drop from S to (window + shard_len) per layer
    (4096→1280 on gemma's 1024-window layers at S=4k/16 shards).

    This also sidesteps head-count divisibility entirely (24 q-heads on
    a 16-way model axis cannot head-shard; GSPMD otherwise inserts
    per-step resharding collectives — measured 5k+ all-reduces per llama
    step).
    """
    from jax.sharding import PartitionSpec as P

    B, S = q.shape[:2]

    def body(qc, kc, vc):
        shards = compat_axis_size(model_axis)
        L = S // shards
        idx = jax.lax.axis_index(model_axis)
        sq0 = idx * L

        hops = -(-window // L) if (window != GLOBAL and causal) else None
        if window_limited and hops is not None and hops < shards - 1:
            # ring fetch: shards i-hops .. i  (older kv first)
            blocks_k, blocks_v, blocks_p = [], [], []
            perm1 = [(s, (s + 1) % shards) for s in range(shards)]
            kh, vh = kc, vc
            fetched = []
            for h in range(1, hops + 1):
                kh = jax.lax.ppermute(kh, model_axis, perm1)
                vh = jax.lax.ppermute(vh, model_axis, perm1)
                src = idx - h
                pos = jnp.where(
                    src >= 0, src * L + jnp.arange(L), 2 ** 30
                ).astype(jnp.int32)
                fetched.append((kh, vh, pos))
            for kh, vh, pos in reversed(fetched):
                blocks_k.append(kh)
                blocks_v.append(vh)
                blocks_p.append(pos)
            blocks_k.append(kc)
            blocks_v.append(vc)
            blocks_p.append((sq0 + jnp.arange(L)).astype(jnp.int32))
            kf = jnp.concatenate(blocks_k, axis=1)
            vf = jnp.concatenate(blocks_v, axis=1)
            kpos = jnp.concatenate(blocks_p)
        else:
            kf = jax.lax.all_gather(kc, model_axis, axis=1, tiled=True)
            vf = jax.lax.all_gather(vc, model_axis, axis=1, tiled=True)
            kpos = jnp.arange(S, dtype=jnp.int32)
        return _flash_core(
            qc, kf, vf, sq0, kpos, window, causal, scale, min(bk, kf.shape[1])
        )

    spec_q = P(dp_axes, model_axis, None, None, None)
    spec_kv = P(dp_axes, model_axis, None, None)
    return compat_shard_map(
        body,
        in_specs=(spec_q, spec_kv, spec_kv),
        out_specs=spec_q,
        check_vma=False,
        axis_names=set(dp_axes) | {model_axis},
    )(q, k, v)
