"""Mamba-1 selective SSM block, TPU-adapted (chunked parallel scan).

GPU Mamba uses a hand-written CUDA "hardware-aware" scan that never
materializes the (B, S, d_inner, N) state tensor in HBM.  The TPU-native
adaptation here blocks the sequence into chunks of ``chunk`` steps:

  * within a chunk: an associative scan over affine maps
    (a_t, b_t) with (a2,b2)∘(a1,b1) = (a1·a2, a2·b1 + b2) — log-depth,
    MXU/VPU friendly, and the materialized state is only
    (B, chunk, d_inner, N);
  * across chunks: a sequential ``lax.scan`` carrying the (B, d_inner, N)
    state — O(S/chunk) steps.

Decode is a single affine state update: O(1) in context length, which is
why falcon-mamba is the long_500k flagship.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map as compat_shard_map

from repro.configs.base import ArchConfig
from repro.models.layers import dense_init


def init_ssm(key, cfg: ArchConfig, d_model: int, dtype) -> dict:
    s = cfg.ssm
    d_in = s.expand * d_model
    dt_rank = s.resolved_dt_rank(d_model)
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], (d_model, 2 * d_in), dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, d_in), dtype, scale=0.5),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * s.d_state), dtype),
        "dt_proj": dense_init(ks[3], (dt_rank, d_in), dtype),
        "dt_bias": jnp.full((d_in,), -4.6, dtype=jnp.float32),  # softplus ≈ 0.01
        "A_log": jnp.log(
            jnp.tile(jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (d_in, 1))
        ),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, d_model), dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, state: jnp.ndarray = None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C).  With ``state``
    (B,K-1,C) the left context comes from the decode buffer."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    # sum_k w[k] * x[t - (K-1) + k]
    S = x.shape[1]
    out = jnp.zeros_like(x)
    for k in range(K):
        out = out + xp[:, k : k + S, :] * w[k][None, None, :]
    return out


def _ssm_inputs(cfg: ArchConfig, params, u: jnp.ndarray):
    """u: (B,S,d_in) post-conv activations -> (dt, B_t, C_t, A)."""
    s = cfg.ssm
    dt_rank = params["dt_proj"].shape[0]
    proj = u @ params["x_proj"]  # (B,S,dt_rank+2N)
    dt_low = proj[..., :dt_rank]
    B_t = proj[..., dt_rank : dt_rank + s.d_state].astype(jnp.float32)
    C_t = proj[..., dt_rank + s.d_state :].astype(jnp.float32)
    dt = jax.nn.softplus(
        (dt_low @ params["dt_proj"]).astype(jnp.float32) + params["dt_bias"]
    )  # (B,S,d_in)
    A = -jnp.exp(params["A_log"])  # (d_in, N)
    return dt, B_t, C_t, A


def _affine_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def ssm_scan_chunked(
    cfg: ArchConfig,
    params: dict,
    u: jnp.ndarray,  # (B, S, d_in) conv+silu output
    h0: jnp.ndarray,  # (B, d_in, N) fp32 initial state
    chunk: int = 256,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (y (B,S,d_in), h_final)."""
    B, S, d_in = u.shape
    N = cfg.ssm.d_state
    chunk = min(chunk, S)
    while S % chunk:  # largest divisor of S not exceeding the requested chunk
        chunk -= 1
    n = S // chunk

    dt, B_t, C_t, A = _ssm_inputs(cfg, params, u)
    uf = u.astype(jnp.float32)

    def chunk_body(h, xs):
        dt_c, B_c, C_c, u_c = xs  # (B, c, ·)
        a = jnp.exp(dt_c[..., None] * A[None, None])            # (B,c,d_in,N)
        b = (dt_c * u_c)[..., None] * B_c[:, :, None, :]        # (B,c,d_in,N)
        a_cum, h_intra = jax.lax.associative_scan(_affine_combine, (a, b), axis=1)
        h_t = a_cum * h[:, None] + h_intra                      # (B,c,d_in,N)
        y_c = jnp.einsum("bcdn,bcn->bcd", h_t, C_c)
        return h_t[:, -1], y_c

    xs = tuple(
        t.reshape(B, n, chunk, *t.shape[2:]).swapaxes(0, 1)
        for t in (dt, B_t, C_t, uf)
    )
    h_final, ys = jax.lax.scan(chunk_body, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, S, d_in)
    y = y + uf * params["D"][None, None]
    return y, h_final


def ssm_scan_sharded(
    cfg: ArchConfig,
    params: dict,
    u: jnp.ndarray,
    h0: jnp.ndarray,
    *,
    chunk: int,
    dp_axes,
    model_axis: str,
    intra_chunk: str = "seq",
):
    """§Perf iteration F1: the chunked scan inside a shard_map (batch →
    data axes, d_inner → model axis).

    GSPMD cannot infer shardings through ``associative_scan``'s log-depth
    combinator tree, so the baseline materializes replicated
    (B, chunk, d_inner, N) state tensors — measured 779 s of HBM time on
    falcon-mamba train_4k.  Manual sharding keeps every scan operand
    local; the only collective is one small psum for the x_proj
    contraction over the sharded d_inner.  The chunk body is
    checkpointed so the backward recomputes in-chunk state instead of
    saving 8 log-levels of it."""
    from jax.sharding import PartitionSpec as P

    scan_params = {
        k: params[k]
        for k in ("x_proj", "dt_proj", "dt_bias", "A_log", "D")
    }
    pspecs = {
        "x_proj": P(model_axis, None),   # (d_in, dt_rank+2N): contract -> psum
        "dt_proj": P(None, model_axis),
        "dt_bias": P(model_axis),
        "A_log": P(model_axis, None),
        "D": P(model_axis),
    }

    def body(p, u_loc, h_loc):
        def inputs_fn(cfg_, p_, u_):
            # replicate _ssm_inputs with the sharded contraction psum'd
            s = cfg_.ssm
            dt_rank = p_["dt_proj"].shape[0]
            proj = jax.lax.psum(u_ @ p_["x_proj"], model_axis)
            dt_low = proj[..., :dt_rank]
            B_t = proj[..., dt_rank : dt_rank + s.d_state].astype(jnp.float32)
            C_t = proj[..., dt_rank + s.d_state :].astype(jnp.float32)
            dt = jax.nn.softplus(
                (dt_low @ p_["dt_proj"]).astype(jnp.float32) + p_["dt_bias"]
            )
            A = -jnp.exp(p_["A_log"])
            return dt, B_t, C_t, A

        B, S, d_loc = u_loc.shape
        N = cfg.ssm.d_state
        c = min(chunk, S)
        while S % c:
            c -= 1
        n = S // c
        dt, B_t, C_t, A = inputs_fn(cfg, p, u_loc)
        uf = u_loc.astype(jnp.float32)

        @jax.checkpoint
        def chunk_body(h, xs):
            dt_c, B_c, C_c, u_c = xs  # (B,c,d) (B,c,N) (B,c,N) (B,c,d)
            if intra_chunk == "seq":
                # §Perf F2 (default): reads (a,b) inputs once per step and
                # never materializes (B,c,d,N) level tensors — 2.7× fewer
                # HBM bytes than the associative form under the corrected
                # cost model (22.3s vs 59.2s on falcon train_4k).  Trade:
                # serial steps; on TPU the same dataflow belongs in a
                # Pallas kernel (state in VMEM, lanes over (B,d,N)).
                def step(h_, ts):
                    dt_t, B_t_, C_t_, u_t = ts
                    a_t = jnp.exp(dt_t[..., None] * A[None])
                    b_t = (dt_t * u_t)[..., None] * B_t_[:, None, :]
                    h_ = a_t * h_ + b_t
                    y_t = jnp.einsum("bdn,bn->bd", h_, C_t_)
                    return h_, y_t

                ts = tuple(t.swapaxes(0, 1) for t in (dt_c, B_c, C_c, u_c))
                h_last, y_c = jax.lax.scan(step, h, ts)
                return h_last, y_c.swapaxes(0, 1)
            a = jnp.exp(dt_c[..., None] * A[None, None])
            b = (dt_c * u_c)[..., None] * B_c[:, :, None, :]
            a_cum, h_intra = jax.lax.associative_scan(
                _affine_combine, (a, b), axis=1
            )
            h_t = a_cum * h[:, None] + h_intra
            y_c = jnp.einsum("bcdn,bcn->bcd", h_t, C_c)
            return h_t[:, -1], y_c

        xs = tuple(
            t.reshape(B, n, c, *t.shape[2:]).swapaxes(0, 1)
            for t in (dt, B_t, C_t, uf)
        )
        h_final, ys = jax.lax.scan(chunk_body, h_loc, xs)
        y = ys.swapaxes(0, 1).reshape(B, S, d_loc)
        y = y + uf * p["D"][None, None]
        return y.astype(u_loc.dtype), h_final

    u_spec = P(dp_axes, None, model_axis)
    h_spec = P(dp_axes, model_axis, None)
    y, h_final = compat_shard_map(
        body,
        in_specs=(pspecs, u_spec, h_spec),
        out_specs=(u_spec, h_spec),
        check_vma=False,
        axis_names=set(dp_axes) | {model_axis},
    )(scan_params, u, h0)
    return y.astype(jnp.float32), h_final


def ssm_block(
    cfg: ArchConfig,
    params: dict,
    x: jnp.ndarray,  # (B, S, d_model)
    chunk: int = 256,
    *,
    dp_axes=(),
    model_axis: str = "model",
    sharded: bool = False,
) -> jnp.ndarray:
    """Full mamba block: in_proj -> conv -> SSM -> gate -> out_proj."""
    B, S, _ = x.shape
    d_in = params["dt_proj"].shape[1]
    xz = x @ params["in_proj"]
    u, z = xz[..., :d_in], xz[..., d_in:]
    u = jax.nn.silu(_causal_conv(u, params["conv_w"]))
    h0 = jnp.zeros((B, d_in, cfg.ssm.d_state), jnp.float32)
    if sharded:
        y, _ = ssm_scan_sharded(
            cfg, params, u, h0, chunk=chunk,
            dp_axes=dp_axes, model_axis=model_axis,
        )
    else:
        y, _ = ssm_scan_chunked(cfg, params, u, h0, chunk=chunk)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"]


# ---------------------------------------------------------------------------
# decode: O(1) state update
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg: ArchConfig, d_model: int, batch: int, dtype):
    s = cfg.ssm
    d_in = s.expand * d_model
    return {
        "h": jnp.zeros((batch, d_in, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
    }


def ssm_decode(cfg: ArchConfig, params: dict, x: jnp.ndarray, cache: dict):
    """x: (B, 1, d_model) -> (y (B,1,d_model), new cache)."""
    d_in = params["dt_proj"].shape[1]
    xz = x @ params["in_proj"]
    u, z = xz[..., :d_in], xz[..., d_in:]
    raw = u  # pre-conv input, buffered for the next step's conv window
    u = jax.nn.silu(_causal_conv(u, params["conv_w"], state=cache["conv"]))
    conv_new = jnp.concatenate([cache["conv"][:, 1:], raw], axis=1)

    dt, B_t, C_t, A = _ssm_inputs(cfg, params, u)
    a = jnp.exp(dt[:, 0, :, None] * A[None])                    # (B,d_in,N)
    b = (dt[:, 0] * u.astype(jnp.float32)[:, 0])[..., None] * B_t[:, 0, None, :]
    h = a * cache["h"] + b
    y = jnp.einsum("bdn,bn->bd", h, C_t[:, 0])
    y = y + u.astype(jnp.float32)[:, 0] * params["D"][None]
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    return y @ params["out_proj"], {"h": h, "conv": conv_new}
