from repro.models.registry import LM, build_model
from repro.models.resnet import ResNet, build_resnet
from repro.models.transformer import ModelOptions

__all__ = ["LM", "build_model", "ResNet", "build_resnet", "ModelOptions"]
