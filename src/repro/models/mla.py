"""Multi-head Latent Attention (DeepSeek-V2), pure JAX.

Train/prefill path decompresses the latent per KV position; the decode
path uses the *absorption* trick (W_UK folded into the query, W_UV into
the output) so the per-step cache read is the compressed latent
(kv_lora + rope_dim per token) — the MLA memory win shows up directly in
the roofline memory term for decode cells.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_rope, dense_init, rmsnorm

_NEG_INF = -1e30


def init_mla(key, cfg: ArchConfig, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    p = {}
    if m.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], (d, m.q_lora_rank), dtype)
        p["q_a_norm"] = jnp.ones((m.q_lora_rank,), dtype=dtype)
        p["wq_b"] = dense_init(ks[1], (m.q_lora_rank, h * qk_head), dtype)
    else:
        p["wq"] = dense_init(ks[0], (d, h * qk_head), dtype)
    # down-projection to compressed latent + decoupled rope key
    p["wkv_a"] = dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype)
    p["kv_a_norm"] = jnp.ones((m.kv_lora_rank,), dtype=dtype)
    # up-projection (decompression): latent -> per-head (k_nope | v)
    p["wkv_b"] = dense_init(
        ks[3], (m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim)), dtype
    )
    p["wo"] = dense_init(ks[4], (h * m.v_head_dim, d), dtype)
    return p


def _queries(cfg: ArchConfig, params, x, positions):
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.num_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.q_lora_rank:
        qa = rmsnorm({"scale": params["q_a_norm"]}, x @ params["wq_a"], cfg.norm_eps)
        q = (qa @ params["wq_b"]).reshape(B, S, h, qk_head)
    else:
        q = (x @ params["wq"]).reshape(B, S, h, qk_head)
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim :], positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent(cfg: ArchConfig, params, x, positions):
    """Compressed latent c (B,S,R) and shared rope key (B,S,Dr)."""
    m = cfg.mla
    kv = x @ params["wkv_a"]
    c = rmsnorm({"scale": params["kv_a_norm"]}, kv[..., : m.kv_lora_rank], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank :][:, :, None, :]  # (B,S,1,Dr)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return c, k_rope


def mla_attention(
    cfg: ArchConfig,
    params: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    causal: bool = True,
    impl: str = "naive",
    block_kv: int = 512,
    dp_axes: tuple = (),
    model_axis: str = "model",
) -> jnp.ndarray:
    """Train/prefill: decompress the latent, then standard attention with
    concatenated (nope | rope) head dims — so MLA reuses the flash core
    (scores = q_nope·k_nope + q_rope·k_rope in one contraction)."""
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.num_heads
    q_nope, q_rope = _queries(cfg, params, x, positions)
    c, k_rope = _latent(cfg, params, x, positions)
    wkv_b = params["wkv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope = jnp.einsum("bsr,rhd->bshd", c, wkv_b[..., : m.qk_nope_head_dim])
    v = jnp.einsum("bsr,rhd->bshd", c, wkv_b[..., m.qk_nope_head_dim :])

    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]  # K=h,G=1
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, h, m.qk_rope_head_dim))],
        axis=-1,
    )
    if impl == "naive":
        from repro.models.attention import _attend_naive

        out = _attend_naive(q_cat, k_cat, v, positions, positions, -1, causal, scale)
    else:
        from repro.models.flash import flash_self_attention, flash_self_attention_sp

        bk = min(block_kv, S)
        if impl == "chunked_sp":
            out = flash_self_attention_sp(
                q_cat, k_cat, v, -1, causal, scale, bk,
                dp_axes=dp_axes, model_axis=model_axis,
            )
        else:
            out = flash_self_attention(q_cat, k_cat, v, -1, causal, scale, bk)
    out = out.reshape(B, S, h * m.v_head_dim)
    return out @ params["wo"]


# ---------------------------------------------------------------------------
# decode with compressed-latent cache + absorption
# ---------------------------------------------------------------------------


def init_mla_cache(cfg: ArchConfig, batch: int, capacity: int, dtype):
    m = cfg.mla
    return {
        "c": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, capacity, m.qk_rope_head_dim), dtype),
    }


def mla_decode(
    cfg: ArchConfig,
    params: dict,
    x: jnp.ndarray,    # (B, 1, D)
    cache: dict,
    pos: jnp.ndarray,  # scalar
):
    m = cfg.mla
    B = x.shape[0]
    h = cfg.num_heads
    cap = cache["c"].shape[1]
    posv = jnp.asarray(pos)[None]

    q_nope, q_rope = _queries(cfg, params, x, posv)   # (B,1,h,·)
    c_new, kr_new = _latent(cfg, params, x, posv)     # (B,1,R), (B,1,Dr)

    slot = jnp.mod(pos, cap)
    c = jax.lax.dynamic_update_slice(cache["c"], c_new.astype(cache["c"].dtype), (0, slot, 0))
    kr = jax.lax.dynamic_update_slice(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), (0, slot, 0)
    )

    wkv_b = params["wkv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[..., : m.qk_nope_head_dim]   # (R,h,Dn)
    w_uv = wkv_b[..., m.qk_nope_head_dim :]   # (R,h,Dv)

    # absorb W_UK into the query: q_c (B,h,R) — score via latent directly
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], w_uk)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = jnp.einsum("bhr,bsr->bhs", q_c.astype(jnp.float32), c.astype(jnp.float32))
    s = s + jnp.einsum(
        "bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32), kr.astype(jnp.float32)
    )
    s = s * scale
    # ring-slot validity: slot j holds absolute position pos - ((slot-j) mod cap)
    slots = jnp.arange(cap)
    abs_pos = pos - jnp.mod(slot - slots, cap)
    s = jnp.where((abs_pos >= 0)[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhs,bsr->bhr", p, c.astype(jnp.float32))  # latent-space output
    out = jnp.einsum("bhr,rhd->bhd", o_c.astype(x.dtype), w_uv)  # absorb W_UV
    out = out.reshape(B, 1, h * m.v_head_dim)
    return out @ params["wo"], {"c": c, "k_rope": kr}
