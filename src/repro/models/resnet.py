"""ResNet-18/152 in pure JAX — the paper's own FL workload (FEMNIST).

GroupNorm replaces BatchNorm: FedAvg over running batch statistics is
ill-defined across non-IID clients, and stateless normalization is
standard practice in FL reproductions (noted in DESIGN.md §8).  The
model-update sizes (the quantity LIFL's data plane cares about) match
the paper's ~44 MB / ~232 MB fp32 updates.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.resnet import ResNetConfig


def _conv_init(key, k, cin, cout):
    fan_in = k * k * cin
    std = (2.0 / fan_in) ** 0.5
    return jax.random.normal(key, (k, k, cin, cout), jnp.float32) * std


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _init_gn(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _gn(p, x, groups=8):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xg = x.reshape(B, H, W, g, C // g)
    mean = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.var(xg, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    x = xg.reshape(B, H, W, C)
    return x * p["scale"] + p["bias"]


def _init_basic(key, cin, cout, stride):
    ks = jax.random.split(key, 3)
    p = {
        "conv1": _conv_init(ks[0], 3, cin, cout), "gn1": _init_gn(cout),
        "conv2": _conv_init(ks[1], 3, cout, cout), "gn2": _init_gn(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[2], 1, cin, cout)
        p["gn_proj"] = _init_gn(cout)
    return p


def _basic(p, x, stride):
    h = jax.nn.relu(_gn(p["gn1"], _conv(x, p["conv1"], stride)))
    h = _gn(p["gn2"], _conv(h, p["conv2"]))
    sc = x
    if "proj" in p:
        sc = _gn(p["gn_proj"], _conv(x, p["proj"], stride))
    return jax.nn.relu(h + sc)


def _init_bottleneck(key, cin, cmid, stride):
    cout = cmid * 4
    ks = jax.random.split(key, 4)
    p = {
        "conv1": _conv_init(ks[0], 1, cin, cmid), "gn1": _init_gn(cmid),
        "conv2": _conv_init(ks[1], 3, cmid, cmid), "gn2": _init_gn(cmid),
        "conv3": _conv_init(ks[2], 1, cmid, cout), "gn3": _init_gn(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[3], 1, cin, cout)
        p["gn_proj"] = _init_gn(cout)
    return p


def _bottleneck(p, x, stride):
    h = jax.nn.relu(_gn(p["gn1"], _conv(x, p["conv1"])))
    h = jax.nn.relu(_gn(p["gn2"], _conv(h, p["conv2"], stride)))
    h = _gn(p["gn3"], _conv(h, p["conv3"]))
    sc = x
    if "proj" in p:
        sc = _gn(p["gn_proj"], _conv(x, p["proj"], stride))
    return jax.nn.relu(h + sc)


class ResNet:
    def __init__(self, cfg: ResNetConfig):
        self.cfg = cfg

    def init(self, key):
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        params = {
            "stem": _conv_init(ks[0], 3, cfg.in_channels, cfg.width),
            "gn_stem": _init_gn(cfg.width),
            "stages": [],
        }
        cin = cfg.width
        expansion = 4 if cfg.block == "bottleneck" else 1
        for si, nblocks in enumerate(cfg.stage_blocks):
            cmid = cfg.width * (2 ** si)
            stage = []
            for bi in range(nblocks):
                k = jax.random.fold_in(ks[1], si * 100 + bi)
                stride = 2 if (bi == 0 and si > 0) else 1
                if cfg.block == "basic":
                    stage.append(_init_basic(k, cin, cmid, stride))
                    cin = cmid
                else:
                    stage.append(_init_bottleneck(k, cin, cmid, stride))
                    cin = cmid * expansion
            params["stages"].append(stage)
        params["head"] = jax.random.normal(ks[2], (cin, cfg.num_classes)) * (cin ** -0.5)
        params["head_b"] = jnp.zeros((cfg.num_classes,))
        return params

    def apply(self, params, images):
        """images: (B, H, W, C) -> logits (B, num_classes)."""
        cfg = self.cfg
        x = jax.nn.relu(_gn(params["gn_stem"], _conv(images, params["stem"])))
        for si, stage in enumerate(params["stages"]):
            for bi, bp in enumerate(stage):
                stride = 2 if (bi == 0 and si > 0) else 1
                x = (_basic if cfg.block == "basic" else _bottleneck)(bp, x, stride)
        x = jnp.mean(x, axis=(1, 2))
        return x @ params["head"] + params["head_b"]

    def loss(self, params, batch):
        logits = self.apply(params, batch["images"])
        labels = batch["labels"]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        tok = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        loss = jnp.mean(logz - tok)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return loss, {"accuracy": acc}


def build_resnet(cfg: ResNetConfig) -> ResNet:
    return ResNet(cfg)
