"""The aggregator function itself: step-based processing (paper App-G).

Multiple producers -> single consumer, three steps:
  Recv — object keys arrive in a FIFO (payloads stay in shared memory);
  Agg  — dequeue + fold (FedAvg cumulative averaging) until the
         aggregation goal n is met; with *eager* timing Recv∥Agg overlap
         (fold on arrival); *lazy* queues everything then folds;
  Send — emit the intermediate/global update one level up.

FedAvg (Eq. 1): w = Σ_k c_k·w_k / Σ_k c_k — implemented as a running
(Σ c·w, Σ c) pair so eager and lazy are numerically identical (cumulative
averaging is exact, §2.1).  The fold's hot loop is the fedavg kernel
(kernels/fedavg: Pallas on TPU, numpy/jnp twin elsewhere).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.gateway import UpdateEnvelope
from repro.core.objectstore import InProcObjectStore
from repro.core.sidecar import EventSidecar


@dataclass
class FedAvgState:
    """Running weighted sum — supports fold (one update) and merge
    (combine two partial aggregates: the hierarchy's associativity)."""

    acc: Optional[np.ndarray] = None
    weight: float = 0.0
    count: int = 0

    def fold(self, update: np.ndarray, w: float) -> None:
        contrib = update.astype(np.float32) * np.float32(w)
        if self.acc is None:
            self.acc = contrib
        else:
            self.acc += contrib  # in-place: the zero-copy consume
        self.weight += w
        self.count += 1

    def merge(self, other: "FedAvgState") -> None:
        if other.acc is None:
            return
        if self.acc is None:
            self.acc = other.acc.copy()
        else:
            self.acc += other.acc
        self.weight += other.weight
        self.count += other.count

    def result(self) -> Tuple[np.ndarray, float]:
        assert self.acc is not None and self.weight > 0
        return self.acc / np.float32(self.weight), self.weight


class Aggregator:
    """One LIFL aggregator instance (leaf/middle/top — homogenized)."""

    def __init__(
        self,
        agg_id: str,
        store,
        goal: int,
        *,
        eager: bool = True,
        sidecar: Optional[EventSidecar] = None,
        on_complete: Optional[Callable[[np.ndarray, float], None]] = None,
    ):
        self.agg_id = agg_id
        self.store = store
        self.goal = goal
        self.eager = eager
        self.sidecar = sidecar
        self.on_complete = on_complete
        self.fifo: Deque[UpdateEnvelope] = deque()
        self.state = FedAvgState()
        self.done = False
        self.result: Optional[Tuple[np.ndarray, float]] = None
        self.agg_exec_s = 0.0

    # ------------------------------------------------------------------
    # Recv step — called by the sockmap notify hook (event-driven)
    # ------------------------------------------------------------------
    def recv(self, env: UpdateEnvelope) -> None:
        self.fifo.append(env)
        if self.sidecar:
            self.sidecar.on_recv(
                self.store.meta(env.object_key).nbytes
                if hasattr(self.store, "meta") else 0,
                time.perf_counter() - env.enqueue_ts,
            )
        if self.eager:
            # Recv ∥ Agg: fold immediately (App-G)
            self._drain()

    # ------------------------------------------------------------------
    # Agg step
    # ------------------------------------------------------------------
    def _fold_one(self, env: UpdateEnvelope) -> None:
        t0 = time.perf_counter()
        update = self.store.get(env.object_key)
        self.state.fold(np.asarray(update), env.num_samples)
        self.store.release(env.object_key)
        dt = time.perf_counter() - t0
        self.agg_exec_s += dt
        if self.sidecar:
            self.sidecar.on_aggregate(1, dt)

    def _drain(self) -> None:
        while self.fifo and not self.done:
            self._fold_one(self.fifo.popleft())
            if self.state.count >= self.goal:
                self._send()

    def flush(self) -> None:
        """Lazy timing: called once the goal's worth of updates queued."""
        self._drain()

    # ------------------------------------------------------------------
    # Send step
    # ------------------------------------------------------------------
    def _send(self) -> None:
        self.done = True
        self.result = self.state.result()
        if self.sidecar:
            self.sidecar.on_send(self.result[0].nbytes)
        if self.on_complete:
            self.on_complete(*self.result)


def fedavg_oracle(updates: List[np.ndarray], weights: List[float]) -> np.ndarray:
    """Reference weighted mean (tests compare every path against this)."""
    num = sum(np.float32(w) * u.astype(np.float32) for u, w in zip(updates, weights))
    return num / np.float32(sum(weights))
