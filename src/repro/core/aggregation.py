"""The aggregator function itself: step-based processing (paper App-G).

Multiple producers -> single consumer, three steps:
  Recv — object keys arrive in a FIFO (payloads stay in shared memory);
  Agg  — dequeue + fold (FedAvg cumulative averaging) until the
         aggregation goal n is met; with *eager* timing Recv∥Agg overlap
         (fold on arrival); *lazy* queues everything then folds;
  Send — emit the intermediate/global update one level up.

FedAvg (Eq. 1): w = Σ_k c_k·w_k / Σ_k c_k — implemented as a running
(Σ c·w, Σ c) pair so eager and lazy are numerically identical (cumulative
averaging is exact, §2.1).  The fold's hot loop is delegated to a
pluggable aggregation *engine* (core/engine.py): blocked numpy tiles on
hosts, the kernels/fedavg Pallas path on TPU, with the seed's scalar
path kept as the ``naive`` baseline.  ``_drain`` dequeues bursts of up
to ``batch_k`` pending envelopes and folds them in one K-way pass, so a
burst of arrivals costs ~one read of the accumulator rather than K.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Deque, List, Optional, Tuple

import numpy as np

from repro.core.engine import AggregationEngine, make_engine
from repro.core.gateway import UpdateEnvelope
from repro.core.objectstore import InProcObjectStore
from repro.core.sidecar import EventSidecar


@dataclass
class FedAvgState:
    """Running weighted sum — supports fold (one update), fold_many (a
    K-way burst) and merge (combine two partial aggregates: the
    hierarchy's associativity).  The arithmetic is the engine's."""

    acc: Optional[Any] = None
    weight: float = 0.0
    count: int = 0
    engine: Any = None

    def __post_init__(self) -> None:
        if not isinstance(self.engine, AggregationEngine):
            # bare FedAvgState() keeps the seed's scalar semantics
            self.engine = make_engine(self.engine or "naive")

    def _ensure_acc(self, n: int) -> None:
        if self.acc is None:
            self.acc = self.engine.begin(n)

    def fold(self, update: np.ndarray, w: float) -> None:
        self._ensure_acc(update.size)
        self.acc = self.engine.fold(self.acc, update, w)
        self.weight += w
        self.count += 1

    def fold_many(self, updates: List[np.ndarray], weights: List[float]) -> None:
        if not updates:
            return
        self._ensure_acc(updates[0].size)
        self.acc = self.engine.fold_many(self.acc, updates, weights)
        self.weight += float(sum(weights))
        self.count += len(updates)

    def absorb(self, partial: np.ndarray, weight: float, count: int = 0) -> None:
        """Fold one published raw partial Σ c·u into the running sum —
        the root fold of a FoldPlan, identical arithmetic to the
        driver's controller-side top fold (``engine.add_partial``), so
        where the fold runs never changes the bits."""
        self._ensure_acc(partial.size)
        self.acc = self.engine.add_partial(self.acc, partial)
        self.weight += float(weight)
        self.count += int(count)

    def merge(self, other: "FedAvgState") -> None:
        if other.acc is None:
            return
        partial = other.engine.to_numpy(other.acc)
        if self.acc is None:
            self.acc = self.engine.begin(partial.size)
        self.acc = self.engine.add_partial(self.acc, partial)
        self.weight += other.weight
        self.count += other.count

    def result(self) -> Tuple[np.ndarray, float]:
        assert self.acc is not None and self.weight > 0
        acc = self.engine.to_numpy(self.acc)
        return acc / np.float32(self.weight), self.weight


class Aggregator:
    """One LIFL aggregator instance (leaf/middle/top — homogenized)."""

    def __init__(
        self,
        agg_id: str,
        store,
        goal: int,
        *,
        eager: bool = True,
        sidecar: Optional[EventSidecar] = None,
        on_complete: Optional[Callable[[np.ndarray, float], None]] = None,
        engine: Any = "auto",
        batch_k: int = 8,
    ):
        self.agg_id = agg_id
        self.store = store
        self.goal = goal
        self.eager = eager
        self.sidecar = sidecar
        self.on_complete = on_complete
        self.engine = make_engine(engine)
        self.batch_k = max(1, int(batch_k))
        self.fifo: Deque[UpdateEnvelope] = deque()
        self.state = FedAvgState(engine=self.engine)
        self.done = False
        self.result: Optional[Tuple[np.ndarray, float]] = None
        self.agg_exec_s = 0.0
        # root-fold inputs (recv_partial) count toward the goal in
        # partials, not updates — state.count then carries the subtree
        # totals instead
        self.partials_absorbed = 0

    # ------------------------------------------------------------------
    # Recv step — called by the sockmap notify hook (event-driven)
    # ------------------------------------------------------------------
    def recv(self, env: UpdateEnvelope) -> None:
        self.fifo.append(env)
        if self.sidecar:
            self.sidecar.on_recv(
                self.store.meta(env.object_key).nbytes,
                time.perf_counter() - env.enqueue_ts,
            )
        if self.eager:
            # Recv ∥ Agg: fold immediately (App-G)
            self._drain()

    # ------------------------------------------------------------------
    # Agg step
    # ------------------------------------------------------------------
    def _drain(self) -> None:
        """Dequeue-and-fold in K-way bursts through the engine layer.

        Under eager timing arrivals trickle in and bursts are usually
        size 1; under lazy timing (or an arrival burst outpacing the
        fold) up to ``batch_k`` queued envelopes are folded in a single
        pass over the accumulator."""
        while self.fifo and not self.done:
            k = min(len(self.fifo), self.batch_k, self.goal - self.state.count)
            if k <= 0:
                break
            envs = [self.fifo.popleft() for _ in range(k)]
            t0 = time.perf_counter()
            views = [np.asarray(self.store.get(e.object_key)) for e in envs]
            if k == 1:
                self.state.fold(views[0], envs[0].num_samples)
            else:
                self.state.fold_many(views, [e.num_samples for e in envs])
            for e in envs:
                self.store.release(e.object_key)
            self.engine.sync(self.state.acc)  # async engines: the timed
            dt = time.perf_counter() - t0     # fold must have executed
            self.agg_exec_s += dt
            if self.sidecar:
                self.sidecar.on_aggregate(k, dt)
            if self.state.count >= self.goal:
                self._send()

    def flush(self) -> None:
        """Lazy timing: called once the goal's worth of updates queued."""
        self._drain()

    def recv_partial(self, key: str, weight: float, count: int = 0) -> None:
        """Root-fold input: absorb a published raw partial Σ c·u from
        the store.  Folds immediately (the driver only routes partials
        here once every input is at hand, in plan order) and publishes
        when ``goal`` partials have been absorbed."""
        t0 = time.perf_counter()
        view = np.asarray(self.store.get(key))
        if self.sidecar:
            self.sidecar.on_recv(view.nbytes, 0.0)
        self.state.absorb(view, weight, count)
        self.store.release(key)
        self.engine.sync(self.state.acc)
        dt = time.perf_counter() - t0
        self.agg_exec_s += dt
        if self.sidecar:
            self.sidecar.on_aggregate(1, dt)
        self.partials_absorbed += 1
        if self.partials_absorbed >= self.goal and not self.done:
            self._send()

    # ------------------------------------------------------------------
    # Send step
    # ------------------------------------------------------------------
    def _send(self) -> None:
        self.done = True
        self.result = self.state.result()
        if self.sidecar:
            self.sidecar.on_send(self.result[0].nbytes)
        if self.on_complete:
            self.on_complete(*self.result)


def fedavg_oracle(updates: List[np.ndarray], weights: List[float]) -> np.ndarray:
    """Reference weighted mean (tests compare every path against this)."""
    num = sum(np.float32(w) * u.astype(np.float32) for u, w in zip(updates, weights))
    return num / np.float32(sum(weights))
