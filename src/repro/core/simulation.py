"""Event-driven cluster simulator — reproduces the paper's system-level
experiments (Fig 4, Fig 8, Fig 9/10) without a 20-node testbed.

Entities: clients (optionally hibernating mobile devices), per-node
gateways, aggregators (leaf/middle/top), a network with distinct
intra-node (shared-memory) and inter-node (kernel TCP) costs, and the
control plane (placement + hierarchy planner + reuse pool).

Cost model constants are calibrated from the paper's own measurements
(§6.1): inter-node ResNet-152 transfer ≈ 4.2 s; MC_i = 20 on the
testbed; eager aggregation saves ≈20% ACT; data-plane per-transfer
latencies from Fig 7(a).  Each figure benchmark states which constants
it uses so the reproduction is auditable.
"""
from __future__ import annotations

import heapq
import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.hierarchy import HierarchyPlanner
from repro.core.placement import NodeState, choose_top_node, place_updates
from repro.core.reuse import AggregatorPool, Role


@dataclass
class DataPlaneCosts:
    """Per-transfer latency + CPU of one model update, by path.

    Defaults ≈ paper Fig 7(a/b) for ResNet-152 (~232 MB): LIFL intra-node
    (shared memory) ~0.7 s; serverful gRPC ~2.1 s (3× LIFL); serverless
    broker+sidecar ~4.1 s (5.8×); inter-node wire transfer ~4.2 s (§6.1).
    """

    t_intra_shm: float = 0.7
    t_intra_serverful: float = 2.1
    t_intra_serverless: float = 4.1
    t_inter_node: float = 4.2
    cpu_intra_shm: float = 0.15
    cpu_intra_serverful: float = 0.8
    cpu_intra_serverless: float = 2.4
    cpu_inter_node: float = 1.0
    t_agg: float = 0.55        # fold one ResNet-152 update (naive engine)
    cpu_agg: float = 0.55
    t_cold_start: float = 2.0  # container cold start
    cpu_cold_start: float = 1.0
    # Relative fold throughput of the aggregation engines (core/engine.py)
    # vs the naive scalar baseline.  Defaults from benchmarks/
    # bench_agg_kernel.py on the dev host (see BENCH_agg.json); bench_tta
    # re-calibrates from a live measurement before simulating.
    agg_engine_speedup: Dict[str, float] = field(default_factory=lambda: {
        "naive": 1.0, "blocked": 4.0, "jnp": 2.0, "pallas": 8.0,
    })

    def _speedup(self, engine: str) -> float:
        if engine == "auto":
            from repro.core.engine import _auto_name
            engine = _auto_name()
        if engine not in self.agg_engine_speedup:
            raise ValueError(
                f"no fold-speedup calibration for engine {engine!r} "
                f"(known: {sorted(self.agg_engine_speedup)}); add it to "
                f"DataPlaneCosts.agg_engine_speedup")
        return self.agg_engine_speedup[engine]

    def t_agg_for(self, engine: str) -> float:
        return self.t_agg / self._speedup(engine)

    def cpu_agg_for(self, engine: str) -> float:
        return self.cpu_agg / self._speedup(engine)


@dataclass
class SimConfig:
    n_nodes: int = 5
    mc_per_node: float = 20.0          # MC_i (paper §6.1)
    placement_policy: str = "bestfit"  # worstfit = SL-H (Least Connection)
    hierarchy: bool = True
    reuse: bool = True
    eager: bool = True
    fan_in: int = 2
    dataplane: str = "shm"             # shm | serverful | serverless
    agg_engine: str = "naive"          # fold engine (core/engine.py)
    costs: DataPlaneCosts = field(default_factory=DataPlaneCosts)
    seed: int = 0


@dataclass
class SimResult:
    act_s: float                 # aggregation completion time
    cpu_s: float                 # CPU time consumed by aggregation svc
    aggregators_created: int
    aggregators_active: int
    nodes_used: int
    inter_node_transfers: int
    cold_starts: int
    reused: int


def _transfer_cost(cfg: SimConfig) -> Tuple[float, float]:
    c = cfg.costs
    if cfg.dataplane == "shm":
        return c.t_intra_shm, c.cpu_intra_shm
    if cfg.dataplane == "serverful":
        return c.t_intra_serverful, c.cpu_intra_serverful
    if cfg.dataplane == "serverless":
        return c.t_intra_serverless, c.cpu_intra_serverless
    raise ValueError(cfg.dataplane)


def simulate_round(
    num_updates: int,
    cfg: SimConfig,
    pool: Optional[AggregatorPool] = None,
    arrival_span_s: float = 0.0,
) -> SimResult:
    """Simulate one aggregation round of ``num_updates`` model updates.

    ``arrival_span_s``: client updates arrive uniformly over this span
    (eager aggregation overlaps it; lazy waits for the last arrival).
    """
    rng = random.Random(cfg.seed)
    c = cfg.costs
    t_agg = c.t_agg_for(cfg.agg_engine)
    cpu_agg = c.cpu_agg_for(cfg.agg_engine)
    t_intra, cpu_intra = _transfer_cost(cfg)
    pool = pool if pool is not None else AggregatorPool(cold_start_s=c.t_cold_start)

    nodes = {
        f"node{i}": NodeState(node=f"node{i}", max_capacity=cfg.mc_per_node)
        for i in range(cfg.n_nodes)
    }
    placement = place_updates(num_updates, nodes, policy=cfg.placement_policy)
    # overflow updates queue behind capacity — they still run, serialized
    top = choose_top_node(nodes, placement.assignment) or "node0"

    planner = HierarchyPlanner(fan_in=cfg.fan_in)
    created_before = pool.stats.created
    cold_before = pool.stats.cold_starts
    reused_before = pool.stats.reused

    cpu = 0.0
    node_times: List[float] = []
    inter_transfers = 0

    for node, idxs in placement.assignment.items():
        n_node = len(idxs)
        if n_node == 0:
            continue
        if cfg.hierarchy:
            n_leaves = max(1, math.ceil(n_node / cfg.fan_in))
            has_middle = n_leaves > 1
        else:
            n_leaves, has_middle = 1, False

        # reuse disabled -> caller passes a fresh pool, so every acquire
        # is a cold start; warm pool -> acquire returns idle instances
        cold_delay = 0.0
        for _ in range(n_leaves):
            _, d = pool.acquire(node, Role.LEAF)
            cold_delay = max(cold_delay, d)
            cpu += c.cpu_cold_start if d > 0 else 0.0
        if has_middle:
            _, d = pool.acquire(node, Role.MIDDLE)
            cold_delay = max(cold_delay, d)
            cpu += c.cpu_cold_start if d > 0 else 0.0

        per_leaf = math.ceil(n_node / n_leaves)
        # leaf level: receive per_leaf updates + fold each
        if cfg.eager:
            # arrivals (and the cold start) overlap aggregation; only the
            # last update's transfer+fold is exposed (§5.4)
            leaf_t = max(arrival_span_s, cold_delay) + per_leaf * (t_intra + t_agg)
        else:
            # lazy: wait for all arrivals, then aggregate the batch
            leaf_t = cold_delay + arrival_span_s + per_leaf * (t_intra + t_agg)
        cpu += n_node * (cpu_intra + cpu_agg)

        mid_t = 0.0
        if has_middle:
            mid_in = n_leaves
            if cfg.eager:
                mid_t = t_intra + mid_in * t_agg
            else:
                mid_t = mid_in * t_intra + mid_in * t_agg
            cpu += mid_in * (cpu_intra + cpu_agg)
        node_times.append(leaf_t + mid_t)
        if node != top:
            inter_transfers += 1

    # top level: one intermediate per used node
    _, d_top = pool.acquire(top, Role.TOP)
    n_used = max(1, len(placement.assignment))
    remote = max(0, n_used - 1)
    t_in_top = c.t_inter_node if remote else t_intra
    if cfg.eager:
        top_t = t_in_top + n_used * t_agg
    else:
        top_t = remote * c.t_inter_node + t_intra + n_used * t_agg
    cpu += remote * (c.cpu_inter_node + cpu_agg) + cpu_agg
    cpu += cpu_intra * 1

    act = (max(node_times) if node_times else 0.0) + top_t + (
        0.0 if cfg.reuse else c.t_cold_start
    )

    for agg_id in list(pool.instances):
        pool.release(agg_id)

    return SimResult(
        act_s=act,
        cpu_s=cpu,
        aggregators_created=pool.stats.created - created_before,
        aggregators_active=pool.count(),
        nodes_used=len(placement.assignment),
        inter_node_transfers=inter_transfers,
        cold_starts=pool.stats.cold_starts - cold_before,
        reused=pool.stats.reused - reused_before,
    )
